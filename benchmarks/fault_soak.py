"""Crash-under-load soak: YCSB-A traffic through a firing ``FaultPlan``.

The durability claims that matter under a *sick* disk, not just a clean
one: every recovery must land on a witnessed committed round prefix, the
serve layer must keep ticking (degraded volatile mode) instead of raising,
and recovery time must not regress.  Each soak leg drives a seeded YCSB-A
stream (50% updates, Zipf) over a 2-shard ``DurableForest`` while a
``FaultPlan`` injects one fault class — transient fsync EIO, ENOSPC on
segment writes, silent torn segments, manifest-rename failures, or a
fail-stop kill mid-protocol — then abandons the live object, recovers from
disk, and verifies the recovered contents two independent ways:

  1. **forensics witness** — the recovered sidecar's history must be
     linearizable (``check_history`` raises ``WitnessError`` otherwise)
     and the recovered contents must be one of its oracle round-prefix
     states (``collect_prefixes=True``);
  2. **driver oracle** — the recovered contents must equal a round prefix
     of the *driver's* own sequential replay of the stream it submitted
     (ground truth independent of the recorder).

Fault schedules are pure hash functions of (seed, site, commit, shard,
attempt) — no wall clock, no thread order — so the committed prefix each
leg recovers is deterministic and ``run.py --check`` gates it exactly
(``rounds`` = recovered prefix length, ``commits`` = successful commits).
Recovery latency is the throughput-gated metric (``ops_per_s`` =
recoveries/s, a cliff detector).

The final leg boots a ``ServeEngine`` on a journal whose manifest fsyncs
always fail: the engine must serve every session to completion with ZERO
exceptions from ``tick()`` (the section raises otherwise), flip its
``stats()["durability"]["degraded"]`` flag, and auto-reattach once the
plan is cleared (the disk "healed").
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit

SHARDS = 2
SEEDS = (1, 2, 3)

# one spec per fault class; p < 1.0 so retry attempts re-draw (a commit
# eventually succeeds), torn writes "succeed" silently and surface only at
# recovery as CRC mismatches.  The kill class is a CrashPoint instead,
# cycling through the mid-protocol steps by seed.
_KILL_STEPS = ("after_segment", "mid_manifest", "before_dirsync")


def _plan_for(klass: str, seed: int, rounds: int):
    from repro.core.faults import CrashPoint, FaultPlan, FaultSpec

    plan = FaultPlan(seed=seed)
    if klass == "eio":
        plan.add(FaultSpec(site="segment_fsync", kind="eio", p=0.2))
        plan.add(FaultSpec(site="manifest_fsync", kind="eio", p=0.1))
    elif klass == "enospc":
        plan.add(FaultSpec(site="segment_write", kind="enospc", p=0.25))
    elif klass == "torn":
        # window past commit 0: the initial snapshot is the root of every
        # shard's chain, and a torn SNAPSHOT surviving into both manifest
        # generations is unrecoverable by design (RecoveryError — covered
        # by tests/test_faults.py); the soak exercises the recoverable
        # path, torn SEGMENTS, so its leg never snapshots mid-run.
        plan.add(
            FaultSpec(
                site="segment_write", kind="torn", p=0.5, torn_frac=0.4,
                commits=(1 + rounds // 2, 10**9),
            )
        )
    elif klass == "rename_fail":
        plan.add(FaultSpec(site="manifest_rename", kind="rename_fail", p=0.35))
    elif klass == "kill":
        step = _KILL_STEPS[seed % len(_KILL_STEPS)]
        plan.add_crash(CrashPoint(step=step, at_commit=1 + rounds // 2))
    else:  # pragma: no cover - registry drift guard
        raise ValueError(f"unknown fault class {klass!r}")
    return plan


def _soak_leg(klass: str, seed: int, rounds: int, batch: int, key_range: int):
    from repro.configs.abtree import TPU8
    from repro.core.durable import DurableForest, recover_forest
    from repro.core.faults import SimulatedCrash
    from repro.core.oracle import DictOracle
    from repro.data.workloads import WorkloadConfig, op_stream
    from repro.obs.witness import check_history

    cfg = WorkloadConfig(
        key_range=key_range, update_frac=0.5, dist="zipf", zipf_s=1.0,
        batch=batch, seed=seed,
    )
    stream = list(op_stream(cfg, rounds))
    # driver-side ground truth: sequential replay of the exact stream we
    # submit; prefixes[r] = contents after the first r rounds.
    oracle = DictOracle()
    prefixes = [oracle.items()]
    for ops, keys, vals in stream:
        oracle.apply_round(ops, keys, vals)
        prefixes.append(oracle.items())

    d = tempfile.mkdtemp(prefix=f"fault_soak_{klass}_s{seed}_")
    plan = _plan_for(klass, seed, rounds)
    dur = DurableForest(
        d, n_shards=SHARDS, cfg=TPU8._replace(capacity=4 * key_range),
        mode="elim", key_space=(0, key_range),
        snapshot_every=10**9 if klass == "torn" else 4, faults=plan,
    )
    killed = False
    t0 = time.perf_counter()
    for ops, keys, vals in stream:
        try:
            dur.apply_round(ops, keys, vals)
        except SimulatedCrash:
            killed = True
            break
    t_run = time.perf_counter() - t0
    status = dur.durability_status()
    n_commits = int(dur.dstats.commits)
    del dur  # the live object is "dead" — recovery must come from disk

    t1 = time.perf_counter()
    rec = recover_forest(d)
    t_recover = time.perf_counter() - t1
    got = rec.items()

    # (1) forensics witness: the recovered sidecar's history is legal AND
    # the recovered contents are one of its round-prefix oracle states.
    recs = rec.forensics_records()
    rep = check_history(recs, collect_prefixes=True)
    if recs and got not in rep.prefix_states:
        raise RuntimeError(
            f"fault_soak.{klass}.seed{seed}: recovered contents match no "
            f"witnessed sidecar prefix ({len(rep.prefix_states)} candidates)"
        )
    # (2) driver oracle: the recovered contents are a committed prefix of
    # the stream the driver actually submitted.
    matches = [r for r, st in enumerate(prefixes) if st == got]
    if not matches:
        raise RuntimeError(
            f"fault_soak.{klass}.seed{seed}: recovered contents are not a "
            f"prefix of the driver's oracle replay (killed={killed})"
        )
    recovered_rounds = matches[-1]
    if klass == "kill" and recovered_rounds >= rounds:
        raise RuntimeError(
            f"fault_soak.{klass}.seed{seed}: kill leg committed the whole "
            f"stream — the crash point never fired"
        )
    shutil.rmtree(d, ignore_errors=True)

    n_ops = batch * max(recovered_rounds, 1)
    emit(
        f"fault_soak.{klass}.seed{seed}",
        t_run / (batch * rounds) * 1e6,
        f"recovered_rounds={recovered_rounds}/{rounds};killed={killed};"
        f"faults={plan.injected};retries={status['commit_retries']};"
        f"quarantined={len(rec._quarantined)};recovery_ms={t_recover * 1e3:.1f}",
        ops_per_s=1.0 / max(t_recover, 1e-9),
        rounds=recovered_rounds,
        commits=n_commits,
        faults_injected=plan.injected,
        commit_retries=status["commit_retries"],
        quarantined=len(rec._quarantined),
        recovery_ms=t_recover * 1e3,
        replay_items=len(got),
        replay_ops=n_ops,
    )


def _serve_leg(quick: bool):
    from repro.configs import get_config
    from repro.core.faults import FaultPlan, FaultSpec
    from repro.models import reduced
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=1)
    plan = FaultPlan(seed=7)
    plan.add(FaultSpec(site="manifest_fsync", kind="eio"))  # p=1: always sick
    ddir = tempfile.mkdtemp(prefix="fault_soak_serve_")
    eng = ServeEngine(
        cfg, max_batch=4, s_max=64, n_pages=128,
        index_shards=2, index_durable_dir=ddir, index_faults=plan,
    )
    rng = np.random.default_rng(0)
    n_sessions = 4 if quick else 8
    for rid in range(n_sessions):
        eng.submit(
            Request(rid=rid, prompt=list(rng.integers(0, cfg.vocab, 8)), max_new=2)
        )
    raised = 0
    t0 = time.perf_counter()
    ticks = 0
    while (eng.waiting or eng.running) and ticks < 500:
        try:
            eng.tick()
        except Exception:  # noqa: BLE001 - the gate IS "tick never raises"
            raised += 1
            break
        ticks += 1
    t_sick = time.perf_counter() - t0
    s = eng.stats()
    degraded = bool(s.get("durability", {}).get("degraded"))
    if raised or not degraded:
        raise RuntimeError(
            f"fault_soak.serve: sick-disk serving must degrade without "
            f"raising (raised={raised}, degraded={degraded})"
        )
    # disk "heals": the next reattach probe must close the breaker.
    plan.clear()
    for rid in range(100, 100 + n_sessions):
        eng.submit(
            Request(rid=rid, prompt=list(rng.integers(0, cfg.vocab, 8)), max_new=2)
        )
    while (eng.waiting or eng.running) and ticks < 1000:
        eng.tick()
        ticks += 1
    s2 = eng.stats()
    if s2["durability"]["degraded"]:
        raise RuntimeError("fault_soak.serve: breaker failed to reattach after heal")
    shutil.rmtree(ddir, ignore_errors=True)
    emit(
        "fault_soak.serve.degraded",
        t_sick / max(ticks, 1) * 1e6,
        f"ticks={ticks};raised={raised};degraded_then_reattached=True;"
        f"suspended={s['durability']['sessions']['commits_suspended']}",
        ops_per_s=ticks / max(t_sick, 1e-9),
        rounds=ticks,
        raised=raised,
        n_done=len(eng.done),
    )


def main(quick: bool = False):
    rounds = 10 if quick else 20
    batch, key_range = 64, 512
    for klass in ("eio", "enospc", "torn", "rename_fail", "kill"):
        for seed in SEEDS:
            _soak_leg(klass, seed, rounds, batch, key_range)
    _serve_leg(quick)


if __name__ == "__main__":
    main()
