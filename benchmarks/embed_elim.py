"""EmbedElim benchmark: the paper's write-collapse on the framework's
sparse embedding-update path (Zipfian token stream), vs the OCC scatter."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.sparse import embed_elim_update, embed_occ_update

from benchmarks.common import emit, timeit


def main(quick=False):
    rng = np.random.default_rng(0)
    v, d = 50_000, 512
    t = 8192 if quick else 65_536
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    ids = jnp.asarray(np.minimum(rng.zipf(1.3, t), v) - 1, jnp.int32)
    grads = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)

    elim = jax.jit(lambda tb, i, g: embed_elim_update(tb, i, g, 1e-2))
    occ = jax.jit(lambda tb, i, g: embed_occ_update(tb, i, g, 1e-2))

    out, stats = elim(table, ids, grads)
    jax.block_until_ready(out)
    jax.block_until_ready(occ(table, ids, grads))

    te = timeit(lambda: jax.block_until_ready(elim(table, ids, grads)[0]))
    to = timeit(lambda: jax.block_until_ready(occ(table, ids, grads)))
    emit(
        "embed_elim.elim", te * 1e6,
        f"rows_written={int(stats.writes_elim)};eliminated={int(stats.eliminated)}",
    )
    emit("embed_elim.occ", to * 1e6, f"rows_written={int(stats.writes_occ)}")
    emit(
        "embed_elim.reduction", 0.0,
        f"write_reduction={int(stats.writes_occ)/max(int(stats.writes_elim),1):.2f}x",
    )


if __name__ == "__main__":
    main()
