"""Persistence overhead (paper Table 1 analog), sharded: throughput change
from enabling durable commits and the flush-traffic gap between p-Elim and
p-OCC (elimination ⇒ fewer dirty nodes ⇒ fewer flushed bytes), measured on
the per-shard-journaled ``DurableForest`` at shard counts {1, 4}.

Emits structured metrics (``flush_bytes`` / ``fsyncs`` / ``commits`` /
``flush_bytes_per_op``) into ``results/BENCH_persistence.json`` via the run
aggregator; ``commits`` and ``fsyncs`` are deterministic for a given seeded
workload, so ``benchmarks/run.py --check`` gates them exactly.  The section
FAILS (raises) unless elim flushes strictly fewer bytes/op than occ at
every shard count — the paper's durability headline, published per shard
count."""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.configs.abtree import TPU8
from repro.core import ABForest
from repro.core.durable import DurableForest, DurableStats
from repro.data.workloads import WorkloadConfig, op_stream, prefill_tree

from benchmarks.common import emit


WARM = 4
SHARD_COUNTS = (1, 4)


def _run(tree, stream):
    for r in stream[:WARM]:
        tree.apply_round(*r)
    t0 = time.perf_counter()
    for ops, keys, vals in stream[WARM:]:
        tree.apply_round(ops, keys, vals)
    return time.perf_counter() - t0


def main(quick=False):
    key_range, batch = 2048, 256
    rounds = 8 if quick else 20
    n_ops = batch * (rounds - WARM)
    cfg = WorkloadConfig(
        key_range=key_range, update_frac=1.0, dist="zipf", zipf_s=1.0,
        batch=batch, seed=11,
    )
    stream = list(op_stream(cfg, rounds))
    tree_cfg = TPU8._replace(capacity=4 * key_range)
    for shards in SHARD_COUNTS:
        bytes_per_op = {}
        for mode in ("elim", "occ"):
            vol = ABForest(
                n_shards=shards, cfg=tree_cfg, mode=mode,
                key_space=(0, key_range),
            )
            prefill_tree(vol, cfg)
            t_vol = _run(vol, stream)

            d = tempfile.mkdtemp(prefix=f"ptree_{mode}_s{shards}_")
            dur = DurableForest(
                d, n_shards=shards, cfg=tree_cfg, mode=mode,
                key_space=(0, key_range), snapshot_every=10**9,
            )
            prefill_tree(dur.forest, cfg)  # prefill outside timed commits
            dur._commit(force_snapshot=True)  # journal the prefilled state
            dur.dstats = DurableStats()  # count the timed stream only
            t_dur = _run(dur, stream)
            overhead = (t_dur - t_vol) / t_vol * 100
            s = dur.stats()
            bytes_per_op[mode] = s["flush_bytes"] / n_ops
            emit(
                f"persistence.zipf.{mode}.s{shards}",
                t_dur / n_ops * 1e6,
                f"overhead_vs_volatile={overhead:.0f}%;"
                f"flush_bytes={s['flush_bytes']};fsyncs={s['fsyncs']};"
                f"commits={s['commits']}",
                ops_per_s=n_ops / t_dur,
                flush_bytes=s["flush_bytes"],
                flush_bytes_per_op=s["flush_bytes"] / n_ops,
                fsyncs=s["fsyncs"],
                commits=s["commits"],
                nodes_flushed=s["nodes_flushed"],
                gc_removed=s["gc_removed"],
            )
            shutil.rmtree(d, ignore_errors=True)
        ratio = bytes_per_op["occ"] / max(bytes_per_op["elim"], 1e-9)
        emit(
            f"persistence.zipf.flush_reduction.s{shards}",
            0.0,
            f"elim_vs_occ_bytes_per_op={ratio:.2f}x",
            flush_reduction=ratio,
        )
        if bytes_per_op["elim"] >= bytes_per_op["occ"]:
            raise RuntimeError(
                f"persistence: elim must flush fewer bytes/op than occ at "
                f"shards={shards} (elim={bytes_per_op['elim']:.1f}, "
                f"occ={bytes_per_op['occ']:.1f})"
            )

    # Snapshot-churn leg, delta vs full: with ``incremental_snapshots`` the
    # periodic snapshot writes only the rows dirtied since the last FULL
    # image (a ``_delta_`` file that replaces the segment chain) instead of
    # re-serializing every node.  HARD gate: the delta path must flush
    # strictly fewer bytes/op than the full-snapshot path on the identical
    # stream — otherwise incremental snapshots are dead weight.
    churn_bytes_per_op = {}
    for variant, incremental in (("full", False), ("delta", True)):
        d = tempfile.mkdtemp(prefix=f"ptree_churn_{variant}_")
        dur = DurableForest(
            d, n_shards=2, cfg=tree_cfg, mode="elim",
            key_space=(0, key_range), snapshot_every=4,
            incremental_snapshots=incremental,
        )
        prefill_tree(dur.forest, cfg)
        dur._commit(force_snapshot=True)
        dur.dstats = DurableStats()
        t_churn = _run(dur, stream)
        s = dur.stats()
        churn_bytes_per_op[variant] = s["flush_bytes"] / n_ops
        emit(
            f"persistence.snapshot_churn.{variant}.s2",
            t_churn / n_ops * 1e6,
            f"flush_bytes_per_op={s['flush_bytes'] / n_ops:.1f};"
            f"commits={s['commits']};fsyncs={s['fsyncs']}",
            ops_per_s=n_ops / t_churn,
            flush_bytes=s["flush_bytes"],
            flush_bytes_per_op=s["flush_bytes"] / n_ops,
            commits=s["commits"],
            fsyncs=s["fsyncs"],
        )
        shutil.rmtree(d, ignore_errors=True)
    if churn_bytes_per_op["delta"] >= churn_bytes_per_op["full"]:
        raise RuntimeError(
            f"persistence.snapshot_churn: delta snapshots must flush fewer "
            f"bytes/op than full snapshots "
            f"(delta={churn_bytes_per_op['delta']:.1f}, "
            f"full={churn_bytes_per_op['full']:.1f})"
        )

    # Group-commit leg: G rounds per manifest rename (count-based
    # boundaries — the wall-clock bound is pinned huge so the commit
    # schedule is deterministic and exact-gated).  HARD gate: grouping must
    # strictly reduce both commits and fsyncs vs the serial journal on the
    # identical stream.
    group_counts = {}
    for variant, G in (("serial", 1), ("g4", 4)):
        d = tempfile.mkdtemp(prefix=f"ptree_grp_{variant}_")
        dur = DurableForest(
            d, n_shards=2, cfg=tree_cfg, mode="elim",
            key_space=(0, key_range), snapshot_every=10**9,
            group_commit_every=G, group_commit_max_wait_s=1e9,
            commit_async=(G > 1),
        )
        prefill_tree(dur.forest, cfg)
        dur._commit(force_snapshot=True)
        dur.drain()
        dur.dstats = DurableStats()
        t0 = time.perf_counter()
        for r in stream[WARM:]:
            dur.apply_round(*r)
        dur.drain()  # the persist fence is part of the measured cost
        t_grp = time.perf_counter() - t0
        s = dur.stats()
        group_counts[variant] = (s["commits"], s["fsyncs"])
        rpc = dur.metrics.histogram_summary("rounds_per_commit")
        emit(
            f"persistence.group_commit.{variant}.s2",
            t_grp / n_ops * 1e6,
            f"commits={s['commits']};fsyncs={s['fsyncs']};"
            f"rounds_per_commit_max={rpc['max']:.0f}",
            ops_per_s=n_ops / t_grp,
            commits=s["commits"],
            fsyncs=s["fsyncs"],
            flush_bytes=s["flush_bytes"],
            rounds_per_commit_max=rpc["max"],
        )
        shutil.rmtree(d, ignore_errors=True)
    if not (
        group_counts["g4"][0] < group_counts["serial"][0]
        and group_counts["g4"][1] < group_counts["serial"][1]
    ):
        raise RuntimeError(
            f"persistence.group_commit: grouping must reduce commits AND "
            f"fsyncs (serial={group_counts['serial']}, g4={group_counts['g4']})"
        )

    # GC churn leg: frequent snapshots supersede earlier journal files, so
    # the post-commit GC must actually collect them (gc_removed > 0 —
    # guards against the journal directory growing without bound; the
    # main legs never snapshot, so they never exercise GC).  Also the one
    # leg that publishes fsync latency percentiles, from the registry
    # histogram the durable layer feeds.
    d = tempfile.mkdtemp(prefix="ptree_gc_")
    dur = DurableForest(
        d, n_shards=2, cfg=tree_cfg, mode="elim",
        key_space=(0, key_range), snapshot_every=2,
    )
    prefill_tree(dur.forest, cfg)
    t_gc = _run(dur, stream)
    s = dur.stats()
    fs = dur.metrics.histogram_summary("fsync_latency_s")
    if s["gc_removed"] <= 0:
        raise RuntimeError(
            "persistence.gc: snapshot churn must GC superseded journal "
            f"files (gc_removed={s['gc_removed']})"
        )
    emit(
        "persistence.zipf.gc_churn.s2",
        t_gc / n_ops * 1e6,
        f"gc_removed={s['gc_removed']};fsync_p99_us={fs['p99'] * 1e6:.0f}",
        ops_per_s=n_ops / t_gc,
        gc_removed=s["gc_removed"],
        commits=s["commits"],
        fsyncs=s["fsyncs"],
        fsync_p50_us=fs["p50"] * 1e6,
        fsync_p99_us=fs["p99"] * 1e6,
    )
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
