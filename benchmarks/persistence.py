"""Persistence overhead (paper Table 1 analog): throughput change from
enabling durable commits, and the flush-traffic gap between p-Elim and
p-OCC (elimination ⇒ fewer dirty nodes ⇒ fewer flushed bytes)."""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.configs.abtree import TPU8
from repro.core import ABTree, DurableABTree
from repro.data.workloads import WorkloadConfig, op_stream, prefill_tree

from benchmarks.common import emit


WARM = 4


def _run(tree, stream):
    for r in stream[:WARM]:
        tree.apply_round(*r)
    t0 = time.perf_counter()
    for ops, keys, vals in stream[WARM:]:
        tree.apply_round(ops, keys, vals)
    return time.perf_counter() - t0


def main(quick=False):
    key_range, batch = 2048, 256
    rounds = 8 if quick else 20
    for dist in ("uniform", "zipf"):
        cfg = WorkloadConfig(
            key_range=key_range, update_frac=1.0, dist=dist, zipf_s=1.0,
            batch=batch, seed=11,
        )
        stream = list(op_stream(cfg, rounds))
        stats = {}
        for mode in ("elim", "occ"):
            vol = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
            prefill_tree(vol, cfg)
            t_vol = _run(vol, stream)

            d = tempfile.mkdtemp(prefix=f"ptree_{mode}_")
            dur = DurableABTree(
                d, TPU8._replace(capacity=4 * key_range), mode=mode,
                snapshot_every=10**9,
            )
            prefill_tree(dur.tree, cfg)  # prefill outside timed commits
            t_dur = _run(dur, stream)
            overhead = (t_dur - t_vol) / t_vol * 100
            stats[mode] = dur.stats()
            n_ops = batch * (rounds - WARM)
            emit(
                f"persistence.{dist}.{mode}",
                t_dur / n_ops * 1e6,
                f"overhead_vs_volatile={overhead:.0f}%;flush_bytes={stats[mode]['flush_bytes']};nodes_flushed={stats[mode]['nodes_flushed']}",
            )
            shutil.rmtree(d, ignore_errors=True)
        if stats["occ"]["nodes_flushed"]:
            emit(
                f"persistence.{dist}.flush_reduction",
                0.0,
                f"elim_vs_occ_nodes_flushed={stats['occ']['nodes_flushed']/max(stats['elim']['nodes_flushed'],1):.2f}x",
            )


if __name__ == "__main__":
    main()
