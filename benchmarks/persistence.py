"""Persistence overhead (paper Table 1 analog), sharded: throughput change
from enabling durable commits and the flush-traffic gap between p-Elim and
p-OCC (elimination ⇒ fewer dirty nodes ⇒ fewer flushed bytes), measured on
the per-shard-journaled ``DurableForest`` at shard counts {1, 4}.

Emits structured metrics (``flush_bytes`` / ``fsyncs`` / ``commits`` /
``flush_bytes_per_op``) into ``results/BENCH_persistence.json`` via the run
aggregator; ``commits`` and ``fsyncs`` are deterministic for a given seeded
workload, so ``benchmarks/run.py --check`` gates them exactly.  The section
FAILS (raises) unless elim flushes strictly fewer bytes/op than occ at
every shard count — the paper's durability headline, published per shard
count."""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.configs.abtree import TPU8
from repro.core import ABForest
from repro.core.durable import DurableForest, DurableStats
from repro.data.workloads import WorkloadConfig, op_stream, prefill_tree

from benchmarks.common import emit


WARM = 4
SHARD_COUNTS = (1, 4)


def _run(tree, stream):
    for r in stream[:WARM]:
        tree.apply_round(*r)
    t0 = time.perf_counter()
    for ops, keys, vals in stream[WARM:]:
        tree.apply_round(ops, keys, vals)
    return time.perf_counter() - t0


def main(quick=False):
    key_range, batch = 2048, 256
    rounds = 8 if quick else 20
    n_ops = batch * (rounds - WARM)
    cfg = WorkloadConfig(
        key_range=key_range, update_frac=1.0, dist="zipf", zipf_s=1.0,
        batch=batch, seed=11,
    )
    stream = list(op_stream(cfg, rounds))
    tree_cfg = TPU8._replace(capacity=4 * key_range)
    for shards in SHARD_COUNTS:
        bytes_per_op = {}
        for mode in ("elim", "occ"):
            vol = ABForest(
                n_shards=shards, cfg=tree_cfg, mode=mode,
                key_space=(0, key_range),
            )
            prefill_tree(vol, cfg)
            t_vol = _run(vol, stream)

            d = tempfile.mkdtemp(prefix=f"ptree_{mode}_s{shards}_")
            dur = DurableForest(
                d, n_shards=shards, cfg=tree_cfg, mode=mode,
                key_space=(0, key_range), snapshot_every=10**9,
            )
            prefill_tree(dur.forest, cfg)  # prefill outside timed commits
            dur._commit(force_snapshot=True)  # journal the prefilled state
            dur.dstats = DurableStats()  # count the timed stream only
            t_dur = _run(dur, stream)
            overhead = (t_dur - t_vol) / t_vol * 100
            s = dur.stats()
            bytes_per_op[mode] = s["flush_bytes"] / n_ops
            emit(
                f"persistence.zipf.{mode}.s{shards}",
                t_dur / n_ops * 1e6,
                f"overhead_vs_volatile={overhead:.0f}%;"
                f"flush_bytes={s['flush_bytes']};fsyncs={s['fsyncs']};"
                f"commits={s['commits']}",
                ops_per_s=n_ops / t_dur,
                flush_bytes=s["flush_bytes"],
                flush_bytes_per_op=s["flush_bytes"] / n_ops,
                fsyncs=s["fsyncs"],
                commits=s["commits"],
                nodes_flushed=s["nodes_flushed"],
                gc_removed=s["gc_removed"],
            )
            shutil.rmtree(d, ignore_errors=True)
        ratio = bytes_per_op["occ"] / max(bytes_per_op["elim"], 1e-9)
        emit(
            f"persistence.zipf.flush_reduction.s{shards}",
            0.0,
            f"elim_vs_occ_bytes_per_op={ratio:.2f}x",
            flush_reduction=ratio,
        )
        if bytes_per_op["elim"] >= bytes_per_op["occ"]:
            raise RuntimeError(
                f"persistence: elim must flush fewer bytes/op than occ at "
                f"shards={shards} (elim={bytes_per_op['elim']:.1f}, "
                f"occ={bytes_per_op['occ']:.1f})"
            )

    # GC churn leg: frequent snapshots supersede earlier journal files, so
    # the post-commit GC must actually collect them (gc_removed > 0 —
    # guards against the journal directory growing without bound; the
    # main legs never snapshot, so they never exercise GC).  Also the one
    # leg that publishes fsync latency percentiles, from the registry
    # histogram the durable layer feeds.
    d = tempfile.mkdtemp(prefix="ptree_gc_")
    dur = DurableForest(
        d, n_shards=2, cfg=tree_cfg, mode="elim",
        key_space=(0, key_range), snapshot_every=2,
    )
    prefill_tree(dur.forest, cfg)
    t_gc = _run(dur, stream)
    s = dur.stats()
    fs = dur.metrics.histogram_summary("fsync_latency_s")
    if s["gc_removed"] <= 0:
        raise RuntimeError(
            "persistence.gc: snapshot churn must GC superseded journal "
            f"files (gc_removed={s['gc_removed']})"
        )
    emit(
        "persistence.zipf.gc_churn.s2",
        t_gc / n_ops * 1e6,
        f"gc_removed={s['gc_removed']};fsync_p99_us={fs['p99'] * 1e6:.0f}",
        ops_per_s=n_ops / t_gc,
        gc_removed=s["gc_removed"],
        commits=s["commits"],
        fsyncs=s["fsyncs"],
        fsync_p50_us=fs["p50"] * 1e6,
        fsync_p99_us=fs["p99"] * 1e6,
    )
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
