"""Elimination-rate study (paper §4 validation): fraction of update ops
eliminated and write reduction as a function of Zipf skew — the mechanism
behind the Figs 12–15 gap."""
from __future__ import annotations

from repro.configs.abtree import TPU8
from repro.core import ABTree
from repro.data.workloads import WorkloadConfig, op_stream, prefill_tree

from benchmarks.common import emit


def main(quick=False):
    svals = [0.5, 1.0, 1.5] if quick else [0.0, 0.5, 0.8, 1.0, 1.2, 1.5, 2.0]
    for s in svals:
        cfg = WorkloadConfig(
            key_range=4096, update_frac=1.0, dist="zipf" if s > 0 else "uniform",
            zipf_s=s, batch=512, seed=5,
        )
        tree = ABTree(TPU8._replace(capacity=1 << 15), mode="elim")
        prefill_tree(tree, cfg)
        n_updates = 0
        for ops, keys, vals in op_stream(cfg, 12):
            tree.apply_round(ops, keys, vals)
            n_updates += int((ops > 1).sum())
        st = tree.stats()
        rate = st["eliminated"] / max(n_updates, 1)
        emit(
            f"elim_rate.zipf{s}",
            0.0,
            f"eliminated_frac={rate:.3f};slot_writes={st['slot_writes']};updates={n_updates}",
        )


if __name__ == "__main__":
    main()
