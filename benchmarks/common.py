"""Shared benchmark utilities."""
from __future__ import annotations

import time


def timeit(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
