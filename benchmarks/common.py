"""Shared benchmark utilities.

``emit`` both prints the human-readable CSV line and records a
machine-readable entry (with optional structured metrics such as ops/s,
round counts, or conflict retries).  ``benchmarks/run.py`` drains the
records after each section and writes them to ``results/BENCH_<name>.json``
so the perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import json
import os
import time
from typing import List

_RECORDS: List[dict] = []


def timeit(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: str = "", **metrics):
    """Print one CSV result line and record it (plus structured ``metrics``
    key/values) for the JSON dump."""
    print(f"{name},{us_per_call:.2f},{derived}")
    _RECORDS.append(
        {"name": name, "us_per_call": us_per_call, "derived": derived, **metrics}
    )


def drain_records() -> List[dict]:
    """Return and clear the records emitted since the last drain."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out


def write_bench_json(workload: str, records: List[dict], directory: str = None) -> str:
    """Write one section's records to ``<directory>/BENCH_<workload>.json``.

    Defaults to the repo's ``results/`` directory.  Returns the path."""
    if directory is None:
        directory = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results"
        )
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"BENCH_{workload}.json")
    with open(path, "w") as f:
        json.dump({"workload": workload, "results": records}, f, indent=2)
        f.write("\n")
    return path
