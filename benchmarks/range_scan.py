"""Range-scan microbenchmark: batched ``scan_round`` throughput vs span and
batch size, plus the ``kernels/range_scan`` Pallas kernel vs its jnp ref on
the gather hot loop (int32 device keys, interpret mode on CPU)."""
from __future__ import annotations

import os
import sys

import numpy as np

import jax.numpy as jnp

if __package__ in (None, ""):  # `python benchmarks/range_scan.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from repro.configs.abtree import TPU8
from repro.core import ABTree
from repro.data.workloads import WorkloadConfig, prefill_tree
from repro.kernels.range_scan import range_scan_pallas, range_scan_ref

from benchmarks.common import emit, timeit


def _bench_scan_round(quick=False):
    key_range = 1 << 14
    batch = 64 if quick else 256
    iters = 2 if quick else 5
    tree = ABTree(TPU8._replace(capacity=4 * key_range), mode="elim")
    prefill_tree(tree, WorkloadConfig(key_range=key_range, seed=11))
    rng = np.random.default_rng(17)
    for span in (16, 256) if quick else (16, 64, 256, 1024):
        lo = rng.integers(0, key_range - span, batch).astype(np.int64)
        hi = lo + span
        cap = min(2 * span, 1024)
        tree.scan_round(lo, hi, cap=cap)  # warm / compile
        dt = timeit(lambda: tree.scan_round(lo, hi, cap=cap), warmup=1, iters=iters)
        emit(
            f"range_scan.round.span{span}",
            dt / batch * 1e6,
            f"scans/s={batch/dt:.0f}",
        )


def _bench_kernel(quick=False):
    rng = np.random.default_rng(23)
    bsz, n, cap = (64, 128, 32) if quick else (256, 256, 64)
    keys = np.sort(rng.choice(1 << 20, size=(bsz, n), replace=False, axis=None).reshape(bsz, n), axis=1)
    keys = keys.astype(np.int32)
    vals = rng.integers(0, 1 << 20, (bsz, n)).astype(np.int32)
    lo = keys[:, n // 4].astype(np.int32)
    hi = keys[:, 3 * n // 4].astype(np.int32)
    args = tuple(jnp.asarray(x) for x in (keys, vals, lo, hi))
    for name, fn in (
        ("pallas", lambda: range_scan_pallas(*args, cap=cap, interpret=True)[0].block_until_ready()),
        ("ref", lambda: range_scan_ref(*args, cap)[0].block_until_ready()),
    ):
        dt = timeit(fn, warmup=1, iters=2 if quick else 5)
        emit(f"range_scan.kernel.{name}", dt / bsz * 1e6, f"rows/s={bsz/dt:.0f}")


def main(quick=False):
    _bench_scan_round(quick=quick)
    _bench_kernel(quick=quick)


if __name__ == "__main__":
    main()
