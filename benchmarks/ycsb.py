"""YCSB workload analogs on the batched tree index.

  A (paper Fig 16): 50% reads / 50% writes where a "write" reads the row
    pointer from the index then mutates the row payload (NOT the index) —
    index traffic is find-dominated, Zipf 0.5.
  E: 95% short range scans / 5% inserts (Zipf start keys) — the scan-heavy
    mix.  Runs FUSED by default: each mixed batch is ONE ``apply_round``
    call (scans linearized before the round's writes by the round engine).
    ``--scan-path split`` selects the legacy baseline (host-side
    ``split_scan_round`` → one scan round + one point round per batch, 2×
    the round count); ``--scan-path both`` (the default) A/Bs the two and
    reports the round counts side by side.

``python benchmarks/ycsb.py [--workload A|E] [--scan-path fused|split|both]
[--quick]``
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/ycsb.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from repro.configs.abtree import TPU8
from repro.core import ABTree, OP_FIND
from repro.data.workloads import (
    WorkloadConfig,
    prefill_tree,
    split_scan_round,
    ycsb_e_stream,
    zipf_keys,
)

from benchmarks.common import emit


def _run_a(quick=False):
    key_range = 4096
    batch = 512
    rounds = 10 if quick else 30
    rows = np.zeros(key_range, np.int64)
    rng = np.random.default_rng(3)
    for mode in ("elim", "occ"):
        tree = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
        prefill_tree(tree, WorkloadConfig(key_range=key_range, seed=1))
        keys = zipf_keys(rng, batch * rounds, key_range, 0.5)
        is_write = rng.random(batch * rounds) < 0.5
        tree.apply_round([OP_FIND] * batch, keys[:batch], [0] * batch)  # warm
        t0 = time.perf_counter()
        for r in range(rounds):
            k = keys[r * batch : (r + 1) * batch]
            w = is_write[r * batch : (r + 1) * batch]
            out = tree.apply_round(np.full(batch, OP_FIND, np.int32), k, np.zeros(batch, np.int64))
            # writes mutate the ROW (host payload), not the index
            res = np.asarray(out.results)
            hit = np.asarray(out.found) & w
            rows[k[hit] % key_range] += res[hit] % 7
        dt = time.perf_counter() - t0
        n_ops = batch * rounds
        emit(
            f"ycsb_a.{mode}",
            dt / n_ops * 1e6,
            f"tx/s={n_ops/dt:.0f}",
            ops_per_s=n_ops / dt,
            rounds=rounds,
        )


def _run_e_path(mode, path, wl, rounds, cap):
    """Run YCSB-E in one (tree mode, scan path) config; returns metrics.

    fused: one ``apply_round`` per mixed batch (the round engine's fused
    scan+update pipeline).  split: the legacy host-split baseline — one
    ``scan_round`` + one ``apply_round`` per batch (2 rounds/batch)."""
    key_range = wl.key_range
    tree = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
    prefill_tree(tree, wl)
    # warm: several rounds so the scan frontier reaches steady state and
    # every (frontier, cap) jit compile lands outside the timed region
    # (the compile cache is shared across modes).
    for ops, keys, vals in ycsb_e_stream(wl, 3):
        if path == "fused":
            tree.apply_round(ops, keys, vals, scan_cap=cap)
        else:
            (lo, hi), point = split_scan_round(ops, keys, vals)
            tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
    n_ops = n_items = n_rounds = 0
    t0 = time.perf_counter()
    for ops, keys, vals in ycsb_e_stream(wl, rounds):
        if path == "fused":
            out = tree.apply_round(ops, keys, vals, scan_cap=cap)
            n_items += int(np.sum(np.asarray(out.scan.count)))
            n_rounds += 1
        else:
            (lo, hi), point = split_scan_round(ops, keys, vals)
            out = tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
            n_items += int(np.sum(np.asarray(out.count)))
            n_rounds += 2
        n_ops += len(ops)
    dt = time.perf_counter() - t0
    return {
        "ops_per_s": n_ops / dt,
        "items_per_s": n_items / dt,
        "rounds": n_rounds,
        "scan_retries": tree.stats()["scan_retries"],
        "us_per_op": dt / n_ops * 1e6,
    }


def _run_e(quick=False, scan_path="both"):
    key_range = 4096
    batch = 256
    rounds = 6 if quick else 20
    cap = 128
    wl = WorkloadConfig(key_range=key_range, dist="zipf", zipf_s=1.0, batch=batch, seed=5)
    paths = ("fused", "split") if scan_path == "both" else (scan_path,)
    for mode in ("elim", "occ"):
        per_path = {}
        for path in paths:
            m = _run_e_path(mode, path, wl, rounds, cap)
            per_path[path] = m
            emit(
                f"ycsb_e.{mode}.{path}",
                m["us_per_op"],
                f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
                f"rounds={m['rounds']};scan_retries={m['scan_retries']}",
                ops_per_s=m["ops_per_s"],
                rounds=m["rounds"],
                conflict_retries=m["scan_retries"],
            )
        if scan_path == "both":
            rf, rs = per_path["fused"]["rounds"], per_path["split"]["rounds"]
            if rf >= rs:  # hard error, not assert: must survive python -O
                raise RuntimeError(
                    f"fused rounds {rf} not below split baseline {rs}"
                )
            emit(
                f"ycsb_e.{mode}.fused_vs_split",
                0.0,
                f"rounds_fused={rf};rounds_split={rs};"
                f"speedup={per_path['split']['us_per_op']/per_path['fused']['us_per_op']:.2f}x",
                rounds_fused=rf,
                rounds_split=rs,
            )


def main(quick=False, workload="A", scan_path="both"):
    if workload.upper() == "A":
        _run_a(quick=quick)
    elif workload.upper() == "E":
        _run_e(quick=quick, scan_path=scan_path)
    else:
        raise ValueError(f"unknown YCSB workload {workload!r} (A or E)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="A", choices=["A", "E", "a", "e"])
    ap.add_argument(
        "--scan-path",
        default="both",
        choices=["fused", "split", "both"],
        help="workload E execution: 'fused' (mixed rounds, the engine's "
        "default path), 'split' (legacy 2-rounds-per-batch baseline), or "
        "'both' (default) — runs fused then split and reports the A/B "
        "round-count comparison",
    )
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick, workload=args.workload, scan_path=args.scan_path)
