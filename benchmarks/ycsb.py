"""YCSB Workload-A analog (paper Fig 16): 50% reads / 50% writes where a
"write" reads the row pointer from the index then mutates the row payload
(NOT the index) — index traffic is find-dominated, Zipf 0.5."""
from __future__ import annotations

import time

import numpy as np

from repro.configs.abtree import TPU8
from repro.core import ABTree, OP_FIND
from repro.data.workloads import WorkloadConfig, prefill_tree, zipf_keys

from benchmarks.common import emit


def main(quick=False):
    key_range = 4096
    batch = 512
    rounds = 10 if quick else 30
    rows = np.zeros(key_range, np.int64)
    rng = np.random.default_rng(3)
    for mode in ("elim", "occ"):
        tree = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
        prefill_tree(tree, WorkloadConfig(key_range=key_range, seed=1))
        keys = zipf_keys(rng, batch * rounds, key_range, 0.5)
        is_write = rng.random(batch * rounds) < 0.5
        tree.apply_round([OP_FIND] * batch, keys[:batch], [0] * batch)  # warm
        t0 = time.perf_counter()
        for r in range(rounds):
            k = keys[r * batch : (r + 1) * batch]
            w = is_write[r * batch : (r + 1) * batch]
            out = tree.apply_round(np.full(batch, OP_FIND, np.int32), k, np.zeros(batch, np.int64))
            # writes mutate the ROW (host payload), not the index
            res = np.asarray(out.results)
            hit = np.asarray(out.found) & w
            rows[k[hit] % key_range] += res[hit] % 7
        dt = time.perf_counter() - t0
        n_ops = batch * rounds
        emit(
            f"ycsb_a.{mode}",
            dt / n_ops * 1e6,
            f"tx/s={n_ops/dt:.0f}",
        )


if __name__ == "__main__":
    main()
