"""YCSB workload analogs on the batched tree index.

  A (paper Fig 16): 50% reads / 50% writes where a "write" reads the row
    pointer from the index then mutates the row payload (NOT the index) —
    index traffic is find-dominated, Zipf 0.5.
  E: 95% short range scans / 5% inserts (Zipf start keys) — the scan-heavy
    mix served by the range-scan subsystem (``ABTree.scan_round``).

``python benchmarks/ycsb.py [--workload A|E] [--quick]``
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/ycsb.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from repro.configs.abtree import TPU8
from repro.core import ABTree, OP_FIND
from repro.data.workloads import (
    WorkloadConfig,
    prefill_tree,
    split_scan_round,
    ycsb_e_stream,
    zipf_keys,
)

from benchmarks.common import emit


def _run_a(quick=False):
    key_range = 4096
    batch = 512
    rounds = 10 if quick else 30
    rows = np.zeros(key_range, np.int64)
    rng = np.random.default_rng(3)
    for mode in ("elim", "occ"):
        tree = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
        prefill_tree(tree, WorkloadConfig(key_range=key_range, seed=1))
        keys = zipf_keys(rng, batch * rounds, key_range, 0.5)
        is_write = rng.random(batch * rounds) < 0.5
        tree.apply_round([OP_FIND] * batch, keys[:batch], [0] * batch)  # warm
        t0 = time.perf_counter()
        for r in range(rounds):
            k = keys[r * batch : (r + 1) * batch]
            w = is_write[r * batch : (r + 1) * batch]
            out = tree.apply_round(np.full(batch, OP_FIND, np.int32), k, np.zeros(batch, np.int64))
            # writes mutate the ROW (host payload), not the index
            res = np.asarray(out.results)
            hit = np.asarray(out.found) & w
            rows[k[hit] % key_range] += res[hit] % 7
        dt = time.perf_counter() - t0
        n_ops = batch * rounds
        emit(
            f"ycsb_a.{mode}",
            dt / n_ops * 1e6,
            f"tx/s={n_ops/dt:.0f}",
        )


def _run_e(quick=False):
    key_range = 4096
    batch = 256
    rounds = 6 if quick else 20
    cap = 128
    wl = WorkloadConfig(key_range=key_range, dist="zipf", zipf_s=1.0, batch=batch, seed=5)
    for mode in ("elim", "occ"):
        tree = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
        prefill_tree(tree, wl)
        # warm both round types: several rounds so the scan frontier reaches
        # steady state and every (frontier, cap) jit compile lands outside
        # the timed region (the compile cache is shared across modes).
        for ops, keys, vals in ycsb_e_stream(wl, 3):
            (lo, hi), point = split_scan_round(ops, keys, vals)
            tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
        n_ops = n_items = 0
        t0 = time.perf_counter()
        for ops, keys, vals in ycsb_e_stream(wl, rounds):
            (lo, hi), point = split_scan_round(ops, keys, vals)
            out = tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
            n_ops += len(ops)
            n_items += int(np.sum(np.asarray(out.count)))
        dt = time.perf_counter() - t0
        emit(
            f"ycsb_e.{mode}",
            dt / n_ops * 1e6,
            f"tx/s={n_ops/dt:.0f};items/s={n_items/dt:.0f};"
            f"scan_retries={tree.stats()['scan_retries']}",
        )


def main(quick=False, workload="A"):
    if workload.upper() == "A":
        _run_a(quick=quick)
    elif workload.upper() == "E":
        _run_e(quick=quick)
    else:
        raise ValueError(f"unknown YCSB workload {workload!r} (A or E)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="A", choices=["A", "E", "a", "e"])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick, workload=args.workload)
