"""YCSB workload analogs on the batched tree index.

  A (paper Fig 16): 50% reads / 50% writes where a "write" reads the row
    pointer from the index then mutates the row payload (NOT the index) —
    index traffic is find-dominated, Zipf 0.5.
  E: 95% short range scans / 5% inserts (Zipf start keys) — the scan-heavy
    mix.  Runs FUSED by default: each mixed batch is ONE ``apply_round``
    call (scans linearized before the round's writes by the round engine).
    ``--scan-path split`` selects the legacy baseline (host-side
    ``split_scan_round`` → one scan round + one point round per batch, 2×
    the round count); ``--scan-path both`` (the default) A/Bs the two and
    reports the round counts side by side.

``--shards K`` (K ≥ 1) switches the index to the key-partitioned
``ABForest`` and A/Bs it against the 1-shard forest baseline:

  A: reads execute as *validated optimistic point-reads* (the paper's
     ``searchLeaf`` version discipline, batched) while a concurrent writer
     replica — modeled by the forest's ``scan_hook`` — churns Zipf-hot keys
     between each round's gather and validation.  The single tree
     validates the whole batch's touched set, so one hot write retries
     every lane; the forest validates per shard, so only the conflicted
     shards' lanes retry.  ``conflict_retries`` counts retried lanes; with
     K > 1 the run fails unless retries/op is strictly below the 1-shard
     baseline on the skewed workload.
  E: the same fused mixed rounds, with cross-shard OP_RANGE lanes split at
     shard boundaries and executed as one vmapped round.

``--narrow`` asserts the workload's keys/values fit int32 (true for every
YCSB config here) and routes the whole search path through the
``kernels/tree_descend`` + ``kernels/range_scan`` device kernels (fused
descent+probe, Pallas frontier compaction, kernel rank-select) instead of
the int64 jnp references — the A/B for the device-resident search path.

``python benchmarks/ycsb.py [--workload A|E] [--scan-path fused|split|both]
[--shards K] [--narrow] [--trace PATH] [--quick]``

``--trace PATH`` installs a phase ``Tracer`` on every holder the section
builds and writes Chrome trace-event JSON (Perfetto-loadable; or render a
phase/shard table with ``python -m repro.obs.report PATH``).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/ycsb.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from repro.configs.abtree import TPU8
from repro.core import ABForest, ABTree, OP_DELETE, OP_FIND, OP_INSERT
from repro.data.workloads import (
    WorkloadConfig,
    prefill_tree,
    split_scan_round,
    ycsb_e_stream,
    zipf_keys,
)

from benchmarks.common import emit

# set by main(trace=...): every holder the section builds gets this tracer
# installed, so one --trace run captures all of the section's rounds.
_TRACER = None


def _instrument(holder):
    if _TRACER is not None:
        holder.tracer = _TRACER
    return holder


def _run_a(quick=False, narrow=False):
    key_range = 4096
    batch = 512
    rounds = 10 if quick else 30
    rows = np.zeros(key_range, np.int64)
    rng = np.random.default_rng(3)
    for mode in ("elim", "occ"):
        tree = _instrument(
            ABTree(TPU8._replace(capacity=4 * key_range), mode=mode, narrow=narrow)
        )
        prefill_tree(tree, WorkloadConfig(key_range=key_range, seed=1))
        keys = zipf_keys(rng, batch * rounds, key_range, 0.5)
        is_write = rng.random(batch * rounds) < 0.5
        tree.apply_round([OP_FIND] * batch, keys[:batch], [0] * batch)  # warm
        t0 = time.perf_counter()
        for r in range(rounds):
            k = keys[r * batch : (r + 1) * batch]
            w = is_write[r * batch : (r + 1) * batch]
            out = tree.apply_round(np.full(batch, OP_FIND, np.int32), k, np.zeros(batch, np.int64))
            # writes mutate the ROW (host payload), not the index
            res = np.asarray(out.results)
            hit = np.asarray(out.found) & w
            rows[k[hit] % key_range] += res[hit] % 7
        dt = time.perf_counter() - t0
        n_ops = batch * rounds
        emit(
            f"ycsb_a.{mode}{'.narrow' if narrow else ''}",
            dt / n_ops * 1e6,
            f"tx/s={n_ops/dt:.0f}",
            ops_per_s=n_ops / dt,
            rounds=rounds,
        )


def run_a_forest(shards, quick=False, key_range=4096, batch=256, narrow=False):
    """YCSB-A on an ``ABForest``: reads as validated optimistic point-reads
    under a concurrent writer replica (the ``scan_hook``).  Returns metrics
    incl. ``conflict_retries`` = retried lanes (per-shard validation only
    retries the shards the writer actually touched)."""
    rounds_n = 10 if quick else 30
    wl = WorkloadConfig(key_range=key_range, seed=1)
    forest = _instrument(ABForest(
        n_shards=shards,
        cfg=TPU8._replace(capacity=4 * key_range),
        mode="elim",
        key_space=(0, key_range),
        narrow=narrow,
    ))
    prefill_tree(forest, wl)
    rng = np.random.default_rng(3)
    n_w = 8  # hot-key writes per round (the contended fraction)
    reads = zipf_keys(rng, batch * (rounds_n + 1), key_range, 0.5)
    writes = zipf_keys(rng, n_w * (rounds_n + 1), key_range, 1.2)
    wvals = rng.integers(0, 1 << 30, n_w * (rounds_n + 1)).astype(np.int64)
    # writer round: delete+insert per hot key collapses to ONE net leaf
    # write (overwrite / insert) that always bumps the leaf version.
    w_ops = np.concatenate(
        [np.full(n_w, OP_DELETE, np.int32), np.full(n_w, OP_INSERT, np.int32)]
    )
    pending = {}

    def writer_replica():
        w = pending.pop("w", None)
        if w is not None:
            wk, wv = w
            forest.apply_round(
                w_ops,
                np.concatenate([wk, wk]),
                np.concatenate([np.zeros(n_w, np.int64), wv]),
            )

    forest.scan_hook = writer_replica

    def one_round(r):
        k = reads[r * batch : (r + 1) * batch]
        pending["w"] = (
            writes[r * n_w : (r + 1) * n_w],
            wvals[r * n_w : (r + 1) * n_w],
        )
        forest.scan_round(k, k + 1, cap=1)

    one_round(rounds_n)  # warm (jit compiles land outside the timed region)
    base_retries = forest.stats()["scan_retries"]
    t0 = time.perf_counter()
    for r in range(rounds_n):
        one_round(r)
    dt = time.perf_counter() - t0
    forest.scan_hook = None
    retries = forest.stats()["scan_retries"] - base_retries
    n_ops = batch * rounds_n
    return {
        "shards": shards,
        "ops_per_s": n_ops / dt,
        "us_per_op": dt / n_ops * 1e6,
        "conflict_retries": retries,
        "retries_per_op": retries / n_ops,
        "rounds": rounds_n,
    }


def run_e_forest(shards, quick=False, key_range=4096, batch=256, cap=128, narrow=False):
    """YCSB-E fused mixed rounds on an ``ABForest`` (cross-shard OP_RANGE
    lanes split at shard boundaries, one vmapped round per batch)."""
    rounds_n = 6 if quick else 20
    wl = WorkloadConfig(
        key_range=key_range, dist="zipf", zipf_s=1.0, batch=batch, seed=5
    )
    forest = _instrument(ABForest(
        n_shards=shards,
        cfg=TPU8._replace(capacity=4 * key_range),
        mode="elim",
        key_space=(0, key_range),
        narrow=narrow,
    ))
    prefill_tree(forest, wl)
    for ops, keys, vals in ycsb_e_stream(wl, 3):  # warm
        forest.apply_round(ops, keys, vals, scan_cap=cap)
    n_ops = n_items = 0
    t0 = time.perf_counter()
    for ops, keys, vals in ycsb_e_stream(wl, rounds_n):
        out = forest.apply_round(ops, keys, vals, scan_cap=cap)
        n_items += int(np.sum(np.asarray(out.scan.count)))
        n_ops += len(ops)
    dt = time.perf_counter() - t0
    st = forest.stats()
    return {
        "shards": shards,
        "ops_per_s": n_ops / dt,
        "items_per_s": n_items / dt,
        "us_per_op": dt / n_ops * 1e6,
        "rounds": rounds_n,
        "conflict_retries": st["scan_retries"],
    }


def _run_a_sharded(shards, quick=False, narrow=False):
    per = {}
    sfx = ".narrow" if narrow else ""
    for k in sorted({1, shards}):
        m = run_a_forest(k, quick=quick, narrow=narrow)
        per[k] = m
        emit(
            f"ycsb_a.forest.s{k}{sfx}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};conflict_retries={m['conflict_retries']};"
            f"retries/op={m['retries_per_op']:.3f}",
            **m,
        )
    if shards > 1:
        r1, rk = per[1]["retries_per_op"], per[shards]["retries_per_op"]
        if rk >= r1:  # hard error, not assert: must survive python -O
            raise RuntimeError(
                f"forest({shards}) retries/op {rk:.3f} not strictly below "
                f"1-shard baseline {r1:.3f}"
            )
        emit(
            f"ycsb_a.forest.s{shards}_vs_s1{sfx}",
            0.0,
            f"retries/op={rk:.3f} vs {r1:.3f} ({r1 / max(rk, 1e-9):.2f}x fewer)",
            retries_per_op_sharded=rk,
            retries_per_op_single=r1,
        )


def _run_e_sharded(shards, quick=False, narrow=False):
    per = {}
    sfx = ".narrow" if narrow else ""
    for k in sorted({1, shards}):
        m = run_e_forest(k, quick=quick, narrow=narrow)
        per[k] = m
        emit(
            f"ycsb_e.forest.s{k}{sfx}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
            f"conflict_retries={m['conflict_retries']}",
            **m,
        )
    if shards > 1:
        emit(
            f"ycsb_e.forest.s{shards}_vs_s1{sfx}",
            0.0,
            f"speedup={per[1]['us_per_op'] / per[shards]['us_per_op']:.2f}x",
            us_per_op_sharded=per[shards]["us_per_op"],
            us_per_op_single=per[1]["us_per_op"],
        )


def _run_e_path(mode, path, wl, rounds, cap, narrow=False):
    """Run YCSB-E in one (tree mode, scan path) config; returns metrics.

    fused: one ``apply_round`` per mixed batch (the round engine's fused
    scan+update pipeline).  split: the legacy host-split baseline — one
    ``scan_round`` + one ``apply_round`` per batch (2 rounds/batch)."""
    key_range = wl.key_range
    tree = _instrument(
        ABTree(TPU8._replace(capacity=4 * key_range), mode=mode, narrow=narrow)
    )
    prefill_tree(tree, wl)
    # warm: several rounds so the scan frontier reaches steady state and
    # every (frontier, cap) jit compile lands outside the timed region
    # (the compile cache is shared across modes).
    for ops, keys, vals in ycsb_e_stream(wl, 3):
        if path == "fused":
            tree.apply_round(ops, keys, vals, scan_cap=cap)
        else:
            (lo, hi), point = split_scan_round(ops, keys, vals)
            tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
    n_ops = n_items = n_rounds = 0
    t0 = time.perf_counter()
    for ops, keys, vals in ycsb_e_stream(wl, rounds):
        if path == "fused":
            out = tree.apply_round(ops, keys, vals, scan_cap=cap)
            n_items += int(np.sum(np.asarray(out.scan.count)))
            n_rounds += 1
        else:
            (lo, hi), point = split_scan_round(ops, keys, vals)
            out = tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
            n_items += int(np.sum(np.asarray(out.count)))
            n_rounds += 2
        n_ops += len(ops)
    dt = time.perf_counter() - t0
    return {
        "ops_per_s": n_ops / dt,
        "items_per_s": n_items / dt,
        "rounds": n_rounds,
        "scan_retries": tree.stats()["scan_retries"],
        "us_per_op": dt / n_ops * 1e6,
    }


def _run_e(quick=False, scan_path="both", narrow=False):
    key_range = 4096
    batch = 256
    rounds = 6 if quick else 20
    cap = 128
    wl = WorkloadConfig(key_range=key_range, dist="zipf", zipf_s=1.0, batch=batch, seed=5)
    paths = ("fused", "split") if scan_path == "both" else (scan_path,)
    for mode in ("elim", "occ"):
        per_path = {}
        for path in paths:
            m = _run_e_path(mode, path, wl, rounds, cap, narrow=narrow)
            per_path[path] = m
            emit(
                f"ycsb_e.{mode}.{path}{'.narrow' if narrow else ''}",
                m["us_per_op"],
                f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
                f"rounds={m['rounds']};scan_retries={m['scan_retries']}",
                ops_per_s=m["ops_per_s"],
                rounds=m["rounds"],
                conflict_retries=m["scan_retries"],
            )
        if scan_path == "both":
            rf, rs = per_path["fused"]["rounds"], per_path["split"]["rounds"]
            if rf >= rs:  # hard error, not assert: must survive python -O
                raise RuntimeError(
                    f"fused rounds {rf} not below split baseline {rs}"
                )
            emit(
                f"ycsb_e.{mode}.fused_vs_split",
                0.0,
                f"rounds_fused={rf};rounds_split={rs};"
                f"speedup={per_path['split']['us_per_op']/per_path['fused']['us_per_op']:.2f}x",
                rounds_fused=rf,
                rounds_split=rs,
            )


def main(quick=False, workload="A", scan_path="both", shards=0, narrow=False,
         trace=None):
    global _TRACER
    if trace:
        from repro.obs.tracer import Tracer

        _TRACER = Tracer()
    try:
        if workload.upper() == "A":
            if shards:
                _run_a_sharded(shards, quick=quick, narrow=narrow)
            else:
                _run_a(quick=quick, narrow=narrow)
        elif workload.upper() == "E":
            if shards:
                _run_e_sharded(shards, quick=quick, narrow=narrow)
            else:
                _run_e(quick=quick, scan_path=scan_path, narrow=narrow)
        else:
            raise ValueError(f"unknown YCSB workload {workload!r} (A or E)")
    finally:
        if trace:
            from repro.obs.trace_export import write_chrome_trace

            write_chrome_trace(trace, _TRACER)
            print(f"# wrote trace: {trace} ({len(_TRACER.events)} events)")
            _TRACER = None


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="A", choices=["A", "E", "a", "e"])
    ap.add_argument(
        "--scan-path",
        default="both",
        choices=["fused", "split", "both"],
        help="workload E execution: 'fused' (mixed rounds, the engine's "
        "default path), 'split' (legacy 2-rounds-per-batch baseline), or "
        "'both' (default) — runs fused then split and reports the A/B "
        "round-count comparison",
    )
    ap.add_argument(
        "--shards",
        type=int,
        default=0,
        choices=[0, 1, 2, 4, 8],
        help="run the workload on a key-partitioned ABForest with this many "
        "shards, A/B'd against the 1-shard forest baseline (0 = legacy "
        "single-tree path).  Workload A fails unless the sharded run has "
        "strictly fewer conflict retries per op than the baseline",
    )
    ap.add_argument(
        "--narrow",
        action="store_true",
        help="route the search path through the int32 device kernels "
        "(fused descent+probe, Pallas frontier compaction, kernel "
        "rank-select) — the device-resident A/B against the jnp refs",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="record a phase trace of the whole section (every holder the "
        "section builds) and write Chrome trace-event JSON to PATH — "
        "load it in Perfetto, or render a table with "
        "`python -m repro.obs.report PATH`",
    )
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(
        quick=args.quick,
        workload=args.workload,
        scan_path=args.scan_path,
        shards=args.shards,
        narrow=args.narrow,
        trace=args.trace,
    )
