"""YCSB workload analogs on the batched tree index.

  A (paper Fig 16): 50% reads / 50% writes where a "write" reads the row
    pointer from the index then mutates the row payload (NOT the index) —
    index traffic is find-dominated, Zipf 0.5.
  E: 95% short range scans / 5% inserts (Zipf start keys) — the scan-heavy
    mix.  Runs FUSED by default: each mixed batch is ONE ``apply_round``
    call (scans linearized before the round's writes by the round engine).
    ``--scan-path split`` selects the legacy baseline (host-side
    ``split_scan_round`` → one scan round + one point round per batch, 2×
    the round count); ``--scan-path both`` (the default) A/Bs the two and
    reports the round counts side by side.

``--shards K`` (K ≥ 1) switches the index to the key-partitioned
``ABForest`` and A/Bs it against the 1-shard forest baseline:

  A: reads execute as *validated optimistic point-reads* (the paper's
     ``searchLeaf`` version discipline, batched) while a concurrent writer
     replica — modeled by the forest's ``scan_hook`` — churns Zipf-hot keys
     between each round's gather and validation.  The single tree
     validates the whole batch's touched set, so one hot write retries
     every lane; the forest validates per shard, so only the conflicted
     shards' lanes retry.  ``conflict_retries`` counts retried lanes; with
     K > 1 the run fails unless retries/op is strictly below the 1-shard
     baseline on the skewed workload.
  E: the same fused mixed rounds, with cross-shard OP_RANGE lanes split at
     shard boundaries and executed as one vmapped round.

``--narrow`` asserts the workload's keys/values fit int32 (true for every
YCSB config here) and routes the whole search path through the
``kernels/tree_descend`` + ``kernels/range_scan`` device kernels (fused
descent+probe, Pallas frontier compaction, kernel rank-select) instead of
the int64 jnp references — the A/B for the device-resident search path.

``python benchmarks/ycsb.py [--workload A|E] [--scan-path fused|split|both]
[--shards K] [--narrow] [--trace PATH] [--quick]``

``--trace PATH`` installs a phase ``Tracer`` on every holder the section
builds and writes Chrome trace-event JSON (Perfetto-loadable; or render a
phase/shard table with ``python -m repro.obs.report PATH``).

``--audit PATH`` re-runs the workload's forest leg with the flight
recorder installed, writes the semantic audit log (JSONL) to PATH, and
replays it through the linearizability witness
(``python -m repro.obs.witness PATH``); workload A also gates the
recorder's measured overhead at ≤ 5% ops/s vs a disabled-recorder twin.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # `python benchmarks/ycsb.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from repro.configs.abtree import TPU8
from repro.core import ABForest, ABTree, OP_DELETE, OP_FIND, OP_INSERT
from repro.data.workloads import (
    WorkloadConfig,
    prefill_tree,
    split_scan_round,
    ycsb_e_stream,
    zipf_keys,
)

from benchmarks.common import emit

# set by main(trace=...): every holder the section builds gets this tracer
# installed, so one --trace run captures all of the section's rounds.
_TRACER = None
# set by _run_audit: the audit leg's flight recorder.  Unlike the tracer
# this is only ever installed for ONE holder at a time — the witness
# replays the ring as a single sequential history, so interleaving rounds
# from two different trees would be an (incorrectly) rejected history.
_RECORDER = None


def _instrument(holder):
    if _TRACER is not None:
        holder.tracer = _TRACER
    if _RECORDER is not None:
        holder.recorder = _RECORDER
    return holder


def _run_a(quick=False, narrow=False):
    key_range = 4096
    batch = 512
    rounds = 10 if quick else 30
    rows = np.zeros(key_range, np.int64)
    rng = np.random.default_rng(3)
    for mode in ("elim", "occ"):
        tree = _instrument(
            ABTree(TPU8._replace(capacity=4 * key_range), mode=mode, narrow=narrow)
        )
        prefill_tree(tree, WorkloadConfig(key_range=key_range, seed=1))
        keys = zipf_keys(rng, batch * rounds, key_range, 0.5)
        is_write = rng.random(batch * rounds) < 0.5
        tree.apply_round([OP_FIND] * batch, keys[:batch], [0] * batch)  # warm
        t0 = time.perf_counter()
        for r in range(rounds):
            k = keys[r * batch : (r + 1) * batch]
            w = is_write[r * batch : (r + 1) * batch]
            out = tree.apply_round(np.full(batch, OP_FIND, np.int32), k, np.zeros(batch, np.int64))
            # writes mutate the ROW (host payload), not the index
            res = np.asarray(out.results)
            hit = np.asarray(out.found) & w
            rows[k[hit] % key_range] += res[hit] % 7
        dt = time.perf_counter() - t0
        n_ops = batch * rounds
        emit(
            f"ycsb_a.{mode}{'.narrow' if narrow else ''}",
            dt / n_ops * 1e6,
            f"tx/s={n_ops/dt:.0f}",
            ops_per_s=n_ops / dt,
            rounds=rounds,
        )


def run_a_forest(shards, quick=False, key_range=4096, batch=256, narrow=False,
                 dist="zipf", repartition=False):
    """YCSB-A on an ``ABForest``: reads as validated optimistic point-reads
    under a concurrent writer replica (the ``scan_hook``).  Returns metrics
    incl. ``conflict_retries`` = retried lanes (per-shard validation only
    retries the shards the writer actually touched).

    ``dist`` picks the read-key distribution: "zipf" (s=0.5, the skewed
    leg) or "uniform" (the scaling leg — per-shard lane groups stay even,
    so s4 ≥ s1 ops/s is the ragged-batching gate).  ``repartition`` turns
    on the forest's load-aware boundary moves (the zipf leg's fix)."""
    rounds_n = 10 if quick else 30
    n_warm = 8  # adaptive warm budget (see below)
    n_total = rounds_n + n_warm
    wl = WorkloadConfig(key_range=key_range, seed=1)
    forest = _instrument(ABForest(
        n_shards=shards,
        cfg=TPU8._replace(capacity=4 * key_range),
        mode="elim",
        key_space=(0, key_range),
        narrow=narrow,
        auto_repartition=repartition,
    ))
    prefill_tree(forest, wl)
    rng = np.random.default_rng(3)
    n_w = 8  # hot-key writes per round (the contended fraction)
    if dist == "uniform":
        reads = rng.integers(0, key_range, batch * n_total).astype(np.int64)
    else:
        reads = zipf_keys(rng, batch * n_total, key_range, 0.5)
    writes = zipf_keys(rng, n_w * n_total, key_range, 1.2)
    wvals = rng.integers(0, 1 << 30, n_w * n_total).astype(np.int64)
    # writer round: delete+insert per hot key collapses to ONE net leaf
    # write (overwrite / insert) that always bumps the leaf version.
    w_ops = np.concatenate(
        [np.full(n_w, OP_DELETE, np.int32), np.full(n_w, OP_INSERT, np.int32)]
    )
    pending = {}

    def writer_replica():
        w = pending.pop("w", None)
        if w is not None:
            wk, wv = w
            forest.apply_round(
                w_ops,
                np.concatenate([wk, wk]),
                np.concatenate([np.zeros(n_w, np.int64), wv]),
            )

    forest.scan_hook = writer_replica

    def one_round(r):
        k = reads[r * batch : (r + 1) * batch]
        pending["w"] = (
            writes[r * n_w : (r + 1) * n_w],
            wvals[r * n_w : (r + 1) * n_w],
        )
        forest.scan_round(k, k + 1, cap=1)

    # warm adaptively: the ragged round widths (retry re-gathers, writer
    # point blocks, structural waves) each jit-compile on first sight, so
    # run real rounds until one executes without a compile spike — then
    # every width the steady state touches is cached outside the timed
    # region.  Pre-compile the common retry scan widths explicitly too.
    forest.scan_hook = None
    for w_ in (32, 64, 128):
        kw = reads[:w_]
        forest.scan_round(kw, kw + 1, cap=1)
    forest.scan_hook = writer_replica
    t_best = None
    for w_r in range(rounds_n, n_total):
        t0 = time.perf_counter()
        one_round(w_r)
        t_r = time.perf_counter() - t0
        if t_best is not None and t_r <= 1.5 * t_best:
            break  # no compile landed in this round: warmed up
        t_best = t_r if t_best is None else min(t_best, t_r)
    base_retries = forest.stats()["scan_retries"]
    t0 = time.perf_counter()
    for r in range(rounds_n):
        one_round(r)
    dt = time.perf_counter() - t0
    forest.scan_hook = None
    retries = forest.stats()["scan_retries"] - base_retries
    n_ops = batch * rounds_n
    return {
        "shards": shards,
        "dist": dist,
        "ops_per_s": n_ops / dt,
        "us_per_op": dt / n_ops * 1e6,
        "conflict_retries": retries,
        "retries_per_op": retries / n_ops,
        "rounds": rounds_n,
        "repartitions": int(forest.metrics.snapshot()["counters"].get("repartitions", 0)),
    }


def run_e_forest(shards, quick=False, key_range=4096, batch=256, cap=128, narrow=False):
    """YCSB-E fused mixed rounds on an ``ABForest`` (cross-shard OP_RANGE
    lanes split at shard boundaries, one vmapped round per batch)."""
    rounds_n = 6 if quick else 20
    wl = WorkloadConfig(
        key_range=key_range, dist="zipf", zipf_s=1.0, batch=batch, seed=5
    )
    forest = _instrument(ABForest(
        n_shards=shards,
        cfg=TPU8._replace(capacity=4 * key_range),
        mode="elim",
        key_space=(0, key_range),
        narrow=narrow,
    ))
    prefill_tree(forest, wl)
    # Warm adaptively on a prefix of the stream, then time its
    # CONTINUATION — replaying warm batches on the (now mutated) forest
    # shifts round widths and lands fresh compiles inside the timed
    # region, which is where this leg's run-to-run 10x swings came from.
    n_warm = 8
    batches = list(ycsb_e_stream(wl, n_warm + rounds_n))
    t_best = None
    for ops, keys, vals in batches[:n_warm]:
        t0 = time.perf_counter()
        forest.apply_round(ops, keys, vals, scan_cap=cap)
        t_r = time.perf_counter() - t0
        if t_best is not None and t_r <= 1.5 * t_best:
            break  # no compile landed in this round: warmed up
        t_best = t_r if t_best is None else min(t_best, t_r)
    n_ops = n_items = 0
    dts = []
    for ops, keys, vals in batches[n_warm:]:
        t0 = time.perf_counter()
        out = forest.apply_round(ops, keys, vals, scan_cap=cap)
        dts.append(time.perf_counter() - t0)
        n_items += int(np.sum(np.asarray(out.scan.count)))
        n_ops += len(ops)
    # median x count: one straggler round (late compile, scheduler
    # spike) must not own the section's committed ops/s record.
    dt = float(np.median(dts)) * len(dts)
    st = forest.stats()
    return {
        "shards": shards,
        "ops_per_s": n_ops / dt,
        "items_per_s": n_items / dt,
        "us_per_op": dt / n_ops * 1e6,
        "rounds": rounds_n,
        "conflict_retries": st["scan_retries"],
    }


def _run_a_sharded(shards, quick=False, narrow=False, dist="zipf",
                   repartition=False):
    per = {}
    sfx = ".narrow" if narrow else ""
    if dist != "zipf":
        sfx += f".{dist}"
    for k in sorted({1, shards}):
        m = run_a_forest(k, quick=quick, narrow=narrow, dist=dist,
                         repartition=repartition)
        per[k] = m
        emit(
            f"ycsb_a.forest.s{k}{sfx}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};conflict_retries={m['conflict_retries']};"
            f"retries/op={m['retries_per_op']:.3f}",
            **m,
        )
    if shards > 1:
        r1, rk = per[1]["retries_per_op"], per[shards]["retries_per_op"]
        if rk >= r1:  # hard error, not assert: must survive python -O
            raise RuntimeError(
                f"forest({shards}) retries/op {rk:.3f} not strictly below "
                f"1-shard baseline {r1:.3f}"
            )
        o1, ok = per[1]["ops_per_s"], per[shards]["ops_per_s"]
        if dist == "uniform" and shards >= 4 and ok < o1:
            # the ragged-batching gate: sharding must pay in wall-clock,
            # not just in retries (the s1→s4 cliff can never return).
            raise RuntimeError(
                f"forest({shards}) uniform ops/s {ok:.0f} below 1-shard "
                f"baseline {o1:.0f} — sharding lost throughput"
            )
        emit(
            f"ycsb_a.forest.s{shards}_vs_s1{sfx}",
            0.0,
            f"retries/op={rk:.3f} vs {r1:.3f} ({r1 / max(rk, 1e-9):.2f}x fewer);"
            f"ops/s={ok:.0f} vs {o1:.0f} ({ok / max(o1, 1e-9):.2f}x)",
            retries_per_op_sharded=rk,
            retries_per_op_single=r1,
            ops_per_s_sharded=ok,
            ops_per_s_single=o1,
        )


def _run_e_sharded(shards, quick=False, narrow=False):
    per = {}
    sfx = ".narrow" if narrow else ""
    for k in sorted({1, shards}):
        m = run_e_forest(k, quick=quick, narrow=narrow)
        per[k] = m
        emit(
            f"ycsb_e.forest.s{k}{sfx}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
            f"conflict_retries={m['conflict_retries']}",
            **m,
        )
    if shards > 1:
        emit(
            f"ycsb_e.forest.s{shards}_vs_s1{sfx}",
            0.0,
            f"speedup={per[1]['us_per_op'] / per[shards]['us_per_op']:.2f}x",
            us_per_op_sharded=per[shards]["us_per_op"],
            us_per_op_single=per[1]["us_per_op"],
        )


def _run_e_path(mode, paths, wl, rounds, cap, narrow=False):
    """Run YCSB-E in one tree mode across ``paths``, batch-INTERLEAVED on
    one tree per path; returns ``{path: metrics}``.

    fused: one ``apply_round`` per mixed batch (the round engine's fused
    scan+update pipeline).  split: the legacy host-split baseline — one
    ``scan_round`` + one ``apply_round`` per batch (2 rounds/batch).

    The paths are timed interleaved (batch i on every path before batch
    i+1) and aggregated as median-of-batches × batches: the fused/split
    gate compares estimates whose true ratio sits a few percent above
    1.0, so sequential timing — where heap growth, GC epochs and CPU
    clocks drift between the two passes — made the ratio a coin flip."""
    key_range = wl.key_range
    trees = {
        path: _instrument(
            ABTree(
                TPU8._replace(capacity=4 * key_range), mode=mode,
                narrow=narrow,
            )
        )
        for path in paths
    }
    stats = {
        path: {"dts": [], "ops": 0, "items": 0, "rounds": 0}
        for path in paths
    }

    def _one(path, ops, keys, vals, timed):
        tree = trees[path]
        st = stats[path]
        t0 = time.perf_counter()
        if path == "fused":
            out = tree.apply_round(ops, keys, vals, scan_cap=cap)
            dt = time.perf_counter() - t0
            items = int(np.sum(np.asarray(out.scan.count)))
            n_rounds = 1
        else:
            (lo, hi), point = split_scan_round(ops, keys, vals)
            out = tree.scan_round(lo, hi, cap=cap)
            tree.apply_round(*point)
            dt = time.perf_counter() - t0
            items = int(np.sum(np.asarray(out.count)))
            n_rounds = 2
        if timed:
            st["dts"].append(dt)
            st["ops"] += len(ops)
            st["items"] += items
            st["rounds"] += n_rounds

    # Timed rounds CONTINUE the stream past the warm prefix rather than
    # replaying it: a replay re-runs the same batches against a larger
    # tree, so the ragged widths shift and fresh jit compiles land in the
    # timed region.  Advancing the stream keeps the width mix evolving
    # continuously out of the warm state.
    n_warm = 10
    batches = list(ycsb_e_stream(wl, n_warm + rounds))
    for path in paths:
        prefill_tree(trees[path], wl)
        # pre-compile the small point-block widths the mixed rounds bucket
        # to (the ~5% insert fraction flaps across pow2 buckets round to
        # round); FIND-only rounds hit the compiled pipeline w/o mutating.
        for w_ in (8, 16, 32):
            trees[path].apply_round(
                np.full(w_, OP_FIND, np.int32),
                np.arange(w_, dtype=np.int64),
                np.zeros(w_, np.int64),
            )
        for ops, keys, vals in batches[:n_warm]:
            _one(path, ops, keys, vals, timed=False)
    for ops, keys, vals in batches[n_warm:]:
        for path in paths:
            _one(path, ops, keys, vals, timed=True)
    out = {}
    for path in paths:
        st = stats[path]
        dt = float(np.median(st["dts"])) * len(st["dts"])
        out[path] = {
            "ops_per_s": st["ops"] / dt,
            "items_per_s": st["items"] / dt,
            "rounds": st["rounds"],
            "scan_retries": trees[path].stats()["scan_retries"],
            "us_per_op": dt / st["ops"] * 1e6,
            "batch_dts": st["dts"],
        }
    return out


def _run_e(quick=False, scan_path="both", narrow=False):
    key_range = 4096
    batch = 256
    # quick still times 16 batches: the occ fused-vs-split gate compares
    # two median-of-batches estimates whose true ratio sits only a few
    # percent above 1.0 — 6 batches left it a coin flip.
    rounds = 16 if quick else 20
    cap = 128
    wl = WorkloadConfig(key_range=key_range, dist="zipf", zipf_s=1.0, batch=batch, seed=5)
    paths = ("fused", "split") if scan_path == "both" else (scan_path,)
    for mode in ("elim", "occ"):
        per_path = _run_e_path(mode, paths, wl, rounds, cap, narrow=narrow)
        for path in paths:
            m = per_path[path]
            emit(
                f"ycsb_e.{mode}.{path}{'.narrow' if narrow else ''}",
                m["us_per_op"],
                f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
                f"rounds={m['rounds']};scan_retries={m['scan_retries']}",
                ops_per_s=m["ops_per_s"],
                rounds=m["rounds"],
                conflict_retries=m["scan_retries"],
            )
        if scan_path == "both":
            rf, rs = per_path["fused"]["rounds"], per_path["split"]["rounds"]
            if rf >= rs:  # hard error, not assert: must survive python -O
                raise RuntimeError(
                    f"fused rounds {rf} not below split baseline {rs}"
                )
            # Paired estimator: batch i ran on both trees back to back, so
            # the per-pair ratio cancels batch difficulty (subround count,
            # scan spans) and the median cancels scheduler spikes.
            speedup = float(np.median(
                np.asarray(per_path["split"]["batch_dts"])
                / np.asarray(per_path["fused"]["batch_dts"])
            ))
            if mode == "occ" and speedup < 0.9:
                # the ragged duplicate-rank gate: with already-satisfied
                # lanes masked out of each occ sub-pass, fusing runs at
                # parity-or-better with the 2-rounds-per-batch host split
                # (measured ~1.0x; the old full-width sub-pass penalty
                # this guards against costs well over 10%).  The floor
                # sits below the ±5% noise of a shared host; the committed
                # BENCH_ycsb_e.json speedup_x record is the ≥ 1.0x anchor
                # the --check gate compares against.
                raise RuntimeError(
                    f"occ fused {speedup:.2f}x vs split — full-width "
                    f"sub-pass padding regressed the fused occ path"
                )
            emit(
                f"ycsb_e.{mode}.fused_vs_split",
                0.0,
                f"rounds_fused={rf};rounds_split={rs};speedup={speedup:.2f}x",
                rounds_fused=rf,
                rounds_split=rs,
                speedup_x=speedup,
            )


def _recorder_overhead_ratio(shards, narrow=False, rounds=24):
    """Paired in-bench recorder-overhead estimate on the YCSB-A round mix
    (validated scan-reads + a hot-key writer block): one warmed forest,
    each iteration runs the SAME batch recorder-off then recorder-on, and
    the estimate is the median of the per-pair time ratios (on/off).
    Pairing cancels the host drift that makes sequential whole-leg A/Bs a
    coin flip — the same estimator ``_run_e_path`` uses for fused/split."""
    from repro.obs.recorder import Recorder

    key_range, batch, n_w = 4096, 256, 8
    forest = ABForest(
        n_shards=shards,
        cfg=TPU8._replace(capacity=4 * key_range),
        mode="elim",
        key_space=(0, key_range),
        narrow=narrow,
    )
    prefill_tree(forest, WorkloadConfig(key_range=key_range, seed=1))
    rng = np.random.default_rng(7)
    n_total = rounds + 8
    reads = zipf_keys(rng, batch * n_total, key_range, 0.5)
    writes = zipf_keys(rng, n_w * n_total, key_range, 1.2)
    wvals = rng.integers(0, 1 << 30, n_w * n_total).astype(np.int64)
    w_ops = np.concatenate(
        [np.full(n_w, OP_DELETE, np.int32), np.full(n_w, OP_INSERT, np.int32)]
    )

    def one(r):
        kr = reads[r * batch : (r + 1) * batch]
        wk = writes[r * n_w : (r + 1) * n_w]
        wv = wvals[r * n_w : (r + 1) * n_w]
        forest.scan_round(kr, kr + 1, cap=1)
        forest.apply_round(
            w_ops,
            np.concatenate([wk, wk]),
            np.concatenate([np.zeros(n_w, np.int64), wv]),
        )

    for r in range(8):  # warm every width the mix touches
        one(r)
    on_rec = Recorder(capacity=1_000_000)
    off_rec = Recorder(enabled=False)
    dts = {False: [], True: []}
    for r in range(8, n_total):
        # off-then-on with identical inputs: delete+insert of the same hot
        # keys nets to the same state, so the pair stays like-for-like
        for enabled in (False, True):
            forest.recorder = off_rec if not enabled else on_rec
            t0 = time.perf_counter()
            one(r)
            dts[enabled].append(time.perf_counter() - t0)
    return float(np.median(np.asarray(dts[True]) / np.asarray(dts[False])))


def _run_audit(path, workload="A", shards=4, quick=False, narrow=False):
    """``--audit PATH`` leg: re-run the workload's forest leg with a
    high-capacity flight recorder installed from construction (the witness
    replays from the EMPTY tree, so prefill must be on the ring too),
    export the audit log to ``path``, and replay it through the
    linearizability witness — a ``WitnessError`` fails the run non-zero.

    Workload A additionally gates the recorder's cost at ≤ 5%: the paired
    on/off estimator ``_recorder_overhead_ratio`` must report ≤ 1.05x."""
    global _RECORDER
    from repro.obs.recorder import Recorder
    from repro.obs.witness import check_file

    runner = run_a_forest if workload.upper() == "A" else run_e_forest
    k = max(shards, 1)
    rec = Recorder(capacity=1_000_000)
    _RECORDER = rec
    try:
        runner(k, quick=quick, narrow=narrow)
    finally:
        _RECORDER = None
    rec.export(path)
    rep = check_file(path)  # raises WitnessError on an illegal history
    gate = workload.upper() == "A"
    ratio = _recorder_overhead_ratio(k, narrow=narrow) if gate else None
    emit(
        f"ycsb_audit.{workload.lower()}.s{k}{'.narrow' if narrow else ''}",
        0.0,
        f"witness_rounds={rep.rounds};lanes={rep.lanes};"
        f"eliminated={rep.eliminated}"
        + (f";recorder_overhead_x={ratio:.3f}" if gate else ""),
        witness_rounds=rep.rounds,
        witness_lanes=rep.lanes,
        witness_eliminated=rep.eliminated,
        **({"recorder_overhead_x": ratio} if gate else {}),
    )
    print(f"# wrote audit: {path} — {rep.summary()}")
    if gate and ratio > 1.05:  # hard error: must survive python -O
        raise RuntimeError(
            f"recorder overhead gate: paired on/off round-time ratio "
            f"{ratio:.3f}x above the 1.05x ceiling"
        )


def main(quick=False, workload="A", scan_path="both", shards=0, narrow=False,
         trace=None, dist="zipf", repartition=False, audit=None):
    global _TRACER
    if trace:
        from repro.obs.tracer import Tracer

        _TRACER = Tracer()
    try:
        if workload.upper() == "A":
            if shards:
                _run_a_sharded(shards, quick=quick, narrow=narrow, dist=dist,
                               repartition=repartition)
            else:
                _run_a(quick=quick, narrow=narrow)
        elif workload.upper() == "E":
            if shards:
                _run_e_sharded(shards, quick=quick, narrow=narrow)
            else:
                _run_e(quick=quick, scan_path=scan_path, narrow=narrow)
        else:
            raise ValueError(f"unknown YCSB workload {workload!r} (A or E)")
        if audit:
            _run_audit(audit, workload=workload, shards=shards or 1,
                       quick=quick, narrow=narrow)
    finally:
        if trace:
            from repro.obs.trace_export import write_chrome_trace

            write_chrome_trace(trace, _TRACER)
            print(f"# wrote trace: {trace} ({len(_TRACER.events)} events)")
            _TRACER = None


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="A", choices=["A", "E", "a", "e"])
    ap.add_argument(
        "--scan-path",
        default="both",
        choices=["fused", "split", "both"],
        help="workload E execution: 'fused' (mixed rounds, the engine's "
        "default path), 'split' (legacy 2-rounds-per-batch baseline), or "
        "'both' (default) — runs fused then split and reports the A/B "
        "round-count comparison",
    )
    ap.add_argument(
        "--shards",
        type=int,
        default=0,
        choices=[0, 1, 2, 4, 8],
        help="run the workload on a key-partitioned ABForest with this many "
        "shards, A/B'd against the 1-shard forest baseline (0 = legacy "
        "single-tree path).  Workload A fails unless the sharded run has "
        "strictly fewer conflict retries per op than the baseline",
    )
    ap.add_argument(
        "--narrow",
        action="store_true",
        help="route the search path through the int32 device kernels "
        "(fused descent+probe, Pallas frontier compaction, kernel "
        "rank-select) — the device-resident A/B against the jnp refs",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="record a phase trace of the whole section (every holder the "
        "section builds) and write Chrome trace-event JSON to PATH — "
        "load it in Perfetto, or render a table with "
        "`python -m repro.obs.report PATH`",
    )
    ap.add_argument(
        "--audit",
        default=None,
        metavar="PATH",
        help="after the section, re-run the workload's forest leg with the "
        "flight recorder installed, write the audit log (JSONL) to PATH, "
        "and replay it through the linearizability witness — a witness "
        "violation (or, on workload A, recorder overhead above 5% ops/s) "
        "fails the run",
    )
    ap.add_argument(
        "--dist",
        default="zipf",
        choices=["zipf", "uniform"],
        help="workload A read-key distribution (sharded path only): 'zipf' "
        "(s=0.5, the skewed leg) or 'uniform' (the scaling leg — with "
        "--shards ≥ 4 the run fails unless sharded ops/s ≥ the 1-shard "
        "baseline)",
    )
    ap.add_argument(
        "--repartition",
        action="store_true",
        help="enable the forest's load-aware repartitioning (boundary "
        "rebalance / cold-shard merge driven by the hot-shard window)",
    )
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(
        quick=args.quick,
        workload=args.workload,
        scan_path=args.scan_path,
        shards=args.shards,
        narrow=args.narrow,
        trace=args.trace,
        dist=args.dist,
        repartition=args.repartition,
        audit=args.audit,
    )
