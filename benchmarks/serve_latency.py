"""Latency under load: p50/p99 ``ServeEngine.tick`` at N concurrent
sessions — volatile vs durable-serial vs durable-pipelined backends.

The serving engine's tick latency is the paper claim that matters at the
system level: the batched index rounds (admit lookups, prefix publishes,
session-range sweeps) ride the scheduler tick, so index-side regressions
surface here as tail latency.  Each leg submits N seeded sessions against
a 2-shard forest index (durable legs journal both indexes to a temp
directory) and reads p50/p99 from the engine's ``tick_latency_s``
histogram — compile time is excluded by warming the engine on a couple of
throwaway sessions and then swapping in a fresh registry.

The ``durable_pipelined`` leg is the PR-10 configuration: double-buffered
ticks (admit overlapped under the in-flight decode) + group commit
(``group_commit_every`` rounds per manifest rename, committed
asynchronously off the tick thread).  Two HARD gates ride the bench:

  * durable-pipelined p99 must be STRICTLY below durable-serial p99 at
    every load (else the pipeline bought nothing — RuntimeError);
  * the pipelined legs must report ``tick_overlap_frac`` > 0 (the admit
    work really ran under a decode in flight).

Gating (``run.py --check results/BENCH_serve_latency.json``):
``ops_per_s`` (ticks/s of measured wall time) is floor-gated; ``rounds``
(the measured tick count — deterministic for seeded prompts under greedy
decode; grouping is count-based, ``group_commit_max_wait_s`` is pinned
huge) is exact-gated.

CI smoke: ``python -m benchmarks.serve_latency --quick
--group-commit-every 4`` runs the same legs with the chosen group depth.
"""
from __future__ import annotations

import argparse
import tempfile

import numpy as np

from benchmarks.common import emit


def _run_leg(cfg, n_sessions: int, durable: bool, *, pipelined: bool = False,
             group_commit_every: int = 1, seed: int = 0):
    from repro.obs.metrics import MetricsRegistry
    from repro.serve import Request, ServeEngine

    ddir = tempfile.mkdtemp(prefix="bench_serve_lat_") if durable else None
    eng = ServeEngine(
        cfg,
        max_batch=4,
        s_max=64,
        n_pages=128,
        index_shards=2,
        index_durable_dir=ddir,
        pipelined=pipelined,
        group_commit_every=group_commit_every,
        # count-based boundaries only: wall-clock boundaries would make the
        # commit schedule (and the exact-gated counters) machine-dependent
        group_commit_max_wait_s=1e9,
    )
    rng = np.random.default_rng(seed)
    # warm: compile the decode step + round kernels outside the window
    for rid in range(2):
        eng.submit(
            Request(rid=rid, prompt=list(rng.integers(0, cfg.vocab, 8)), max_new=2)
        )
    eng.run_until_done(max_ticks=200)
    eng.metrics = MetricsRegistry()  # drop warm-up ticks from the histogram
    for rid in range(100, 100 + n_sessions):
        eng.submit(
            Request(rid=rid, prompt=list(rng.integers(0, cfg.vocab, 8)), max_new=4)
        )
    eng.run_until_done(max_ticks=2000)  # drains pending commit groups at exit
    hist = eng.metrics.histogram_summary("tick_latency_s")
    overlap = eng.metrics.histogram_summary("tick_overlap_frac")
    return hist, int(eng.metrics.value("ticks")), overlap


def main(quick: bool = False, group_commit_every: int = 4):
    from repro.configs import get_config
    from repro.models import reduced

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=1)
    loads = (2, 8) if quick else (2, 8, 16)
    legs = (
        ("volatile", dict(durable=False)),
        ("durable", dict(durable=True)),
        (
            "durable_pipelined",
            dict(durable=True, pipelined=True,
                 group_commit_every=group_commit_every),
        ),
    )
    for n in loads:
        p99 = {}
        for mode, kw in legs:
            hist, ticks, overlap = _run_leg(cfg, n, **kw)
            p99[mode] = hist["p99"]
            total_s = hist["sum"] or 1e-9
            extra = {}
            derived = f"p99_us={hist['p99'] * 1e6:.1f};ticks={ticks}"
            if kw.get("pipelined"):
                if not overlap["max"] > 0.0:
                    raise RuntimeError(
                        f"serve_latency.n{n}.{mode}: tick_overlap_frac never "
                        "positive — the pipelined tick overlapped nothing"
                    )
                extra["overlap_frac_p50"] = overlap["p50"]
                extra["overlap_frac_max"] = overlap["max"]
                derived += f";overlap_max={overlap['max']:.2f}"
            emit(
                f"serve_latency.n{n}.{mode}",
                hist["p50"] * 1e6,
                derived,
                ops_per_s=ticks / total_s,
                rounds=ticks,
                p50_us=hist["p50"] * 1e6,
                p99_us=hist["p99"] * 1e6,
                **extra,
            )
        if p99["durable_pipelined"] >= p99["durable"]:
            raise RuntimeError(
                f"serve_latency: pipelined durable p99 must beat serial "
                f"durable p99 at n={n} "
                f"(pipelined={p99['durable_pipelined'] * 1e6:.1f}us, "
                f"serial={p99['durable'] * 1e6:.1f}us)"
            )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--group-commit-every", type=int, default=4,
                    help="journal rounds per manifest rename on the "
                    "pipelined leg (CI smoke runs 4)")
    args = ap.parse_args()
    main(quick=args.quick, group_commit_every=args.group_commit_every)
