"""Latency under load: p50/p99 ``ServeEngine.tick`` at N concurrent
sessions, durable vs volatile index backends.

The serving engine's tick latency is the paper claim that matters at the
system level: the batched index rounds (admit lookups, prefix publishes,
session-range sweeps) ride the scheduler tick, so index-side regressions
surface here as tail latency.  Each leg submits N seeded sessions against
a 2-shard forest index (durable legs journal both indexes to a temp
directory) and reads p50/p99 from the engine's ``tick_latency_s``
histogram — compile time is excluded by warming the engine on a couple of
throwaway sessions and then swapping in a fresh registry.

Gating (``run.py --check results/BENCH_serve_latency.json``):
``ops_per_s`` (ticks/s of measured wall time) is floor-gated; ``rounds``
(the measured tick count — deterministic for seeded prompts under greedy
decode) is exact-gated.
"""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import emit


def _run_leg(cfg, n_sessions: int, durable: bool, *, seed: int = 0):
    from repro.obs.metrics import MetricsRegistry
    from repro.serve import Request, ServeEngine

    ddir = tempfile.mkdtemp(prefix="bench_serve_lat_") if durable else None
    eng = ServeEngine(
        cfg,
        max_batch=4,
        s_max=64,
        n_pages=128,
        index_shards=2,
        index_durable_dir=ddir,
    )
    rng = np.random.default_rng(seed)
    # warm: compile the decode step + round kernels outside the window
    for rid in range(2):
        eng.submit(
            Request(rid=rid, prompt=list(rng.integers(0, cfg.vocab, 8)), max_new=2)
        )
    eng.run_until_done(max_ticks=200)
    eng.metrics = MetricsRegistry()  # drop warm-up ticks from the histogram
    for rid in range(100, 100 + n_sessions):
        eng.submit(
            Request(rid=rid, prompt=list(rng.integers(0, cfg.vocab, 8)), max_new=4)
        )
    eng.run_until_done(max_ticks=2000)
    hist = eng.metrics.histogram_summary("tick_latency_s")
    return hist, int(eng.metrics.value("ticks"))


def main(quick: bool = False):
    from repro.configs import get_config
    from repro.models import reduced

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=1)
    loads = (2, 8) if quick else (2, 8, 16)
    for n in loads:
        for durable in (False, True):
            hist, ticks = _run_leg(cfg, n, durable)
            mode = "durable" if durable else "volatile"
            total_s = hist["sum"] or 1e-9
            emit(
                f"serve_latency.n{n}.{mode}",
                hist["p50"] * 1e6,
                f"p99_us={hist['p99'] * 1e6:.1f};ticks={ticks}",
                ops_per_s=ticks / total_s,
                rounds=ticks,
                p50_us=hist["p50"] * 1e6,
                p99_us=hist["p99"] * 1e6,
            )


if __name__ == "__main__":
    main()
