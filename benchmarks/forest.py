"""Sharded-forest scaling section: ops/s and conflict retries per shard
count, for the perf trajectory (``results/BENCH_forest.json``).

Sweeps ``ABForest`` shard counts over three index workloads:

  forest.a.sK — uniform YCSB-A with validated optimistic point-reads
    under a concurrent writer replica (see ``benchmarks/ycsb.run_a_forest``):
    per-shard lane groups stay even, so this is the ragged-batching
    scaling leg.  The run fails unless 4 shards beat 1 shard on BOTH
    retries/op (strictly) and ops/s (sharding must pay in wall-clock),
    and unless s4 retries/op ≤ 0.54.  s4 runs with load-aware
    repartitioning enabled and must report zero repartitions: uniform
    traffic never trips the hot-shard window (the skew detector's
    false-positive gate).
  forest.a.zipf.sK — the skewed leg (Zipf-0.5 read keys) with load-aware
    repartitioning on: the hot-shard window must FIRE at 4 shards (the
    boundary moves toward the hot prefix) and stay silent at 1 shard.
  forest.e.sK — YCSB-E fused mixed rounds (cross-shard range lanes split
    at shard boundaries, one vmapped round per batch).

``python benchmarks/forest.py [--quick] [--trace PATH] [--audit PATH]``

``--trace PATH`` installs a phase ``Tracer`` on every forest the sweep
builds (via ``benchmarks.ycsb._instrument``) and writes Chrome
trace-event JSON to PATH.  ``--audit PATH`` appends the flight-recorder
leg: a fresh 4-shard YCSB-A run with the recorder installed, audit log
written to PATH and replayed through the linearizability witness.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/forest.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

import benchmarks.ycsb as _ycsb
from benchmarks.common import emit
from benchmarks.ycsb import run_a_forest, run_e_forest


def main(quick=False, trace=None, audit=None):
    if trace:
        from repro.obs.tracer import Tracer

        _ycsb._TRACER = Tracer()
    try:
        _sections(quick=quick)
        if audit:
            _ycsb._run_audit(audit, workload="A", shards=4, quick=quick)
    finally:
        if trace:
            from repro.obs.trace_export import write_chrome_trace

            write_chrome_trace(trace, _ycsb._TRACER)
            print(f"# wrote trace: {trace} ({len(_ycsb._TRACER.events)} events)")
            _ycsb._TRACER = None


def _sections(quick=False):
    sweep = (1, 2, 4) if quick else (1, 2, 4, 8)

    # --- uniform scaling leg: sharding must pay in wall-clock ----------
    per_u = {}
    for k in sweep:
        m = run_a_forest(k, quick=quick, dist="uniform", repartition=(k == 4))
        per_u[k] = m
        emit(
            f"forest.a.s{k}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};conflict_retries={m['conflict_retries']};"
            f"retries/op={m['retries_per_op']:.3f};repartitions={m['repartitions']}",
            **m,
        )
    if 4 in per_u:  # hard errors, not asserts: must survive python -O
        if per_u[4]["retries_per_op"] >= per_u[1]["retries_per_op"]:
            raise RuntimeError(
                f"forest(4) retries/op {per_u[4]['retries_per_op']:.3f} not "
                f"strictly below 1-shard baseline "
                f"{per_u[1]['retries_per_op']:.3f}"
            )
        if per_u[4]["retries_per_op"] > 0.54:
            raise RuntimeError(
                f"forest(4) retries/op {per_u[4]['retries_per_op']:.3f} "
                f"above the 0.54 ceiling"
            )
        if per_u[4]["ops_per_s"] < per_u[1]["ops_per_s"]:
            raise RuntimeError(
                f"forest(4) uniform ops/s {per_u[4]['ops_per_s']:.0f} below "
                f"1-shard baseline {per_u[1]['ops_per_s']:.0f} — sharding "
                f"lost wall-clock"
            )
        if per_u[4]["repartitions"] != 0:
            raise RuntimeError(
                f"forest(4) fired {per_u[4]['repartitions']} repartitions "
                f"under uniform traffic — the hot-shard window must not "
                f"trip without skew"
            )
    emit(
        "forest.a.scaling",
        0.0,
        ";".join(f"s{k}={per_u[k]['retries_per_op']:.3f}" for k in sweep),
        **{f"retries_per_op_s{k}": per_u[k]["retries_per_op"] for k in sweep},
        **{f"ops_per_s_s{k}": per_u[k]["ops_per_s"] for k in sweep},
    )

    # --- zipf skew leg: the load-aware repartition must fire -----------
    per_z = {}
    for k in (1, 2, 4):
        m = run_a_forest(k, quick=quick, dist="zipf", repartition=True)
        per_z[k] = m
        emit(
            f"forest.a.zipf.s{k}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};conflict_retries={m['conflict_retries']};"
            f"retries/op={m['retries_per_op']:.3f};repartitions={m['repartitions']}",
            **m,
        )
    if per_z[1]["repartitions"] != 0:
        raise RuntimeError(
            f"forest(1) fired {per_z[1]['repartitions']} repartitions — "
            f"one shard has no partition to move"
        )
    if per_z[4]["repartitions"] < 1:
        raise RuntimeError(
            "forest(4) fired no repartition under Zipf reads — the "
            "hot-shard window never tripped"
        )
    emit(
        "forest.a.zipf.summary",
        0.0,
        ";".join(
            f"s{k}={per_z[k]['retries_per_op']:.3f}/r{per_z[k]['repartitions']}"
            for k in (1, 2, 4)
        ),
        **{f"retries_per_op_s{k}": per_z[k]["retries_per_op"] for k in (1, 2, 4)},
        **{f"repartitions_s{k}": per_z[k]["repartitions"] for k in (1, 2, 4)},
    )

    # --- YCSB-E fused mixed rounds -------------------------------------
    for k in sweep:
        m = run_e_forest(k, quick=quick)
        emit(
            f"forest.e.s{k}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
            f"conflict_retries={m['conflict_retries']}",
            **m,
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="record a phase trace of the whole sweep (every forest it "
        "builds) and write Chrome trace-event JSON to PATH — render a "
        "table with `python -m repro.obs.report PATH`",
    )
    ap.add_argument(
        "--audit",
        default=None,
        metavar="PATH",
        help="append the flight-recorder leg: a 4-shard YCSB-A run with "
        "the recorder installed, audit log written to PATH and replayed "
        "through the linearizability witness (non-zero exit on violation)",
    )
    args = ap.parse_args()
    main(quick=args.quick, trace=args.trace, audit=args.audit)
