"""Sharded-forest scaling section: ops/s and conflict retries per shard
count, for the perf trajectory (``results/BENCH_forest.json``).

Sweeps ``ABForest`` shard counts over two index workloads:

  forest.a.sK — YCSB-A with validated optimistic point-reads under a
    concurrent writer replica (see ``benchmarks/ycsb.run_a_forest``):
    per-shard validation confines each hot write's conflict window to its
    own shard, so retried lanes per op must FALL as shards grow — the run
    fails if 4 shards do not beat 1 shard strictly.
  forest.e.sK — YCSB-E fused mixed rounds (cross-shard range lanes split
    at shard boundaries, one vmapped round per batch).

``python benchmarks/forest.py [--quick]``
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/forest.py` (not -m)
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [_ROOT, os.path.join(_ROOT, "src")]

from benchmarks.common import emit
from benchmarks.ycsb import run_a_forest, run_e_forest


def main(quick=False):
    sweep = (1, 2, 4) if quick else (1, 2, 4, 8)
    per_a = {}
    for k in sweep:
        m = run_a_forest(k, quick=quick)
        per_a[k] = m
        emit(
            f"forest.a.s{k}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};conflict_retries={m['conflict_retries']};"
            f"retries/op={m['retries_per_op']:.3f}",
            **m,
        )
    if 4 in per_a and per_a[4]["retries_per_op"] >= per_a[1]["retries_per_op"]:
        raise RuntimeError(  # hard error, not assert: must survive python -O
            f"forest(4) retries/op {per_a[4]['retries_per_op']:.3f} not "
            f"strictly below 1-shard baseline {per_a[1]['retries_per_op']:.3f}"
        )
    emit(
        "forest.a.scaling",
        0.0,
        ";".join(
            f"s{k}={per_a[k]['retries_per_op']:.3f}" for k in sweep
        ),
        **{f"retries_per_op_s{k}": per_a[k]["retries_per_op"] for k in sweep},
    )
    for k in sweep:
        m = run_e_forest(k, quick=quick)
        emit(
            f"forest.e.s{k}",
            m["us_per_op"],
            f"tx/s={m['ops_per_s']:.0f};items/s={m['items_per_s']:.0f};"
            f"conflict_retries={m['conflict_retries']}",
            **m,
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
