"""SetBench-style microbenchmark (paper Figs 12–15 analog).

Grid: {uniform, zipf-1.0} × update rate {5%, 50%, 100%} × key range,
comparing Elim-ABtree vs OCC-ABtree (and a Python-dict control for
sanity).  Throughput is ops/s over batched rounds; `derived` reports the
paper's headline effect: the Elim/OCC speedup and the physical-write
collapse under skew.

CPU note: batch-parallel rounds play the role of hardware threads; the
relative Elim/OCC ratio is the reproduced claim (paper: up to 2.5× on
Zipf update-heavy), absolute ops/µs are CPU-backend numbers.
"""
from __future__ import annotations

import time

from repro.configs.abtree import TPU8
from repro.core import ABTree, DictOracle
from repro.data.workloads import WorkloadConfig, op_stream, prefill_tree

from benchmarks.common import emit


def run_case(dist, update_frac, key_range=4096, batch=512, rounds=32, zipf_s=1.0, warm=10):
    results = {}
    for mode in ("elim", "occ"):
        cfg = WorkloadConfig(
            key_range=key_range,
            update_frac=update_frac,
            dist=dist,
            zipf_s=zipf_s,
            batch=batch,
            seed=7,
        )
        tree = ABTree(TPU8._replace(capacity=4 * key_range), mode=mode)
        prefill_tree(tree, cfg)
        stream = list(op_stream(cfg, rounds))
        # warmup: cover split/merge/retry phase compiles (steady-state is
        # what the paper's 10-second runs measure)
        for r in stream[:warm]:
            tree.apply_round(*r)
        t0 = time.perf_counter()
        for ops, keys, vals in stream[warm:]:
            tree.apply_round(ops, keys, vals)
        dt = time.perf_counter() - t0
        n_ops = batch * (rounds - warm)
        results[mode] = {
            "ops_per_s": n_ops / dt,
            "us_per_op": dt / n_ops * 1e6,
            **tree.stats(),
        }
    return results


def main(quick=False):
    grid = [
        ("uniform", 0.05),
        ("uniform", 0.5),
        ("uniform", 1.0),
        ("zipf", 0.05),
        ("zipf", 0.5),
        ("zipf", 1.0),
    ]
    if quick:
        grid = [("uniform", 1.0), ("zipf", 1.0)]
    for dist, uf in grid:
        r = run_case(dist, uf)
        speedup = r["elim"]["ops_per_s"] / r["occ"]["ops_per_s"]
        writes_ratio = r["occ"]["slot_writes"] / max(r["elim"]["slot_writes"], 1)
        emit(
            f"microbench.{dist}.upd{int(uf*100)}.elim",
            r["elim"]["us_per_op"],
            f"ops/s={r['elim']['ops_per_s']:.0f};eliminated={r['elim']['eliminated']}",
        )
        emit(
            f"microbench.{dist}.upd{int(uf*100)}.occ",
            r["occ"]["us_per_op"],
            f"ops/s={r['occ']['ops_per_s']:.0f};subrounds={r['occ']['subrounds']}",
        )
        emit(
            f"microbench.{dist}.upd{int(uf*100)}.ratio",
            0.0,
            f"elim_vs_occ_speedup={speedup:.2f}x;write_reduction={writes_ratio:.2f}x",
        )


if __name__ == "__main__":
    main()
