"""Kernel microbenchmarks: Pallas (interpret on CPU — structural check)
vs the pure-jnp oracles (XLA-compiled, the actual CPU fast path).

The ``search_phase.hlo`` records report host-visible XLA sort/gather op
counts lowered from the round engine's search and scan-descent phases —
the structural metric the device-resident search path (kernels/
tree_descend) is buying down: zero sorts in the scan descent on every
path, and the narrow point-op search collapsing to one fused kernel."""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_ref
from repro.kernels.flash_attention import attention_ref
from repro.kernels.leaf_probe import leaf_probe_pallas, leaf_probe_ref

from benchmarks.common import emit, timeit


def _hlo_op_counts():
    """Lower the search/scan phases both ways and count sort/gather ops
    (the reusable audit in :mod:`repro.obs.hlo_audit`; the no-sort trace
    tests assert on the same programs)."""
    from repro.obs.hlo_audit import audit_search_phases

    for name, counts in audit_search_phases().items():
        sorts = counts["stablehlo.sort"]
        gathers = counts["stablehlo.gather"]
        emit(
            f"kernel.search_phase.hlo.{name}", 0.0,
            f"sorts={sorts};gathers={gathers}",
            hlo_sorts=sorts, hlo_gathers=gathers,
        )


def main(quick=False):
    rng = np.random.default_rng(0)

    # fused descent + probe (tree_descend): jnp ref path vs Pallas interpret
    from repro.core import ABTree, OP_INSERT, TreeConfig
    from repro.core.rounds import _search_leaves

    t = ABTree(TreeConfig(capacity=4096, b=8, a=2, max_height=16))
    tkeys = rng.choice(1 << 30, size=1500, replace=False).astype(np.int64)
    t.apply_round(np.full(1500, OP_INSERT, np.int32), tkeys, tkeys)
    q = jnp.asarray(rng.choice(tkeys, 1024).astype(np.int64))
    for narrow, tag in ((False, "ref_xla"), (True, "pallas_interp")):
        fn = jax.jit(
            functools.partial(_search_leaves, narrow=narrow), static_argnums=(1,)
        )
        jax.block_until_ready(fn(t.state, t.cfg, q))
        dt = timeit(lambda: jax.block_until_ready(fn(t.state, t.cfg, q)))
        emit(f"kernel.tree_descend.{tag}", dt * 1e6, "batch=1024;pool=4096")

    # segmented frontier compaction: argsort oracle vs scatter jnp vs Pallas
    from repro.kernels.tree_descend import (
        frontier_compact,
        frontier_compact_ref,
    )

    bsz, m, f = 64, 288, 32
    cand = jnp.asarray(rng.integers(0, 4096, (bsz, m)), jnp.int32)
    valid = jnp.asarray(rng.random((bsz, m)) < 0.15)
    ref = jax.jit(lambda c, v: frontier_compact_ref(c, v, f, scratch=0))
    jnp_path = jax.jit(lambda c, v: frontier_compact(c, v, f, scratch=0))
    jax.block_until_ready(ref(cand, valid))
    jax.block_until_ready(jnp_path(cand, valid))
    dt = timeit(lambda: jax.block_until_ready(ref(cand, valid)))
    emit("kernel.frontier_compact.argsort_ref", dt * 1e6, f"m={m};f={f}")
    dt = timeit(lambda: jax.block_until_ready(jnp_path(cand, valid)))
    emit("kernel.frontier_compact.cumsum_xla", dt * 1e6, f"m={m};f={f}")
    pallas_path = lambda: jax.block_until_ready(
        frontier_compact(cand, valid, f, scratch=0, use_pallas=True)
    )
    pallas_path()  # warm: trace/lower outside the timed region
    dt = timeit(pallas_path)  # iters=3: single-shot interpret timings are noisy
    emit("kernel.frontier_compact.pallas_interp", dt * 1e6, "interpret-mode")

    # rank-select: pairwise vs tiled at a large frontier
    from repro.kernels.range_scan.kernel import range_scan_pallas

    n = 512 if quick else 1024
    sk = np.stack([rng.choice(10**7, size=n, replace=False) for _ in range(8)])
    sk = sk.astype(np.int32)
    sv = rng.integers(0, 10**6, (8, n)).astype(np.int32)
    slo = np.zeros(8, np.int32)
    shi = np.full(8, 10**7, np.int32)
    a = (jnp.asarray(sk), jnp.asarray(sv), jnp.asarray(slo), jnp.asarray(shi))
    for tile, tag in ((-1, "pairwise"), (128, "tiled128")):
        run = lambda: jax.block_until_ready(
            range_scan_pallas(*a, cap=128, tile_n=tile)
        )
        run()  # warm: trace/lower outside the timed region
        dt = timeit(run)  # iters=3: single-shot interpret timings are noisy
        emit(f"kernel.rank_select.{tag}", dt * 1e6, f"n={n};cap=128")

    _hlo_op_counts()

    # leaf probe
    bsz, b = 4096, 8
    keys = jnp.asarray(rng.integers(0, 1 << 30, (bsz, b)), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 1 << 30, (bsz, b)), jnp.int32)
    qs = keys[:, 3]
    ref = jax.jit(leaf_probe_ref)
    jax.block_until_ready(ref(keys, vals, qs))
    t = timeit(lambda: jax.block_until_ready(ref(keys, vals, qs)))
    emit("kernel.leaf_probe.ref_xla", t * 1e6, f"batch={bsz}")
    t = timeit(
        lambda: jax.block_until_ready(leaf_probe_pallas(keys, vals, qs, interpret=True)),
    )
    emit("kernel.leaf_probe.pallas_interp", t * 1e6, "interpret-mode (structural)")

    # attention (train shape, small)
    bq, h, s, d = 1, 8, 512 if quick else 1024, 64
    q = jnp.asarray(rng.standard_normal((bq, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((bq, h // 4, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((bq, h // 4, s, d)), jnp.bfloat16)
    ref_attn = jax.jit(lambda a, b_, c: attention_ref(a, b_, c, causal=True))
    jax.block_until_ready(ref_attn(q, k, v))
    t = timeit(lambda: jax.block_until_ready(ref_attn(q, k, v)))
    emit("kernel.flash_attention.ref_xla", t * 1e6, f"s={s},gqa4")

    # decode attention
    bd, hd, kh, sd, dd = 8, 16, 4, 8192, 64
    qd = jnp.asarray(rng.standard_normal((bd, hd, dd)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((bd, kh, sd, dd)), jnp.bfloat16)
    vd = jnp.asarray(rng.standard_normal((bd, kh, sd, dd)), jnp.bfloat16)
    refd = jax.jit(lambda a, b_, c: decode_attention_ref(a, b_, c, sd))
    jax.block_until_ready(refd(qd, kd, vd))
    t = timeit(lambda: jax.block_until_ready(refd(qd, kd, vd)))
    kv_bytes = bd * kh * sd * dd * 2 * 2
    emit(
        "kernel.decode_attention.ref_xla", t * 1e6,
        f"kv_bytes={kv_bytes};GBps={kv_bytes/t/1e9:.1f}",
    )


if __name__ == "__main__":
    main()
