"""Kernel microbenchmarks: Pallas (interpret on CPU — structural check)
vs the pure-jnp oracles (XLA-compiled, the actual CPU fast path)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_ref
from repro.kernels.flash_attention import attention_ref
from repro.kernels.leaf_probe import leaf_probe_pallas, leaf_probe_ref

from benchmarks.common import emit, timeit


def main(quick=False):
    rng = np.random.default_rng(0)

    # leaf probe
    bsz, b = 4096, 8
    keys = jnp.asarray(rng.integers(0, 1 << 30, (bsz, b)), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 1 << 30, (bsz, b)), jnp.int32)
    qs = keys[:, 3]
    ref = jax.jit(leaf_probe_ref)
    jax.block_until_ready(ref(keys, vals, qs))
    t = timeit(lambda: jax.block_until_ready(ref(keys, vals, qs)))
    emit("kernel.leaf_probe.ref_xla", t * 1e6, f"batch={bsz}")
    t = timeit(
        lambda: jax.block_until_ready(leaf_probe_pallas(keys, vals, qs, interpret=True)),
        iters=1,
    )
    emit("kernel.leaf_probe.pallas_interp", t * 1e6, "interpret-mode (structural)")

    # attention (train shape, small)
    bq, h, s, d = 1, 8, 512 if quick else 1024, 64
    q = jnp.asarray(rng.standard_normal((bq, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((bq, h // 4, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((bq, h // 4, s, d)), jnp.bfloat16)
    ref_attn = jax.jit(lambda a, b_, c: attention_ref(a, b_, c, causal=True))
    jax.block_until_ready(ref_attn(q, k, v))
    t = timeit(lambda: jax.block_until_ready(ref_attn(q, k, v)))
    emit("kernel.flash_attention.ref_xla", t * 1e6, f"s={s},gqa4")

    # decode attention
    bd, hd, kh, sd, dd = 8, 16, 4, 8192, 64
    qd = jnp.asarray(rng.standard_normal((bd, hd, dd)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((bd, kh, sd, dd)), jnp.bfloat16)
    vd = jnp.asarray(rng.standard_normal((bd, kh, sd, dd)), jnp.bfloat16)
    refd = jax.jit(lambda a, b_, c: decode_attention_ref(a, b_, c, sd))
    jax.block_until_ready(refd(qd, kd, vd))
    t = timeit(lambda: jax.block_until_ready(refd(qd, kd, vd)))
    kv_bytes = bd * kh * sd * dd * 2 * 2
    emit(
        "kernel.decode_attention.ref_xla", t * 1e6,
        f"kv_bytes={kv_bytes};GBps={kv_bytes/t/1e9:.1f}",
    )


if __name__ == "__main__":
    main()
