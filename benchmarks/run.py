"""Benchmark aggregator — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes each section's records
to ``results/BENCH_<section>.json`` (machine-readable: ops/s, round
counts, conflict retries, …) so the perf trajectory accumulates.

  microbench    — Figs 12–15 (uniform/zipf × update-rate grid, Elim vs OCC)
  ycsb          — Fig 16 (YCSB-A analog)
  ycsb_e        — YCSB-E analog (95% range scans / 5% inserts)
  forest        — ABForest shard-count sweep (ops/s + conflict retries
                  per shard count, YCSB A/E; 4 shards must strictly beat
                  1 shard on retries/op)
  range_scan    — scan_round throughput + kernels/range_scan hot loop
  persistence   — Table 1 (durable overhead + flush traffic + GC churn)
  fault_soak    — crash-under-load soak: YCSB-A through a firing
                  FaultPlan (EIO / ENOSPC / torn / rename / kill ×
                  seeds), recovery witnessed against the committed
                  prefix + degraded-serving gate (tick never raises)
  serve_latency — p50/p99 ServeEngine.tick at N sessions, durable vs
                  volatile index backends (latency under load)
  elim_rate     — §4 mechanism (elimination fraction vs skew)
  embed_elim    — framework integration (sparse-update write collapse)
  kernels       — per-kernel timings
  roofline      — §Roofline terms from results/dryrun.json (if present)

``python -m benchmarks.run [--quick] [--only SECTION]
[--check BASELINE.json ...] [--check-tol T]``

``--check`` turns the run into a regression gate: each given committed
baseline (a prior ``results/BENCH_<section>.json``) is loaded *before* the
run overwrites it, the matching section's fresh records are compared
record-by-record — ``ops_per_s``-style throughput metrics must reach
``(1 - T)`` of the baseline and round counts must match exactly (rounds are
deterministic for a given workload + flags) — and the process exits
non-zero on any regression.  Compare runs with the same ``--quick`` setting
as the baseline; the default tolerance is generous because the gate is a
cliff detector, not a microbenchmark.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import traceback

# metrics compared under the relative tolerance (higher is better);
# integral metrics compared exactly (deterministic for a seeded workload:
# round counts, and the durable layer's commit/fsync counts).
_THROUGHPUT_KEYS = ("ops_per_s", "items_per_s", "speedup_x")
_EXACT_KEYS = ("rounds", "rounds_fused", "rounds_split", "commits", "fsyncs")


def check_against_baseline(records, baseline: dict, tol: float):
    """Compare one section's fresh ``records`` against a loaded baseline
    dict (``{"workload": ..., "results": [...]}``).  Returns a list of
    failure strings (empty = pass)."""
    fresh = {r["name"]: r for r in records}
    failures = []
    compared = 0
    for base in baseline.get("results", []):
        got = fresh.get(base["name"])
        if got is None:
            failures.append(f"{base['name']}: missing from fresh run")
            continue
        for k in _THROUGHPUT_KEYS:
            if k in base and k in got:
                compared += 1
                floor = (1.0 - tol) * float(base[k])
                if float(got[k]) < floor:
                    failures.append(
                        f"{base['name']}.{k}: {float(got[k]):.1f} < "
                        f"{floor:.1f} (= (1-{tol})·baseline {float(base[k]):.1f})"
                    )
        for k in _EXACT_KEYS:
            if k in base and k in got:
                compared += 1
                if int(got[k]) != int(base[k]):
                    failures.append(
                        f"{base['name']}.{k}: {int(got[k])} != baseline {int(base[k])}"
                    )
    if compared == 0:
        failures.append(
            f"baseline {baseline.get('workload')!r}: nothing comparable "
            f"(section not run, or records renamed)"
        )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--check",
        nargs="+",
        default=None,
        metavar="BASELINE.json",
        help="committed BENCH_<section>.json files to gate the fresh run "
        "against (loaded before the run overwrites them)",
    )
    ap.add_argument(
        "--check-tol",
        type=float,
        default=0.6,
        help="allowed fractional throughput drop vs baseline (default 0.6: "
        "fresh ops/s must reach 40%% of baseline — a cliff detector that "
        "tolerates machine variance; tighten locally for perf work)",
    )
    args = ap.parse_args()

    baselines = []
    for path in args.check or []:
        if not os.path.exists(path):
            sys.exit(
                f"--check baseline {path!r} not found — baselines must be "
                f"committed (results/ is gitignored: use `git add -f`)"
            )
        with open(path) as f:  # load BEFORE the run overwrites results/
            baselines.append((path, json.load(f)))

    from benchmarks import (
        elim_rate,
        embed_elim,
        fault_soak,
        forest,
        kernels_bench,
        microbench,
        persistence,
        range_scan,
        serve_latency,
        ycsb,
    )

    sections = {
        "microbench": microbench.main,
        "ycsb": ycsb.main,
        "ycsb_e": functools.partial(ycsb.main, workload="E"),
        "forest": forest.main,
        "range_scan": range_scan.main,
        "persistence": persistence.main,
        "fault_soak": fault_soak.main,
        "serve_latency": serve_latency.main,
        "elim_rate": elim_rate.main,
        "embed_elim": embed_elim.main,
        "kernels": kernels_bench.main,
    }
    from benchmarks.common import drain_records, write_bench_json

    print("name,us_per_call,derived")
    section_records = {}
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}.ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
        records = drain_records()
        if records:
            section_records[name] = records
            path = write_bench_json(name, records)
            print(f"# wrote {path}")

    failures = []
    for path, baseline in baselines:
        section = baseline.get("workload")
        records = section_records.get(section, [])
        for msg in check_against_baseline(records, baseline, args.check_tol):
            failures.append(f"{path}: {msg}")
    if args.check:
        if failures:
            # restore the committed baselines the run just overwrote, so a
            # re-run still compares against the ORIGINAL numbers instead of
            # silently ratcheting the floor down to the regressed run.
            for path, baseline in baselines:
                with open(path, "w") as f:
                    json.dump(baseline, f, indent=2)
                    f.write("\n")
            print("# --- check: REGRESSION (baseline files restored) ---")
            for msg in failures:
                print(f"# CHECK FAIL {msg}")
            sys.exit(1)
        print(f"# --- check: OK ({len(baselines)} baseline(s), tol={args.check_tol}) ---")

    # roofline summary (from the dry-run artifact, if present)
    if args.only in (None, "roofline"):
        path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")
        if os.path.exists(path):
            print("# --- roofline ---")
            from repro.analysis.report import summary

            with open(path) as f:
                res = json.load(f)
            s = summary(res)
            for cid, t in sorted(s.items()):
                print(
                    f"roofline.{cid.replace('|','.')},0.0,"
                    f"dominant={t['dominant']};frac={t['roofline_fraction']:.3f};"
                    f"tc={t['t_compute_s']:.3e};tm={t['t_memory_s']:.3e};tl={t['t_collective_s']:.3e}"
                )


if __name__ == "__main__":
    main()
