"""Benchmark aggregator — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes each section's records
to ``results/BENCH_<section>.json`` (machine-readable: ops/s, round
counts, conflict retries, …) so the perf trajectory accumulates.

  microbench    — Figs 12–15 (uniform/zipf × update-rate grid, Elim vs OCC)
  ycsb          — Fig 16 (YCSB-A analog)
  ycsb_e        — YCSB-E analog (95% range scans / 5% inserts)
  forest        — ABForest shard-count sweep (ops/s + conflict retries
                  per shard count, YCSB A/E; 4 shards must strictly beat
                  1 shard on retries/op)
  range_scan    — scan_round throughput + kernels/range_scan hot loop
  persistence   — Table 1 (durable overhead + flush traffic)
  elim_rate     — §4 mechanism (elimination fraction vs skew)
  embed_elim    — framework integration (sparse-update write collapse)
  kernels       — per-kernel timings
  roofline      — §Roofline terms from results/dryrun.json (if present)

``python -m benchmarks.run [--quick] [--only SECTION]``
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        elim_rate,
        embed_elim,
        forest,
        kernels_bench,
        microbench,
        persistence,
        range_scan,
        ycsb,
    )

    sections = {
        "microbench": microbench.main,
        "ycsb": ycsb.main,
        "ycsb_e": functools.partial(ycsb.main, workload="E"),
        "forest": forest.main,
        "range_scan": range_scan.main,
        "persistence": persistence.main,
        "elim_rate": elim_rate.main,
        "embed_elim": embed_elim.main,
        "kernels": kernels_bench.main,
    }
    from benchmarks.common import drain_records, write_bench_json

    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}.ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
        records = drain_records()
        if records:
            path = write_bench_json(name, records)
            print(f"# wrote {path}")

    # roofline summary (from the dry-run artifact, if present)
    if args.only in (None, "roofline"):
        path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")
        if os.path.exists(path):
            print("# --- roofline ---")
            import json

            from repro.analysis.report import summary

            with open(path) as f:
                res = json.load(f)
            s = summary(res)
            for cid, t in sorted(s.items()):
                print(
                    f"roofline.{cid.replace('|','.')},0.0,"
                    f"dominant={t['dominant']};frac={t['roofline_fraction']:.3f};"
                    f"tc={t['t_compute_s']:.3e};tm={t['t_memory_s']:.3e};tl={t['t_collective_s']:.3e}"
                )


if __name__ == "__main__":
    main()
