"""Unified round engine: one composable phase pipeline behind every round.

A *round* is a batch of mutually concurrent dictionary operations.  This
module owns the execution of rounds: the public ``ABTree`` entry points
(``apply_round``, ``scan_round``, ``scan_delete_round``) are thin wrappers
that build a :class:`RoundPlan` (lane classification) and hand it to
:func:`execute_plan`, which sequences the ordered phase pipeline

    scan → search/combine → apply → retry → rebalance

Phase ↔ paper terminology (Elimination (a,b)-trees, §3–§4):

  ``scan``            the optimistic-reader discipline of ``searchLeaf``
                      generalized to a leaf frontier: gather against a state
                      snapshot, record every node read, re-validate versions
                      (retry on conflict).  Runs FIRST, so every scan in a
                      round linearizes *before* the round's net writes —
                      range lanes observe the pre-round dictionary.
  ``search/combine``  the paper's ``search`` (root-to-leaf descent + unsorted
                      leaf probe) followed by the publishing-elimination
                      combine (§4): all ops on one key fold to ≤ 1 net
                      physical write; eliminated ops compute their return
                      values from the published ElimRecord.
  ``apply``           the collapsed net writes — the paper's leaf slot
                      write + version bump (+2, odd intermediate stamped on
                      the ElimRecord, §4.1).
  ``retry``           deferred inserts (leaf full) re-descend after the
                      splits their overflow triggered — the batched analog
                      of a thread retrying after helping a split.
  ``rebalance``       relaxed-rebalancing waves of the Larsen–Fagerberg
                      sub-operations (split / merge / distribute), each wave
                      touching ≤ 1 violating child per parent (§3's
                      fixTagged / fixUnderfull chains, batched).

Lane classes (``RoundPlan``):

  * **elim-combine / occ** — point ops (find/insert/delete).  In ``elim``
    mode the whole batch runs one combine; in ``occ`` mode duplicate keys
    force sub-rounds (duplicate-rank r executes in sub-round r).
  * **range** — OP_RANGE lanes ``[lo, lo+span)`` (key = lo, val = span),
    served by the scan phase via ``kernels/range_scan``.  Mixed batches need
    no host-side splitting: one ``apply_round`` call executes every lane and
    returns per-lane results in one ``RoundOutput`` (scan rows aligned to
    the batch; non-range rows scan the empty interval).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elimination as elim
from repro.core.abtree import (
    EMPTY,
    INT_MAX,
    KEY_DTYPE,
    NOTFOUND,
    OP_DELETE,
    OP_INSERT,
    OP_NOP,
    OP_RANGE,
    RoundOutput,
    ScanConflictError,
    ScanOutput,
    TreeConfig,
    TreeState,
    VAL_DTYPE,
    apply_net_ops,
    frontier_expand,
    shrink_root,
    split_wave,
    underfull_wave,
    _segment_starts,
)
from repro.kernels.range_scan.ops import range_scan
from repro.kernels.tree_descend.ops import descend_probe

# ----------------------------------------------------------------------------
# Round plans: lane classification
# ----------------------------------------------------------------------------


class RoundPlan(NamedTuple):
    """A classified round: which lanes take which pipeline, plus the derived
    per-lane scan intervals.  Built host-side once per round by
    :func:`build_plan`; the phase selection flags are host booleans so the
    engine only launches the phases the batch actually needs."""

    ops: jax.Array  # (B,) int32 — original lane opcodes
    point_ops: jax.Array  # (B,) int32 — OP_RANGE masked to OP_NOP
    keys: jax.Array  # (B,) KEY_DTYPE
    vals: jax.Array  # (B,) VAL_DTYPE (span on range lanes)
    lo: jax.Array  # (B,) scan lower bounds; EMPTY on non-range lanes
    hi: jax.Array  # (B,) scan upper bounds; EMPTY on non-range lanes
    is_range: jax.Array  # (B,) bool
    has_point: bool  # any find/insert/delete lane
    has_range: bool  # any OP_RANGE lane
    n_range: int
    scan_cap: int


def build_plan(ops, keys, vals=None, *, scan_cap: int = 128) -> RoundPlan:
    """Classify one round's lanes and derive the range lanes' intervals.

    OP_RANGE lane encoding: ``key = lo``, ``val = span`` → the lane scans
    ``[lo, lo + span)`` (``span == 0`` is a legal empty scan).  Raises
    ``ValueError`` for malformed range lanes (``span < 0``, i.e. hi < lo)
    and for unknown op codes.
    """
    ops_np = np.asarray(ops, np.int32)
    keys_np = np.asarray(keys, np.int64)
    vals_np = (
        np.zeros_like(keys_np) if vals is None else np.asarray(vals, np.int64)
    )
    if not (ops_np.shape == keys_np.shape == vals_np.shape and ops_np.ndim == 1):
        raise ValueError("apply_round expects equal-length 1-D ops/keys/vals")
    if ops_np.size and (ops_np.min() < int(OP_NOP) or ops_np.max() > int(OP_RANGE)):
        bad = ops_np[(ops_np < int(OP_NOP)) | (ops_np > int(OP_RANGE))][0]
        raise ValueError(f"unknown op code {int(bad)}")
    is_range_np = ops_np == OP_RANGE
    if np.any(is_range_np & (vals_np < 0)):
        lane = int(np.nonzero(is_range_np & (vals_np < 0))[0][0])
        raise ValueError(
            f"malformed OP_RANGE lane {lane}: negative span {int(vals_np[lane])} "
            f"(hi = lo + span < lo)"
        )
    n_range = int(is_range_np.sum())
    has_point = bool(np.any((ops_np > int(OP_NOP)) & ~is_range_np))

    ops_j = jnp.asarray(ops_np)
    keys_j = jnp.asarray(keys_np, KEY_DTYPE)
    vals_j = jnp.asarray(vals_np, VAL_DTYPE)
    is_range = jnp.asarray(is_range_np)
    # hi = lo + span, saturating at EMPTY: a span reaching past the top of
    # the key space must scan "everything ≥ lo" (matching the unbounded
    # oracle), not wrap to a negative int64 bound that scans nothing.
    with np.errstate(over="ignore"):
        hi_np = keys_np + vals_np
    hi_np = np.where(is_range_np & (hi_np < keys_np), int(EMPTY), hi_np)
    # Non-range lanes scan the empty interval [EMPTY, EMPTY): they expand
    # past the root into nothing and add no nodes to the validated read set.
    lo = jnp.where(is_range, keys_j, EMPTY)
    hi = jnp.where(is_range, jnp.asarray(hi_np, KEY_DTYPE), EMPTY)
    return RoundPlan(
        ops=ops_j,
        point_ops=elim.mask_range_lanes(ops_j),
        keys=keys_j,
        vals=vals_j,
        lo=lo,
        hi=hi,
        is_range=is_range,
        has_point=has_point,
        has_range=n_range > 0,
        n_range=n_range,
        scan_cap=scan_cap,
    )


# ----------------------------------------------------------------------------
# jitted phase kernels (device work; host code below only sequences them)
# ----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 4, 5, 6, 7))
def _phase_scan(
    state: TreeState, cfg: TreeConfig, lo, hi, frontier_cap: int, cap: int,
    narrow: bool = False, narrow_descent: bool = False,
):
    """jit: frontier expansion + in-range gather.  The gather goes through
    ``kernels/range_scan``'s dispatching wrapper: int64 host-index keys take
    the jnp reference, int32 device keys the Pallas kernel.  ``narrow``
    (static, from ``tree.narrow_scan``) asserts the caller's keys/values fit
    in int32, routing the fused-round gather through the Pallas kernel even
    on the int64 host index (the ROADMAP "fused-round scan kernel" path).
    ``narrow_descent`` (static, from ``tree.narrow`` — the full device-path
    gate) additionally routes the per-level frontier compaction through its
    Pallas kernel; either way the jnp compaction is sort-free (cumsum rank
    + scatter), so plain ``narrow_scan`` users keep the PR-1 contract of
    kernel-gathers-only."""
    leaves, ck, cv, touched, overflow = frontier_expand(
        state, cfg, lo, hi, frontier_cap, narrow=narrow_descent
    )
    keys, vals, count, truncated = range_scan(ck, cv, lo, hi, cap=cap, narrow=narrow)
    return ScanOutput(keys=keys, vals=vals, count=count, truncated=truncated), touched, overflow


def _search_leaves(state: TreeState, cfg: TreeConfig, ks, narrow: bool):
    """The search phase proper: fused root-to-leaf descent + unsorted-leaf
    probe via ``kernels/tree_descend`` — the Pallas kernel (pool pinned in
    VMEM, one launch instead of ``max_height`` batched HBM gathers) under
    the ``narrow`` gate, the jnp ref otherwise."""
    return descend_probe(
        state.keys, state.vals, state.children, state.is_leaf, state.root, ks,
        max_height=cfg.max_height, notfound=NOTFOUND, narrow=narrow,
    )


@functools.partial(jax.jit, static_argnums=(2, 3))
def _phase_search_combine(state: TreeState, batch, cfg: TreeConfig, narrow: bool = False):
    """jit: sort → descend → probe → eliminate.  Returns everything apply
    needs plus per-op results in original arrival order."""
    ops, keys, vals = batch
    bsz = ops.shape[0]
    sort_keys = jnp.where(ops == elim.OP_NOP, EMPTY, keys)
    perm = jnp.argsort(sort_keys, stable=True)
    inv = jnp.argsort(perm, stable=True)
    ks = sort_keys[perm]
    os_ = ops[perm]
    vs = vals[perm]
    arrival = perm.astype(jnp.int32)

    seg_head = _segment_starts(ks)
    leaf_ids, found, slot, val0 = _search_leaves(state, cfg, ks, narrow)

    res = elim.eliminate_batch(os_, vs, seg_head, found, jnp.where(found, val0, 0))
    rets_sorted = elim.op_return_values(os_, res, NOTFOUND)
    results = rets_sorted[inv]
    found_out = (rets_sorted != NOTFOUND)[inv]

    stats = state.stats._replace(
        searches=state.stats.searches + jnp.int64(bsz),
        eliminated=state.stats.eliminated + res.n_eliminated.astype(jnp.int64),
    )
    state = state._replace(stats=stats)
    return state, (ks, arrival, leaf_ids, slot, res, results, found_out)


@functools.partial(jax.jit, static_argnums=(1,))
def _phase_apply(state: TreeState, cfg: TreeConfig, ks, arrival, leaf_ids, slot, res):
    out = apply_net_ops(
        state, cfg, leaf_ids, ks, slot,
        res.net_insert, res.net_delete, res.net_overwrite, res.final_val,
        arrival,
    )
    return out.state, out.deferred


@functools.partial(jax.jit, static_argnums=(1, 6))
def _phase_retry_insert(
    state: TreeState, cfg: TreeConfig, ks, vals, arrival, deferred,
    narrow: bool = False,
):
    """Re-descend deferred keys and retry the insert (post-split)."""
    leaf_ids, found, slot, _ = _search_leaves(state, cfg, ks, narrow)
    net_insert = deferred & ~found
    out = apply_net_ops(
        state, cfg, leaf_ids, ks, slot,
        net_insert,
        jnp.zeros_like(deferred),
        jnp.zeros_like(deferred),
        vals,
        arrival,
    )
    return out.state, out.deferred & deferred


@functools.partial(jax.jit, static_argnums=(1, 4))
def _phase_overfull_leaves(
    state: TreeState, cfg: TreeConfig, ks, deferred, narrow: bool = False
):
    """Unique (sentinel-padded, sorted) ids of full leaves holding deferred
    inserts."""
    leaf_ids, _, _, _ = _search_leaves(state, cfg, ks, narrow)
    full = deferred & (state.size[leaf_ids] >= cfg.b)
    ids = jnp.where(full, leaf_ids, INT_MAX)
    srt = jnp.sort(ids)
    first = _segment_starts(srt)
    return jnp.where(first, srt, INT_MAX)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _phase_split(state: TreeState, cfg: TreeConfig, w: int, node_ids, active):
    return split_wave(state, cfg, node_ids, active)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _phase_underfull(state: TreeState, cfg: TreeConfig, w: int, node_ids, active):
    return underfull_wave(state, cfg, node_ids, active)


@functools.partial(jax.jit, static_argnums=(1,))
def _phase_shrink(state: TreeState, cfg: TreeConfig):
    return shrink_root(state, cfg)


def _pad_ids(ids: np.ndarray, w: int) -> Tuple[jax.Array, jax.Array]:
    out = np.zeros((w,), np.int32)
    act = np.zeros((w,), bool)
    out[: ids.size] = ids
    act[: ids.size] = True
    return jnp.asarray(out), jnp.asarray(act)


def _independent_by_parent_np(parent_row: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Host-side: keep one node per parent (lowest id first).  ``parent_row``
    is one tree's parent array — the forest passes one shard's row."""
    keep, seen = [], set()
    for nid in ids.tolist():
        p = int(parent_row[nid])
        if p not in seen:
            seen.add(p)
            keep.append(int(nid))
    return np.asarray(keep, np.int32)


def _independent_by_parent(state: TreeState, ids_np: np.ndarray) -> np.ndarray:
    if ids_np.size == 0:
        return ids_np
    return _independent_by_parent_np(np.asarray(state.parent), ids_np)


def _duplicate_ranks(ops_np: np.ndarray, keys_np: np.ndarray) -> np.ndarray:
    """Per-lane duplicate rank of each key (OP_NOP lanes rank 0): rank r
    executes in OCC sub-round r.  Shared by the tree's OCC round and the
    forest's per-shard rank computation."""
    rank = np.zeros(ops_np.shape[0], np.int32)
    seen: dict = {}
    for i in range(ops_np.shape[0]):
        if ops_np[i] == OP_NOP:
            continue
        k = int(keys_np[i])
        rank[i] = seen.get(k, 0)
        seen[k] = rank[i] + 1
    return rank


# ----------------------------------------------------------------------------
# Phase: scan (optimistic reader; linearizes before the round's writes)
# ----------------------------------------------------------------------------


def gather_until_frontier_fits(holder, gather):
    """Run ``gather(frontier_cap) → (out, touched, overflow)``, doubling
    ``holder._scan_frontier`` until no query overflows its leaf frontier
    (powers of two keep the jit recompiles bounded).  Shared by the tree's
    and the forest's scan phases — the growth state lives on the holder, so
    later rounds start at the steady-state width.  Returns (out, touched)."""
    guard = 0
    while True:
        out, touched, overflow = gather(holder._scan_frontier)
        if not bool(jnp.any(overflow)):
            return out, touched
        guard += 1
        assert guard < 32, "scan frontier growth diverged"
        holder._scan_frontier *= 2


def run_scan_phase(
    tree, lo: jax.Array, hi: jax.Array, cap: int, *, n_scan_ops: int,
    max_retries: int = 8,
) -> ScanOutput:
    """Gather each query's matches from a state snapshot, then validate the
    touched-node versions against the live state (retrying on conflict —
    ``ScanConflictError`` after ``max_retries``).  Within a round the engine
    runs this before any write, so validation only fails when another actor
    (``tree.scan_hook``, modeling other engine replicas) mutates the tree
    between gather and validation."""
    for attempt in range(max_retries):
        snap = tree.state
        out, touched = gather_until_frontier_fits(
            tree,
            lambda fc: _phase_scan(
                snap, tree.cfg, lo, hi, fc, cap,
                getattr(tree, "narrow_scan", False),
                getattr(tree, "narrow", False),
            ),
        )
        if tree.scan_hook is not None:
            tree.scan_hook()
        ids = np.unique(np.asarray(touched))
        if np.array_equal(np.asarray(snap.ver)[ids], np.asarray(tree.state.ver)[ids]):
            st = tree.state.stats
            tree.state = tree.state._replace(
                stats=st._replace(
                    scans=st.scans + jnp.int64(n_scan_ops),
                    scan_retries=st.scan_retries + jnp.int64(attempt),
                )
            )
            return out
    raise ScanConflictError(
        f"scan phase: version validation failed {max_retries} times"
    )


# ----------------------------------------------------------------------------
# Phases: search/combine → apply → retry → rebalance (point lanes)
# ----------------------------------------------------------------------------


def run_point_phases(tree, ops, keys, vals) -> Tuple[jax.Array, jax.Array]:
    """Execute the point-op pipeline in the tree's mode.  ``ops`` must be
    free of OP_RANGE (the plan builder masks range lanes to OP_NOP)."""
    if tree.mode == "elim":
        return _elim_point_round(tree, ops, keys, vals)
    return _occ_point_round(tree, ops, keys, vals)


def _elim_point_round(tree, ops, keys, vals):
    """Elim-ABtree: the whole batch runs one combine; ≤ 1 net write per key."""
    tree.state, pack = _phase_search_combine(
        tree.state, (ops, keys, vals), tree.cfg, getattr(tree, "narrow", False)
    )
    ks, arrival, leaf_ids, slot, res, results, found = pack
    tree.state, deferred = _phase_apply(
        tree.state, tree.cfg, ks, arrival, leaf_ids, slot, res
    )
    _drain_deferred(tree, ks, res.final_val, arrival, deferred)
    _fix_underfull_all(tree)
    return results, found


def _occ_point_round(tree, ops, keys, vals):
    """OCC baseline: duplicate-rank sub-rounds, each fully physical."""
    bsz = int(ops.shape[0])
    rank = _duplicate_ranks(np.asarray(ops), np.asarray(keys))
    n_sub = int(rank.max()) + 1 if bsz else 1
    results = jnp.full((bsz,), NOTFOUND, VAL_DTYPE)
    found = jnp.zeros((bsz,), bool)
    for r in range(n_sub):
        m = jnp.asarray(rank == r) & (ops != OP_NOP)
        sub_ops = jnp.where(m, ops, OP_NOP)
        tree.state, pack = _phase_search_combine(
            tree.state, (sub_ops, keys, vals), tree.cfg,
            getattr(tree, "narrow", False),
        )
        ks, arrival, leaf_ids, slot, res, sub_results, sub_found = pack
        tree.state, deferred = _phase_apply(
            tree.state, tree.cfg, ks, arrival, leaf_ids, slot, res
        )
        _drain_deferred(tree, ks, res.final_val, arrival, deferred)
        _fix_underfull_all(tree)
        results = jnp.where(m, sub_results, results)
        found = jnp.where(m, sub_found, found)
        st = tree.state.stats
        tree.state = tree.state._replace(
            stats=st._replace(subrounds=st.subrounds + 1)
        )
        if tree.subround_hook is not None:
            tree.subround_hook()
    return results, found


def _drain_deferred(tree, ks, final_vals, arrival, deferred):
    """Retry phase: split overflowing leaves and re-apply deferred inserts
    until none remain."""
    guard = 0
    narrow = getattr(tree, "narrow", False)
    while bool(jnp.any(deferred)):
        guard += 1
        assert guard < 512 * tree.cfg.max_height, "split loop diverged"
        uniq = _phase_overfull_leaves(tree.state, tree.cfg, ks, deferred, narrow)
        ids_np = np.asarray(uniq)
        ids_np = ids_np[ids_np != INT_MAX].astype(np.int32)
        if ids_np.size:
            _split_cascade(tree, ids_np)
        tree.state, deferred = _phase_retry_insert(
            tree.state, tree.cfg, ks, final_vals, arrival, deferred, narrow
        )


def _split_cascade(tree, ids_np: np.ndarray):
    """Split the given full nodes.  A node whose parent is itself full is
    postponed until the parent has split (pre-splitting ancestors) —
    keeps every wave's parent-insert within capacity."""
    work = {int(i) for i in ids_np}
    guard = 0
    while work:
        guard += 1
        assert guard < 512 * tree.cfg.max_height, "split cascade diverged"
        size = np.asarray(tree.state.size)
        parent = np.asarray(tree.state.parent)
        alloc = np.asarray(tree.state.alloc)
        # prune: stale entries that are no longer full / no longer allocated
        work = {n for n in work if alloc[n] and size[n] >= tree.cfg.b}
        if not work:
            break
        ready, blocked_parents = [], []
        for n in sorted(work):
            p = int(parent[n])
            if p >= 0 and size[p] >= tree.cfg.b:
                blocked_parents.append(p)
            else:
                ready.append(n)
        if not ready:
            # all blocked: split the blocking parents first
            work |= set(blocked_parents)
            size = None
            continue
        ready_np = _independent_by_parent(tree.state, np.asarray(ready, np.int32))
        ready_np = ready_np[: tree._wave_w]  # fixed wave width (no recompiles)
        tree._ensure_capacity(2 * int(ready_np.size))
        node_ids, active = _pad_ids(ready_np, tree._wave_w)
        tree.state = _phase_split(tree.state, tree.cfg, tree._wave_w, node_ids, active)
        for n in ready_np.tolist():
            work.discard(int(n))
        work |= set(blocked_parents)


def _fix_underfull_all(tree):
    """Rebalance phase: merge/distribute every underfull non-root node,
    bottom-up waves."""
    guard = 0
    while True:
        guard += 1
        assert guard < 512 * tree.cfg.max_height, "underfull loop diverged"
        s = tree.state
        alloc = np.asarray(s.alloc)
        size = np.asarray(s.size)
        parent = np.asarray(s.parent)
        level = np.asarray(s.level)
        root = int(s.root)
        under = alloc & (size < tree.cfg.a) & (parent >= 0)
        under[root] = False
        ids = np.nonzero(under)[0].astype(np.int32)
        actionable = ids[size[parent[ids]] >= 2] if ids.size else ids
        if actionable.size:
            lv = level[actionable].min()
            sel = actionable[level[actionable] == lv]
            sel = _independent_by_parent(tree.state, sel)
            sel = sel[: tree._wave_w]  # fixed wave width (no recompiles)
            node_ids, active = _pad_ids(sel, tree._wave_w)
            tree.state = _phase_underfull(
                tree.state, tree.cfg, tree._wave_w, node_ids, active
            )
            continue
        # nothing actionable: shrink a single-child root chain, else done.
        if (not bool(np.asarray(s.is_leaf)[root])) and int(size[root]) == 1:
            tree.state = _phase_shrink(tree.state, tree.cfg)
            continue
        break


# ----------------------------------------------------------------------------
# Plan execution
# ----------------------------------------------------------------------------


def execute_plan(tree, plan: RoundPlan) -> RoundOutput:
    """Run one round through the phase pipeline.

    Phase order fixes the linearization: range lanes gather from the
    pre-round state (scan phase first), point lanes then apply in arrival
    order per key.  Returns per-lane results in one ``RoundOutput``:
    point lanes get the §3 dictionary return values; range lanes get their
    match count in ``results`` (``found`` ⇔ non-empty) and their rows in
    ``RoundOutput.scan`` (batch-aligned; non-range rows are empty)."""
    bsz = int(plan.ops.shape[0])
    scan_out: Optional[ScanOutput] = None
    if plan.has_range:
        scan_out = run_scan_phase(
            tree, plan.lo, plan.hi, plan.scan_cap, n_scan_ops=plan.n_range
        )
    if plan.has_point:
        tree._ensure_capacity(bsz)
        results, found = run_point_phases(tree, plan.point_ops, plan.keys, plan.vals)
    else:
        results = jnp.full((bsz,), NOTFOUND, VAL_DTYPE)
        found = jnp.zeros((bsz,), bool)
    if scan_out is not None:
        results = jnp.where(plan.is_range, scan_out.count.astype(VAL_DTYPE), results)
        found = jnp.where(plan.is_range, scan_out.count > 0, found)
    st = tree.state.stats
    tree.state = tree.state._replace(stats=st._replace(rounds=st.rounds + 1))
    return RoundOutput(results=results, found=found, scan=scan_out)


def execute_scan_delete(tree, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
    """One fused scan+delete round: gather every key in ``[lo_i, hi_i)``
    (≤ ``cap`` smallest per query) and delete the gathered keys, in ONE
    round.  Legal because the scan linearizes before the round's writes:
    the deletes target exactly the snapshot the scan observed.

    Returns the pre-delete ``ScanOutput`` (the evicted keys/values)."""
    lo = jnp.atleast_1d(jnp.asarray(lo, KEY_DTYPE))
    hi = jnp.atleast_1d(jnp.asarray(hi, KEY_DTYPE))
    assert lo.shape == hi.shape and lo.ndim == 1
    out = run_scan_phase(
        tree, lo, hi, cap, n_scan_ops=int(lo.shape[0]), max_retries=max_retries
    )
    flat_keys = out.keys.reshape(-1)
    valid = flat_keys != EMPTY  # rows are EMPTY-padded beyond count
    del_ops = jnp.where(valid, OP_DELETE, OP_NOP).astype(jnp.int32)
    n_del = int(np.asarray(out.count).sum())
    if n_del:
        tree._ensure_capacity(n_del)
        run_point_phases(tree, del_ops, flat_keys, jnp.zeros_like(flat_keys))
    st = tree.state.stats
    tree.state = tree.state._replace(stats=st._replace(rounds=st.rounds + 1))
    return out
