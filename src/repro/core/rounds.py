"""Unified sharded round engine: ONE (S, wave_w) pipeline behind every round.

A *round* is a batch of mutually concurrent dictionary operations.  This
module owns the execution of rounds for the single tree and the forest
alike: there is exactly one host-sequencing implementation, written in the
leading-shard form — every phase kernel is a ``jax.vmap`` of the per-shard
kernel over a stacked ``TreeState`` (leading shard axis on every array),
every host loop masks its work per shard into shared ``(S, wave_w)`` /
``(S, W)`` blocks, and ``ABTree`` is simply the S = 1 case (its ``stacked``
property views the unstacked state as a one-shard stack).  ``ABForest``
contributes only routing (key-partition split points) and shard lifecycle
(overflow splits / restacks); the loops below never special-case either.

The public ``ABTree``/``ABForest`` entry points (``apply_round``,
``scan_round``, ``scan_delete_round``) are thin wrappers that build a
:class:`RoundPlan` (lane classification) and hand it to
:func:`execute_plan`, which sequences the ordered phase pipeline

    scan → search/combine → apply → retry → rebalance

Phase ↔ paper terminology (Elimination (a,b)-trees, §3–§4):

  ``scan``            the optimistic-reader discipline of ``searchLeaf``
                      generalized to a leaf frontier: gather against a state
                      snapshot, record every node read, re-validate versions
                      (retry on conflict).  Runs FIRST, so every scan in a
                      round linearizes *before* the round's net writes —
                      range lanes observe the pre-round dictionary.
                      Validation is per shard *component*: shards linked by
                      a cross-shard lane accept/retry against ONE snapshot;
                      independent shards validate independently.
  ``search/combine``  the paper's ``search`` (root-to-leaf descent + unsorted
                      leaf probe) followed by the publishing-elimination
                      combine (§4): all ops on one key fold to ≤ 1 net
                      physical write; eliminated ops compute their return
                      values from the published ElimRecord.
  ``apply``           the collapsed net writes — the paper's leaf slot
                      write + version bump (+2, odd intermediate stamped on
                      the ElimRecord, §4.1).
  ``retry``           deferred inserts (leaf full) re-descend after the
                      splits their overflow triggered — the batched analog
                      of a thread retrying after helping a split.
  ``rebalance``       relaxed-rebalancing waves of the Larsen–Fagerberg
                      sub-operations (split / merge / distribute), each wave
                      touching ≤ 1 violating child per parent per shard
                      (§3's fixTagged / fixUnderfull chains, batched).

Lane classes (``RoundPlan``):

  * **elim-combine / occ** — point ops (find/insert/delete).  In ``elim``
    mode the whole batch runs one combine; in ``occ`` mode duplicate keys
    force sub-rounds (duplicate-rank r executes in sub-round r; a shard
    whose own rank budget is exhausted is masked out of the tail).
  * **range** — OP_RANGE lanes ``[lo, lo+span)`` (key = lo, val = span),
    served by the scan phase.  Cross-shard lanes split into per-shard
    sub-lanes and stitch back in key order; mixed batches need no host-side
    splitting — one ``apply_round`` call executes every lane and returns
    per-lane results in one ``RoundOutput``.

Holder protocol (duck-typed; ``ABTree`` and ``ABForest`` both provide it):

  ``stacked``               get/set property: the (S, …) stacked TreeState
  ``cfg`` / ``mode``        TreeConfig, "elim" | "occ"
  ``n_shards``              S (1 for ABTree)
  ``narrow`` / ``narrow_scan``  int32 device-path gates (see ABTree)
  ``_splits`` / ``_bounds`` key-partition routing (empty / [-inf, +inf)
                            for the single tree)
  ``_wave_w``               structural-wave pad width
  ``_scan_frontier``        leaf-frontier pad width (doubles on overflow)
  ``_ensure_capacity(n)``   pool growth
  ``scan_hook`` / ``subround_hook``  optimistic-reader & durability hooks
  ``_rounds`` / ``_scans`` / ``_scan_retries``  host-side counters
  ``_scan_active``          in-flight-scan counter (defers shard splits)
  ``_maybe_split_shards()`` shard-overflow policy (no-op on ABTree)
  ``metrics`` / ``tracer``  telemetry (``repro.obs``): the registry backs
                            the legacy counters; the tracer wraps phase
                            launches host-side (NULL_TRACER = no-op)
  ``recorder``              flight recorder (``repro.obs.recorder``): one
                            semantic audit record per round, captured
                            host-side at round boundaries (NULL_RECORDER
                            = no-op)
  ``_note_shard_load(c)``   per-shard routed-lane counts → hot-shard
                            detection (no-op on ABTree)
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elimination as elim
from repro.core.abtree import (
    EMPTY,
    INT_MAX,
    KEY_DTYPE,
    NOTFOUND,
    OP_DELETE,
    OP_NOP,
    OP_RANGE,
    RoundOutput,
    ScanConflictError,
    ScanOutput,
    TreeConfig,
    TreeState,
    VAL_DTYPE,
    apply_net_ops,
    frontier_expand_sharded,
    shrink_root,
    split_wave,
    underfull_wave,
    _segment_starts,
)
from repro.kernels.range_scan.ops import range_scan
from repro.kernels.tree_descend.ops import descend_probe
from repro.obs.recorder import NULL_RECORDER
from repro.obs.tracer import NULL_TRACER

# ----------------------------------------------------------------------------
# telemetry accessors (host-side only — spans/counters wrap the jitted
# phase launches and never enter them, so tracing cannot change HLO)
# ----------------------------------------------------------------------------


def _tr(holder):
    """The holder's installed tracer (NULL_TRACER when absent/None)."""
    t = getattr(holder, "tracer", None)
    return NULL_TRACER if t is None else t


def _metrics(holder):
    """The holder's metrics registry, or None for bare mock holders."""
    return getattr(holder, "metrics", None)


def _rec(holder):
    """The holder's installed flight recorder (NULL_RECORDER when
    absent/None).  Like the tracer, the recorder is host-side only —
    records are built from values the round already materialised on the
    host, after the jitted phases ran, so recording cannot change HLO."""
    r = getattr(holder, "recorder", None)
    return NULL_RECORDER if r is None else r


def _elim_note(ops_sw, ks, arrival, res) -> dict:
    """Host summary of one combine's elimination decisions: per-shard
    eliminated-op counts plus every multi-update key segment (the
    annihilated insert/delete pairings) with its net physical action.
    Built only when a recorder is enabled."""
    ks_np = np.asarray(ks)  # (S, W) key-sorted; EMPTY on NOP lanes
    arr_np = np.asarray(arrival)  # sorted pos -> packed lane slot
    seg_np = np.asarray(res.seg_head)
    ni = np.asarray(res.net_insert)
    nd = np.asarray(res.net_delete)
    no = np.asarray(res.net_overwrite)
    nel = np.asarray(res.n_eliminated).reshape(-1)
    ops_np = np.asarray(ops_sw)
    segments = []
    for s in range(ks_np.shape[0]):
        ops_sorted = ops_np[s][arr_np[s]]
        upd = (ops_sorted == int(elim.OP_INSERT)) | (ops_sorted == int(elim.OP_DELETE))
        if int(upd.sum()) < 2:
            continue
        seg_id = np.cumsum(seg_np[s]) - 1
        multi = np.nonzero(np.bincount(seg_id[upd]) >= 2)[0]
        heads = np.nonzero(seg_np[s])[0]
        for g in multi.tolist():
            head = int(heads[g])
            key = int(ks_np[s][head])
            if key == int(EMPTY):
                continue
            in_seg = (seg_id == g) & upd
            net = (
                "insert" if ni[s][head]
                else "delete" if nd[s][head]
                else "overwrite" if no[s][head]
                else "none"
            )
            segments.append(
                {
                    "shard": int(s),
                    "key": key,
                    "lanes": arr_np[s][in_seg].astype(np.int64).tolist(),
                    "net": net,
                }
            )
    return {"eliminated": nel.astype(np.int64).tolist(), "segments": segments}


def _note_load(holder, counts):
    """Feed per-shard routed-lane counts to the holder's hot-shard
    detector (a forest concern; ABTree's implementation is a no-op)."""
    note = getattr(holder, "_note_shard_load", None)
    if note is not None:
        note(counts)


def _note_keys(holder, keys):
    """Feed routed lane keys to the holder's key-sample reservoir (the
    forest's skew-aware repartitioner draws its weighted quantiles from
    it; ABTree has no reservoir)."""
    note = getattr(holder, "_note_key_sample", None)
    if note is not None:
        note(keys)


def _note_pack(holder, tr_span, width: int, n_real: int):
    """Record one lane-pack's width + pad waste: gauges in the metrics
    registry (``router_pack_width`` / ``pad_waste_frac``) and span args on
    the pack's trace span, so both the registry snapshot and
    ``repro.obs.report``'s pack table surface the padding the router
    actually shipped."""
    waste = (width - n_real) / width if width else 0.0
    m = _metrics(holder)
    if m is not None:
        m.set_gauge("router_pack_width", width)
        m.set_gauge("pad_waste_frac", waste)
        m.observe("pack_pad_waste", waste)
    tr_span.note(width=width, real=n_real, pad_waste=round(waste, 4))


# ----------------------------------------------------------------------------
# Round plans: lane classification
# ----------------------------------------------------------------------------


class RoundPlan(NamedTuple):
    """A classified round: which lanes take which pipeline, plus the derived
    per-lane scan intervals.  Built host-side once per round by
    :func:`build_plan`; the phase selection flags are host booleans so the
    engine only launches the phases the batch actually needs."""

    ops: jax.Array  # (B,) int32 — original lane opcodes
    point_ops: jax.Array  # (B,) int32 — OP_RANGE masked to OP_NOP
    keys: jax.Array  # (B,) KEY_DTYPE
    vals: jax.Array  # (B,) VAL_DTYPE (span on range lanes)
    lo: jax.Array  # (B,) scan lower bounds; EMPTY on non-range lanes
    hi: jax.Array  # (B,) scan upper bounds; EMPTY on non-range lanes
    is_range: jax.Array  # (B,) bool
    has_point: bool  # any find/insert/delete lane
    has_range: bool  # any OP_RANGE lane
    n_range: int
    scan_cap: int


def build_plan(ops, keys, vals=None, *, scan_cap: int = 128) -> RoundPlan:
    """Classify one round's lanes and derive the range lanes' intervals.

    OP_RANGE lane encoding: ``key = lo``, ``val = span`` → the lane scans
    ``[lo, lo + span)`` (``span == 0`` is a legal empty scan).  Raises
    ``ValueError`` for malformed range lanes (``span < 0``, i.e. hi < lo)
    and for unknown op codes.
    """
    ops_np = np.asarray(ops, np.int32)
    keys_np = np.asarray(keys, np.int64)
    vals_np = (
        np.zeros_like(keys_np) if vals is None else np.asarray(vals, np.int64)
    )
    if not (ops_np.shape == keys_np.shape == vals_np.shape and ops_np.ndim == 1):
        raise ValueError("apply_round expects equal-length 1-D ops/keys/vals")
    if ops_np.size and (ops_np.min() < int(OP_NOP) or ops_np.max() > int(OP_RANGE)):
        bad = ops_np[(ops_np < int(OP_NOP)) | (ops_np > int(OP_RANGE))][0]
        raise ValueError(f"unknown op code {int(bad)}")
    is_range_np = ops_np == OP_RANGE
    if np.any(is_range_np & (vals_np < 0)):
        lane = int(np.nonzero(is_range_np & (vals_np < 0))[0][0])
        raise ValueError(
            f"malformed OP_RANGE lane {lane}: negative span {int(vals_np[lane])} "
            f"(hi = lo + span < lo)"
        )
    n_range = int(is_range_np.sum())
    has_point = bool(np.any((ops_np > int(OP_NOP)) & ~is_range_np))

    ops_j = jnp.asarray(ops_np)
    keys_j = jnp.asarray(keys_np, KEY_DTYPE)
    vals_j = jnp.asarray(vals_np, VAL_DTYPE)
    is_range = jnp.asarray(is_range_np)
    # hi = lo + span, saturating at EMPTY: a span reaching past the top of
    # the key space must scan "everything ≥ lo" (matching the unbounded
    # oracle), not wrap to a negative int64 bound that scans nothing.
    with np.errstate(over="ignore"):
        hi_np = keys_np + vals_np
    hi_np = np.where(is_range_np & (hi_np < keys_np), int(EMPTY), hi_np)
    # Non-range lanes scan the empty interval [EMPTY, EMPTY): they expand
    # past the root into nothing and add no nodes to the validated read set.
    lo = jnp.where(is_range, keys_j, EMPTY)
    hi = jnp.where(is_range, jnp.asarray(hi_np, KEY_DTYPE), EMPTY)
    return RoundPlan(
        ops=ops_j,
        point_ops=elim.mask_range_lanes(ops_j),
        keys=keys_j,
        vals=vals_j,
        lo=lo,
        hi=hi,
        is_range=is_range,
        has_point=has_point,
        has_range=n_range > 0,
        n_range=n_range,
        scan_cap=scan_cap,
    )


# ----------------------------------------------------------------------------
# jitted per-shard phase kernels (device work; host code below only
# sequences their vmapped forms)
# ----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7, 8))
def _phase_scan_flat(
    state: TreeState, cfg: TreeConfig, sid, lo, hi, frontier_cap: int,
    cap: int, narrow: bool = False, narrow_descent: bool = False,
):
    """jit: flat ragged frontier expansion + in-range gather over the
    STACKED state.  One launch covers every shard's scan sub-lanes packed
    side by side (lane ``i`` expands inside shard ``sid[i]``), so the
    device cost is proportional to the TRUE sub-lane count bucketed to one
    power of two — not ``S × pow2(max per-shard count)`` as the old
    per-shard row padding was.  The gather goes through
    ``kernels/range_scan``'s dispatching wrapper: int64 host-index keys
    take the jnp reference, int32 device keys the Pallas kernel.
    ``narrow`` (static, from ``tree.narrow_scan``) asserts the caller's
    keys/values fit in int32, routing the fused-round gather through the
    Pallas kernel even on the int64 host index (the ROADMAP "fused-round
    scan kernel" path).  ``narrow_descent`` (static, from ``tree.narrow``
    — the full device-path gate) additionally routes the per-level
    frontier compaction through its Pallas kernel; either way the jnp
    compaction is sort-free (cumsum rank + scatter)."""
    leaves, ck, cv, touched, overflow = frontier_expand_sharded(
        state, cfg, sid, lo, hi, frontier_cap, narrow=narrow_descent
    )
    keys, vals, count, truncated = range_scan(ck, cv, lo, hi, cap=cap, narrow=narrow)
    return ScanOutput(keys=keys, vals=vals, count=count, truncated=truncated), touched, overflow


def _search_leaves(state: TreeState, cfg: TreeConfig, ks, narrow: bool):
    """The search phase proper: fused root-to-leaf descent + unsorted-leaf
    probe via ``kernels/tree_descend`` — the Pallas kernel (pool pinned in
    VMEM, one launch instead of ``max_height`` batched HBM gathers) under
    the ``narrow`` gate, the jnp ref otherwise."""
    return descend_probe(
        state.keys, state.vals, state.children, state.is_leaf, state.root, ks,
        max_height=cfg.max_height, notfound=NOTFOUND, narrow=narrow,
    )


@functools.partial(jax.jit, static_argnums=(2, 3))
def _phase_search_combine(state: TreeState, batch, cfg: TreeConfig, narrow: bool = False):
    """jit: sort → descend → probe → eliminate.  Returns everything apply
    needs plus per-op results in original arrival order."""
    ops, keys, vals = batch
    bsz = ops.shape[0]
    sort_keys = jnp.where(ops == elim.OP_NOP, EMPTY, keys)
    perm = jnp.argsort(sort_keys, stable=True)
    inv = jnp.argsort(perm, stable=True)
    ks = sort_keys[perm]
    os_ = ops[perm]
    vs = vals[perm]
    arrival = perm.astype(jnp.int32)

    seg_head = _segment_starts(ks)
    leaf_ids, found, slot, val0 = _search_leaves(state, cfg, ks, narrow)

    res = elim.eliminate_batch(os_, vs, seg_head, found, jnp.where(found, val0, 0))
    rets_sorted = elim.op_return_values(os_, res, NOTFOUND)
    results = rets_sorted[inv]
    found_out = (rets_sorted != NOTFOUND)[inv]

    stats = state.stats._replace(
        searches=state.stats.searches + jnp.int64(bsz),
        eliminated=state.stats.eliminated + res.n_eliminated.astype(jnp.int64),
    )
    state = state._replace(stats=stats)
    return state, (ks, arrival, leaf_ids, slot, res, results, found_out)


@functools.partial(jax.jit, static_argnums=(1,))
def _phase_apply(state: TreeState, cfg: TreeConfig, ks, arrival, leaf_ids, slot, res):
    out = apply_net_ops(
        state, cfg, leaf_ids, ks, slot,
        res.net_insert, res.net_delete, res.net_overwrite, res.final_val,
        arrival,
    )
    return out.state, out.deferred


@functools.partial(jax.jit, static_argnums=(1, 6))
def _phase_retry_insert(
    state: TreeState, cfg: TreeConfig, ks, vals, arrival, deferred,
    narrow: bool = False,
):
    """Re-descend deferred keys and retry the insert (post-split)."""
    leaf_ids, found, slot, _ = _search_leaves(state, cfg, ks, narrow)
    net_insert = deferred & ~found
    out = apply_net_ops(
        state, cfg, leaf_ids, ks, slot,
        net_insert,
        jnp.zeros_like(deferred),
        jnp.zeros_like(deferred),
        vals,
        arrival,
    )
    return out.state, out.deferred & deferred


@functools.partial(jax.jit, static_argnums=(1, 4))
def _phase_overfull_leaves(
    state: TreeState, cfg: TreeConfig, ks, deferred, narrow: bool = False
):
    """Unique (sentinel-padded, sorted) ids of full leaves holding deferred
    inserts."""
    leaf_ids, _, _, _ = _search_leaves(state, cfg, ks, narrow)
    full = deferred & (state.size[leaf_ids] >= cfg.b)
    ids = jnp.where(full, leaf_ids, INT_MAX)
    srt = jnp.sort(ids)
    first = _segment_starts(srt)
    return jnp.where(first, srt, INT_MAX)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _phase_split(state: TreeState, cfg: TreeConfig, w: int, node_ids, active):
    return split_wave(state, cfg, node_ids, active)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _phase_underfull(state: TreeState, cfg: TreeConfig, w: int, node_ids, active):
    return underfull_wave(state, cfg, node_ids, active)


@functools.partial(jax.jit, static_argnums=(1,))
def _phase_shrink(state: TreeState, cfg: TreeConfig):
    return shrink_root(state, cfg)


# ----------------------------------------------------------------------------
# vmapped phase kernels: one program, all shards (leading axis 0 everywhere).
# These are the ONLY call sites of the per-shard kernels above — the S = 1
# tree pays one trivially-mapped axis, the forest gets SPMD for free.
# ----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(2, 3))
def _v_search_combine(state, batch, cfg: TreeConfig, narrow: bool = False):
    return jax.vmap(lambda st, b: _phase_search_combine(st, b, cfg, narrow))(
        state, batch
    )


@functools.partial(jax.jit, static_argnums=(1,))
def _v_apply(state, cfg: TreeConfig, ks, arrival, leaf_ids, slot, res):
    f = lambda st, a, b, c, d, e: _phase_apply(st, cfg, a, b, c, d, e)
    return jax.vmap(f)(state, ks, arrival, leaf_ids, slot, res)


@functools.partial(jax.jit, static_argnums=(1, 6))
def _v_retry_insert(state, cfg: TreeConfig, ks, vals, arrival, deferred, narrow=False):
    f = lambda st, a, b, c, d: _phase_retry_insert(st, cfg, a, b, c, d, narrow)
    return jax.vmap(f)(state, ks, vals, arrival, deferred)


@functools.partial(jax.jit, static_argnums=(1, 4))
def _v_overfull(state, cfg: TreeConfig, ks, deferred, narrow=False):
    return jax.vmap(lambda st, k, d: _phase_overfull_leaves(st, cfg, k, d, narrow))(
        state, ks, deferred
    )


@functools.partial(jax.jit, static_argnums=(1, 2))
def _v_split(state, cfg: TreeConfig, w: int, node_ids, active):
    return jax.vmap(lambda st, n, a: _phase_split(st, cfg, w, n, a))(
        state, node_ids, active
    )


@functools.partial(jax.jit, static_argnums=(1, 2))
def _v_underfull(state, cfg: TreeConfig, w: int, node_ids, active):
    return jax.vmap(lambda st, n, a: _phase_underfull(st, cfg, w, n, a))(
        state, node_ids, active
    )


@functools.partial(jax.jit, static_argnums=(1,))
def _v_shrink(state, cfg: TreeConfig):
    return jax.vmap(lambda st: _phase_shrink(st, cfg))(state)


# ----------------------------------------------------------------------------
# host helpers
# ----------------------------------------------------------------------------


def _pow2(n: int) -> int:
    """Shared pad width: power of two ≥ n, floor 8 (bounds jit recompiles)."""
    return max(8, 1 << (int(n) - 1).bit_length())


def _pack_slots(shard: np.ndarray, n_shards: int):
    """Vectorized per-shard slot assignment for lane packing: returns
    ``(shard_sorted, slot_sorted, order)`` where ``order`` stably sorts
    lanes by shard (preserving arrival order within each shard) and
    ``slot_sorted[j]`` is lane ``order[j]``'s slot in its shard's row."""
    order = np.argsort(shard, kind="stable")
    shard_sorted = shard[order]
    starts = np.searchsorted(shard_sorted, np.arange(n_shards))
    slot_sorted = np.arange(shard_sorted.size) - starts[shard_sorted]
    return shard_sorted, slot_sorted, order


def _independent_by_parent_np(parent_row: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Host-side: keep one node per parent (lowest id first).  ``parent_row``
    is one shard's parent array."""
    keep, seen = [], set()
    for nid in ids.tolist():
        p = int(parent_row[nid])
        if p not in seen:
            seen.add(p)
            keep.append(int(nid))
    return np.asarray(keep, np.int32)


def _duplicate_ranks(ops_np: np.ndarray, keys_np: np.ndarray) -> np.ndarray:
    """Per-lane duplicate rank of each key (OP_NOP lanes rank 0): rank r
    executes in OCC sub-round r.  Vectorized: a stable sort groups equal
    keys while preserving arrival order, so a lane's rank is its offset
    from its key-run's first occurrence."""
    rank = np.zeros(ops_np.shape[0], np.int32)
    idx = np.nonzero(ops_np != OP_NOP)[0]
    if idx.size == 0:
        return rank
    k = keys_np[idx]
    order = np.argsort(k, kind="stable")
    ks = k[order]
    run_start = np.searchsorted(ks, ks, side="left")
    rank[idx[order]] = (np.arange(ks.size) - run_start).astype(np.int32)
    return rank


# ----------------------------------------------------------------------------
# Phase: scan (optimistic reader; linearizes before the round's writes)
# ----------------------------------------------------------------------------


def gather_until_frontier_fits(holder, gather):
    """Run ``gather(frontier_cap) → (out, touched, overflow)``, doubling
    ``holder._scan_frontier`` until no query overflows its leaf frontier
    (powers of two keep the jit recompiles bounded).  The growth state lives
    on the holder, so later rounds start at the steady-state width.
    Returns (out, touched)."""
    guard = 0
    while True:
        out, touched, overflow = gather(holder._scan_frontier)
        if not bool(jnp.any(overflow)):
            return out, touched
        guard += 1
        assert guard < 32, "scan frontier growth diverged"
        holder._scan_frontier *= 2


def scan_lanes(holder, lo_np, hi_np, cap, *, n_scan_ops, max_retries: int = 8):
    """Split lanes ``[lo_i, hi_i)`` at shard boundaries, run one FLAT
    ragged scan phase over all sub-lanes (per-lane shard ids, one shared
    width bucketed to a power of two — no per-shard row padding), stitch
    sub-lane rows back per lane in key order (shards are key-ordered, rows
    within a shard ascending, so concatenation is globally sorted).  With
    S = 1 every lane is its own single sub-lane.  Routing is vectorized
    (two ``searchsorted`` calls over the whole batch; only the rare
    cross-shard lanes take a host loop) and computed ONCE per round — the
    retry loop re-gathers pending lanes without re-routing.  Returns numpy
    ``(keys (B,cap), vals, count, truncated)``."""
    n_shards = holder.n_shards
    bsz = int(lo_np.size)
    lo_np = np.asarray(lo_np, np.int64)
    hi_np = np.asarray(hi_np, np.int64)
    out_k = np.full((bsz, cap), int(EMPTY), np.int64)
    out_v = np.zeros((bsz, cap), np.int64)
    out_c = np.zeros((bsz,), np.int32)
    out_t = np.zeros((bsz,), bool)
    holder._scans += int(n_scan_ops)
    tr = _tr(holder)
    m = _metrics(holder)
    live = hi_np > lo_np
    comp = np.arange(n_shards)  # union-find over cross-shard-linked shards
    with tr.span("router_pack", lanes=bsz) as pack_sp:
        s0 = np.searchsorted(holder._splits, lo_np, side="right")
        s1 = np.searchsorted(
            holder._splits, np.maximum(hi_np - 1, lo_np), side="right"
        )
        multi = np.nonzero(live & (s0 < s1))[0]
        single = np.nonzero(live & (s0 == s1))[0]
        if multi.size == 0:
            lane_of = single
            sub_sid = s0[single]
            sub_lo = lo_np[single]
            sub_hi = hi_np[single]
        else:
            # Cross-shard lanes split at shard boundaries (host loop over
            # just those lanes); a stable lane-major sort then interleaves
            # them with the single-shard lanes, keeping each lane's
            # sub-lanes contiguous and shard-ascending.
            ln = [single]
            sd = [s0[single]]
            lo_l = [lo_np[single]]
            hi_l = [hi_np[single]]
            def _find(x):
                while comp[x] != x:
                    comp[x] = comp[comp[x]]
                    x = comp[x]
                return x
            for i in multi.tolist():
                for s in range(int(s0[i]), int(s1[i]) + 1):
                    slo = max(int(lo_np[i]), holder._bounds[s])
                    shi = min(int(hi_np[i]), holder._bounds[s + 1])
                    if shi <= slo:
                        continue
                    ln.append(np.array([i]))
                    sd.append(np.array([s]))
                    lo_l.append(np.array([slo]))
                    hi_l.append(np.array([shi]))
                    # all of a lane's shards validate against ONE snapshot
                    comp[_find(int(s0[i]))] = _find(s)
            lane_of = np.concatenate(ln).astype(np.int64)
            sub_sid = np.concatenate(sd).astype(np.int64)
            sub_lo = np.concatenate(lo_l).astype(np.int64)
            sub_hi = np.concatenate(hi_l).astype(np.int64)
            order = np.argsort(lane_of, kind="stable")
            lane_of = lane_of[order]
            sub_sid = sub_sid[order]
            sub_lo = sub_lo[order]
            sub_hi = sub_hi[order]
        n_sub = int(sub_sid.size)
        n_per = np.bincount(sub_sid, minlength=n_shards).astype(np.int64)
        if n_sub:
            _note_pack(holder, pack_sp, _pow2(n_sub), n_sub)
    tr.shard_marks("scan.sublanes", n_per)
    _note_load(holder, n_per)
    if live.any():
        _note_keys(holder, lo_np[live])
    if m is not None:
        for s in np.nonzero(n_per)[0]:
            m.inc_shard("scan_sublanes", int(n_per[s]), int(s))
        m.inc("scan_sublanes", int(n_per.sum()))
    if n_sub == 0:
        return out_k, out_v, out_c, out_t
    # Shards linked by a cross-shard lane form one validation component:
    # all of a lane's sub-lanes must be accepted against ONE snapshot
    # (else the stitched row could mix states that never coexisted).
    def _root(x):
        while comp[x] != x:
            comp[x] = comp[comp[x]]
            x = comp[x]
        return x

    groups = np.array([_root(s) for s in range(n_shards)])
    buf_k, buf_v, buf_c, buf_t = run_scan_phase(
        holder, sub_sid, sub_lo, sub_hi, cap, max_retries, groups
    )
    if multi.size == 0:
        # every lane is one sub-lane: the stitched output IS the buffer
        out_k[lane_of] = buf_k
        out_v[lane_of] = buf_v
        out_c[lane_of] = buf_c
        out_t[lane_of] = buf_t
        return out_k, out_v, out_c, out_t
    with tr.span("router_stitch", lanes=bsz):
        starts = np.searchsorted(lane_of, np.arange(bsz))
        ends = np.searchsorted(lane_of, np.arange(bsz) + 1)
        for i in np.unique(lane_of).tolist():
            a, e = int(starts[i]), int(ends[i])
            if e - a == 1:
                out_k[i] = buf_k[a]
                out_v[i] = buf_v[a]
                out_c[i] = buf_c[a]
                out_t[i] = buf_t[a]
                continue
            parts_k, parts_v, truncated = [], [], False
            for j in range(a, e):  # shards ascending ⇒ keys ascending
                c = int(buf_c[j])
                truncated = truncated or bool(buf_t[j])
                parts_k.append(buf_k[j, :c])
                parts_v.append(buf_v[j, :c])
            cat_k = np.concatenate(parts_k)
            cat_v = np.concatenate(parts_v)
            n = min(cat_k.size, cap)
            out_k[i, :n] = cat_k[:n]
            out_v[i, :n] = cat_v[:n]
            out_c[i] = n
            out_t[i] = truncated or cat_k.size > cap
    return out_k, out_v, out_c, out_t


def run_scan_phase(
    holder, sub_sid, sub_lo, sub_hi, cap, max_retries: int = 8, groups=None
):
    """One FLAT ragged gather over all sub-lanes + per-*component* version
    validation: shards linked by a cross-shard lane (``groups``) accept
    or retry TOGETHER, so every lane's stitched row comes from one
    snapshot (the single-tree linearization guarantee); independent
    shards validate independently, which is the conflict-window shrink
    sharding buys.  The flat block packs every shard's sub-lanes side by
    side at width ``pow2(n_sub)`` — device cost tracks the true lane
    count, not ``S × pow2(max per-shard count)`` — and a retry re-packs
    ONLY the pending components' lanes (an accepted component's rows are
    frozen; its scans linearized at that validation point), so per-shard
    validation's retry savings convert to wall-clock.  ``scan_retries``
    accrues the retried lane count.  Raises ``ScanConflictError`` after
    ``max_retries``; ``holder.scan_hook`` (modeling update rounds from
    other engine replicas) is called between each gather and its
    validation."""
    n_s = holder.n_shards
    sub_sid = np.asarray(sub_sid, np.int64)
    sub_lo = np.asarray(sub_lo, np.int64)
    sub_hi = np.asarray(sub_hi, np.int64)
    n_sub = int(sub_sid.size)
    if groups is None:
        groups = np.arange(n_s)
    buf_k = np.full((n_sub, cap), int(EMPTY), np.int64)
    buf_v = np.zeros((n_sub, cap), np.int64)
    buf_c = np.zeros((n_sub,), np.int32)
    buf_t = np.zeros((n_sub,), bool)
    n_per_shard = np.bincount(sub_sid, minlength=n_s).astype(np.int64)
    pending = n_per_shard > 0  # lane-less shards are trivially done
    cur = np.arange(n_sub)  # original sub-lane indices in the packed block
    retried = 0
    tr = _tr(holder)
    m = _metrics(holder)
    # a scan_hook writer may push a shard past max_keys_per_shard: the
    # split (which restacks to S+1 shards) must not fire under this
    # loop's flat lane routing — defer it to the next round boundary.
    holder._scan_active += 1
    try:
        with tr.span("scan", lanes=n_sub, shards=n_s) as scan_sp:
            for _attempt in range(max_retries):
                w = _pow2(cur.size)
                sid_w = np.zeros(w, np.int64)
                lo_w = np.full(w, int(EMPTY), np.int64)
                hi_w = np.full(w, int(EMPTY), np.int64)
                sid_w[: cur.size] = sub_sid[cur]
                lo_w[: cur.size] = sub_lo[cur]
                hi_w[: cur.size] = sub_hi[cur]
                snap = holder.stacked
                with tr.span("scan.gather", attempt=_attempt, width=w) as sp:
                    sid_j = jnp.asarray(sid_w, jnp.int32)
                    lo_j = jnp.asarray(lo_w, KEY_DTYPE)
                    hi_j = jnp.asarray(hi_w, KEY_DTYPE)
                    out, touched = gather_until_frontier_fits(
                        holder,
                        lambda fc: _phase_scan_flat(
                            snap, holder.cfg, sid_j, lo_j, hi_j, fc, cap,
                            holder.narrow_scan, holder.narrow,
                        ),
                    )
                    sp.fence((out, touched))
                if holder.scan_hook is not None:
                    holder.scan_hook()
                with tr.span("scan.validate", attempt=_attempt):
                    snap_ver = np.asarray(snap.ver)
                    live_ver = np.asarray(holder.stacked.ver)
                    touched_np = np.asarray(touched)  # (L, w, F) per-lane ids
                    shard_ok = np.zeros(n_s, bool)
                    for s in np.nonzero(pending)[0]:
                        ids = np.unique(touched_np[:, sid_w == s, :])
                        shard_ok[s] = np.array_equal(
                            snap_ver[s][ids], live_ver[s][ids]
                        )
                    accept = np.zeros(n_s, bool)
                    for g in np.unique(groups[pending]):
                        members = pending & (groups == g)
                        if shard_ok[members].all():
                            accept |= members
                        else:  # whole component re-gathers next attempt
                            retried += int(n_per_shard[members].sum())
                            if m is not None:
                                for s in np.nonzero(members)[0]:
                                    m.inc_shard(
                                        "scan_retries",
                                        int(n_per_shard[s]), int(s),
                                    )
                            tr.shard_marks(
                                "scan.retry",
                                np.where(members, n_per_shard, 0),
                                attempt=_attempt,
                            )
                if accept.any():
                    take = accept[sub_sid[cur]]  # rows of accepted shards
                    rows = np.nonzero(take)[0]
                    buf_k[cur[rows]] = np.asarray(out.keys)[rows]
                    buf_v[cur[rows]] = np.asarray(out.vals)[rows]
                    buf_c[cur[rows]] = np.asarray(out.count)[rows]
                    buf_t[cur[rows]] = np.asarray(out.truncated)[rows]
                    pending &= ~accept
                if not pending.any():
                    holder._scan_retries += retried
                    scan_sp.note(retries=retried, attempts=_attempt + 1)
                    rec = _rec(holder)
                    if rec.enabled:
                        rec.note_scan_phase(
                            retries=retried, attempts=_attempt + 1
                        )
                    return buf_k, buf_v, buf_c, buf_t
                # only pending components' sub-lanes re-gather
                cur = cur[pending[sub_sid[cur]]]
            raise ScanConflictError(
                f"scan phase: version validation failed {max_retries} "
                f"times on shards {np.nonzero(pending)[0].tolist()}"
            )
    finally:
        holder._scan_active -= 1


def execute_scan(holder, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
    """One batched scan round: per query the ≤ ``cap`` smallest keys in
    ``[lo_i, hi_i)``, ascending, stitched across shards in key order.  The
    shared body behind ``ABTree.scan_round`` and ``ABForest.scan_round``."""
    lo = np.atleast_1d(np.asarray(lo, np.int64))
    hi = np.atleast_1d(np.asarray(hi, np.int64))
    assert lo.shape == hi.shape and lo.ndim == 1
    k_, v_, c_, t_ = scan_lanes(
        holder, lo, hi, cap, n_scan_ops=int(lo.size), max_retries=max_retries
    )
    rec = _rec(holder)
    if rec.enabled:
        rec.round(
            round_no=holder._rounds,
            mode=holder.mode,
            n_shards=holder.n_shards,
            ops=np.full((lo.size,), int(OP_RANGE), np.int32),
            keys=lo,
            vals=hi - lo,
            results=c_.astype(np.int64),
            found=c_ > 0,
            scans={
                i: list(zip(k_[i, : c_[i]].tolist(), v_[i, : c_[i]].tolist()))
                for i in range(lo.size)
            },
            scan_cap=cap,
            fused="scan",
        )
    # Scan rounds never run the shard-overflow split (pinned: splits defer
    # to the next update round), but load rebalancing may act here — read
    # skew is exactly what the hot-shard window observes on scan traffic.
    holder._maybe_repartition()
    return ScanOutput(
        keys=jnp.asarray(k_),
        vals=jnp.asarray(v_),
        count=jnp.asarray(c_),
        truncated=jnp.asarray(t_),
    )


def execute_scan_stream(holder, lo, hi, cap: int):
    """Validate eagerly (a generator body would not run until first
    ``next``), then stream ``[lo, hi)`` as cursor-chained pages."""
    if cap <= 0:
        raise ValueError(f"scan_stream: cap must be positive, got {cap}")
    return scan_stream_pages(holder, int(lo), int(hi), cap)


def scan_stream_pages(holder, cur: int, hi: int, cap: int):
    """Stream all (key, value) pairs in ``[cur, hi)`` ascending by chaining
    per-shard cursors: each page queries only the shard holding the cursor,
    so arbitrarily long cross-shard scans stay bounded at ``cap`` entries
    (and one shard's gather) per round."""
    while cur < hi:
        s = int(np.searchsorted(holder._splits, cur, side="right"))
        s_hi = min(hi, holder._bounds[s + 1])
        out = holder.scan_round([cur], [s_hi], cap=cap)
        n = int(np.asarray(out.count)[0])
        ks = np.asarray(out.keys)[0, :n]
        vs = np.asarray(out.vals)[0, :n]
        for k, v in zip(ks.tolist(), vs.tolist()):
            yield int(k), int(v)
        if bool(np.asarray(out.truncated)[0]):
            cur = int(ks[-1]) + 1
        else:
            cur = s_hi  # shard exhausted: jump to the next shard's range


# ----------------------------------------------------------------------------
# Phases: search/combine → apply → retry → rebalance (point lanes)
# ----------------------------------------------------------------------------


def run_point_phases(holder, ops_sw, keys_sw, vals_sw):
    """Execute the point-op pipeline in the holder's mode on one packed
    ``(S, W)`` lane block.  ``ops_sw`` must be free of OP_RANGE (the plan
    builder masks range lanes to OP_NOP)."""
    if holder.mode == "elim":
        return _combine_apply(holder, ops_sw, keys_sw, vals_sw)
    return _occ_round(holder, ops_sw, keys_sw, vals_sw)


def _combine_apply(holder, ops_sw, keys_sw, vals_sw):
    """Elim-ABtree: every shard's batch runs one combine; ≤ 1 net write per
    key per shard."""
    tr = _tr(holder)
    with tr.span("search_combine") as sp:
        holder.stacked, pack = _v_search_combine(
            holder.stacked, (ops_sw, keys_sw, vals_sw), holder.cfg,
            holder.narrow,
        )
        sp.fence(pack)
    ks, arrival, leaf_ids, slot, res, results, found = pack
    rec = _rec(holder)
    if rec.enabled:
        rec.note_elim(_elim_note(ops_sw, ks, arrival, res))
    with tr.span("apply") as sp:
        holder.stacked, deferred = _v_apply(
            holder.stacked, holder.cfg, ks, arrival, leaf_ids, slot, res
        )
        sp.fence(holder.stacked)
    # retry and rebalance spans are emitted even when the phase has no
    # work: a trace of any round shows the full five-phase pipeline.
    with tr.span("retry") as sp:
        passes = _drain_deferred(holder, ks, res.final_val, arrival, deferred)
        sp.note(passes=passes)
    with tr.span("rebalance") as sp:
        waves, shrinks = _fix_underfull_all(holder)
        sp.note(waves=waves, shrinks=shrinks)
    return results, found


def _occ_round(holder, ops_sw, keys_sw, vals_sw):
    """OCC baseline: per-shard duplicate-rank sub-rounds, executed as
    max-over-shards vmapped sub-rounds.  A shard whose own duplicate
    rank is exhausted runs all-NOP lanes in the tail sub-rounds — those
    are *not* sub-rounds it executes: its lanes are masked out, its
    ``subrounds`` counter stays put, and its durable/validation cost is
    zero (the vmap itself still spans all shards, as any SPMD program
    must).  Sub-round lane masking is RAGGED: each sub-round re-packs
    only its live lanes (rank-r duplicates) into a block bucketed to
    ``pow2(max per-shard live count)``, so tail sub-rounds — typically a
    handful of duplicate keys — run at width 8 instead of the full round
    width, and already-satisfied lanes never re-enter the search phase.
    ``holder.subround_hook`` fires after every executed sub-round — the
    durable layer's per-update flush+fence discipline."""
    on = np.asarray(ops_sw)
    kn = np.asarray(keys_sw)
    vn = np.asarray(vals_sw)
    n_s, w = on.shape
    rank = np.stack([_duplicate_ranks(on[s], kn[s]) for s in range(n_s)])
    # per-shard sub-round budget: rank r of a real op executes in
    # sub-round r, so shard s is live only while r ≤ max(rank[s]).
    live = on != OP_NOP  # (S, w)
    shard_max = np.where(
        live.any(axis=1), np.where(live, rank, 0).max(axis=1), -1
    )
    n_sub = int(rank.max()) + 1
    results = np.full((n_s, w), int(NOTFOUND), np.int64)
    found = np.zeros((n_s, w), bool)
    tr = _tr(holder)
    reg = _metrics(holder)
    for r in range(n_sub):
        active = shard_max >= r  # (S,) host bools: shard executes r
        m = (rank == r) & live  # (S, w) this sub-round's live lanes
        counts_r = m.sum(axis=1)
        w_r = _pow2(int(counts_r.max()))
        s_idx, pos = np.nonzero(m)  # row-major ⇒ s_idx sorted
        starts = np.searchsorted(s_idx, np.arange(n_s))
        slot = np.arange(s_idx.size) - starts[s_idx]
        sub_ops = np.full((n_s, w_r), OP_NOP, np.int32)
        sub_keys = np.zeros((n_s, w_r), np.int64)
        sub_vals = np.zeros((n_s, w_r), np.int64)
        sub_ops[s_idx, slot] = on[s_idx, pos]
        sub_keys[s_idx, slot] = kn[s_idx, pos]
        sub_vals[s_idx, slot] = vn[s_idx, pos]
        with tr.span(
            "occ_subround", subround=r, active=int(active.sum()), width=w_r
        ) as sp:
            if reg is not None and w_r:
                waste = (n_s * w_r - int(s_idx.size)) / (n_s * w_r)
                reg.set_gauge("router_pack_width", w_r)
                reg.set_gauge("pad_waste_frac", waste)
            sp.note(width=w_r, real=int(s_idx.size))
            sub_res, sub_found = _combine_apply(
                holder,
                jnp.asarray(sub_ops),
                jnp.asarray(sub_keys, KEY_DTYPE),
                jnp.asarray(sub_vals, VAL_DTYPE),
            )
        results[s_idx, pos] = np.asarray(sub_res)[s_idx, slot]
        found[s_idx, pos] = np.asarray(sub_found)[s_idx, slot]
        if reg is not None:
            reg.inc("occ_subrounds", int(active.sum()))
        st = holder.stacked
        holder.stacked = st._replace(
            stats=st.stats._replace(
                subrounds=st.stats.subrounds + jnp.asarray(active, jnp.int64)
            )
        )
        if holder.subround_hook is not None:
            holder.subround_hook()
    rec = _rec(holder)
    if rec.enabled:
        rec.note_occ(
            subrounds=n_sub,
            active_per_subround=[
                int((shard_max >= r).sum()) for r in range(n_sub)
            ],
        )
    return jnp.asarray(results, VAL_DTYPE), jnp.asarray(found)


def _drain_deferred(holder, ks, final_vals, arrival, deferred):
    """Retry phase: split overflowing leaves and re-apply deferred inserts
    until none remain (all shards per wave).  Returns the pass count."""
    guard = 0
    reg = _metrics(holder)
    while bool(jnp.any(deferred)):
        guard += 1
        assert guard < 512 * holder.cfg.max_height, "split loop diverged"
        if reg is not None:
            reg.inc("retry_passes")
        uniq = np.asarray(
            _v_overfull(holder.stacked, holder.cfg, ks, deferred, holder.narrow)
        )
        per_shard = [row[row != INT_MAX].astype(np.int32) for row in uniq]
        if any(r.size for r in per_shard):
            _split_cascade(holder, per_shard)
        holder.stacked, deferred = _v_retry_insert(
            holder.stacked, holder.cfg, ks, final_vals, arrival, deferred,
            holder.narrow,
        )
    return guard


def _split_cascade(holder, ids_per_shard: List[np.ndarray]):
    """Split the given full nodes, all shards per wave.  A node whose parent
    is itself full is postponed until the parent has split (pre-splitting
    ancestors) — keeps every wave's parent-insert within capacity; ≤ 1
    active node per parent per wave."""
    n_s = holder.n_shards
    work = [set(int(i) for i in ids) for ids in ids_per_shard]
    guard = 0
    while any(work):
        guard += 1
        assert guard < 512 * holder.cfg.max_height * n_s, "split cascade diverged"
        st = holder.stacked
        size = np.asarray(st.size)
        parent = np.asarray(st.parent)
        alloc = np.asarray(st.alloc)
        ready_rows: List[np.ndarray] = []
        blocked_rows: List[List[int]] = []
        for s in range(n_s):
            # prune: stale entries no longer full / no longer allocated
            ws = {n for n in work[s] if alloc[s, n] and size[s, n] >= holder.cfg.b}
            work[s] = ws
            ready, blocked = [], []
            for n in sorted(ws):
                p = int(parent[s, n])
                if p >= 0 and size[s, p] >= holder.cfg.b:
                    blocked.append(p)
                else:
                    ready.append(n)
            if not ready:
                # all blocked: queue the blocking parents for splitting
                work[s] |= set(blocked)
                ready_rows.append(np.zeros((0,), np.int32))
                blocked_rows.append([])
                continue
            rd = _independent_by_parent_np(
                parent[s], np.asarray(ready, np.int32)
            )[: holder._wave_w]  # per-wave node cap
            ready_rows.append(rd)
            blocked_rows.append(blocked)
        if not any(r.size for r in ready_rows):
            continue
        holder._ensure_capacity(2 * max(int(r.size) for r in ready_rows))
        # ragged wave width: typical waves touch a handful of nodes, so
        # the vmapped kernel runs at width 8 instead of the full cap.
        # Two buckets only ({8, cap}) — each wave kernel compiles at most
        # twice, and big waves are rare enough that padding them is fine.
        max_nodes = max(int(r.size) for r in ready_rows)
        w_wave = 8 if max_nodes <= 8 else holder._wave_w
        node_ids = np.zeros((n_s, w_wave), np.int32)
        active = np.zeros((n_s, w_wave), bool)
        for s, rd in enumerate(ready_rows):
            node_ids[s, : rd.size] = rd
            active[s, : rd.size] = True
        tr = _tr(holder)
        with tr.span("split_wave", wave=guard, width=w_wave) as sp:
            holder.stacked = _v_split(
                holder.stacked, holder.cfg, w_wave,
                jnp.asarray(node_ids), jnp.asarray(active),
            )
            sp.fence(holder.stacked)
        reg = _metrics(holder)
        if reg is not None:
            reg.inc("split_waves")
            for s, rd in enumerate(ready_rows):
                if rd.size:
                    reg.inc("split_nodes", int(rd.size), shard=s)
        tr.shard_marks("split_wave.nodes", [int(r.size) for r in ready_rows])
        for s, rd in enumerate(ready_rows):
            for n in rd.tolist():
                work[s].discard(int(n))
            work[s] |= set(blocked_rows[s])


def _fix_underfull_all(holder):
    """Rebalance phase: merge/distribute every shard's underfull non-root
    nodes, bottom-up vmapped waves; root shrink once a shard has no
    actionable wave.  Returns (wave count, shrink count)."""
    n_s = holder.n_shards
    tr = _tr(holder)
    reg = _metrics(holder)
    n_waves = n_shrinks = 0
    guard = 0
    while True:
        guard += 1
        assert guard < 512 * holder.cfg.max_height * n_s, (
            "underfull loop diverged"
        )
        st = holder.stacked
        alloc = np.asarray(st.alloc)
        size = np.asarray(st.size)
        parent = np.asarray(st.parent)
        level = np.asarray(st.level)
        is_leaf = np.asarray(st.is_leaf)
        root = np.asarray(st.root)
        sel_rows: List[np.ndarray] = []
        any_wave = False
        want_shrink = False
        for s in range(n_s):
            r = int(root[s])
            under = alloc[s] & (size[s] < holder.cfg.a) & (parent[s] >= 0)
            under[r] = False
            ids = np.nonzero(under)[0].astype(np.int32)
            actionable = ids[size[s][parent[s][ids]] >= 2] if ids.size else ids
            if actionable.size:
                lv = level[s][actionable].min()
                sel = actionable[level[s][actionable] == lv]
                sel = _independent_by_parent_np(parent[s], sel)[: holder._wave_w]
                sel_rows.append(sel)
                any_wave = True
            else:
                sel_rows.append(np.zeros((0,), np.int32))
                if (not is_leaf[s, r]) and int(size[s, r]) == 1:
                    want_shrink = True
        if any_wave:
            # ragged wave width, as in _split_cascade ({8, cap} buckets)
            max_nodes = max(int(r.size) for r in sel_rows)
            w_wave = 8 if max_nodes <= 8 else holder._wave_w
            node_ids = np.zeros((n_s, w_wave), np.int32)
            active = np.zeros((n_s, w_wave), bool)
            for s, sel in enumerate(sel_rows):
                node_ids[s, : sel.size] = sel
                active[s, : sel.size] = True
            with tr.span("underfull_wave", wave=guard, width=w_wave) as sp:
                holder.stacked = _v_underfull(
                    holder.stacked, holder.cfg, w_wave,
                    jnp.asarray(node_ids), jnp.asarray(active),
                )
                sp.fence(holder.stacked)
            n_waves += 1
            if reg is not None:
                reg.inc("underfull_waves")
            tr.shard_marks(
                "underfull_wave.nodes", [int(r.size) for r in sel_rows]
            )
            continue
        if want_shrink:
            # per-shard `can` guard inside shrink_root makes the vmapped
            # call exact: only single-child internal roots collapse.
            with tr.span("root_shrink"):
                holder.stacked = _v_shrink(holder.stacked, holder.cfg)
            n_shrinks += 1
            if reg is not None:
                reg.inc("root_shrinks")
            continue
        break
    return n_waves, n_shrinks


# ----------------------------------------------------------------------------
# Plan execution
# ----------------------------------------------------------------------------


def execute_plan(holder, plan: RoundPlan) -> RoundOutput:
    """Run one round through the phase pipeline: the router partitions
    lanes by key range (a no-op at S = 1), all shards execute as one
    vmapped round, and per-lane results come back batch-aligned.

    Phase order fixes the linearization: range lanes gather from the
    pre-round state (scan phase first; cross-shard lanes split into
    per-shard sub-lanes and stitch back in key order), point lanes then
    apply in arrival order per key (stable packing preserves arrival order
    within a shard, and all ops on one key land in one shard).  Returns
    per-lane results in one ``RoundOutput``: point lanes get the §3
    dictionary return values; range lanes get their match count in
    ``results`` (``found`` ⇔ non-empty) and their rows in
    ``RoundOutput.scan`` (batch-aligned; non-range rows are empty)."""
    bsz = int(plan.ops.shape[0])
    n_shards = holder.n_shards
    if bsz == 0:
        holder._rounds += 1
        return RoundOutput(
            results=jnp.full((0,), NOTFOUND, VAL_DTYPE),
            found=jnp.zeros((0,), bool),
            scan=None,
        )
    tr = _tr(holder)
    reg = _metrics(holder)
    with tr.span("round", lanes=bsz, shards=n_shards):
        ops_np = np.asarray(plan.ops)
        keys_np = np.asarray(plan.keys)
        vals_np = np.asarray(plan.vals)
        # host mirror of elimination.lane_masks: classifying 256 lanes is
        # a handful of numpy compares, not worth five op-by-op dispatches
        # on the round's critical path.
        is_range = ops_np == int(elim.OP_RANGE)
        is_point = (
            (ops_np == int(elim.OP_FIND))
            | (ops_np == int(elim.OP_INSERT))
            | (ops_np == int(elim.OP_DELETE))
        )

        results = np.full((bsz,), int(NOTFOUND), np.int64)
        found = np.zeros((bsz,), bool)

        # --- scan phase first: range lanes linearize before the round's
        # writes.
        scan_out = None
        if plan.has_range:
            rl = np.nonzero(is_range)[0]
            lo_np = np.asarray(plan.lo)[rl]
            hi_np = np.asarray(plan.hi)[rl]
            k_, v_, c_, t_ = scan_lanes(
                holder, lo_np, hi_np, plan.scan_cap, n_scan_ops=plan.n_range
            )
            keys_full = np.full((bsz, plan.scan_cap), int(EMPTY), np.int64)
            vals_full = np.zeros((bsz, plan.scan_cap), np.int64)
            count_full = np.zeros((bsz,), np.int32)
            trunc_full = np.zeros((bsz,), bool)
            keys_full[rl] = k_
            vals_full[rl] = v_
            count_full[rl] = c_
            trunc_full[rl] = t_
            scan_out = ScanOutput(
                keys=jnp.asarray(keys_full),
                vals=jnp.asarray(vals_full),
                count=jnp.asarray(count_full),
                truncated=jnp.asarray(trunc_full),
            )
            results[rl] = c_.astype(np.int64)
            found[rl] = c_ > 0

        # --- point lanes: pack per shard (stable ⇒ arrival order kept).
        if plan.has_point:
            pl = np.nonzero(is_point)[0]
            with tr.span("router_pack", lanes=int(pl.size)) as pack_sp:
                shard = np.searchsorted(
                    holder._splits, keys_np[pl], side="right"
                )
                counts = np.bincount(shard, minlength=n_shards)
                w = _pow2(int(counts.max()))
                ops_sw = np.full((n_shards, w), OP_NOP, np.int32)
                keys_sw = np.zeros((n_shards, w), np.int64)
                vals_sw = np.zeros((n_shards, w), np.int64)
                shard_sorted, slot_sorted, order = _pack_slots(shard, n_shards)
                ops_sw[shard_sorted, slot_sorted] = ops_np[pl][order]
                keys_sw[shard_sorted, slot_sorted] = keys_np[pl][order]
                vals_sw[shard_sorted, slot_sorted] = vals_np[pl][order]
                slot = np.empty(pl.size, np.int64)
                slot[order] = slot_sorted
                _note_pack(holder, pack_sp, n_shards * w, int(pl.size))
            tr.shard_marks("point_lanes", counts)
            _note_load(holder, counts)
            _note_keys(holder, keys_np[pl])
            if reg is not None:
                reg.inc("point_lanes", int(pl.size))
                for s in np.nonzero(counts)[0]:
                    reg.inc_shard("point_lanes", int(counts[s]), int(s))
            holder._ensure_capacity(w)
            res_sw, fnd_sw = run_point_phases(
                holder,
                jnp.asarray(ops_sw),
                jnp.asarray(keys_sw, KEY_DTYPE),
                jnp.asarray(vals_sw, VAL_DTYPE),
            )
            results[pl] = np.asarray(res_sw)[shard, slot]
            found[pl] = np.asarray(fnd_sw)[shard, slot]

        rec = _rec(holder)
        if rec.enabled:
            scans_d = None
            if scan_out is not None:
                scans_d = {
                    int(i): list(zip(k_[j, : c_[j]].tolist(), v_[j, : c_[j]].tolist()))
                    for j, i in enumerate(rl.tolist())
                }
            rec.round(
                round_no=holder._rounds,
                mode=holder.mode,
                n_shards=n_shards,
                ops=ops_np,
                keys=keys_np,
                vals=vals_np,
                results=results,
                found=found,
                scans=scans_d,
                scan_cap=plan.scan_cap,
            )
        holder._rounds += 1
        out = RoundOutput(
            results=jnp.asarray(results, VAL_DTYPE),
            found=jnp.asarray(found),
            scan=scan_out,
        )
        holder._maybe_split_shards()
    return out


def execute_scan_delete(holder, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
    """ONE fused round that gathers every key in ``[lo_i, hi_i)`` (≤ ``cap``
    smallest per query, stitched across shards) and deletes exactly the
    *emitted* keys, in ONE round.  Legal because the scan linearizes before
    the round's writes: the deletes target exactly the snapshot the scan
    observed.  Keys a truncated page did not emit survive for the caller's
    next chunk (the one-fused-round-per-chunk sweep contract of
    ``SessionIndex``).  Returns the pre-delete ``ScanOutput`` (the evicted
    keys/values)."""
    lo = np.atleast_1d(np.asarray(lo, np.int64))
    hi = np.atleast_1d(np.asarray(hi, np.int64))
    assert lo.shape == hi.shape and lo.ndim == 1
    tr = _tr(holder)
    reg = _metrics(holder)
    rec = _rec(holder)
    del_res = del_fnd = None
    with tr.span("round", lanes=int(lo.size), fused="scan_delete"):
        k_, v_, c_, t_ = scan_lanes(
            holder, lo, hi, cap, n_scan_ops=int(lo.size),
            max_retries=max_retries,
        )
        del_keys = k_[k_ != int(EMPTY)]
        if del_keys.size:
            n_shards = holder.n_shards
            with tr.span("router_pack", lanes=int(del_keys.size)) as pack_sp:
                shard = np.searchsorted(holder._splits, del_keys, side="right")
                counts = np.bincount(shard, minlength=n_shards)
                w = _pow2(int(counts.max()))
                ops_sw = np.full((n_shards, w), OP_NOP, np.int32)
                keys_sw = np.zeros((n_shards, w), np.int64)
                shard_sorted, slot_sorted, order = _pack_slots(shard, n_shards)
                ops_sw[shard_sorted, slot_sorted] = OP_DELETE
                keys_sw[shard_sorted, slot_sorted] = del_keys[order]
                _note_pack(holder, pack_sp, n_shards * w, int(del_keys.size))
            tr.shard_marks("point_lanes", counts)
            _note_load(holder, counts)
            if reg is not None:
                reg.inc("point_lanes", int(del_keys.size))
                for s in np.nonzero(counts)[0]:
                    reg.inc_shard("point_lanes", int(counts[s]), int(s))
            holder._ensure_capacity(w)
            res_sw, fnd_sw = run_point_phases(
                holder,
                jnp.asarray(ops_sw),
                jnp.asarray(keys_sw, KEY_DTYPE),
                jnp.zeros((n_shards, w), VAL_DTYPE),
            )
            if rec.enabled:
                slot = np.empty(del_keys.size, np.int64)
                slot[order] = slot_sorted
                del_res = np.asarray(res_sw)[shard, slot]
                del_fnd = np.asarray(fnd_sw)[shard, slot]
        if rec.enabled:
            n_r = int(lo.size)
            n_d = int(del_keys.size)
            ops_rec = np.concatenate(
                [
                    np.full((n_r,), int(OP_RANGE), np.int64),
                    np.full((n_d,), int(OP_DELETE), np.int64),
                ]
            )
            keys_rec = np.concatenate([lo, del_keys.astype(np.int64)])
            vals_rec = np.concatenate([hi - lo, np.zeros(n_d, np.int64)])
            results_rec = np.concatenate(
                [
                    c_.astype(np.int64),
                    del_res if del_res is not None else np.zeros(0, np.int64),
                ]
            )
            found_rec = np.concatenate(
                [c_ > 0, del_fnd if del_fnd is not None else np.zeros(0, bool)]
            )
            rec.round(
                round_no=holder._rounds,
                mode=holder.mode,
                n_shards=holder.n_shards,
                ops=ops_rec,
                keys=keys_rec,
                vals=vals_rec,
                results=results_rec,
                found=found_rec,
                scans={
                    i: list(zip(k_[i, : c_[i]].tolist(), v_[i, : c_[i]].tolist()))
                    for i in range(n_r)
                },
                scan_cap=cap,
                fused="scan_delete",
            )
        holder._rounds += 1
        holder._maybe_split_shards()
    return ScanOutput(
        keys=jnp.asarray(k_),
        vals=jnp.asarray(v_),
        count=jnp.asarray(c_),
        truncated=jnp.asarray(t_),
    )
