"""ABForest: a key-partitioned forest of (a,b)-trees with vmapped rounds.

The round-based OCC/elimination design is embarrassingly shardable: lanes on
disjoint key ranges never conflict, so partitioning the key space by split
points turns one contended tree into ``n_shards`` independent ones — and the
SPMD formulation makes the partition *free* on device: every shard's round
is the same program, so all shards execute in ONE ``jax.vmap`` of the PR-2
round-engine phase kernels (``core/rounds.py`` runs unchanged per shard).

Representation
    All shard trees live in one stacked ``TreeState`` whose every leaf array
    carries a leading shard axis (``keys``: (S, N, b), ``root``: (S,), …).
    This is the layout every later scaling step wants: multi-device
    placement is ``shard_map`` over axis 0, per-shard durability is a slice.

Routing (host, per round)
    ``elimination.lane_masks`` classifies the batch's lanes; point lanes go
    to ``shard = searchsorted(splits, key)``; OP_RANGE lanes are split at
    shard boundaries into per-shard sub-lanes.  Each shard's lane group is
    padded to a shared power-of-two width (bounded recompiles) and the whole
    (S, W) block executes as one vmapped round.  Sub-lane scan rows are
    stitched back in key order (shards are ordered by key range, rows within
    a shard are ascending, so concatenation is globally sorted).

Semantics
    Identical to ``ABTree``: a forest round is one round — scans linearize
    before the round's net writes, point lanes apply in arrival order per
    key (stable packing preserves arrival order within a shard, and all ops
    on one key land in one shard).  ``DictOracle`` remains the single
    reference: a forest with ANY shard count must be oracle-equivalent.

Conflict granularity
    Scan validation is per shard *component*: shards linked by a
    cross-shard lane validate jointly (all of a lane's sub-lanes accept
    against ONE snapshot — the single-tree linearization guarantee), while
    independent shards validate independently, so a concurrent writer
    (``scan_hook``, modeling other engine replicas) invalidates only the
    components whose versions it bumped.  ``scan_retries`` counts retried
    *lanes* (ops), the honest per-op cost the sharding is buying down.

Shard overflow
    With ``max_keys_per_shard`` set, a shard growing past the threshold is
    split: the median key becomes a new split point, the upper half is swept
    off the hot shard with fused scan+delete rounds, a fresh shard is
    restacked in at the new position, and the swept keys re-insert through
    the normal router (which now targets the new shard).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elimination as elim
from repro.core import rounds
from repro.core.abtree import (
    EMPTY,
    INT_MAX,
    KEY_DTYPE,
    KEY_MIN,
    NOTFOUND,
    OP_DELETE,
    OP_INSERT,
    OP_NOP,
    RoundOutput,
    ScanConflictError,
    ScanOutput,
    TreeConfig,
    TreeState,
    VAL_DTYPE,
    grow_pool,
    make_tree,
)
from repro.core.rounds import (
    _duplicate_ranks,
    _independent_by_parent_np,
    _phase_apply,
    _phase_overfull_leaves,
    _phase_retry_insert,
    _phase_scan,
    _phase_search_combine,
    _phase_shrink,
    _phase_split,
    _phase_underfull,
    gather_until_frontier_fits,
)

# ----------------------------------------------------------------------------
# vmapped phase kernels: one program, all shards (leading axis 0 everywhere)
# ----------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 4, 5, 6, 7))
def _v_scan(
    state, cfg: TreeConfig, lo, hi, frontier_cap: int, cap: int,
    narrow: bool, narrow_descent: bool = False,
):
    f = lambda st, l, h: _phase_scan(
        st, cfg, l, h, frontier_cap, cap, narrow, narrow_descent
    )
    return jax.vmap(f)(state, lo, hi)


@functools.partial(jax.jit, static_argnums=(2, 3))
def _v_search_combine(state, batch, cfg: TreeConfig, narrow: bool = False):
    return jax.vmap(lambda st, b: _phase_search_combine(st, b, cfg, narrow))(
        state, batch
    )


@functools.partial(jax.jit, static_argnums=(1,))
def _v_apply(state, cfg: TreeConfig, ks, arrival, leaf_ids, slot, res):
    f = lambda st, a, b, c, d, e: _phase_apply(st, cfg, a, b, c, d, e)
    return jax.vmap(f)(state, ks, arrival, leaf_ids, slot, res)


@functools.partial(jax.jit, static_argnums=(1, 6))
def _v_retry_insert(state, cfg: TreeConfig, ks, vals, arrival, deferred, narrow=False):
    f = lambda st, a, b, c, d: _phase_retry_insert(st, cfg, a, b, c, d, narrow)
    return jax.vmap(f)(state, ks, vals, arrival, deferred)


@functools.partial(jax.jit, static_argnums=(1, 4))
def _v_overfull(state, cfg: TreeConfig, ks, deferred, narrow=False):
    return jax.vmap(lambda st, k, d: _phase_overfull_leaves(st, cfg, k, d, narrow))(
        state, ks, deferred
    )


@functools.partial(jax.jit, static_argnums=(1, 2))
def _v_split(state, cfg: TreeConfig, w: int, node_ids, active):
    return jax.vmap(lambda st, n, a: _phase_split(st, cfg, w, n, a))(
        state, node_ids, active
    )


@functools.partial(jax.jit, static_argnums=(1, 2))
def _v_underfull(state, cfg: TreeConfig, w: int, node_ids, active):
    return jax.vmap(lambda st, n, a: _phase_underfull(st, cfg, w, n, a))(
        state, node_ids, active
    )


@functools.partial(jax.jit, static_argnums=(1,))
def _v_shrink(state, cfg: TreeConfig):
    return jax.vmap(lambda st: _phase_shrink(st, cfg))(state)


# ----------------------------------------------------------------------------
# host helpers
# ----------------------------------------------------------------------------


def _pow2(n: int) -> int:
    """Shared pad width: power of two ≥ n, floor 8 (bounds jit recompiles)."""
    return max(8, 1 << (int(n) - 1).bit_length())


def _pack_slots(shard: np.ndarray, n_shards: int):
    """Vectorized per-shard slot assignment for lane packing: returns
    ``(shard_sorted, slot_sorted, order)`` where ``order`` stably sorts
    lanes by shard (preserving arrival order within each shard) and
    ``slot_sorted[j]`` is lane ``order[j]``'s slot in its shard's row."""
    order = np.argsort(shard, kind="stable")
    shard_sorted = shard[order]
    starts = np.searchsorted(shard_sorted, np.arange(n_shards))
    slot_sorted = np.arange(shard_sorted.size) - starts[shard_sorted]
    return shard_sorted, slot_sorted, order


def _stack_states(states: List[TreeState]) -> TreeState:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


# ----------------------------------------------------------------------------
# ABForest
# ----------------------------------------------------------------------------


class ABForest:
    """Key-partitioned forest of batched (a,b)-trees; ``ABTree``-compatible
    round API (``apply_round`` / ``scan_round`` / ``scan_delete_round`` /
    ``scan_stream``), one vmapped round across all shards per call."""

    def __init__(
        self,
        n_shards: int = 2,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        *,
        splits=None,
        key_space: Optional[Tuple[int, int]] = None,
        narrow_scan: bool = False,
        narrow: bool = False,
        max_keys_per_shard: Optional[int] = None,
    ):
        assert mode in ("elim", "occ")
        assert 2 <= cfg.a <= cfg.b // 2, "(a,b) requires 2 ≤ a ≤ b/2"
        assert n_shards >= 1
        self.cfg = cfg
        self.mode = mode
        self.n_shards = int(n_shards)
        # same contracts as ABTree: narrow_scan = int32 keys/values on the
        # scan gather; narrow = the whole search path (vmapped fused
        # descent+probe kernel + Pallas frontier compaction per shard).
        self.narrow = narrow
        self.narrow_scan = narrow_scan or narrow
        if splits is not None:
            splits = np.asarray(splits, np.int64).reshape(-1)
            assert splits.size == self.n_shards - 1, (
                f"need {self.n_shards - 1} split points, got {splits.size}"
            )
            assert np.all(np.diff(splits) > 0), "splits must be strictly ascending"
        else:
            lo, hi = key_space if key_space is not None else (0, 1 << 63)
            assert hi - lo >= self.n_shards, "key_space too small for n_shards"
            step = (hi - lo) // self.n_shards
            splits = lo + step * np.arange(1, self.n_shards, dtype=np.int64)
        self._splits = splits.astype(np.int64)
        self._rebuild_bounds()
        self.state: TreeState = _stack_states(
            [make_tree(cfg) for _ in range(self.n_shards)]
        )
        self.max_keys_per_shard = max_keys_per_shard
        self._in_split = False
        self._scan_active = 0  # defers shard splits while a scan is in flight
        self._wave_w = 64  # pad width for structural waves (recompile-bounded)
        self._scan_frontier = 8  # leaf-frontier pad width (doubles on overflow)
        # optimistic-reader hook, as on ABTree: called between a scan's
        # gather and its per-shard version validation (models update rounds
        # from other engine replicas).
        self.scan_hook = None
        # forest-level counters (device stats stay per shard; see stats()).
        self._rounds = 0
        self._scans = 0
        self._scan_retries = 0

    # -- routing --------------------------------------------------------------

    def _rebuild_bounds(self):
        self._bounds = (
            [int(KEY_MIN)] + [int(x) for x in self._splits] + [int(EMPTY)]
        )

    def _shard_of(self, keys: np.ndarray) -> np.ndarray:
        return np.searchsorted(self._splits, keys, side="right")

    # -- public API -----------------------------------------------------------

    def apply_round(self, ops, keys, vals=None, *, scan_cap: int = 128) -> RoundOutput:
        """Apply one round of concurrent ops (semantics of
        ``ABTree.apply_round``, including fused OP_RANGE lanes): the router
        partitions lanes by key range, all shards execute as one vmapped
        round, and per-lane results come back batch-aligned.  Cross-shard
        range lanes are split into per-shard sub-lanes and their rows
        stitched back in key order."""
        plan = rounds.build_plan(ops, keys, vals, scan_cap=scan_cap)
        bsz = int(plan.ops.shape[0])
        if bsz == 0:
            self._rounds += 1
            return RoundOutput(
                results=jnp.full((0,), NOTFOUND, VAL_DTYPE),
                found=jnp.zeros((0,), bool),
                scan=None,
            )
        ops_np = np.asarray(plan.ops)
        keys_np = np.asarray(plan.keys)
        vals_np = np.asarray(plan.vals)
        is_point_j, is_range_j = elim.lane_masks(plan.ops)
        is_point = np.asarray(is_point_j)
        is_range = np.asarray(is_range_j)

        results = np.full((bsz,), int(NOTFOUND), np.int64)
        found = np.zeros((bsz,), bool)

        # --- scan phase first: range lanes linearize before the round's writes.
        scan_out = None
        if plan.has_range:
            rl = np.nonzero(is_range)[0]
            lo_np = np.asarray(plan.lo)[rl]
            hi_np = np.asarray(plan.hi)[rl]
            k_, v_, c_, t_ = self._scan_lanes(
                lo_np, hi_np, scan_cap, n_scan_ops=plan.n_range
            )
            keys_full = np.full((bsz, scan_cap), int(EMPTY), np.int64)
            vals_full = np.zeros((bsz, scan_cap), np.int64)
            count_full = np.zeros((bsz,), np.int32)
            trunc_full = np.zeros((bsz,), bool)
            keys_full[rl] = k_
            vals_full[rl] = v_
            count_full[rl] = c_
            trunc_full[rl] = t_
            scan_out = ScanOutput(
                keys=jnp.asarray(keys_full),
                vals=jnp.asarray(vals_full),
                count=jnp.asarray(count_full),
                truncated=jnp.asarray(trunc_full),
            )
            results[rl] = c_.astype(np.int64)
            found[rl] = c_ > 0

        # --- point lanes: pack per shard (stable ⇒ arrival order kept).
        if plan.has_point:
            pl = np.nonzero(is_point)[0]
            shard = self._shard_of(keys_np[pl])
            w = _pow2(int(np.bincount(shard, minlength=self.n_shards).max()))
            ops_sw = np.full((self.n_shards, w), OP_NOP, np.int32)
            keys_sw = np.zeros((self.n_shards, w), np.int64)
            vals_sw = np.zeros((self.n_shards, w), np.int64)
            shard_sorted, slot_sorted, order = _pack_slots(shard, self.n_shards)
            ops_sw[shard_sorted, slot_sorted] = ops_np[pl][order]
            keys_sw[shard_sorted, slot_sorted] = keys_np[pl][order]
            vals_sw[shard_sorted, slot_sorted] = vals_np[pl][order]
            slot = np.empty(pl.size, np.int64)
            slot[order] = slot_sorted
            self._ensure_capacity(w)
            res_sw, fnd_sw = self._point_phases(
                jnp.asarray(ops_sw),
                jnp.asarray(keys_sw, KEY_DTYPE),
                jnp.asarray(vals_sw, VAL_DTYPE),
            )
            results[pl] = np.asarray(res_sw)[shard, slot]
            found[pl] = np.asarray(fnd_sw)[shard, slot]

        self._rounds += 1
        out = RoundOutput(
            results=jnp.asarray(results, VAL_DTYPE),
            found=jnp.asarray(found),
            scan=scan_out,
        )
        self._maybe_split_shards()
        return out

    def scan_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        """Batched range scans (semantics of ``ABTree.scan_round``): per
        query the ≤ ``cap`` smallest keys in ``[lo_i, hi_i)``, ascending,
        stitched across shards in key order."""
        lo = np.atleast_1d(np.asarray(lo, np.int64))
        hi = np.atleast_1d(np.asarray(hi, np.int64))
        assert lo.shape == hi.shape and lo.ndim == 1
        k_, v_, c_, t_ = self._scan_lanes(
            lo, hi, cap, n_scan_ops=int(lo.size), max_retries=max_retries
        )
        return ScanOutput(
            keys=jnp.asarray(k_),
            vals=jnp.asarray(v_),
            count=jnp.asarray(c_),
            truncated=jnp.asarray(t_),
        )

    def scan_delete_round(
        self, lo, hi, cap: int = 128, max_retries: int = 8
    ) -> ScanOutput:
        """ONE fused forest round that gathers every key in ``[lo_i, hi_i)``
        (≤ ``cap`` smallest per query, stitched across shards) and deletes
        exactly the *emitted* keys — keys a truncated page did not emit
        survive for the caller's next chunk, preserving the
        one-fused-round-per-chunk sweep contract of ``SessionIndex``."""
        lo = np.atleast_1d(np.asarray(lo, np.int64))
        hi = np.atleast_1d(np.asarray(hi, np.int64))
        assert lo.shape == hi.shape and lo.ndim == 1
        k_, v_, c_, t_ = self._scan_lanes(
            lo, hi, cap, n_scan_ops=int(lo.size), max_retries=max_retries
        )
        del_keys = k_[k_ != int(EMPTY)]
        if del_keys.size:
            shard = self._shard_of(del_keys)
            w = _pow2(int(np.bincount(shard, minlength=self.n_shards).max()))
            ops_sw = np.full((self.n_shards, w), OP_NOP, np.int32)
            keys_sw = np.zeros((self.n_shards, w), np.int64)
            shard_sorted, slot_sorted, order = _pack_slots(shard, self.n_shards)
            ops_sw[shard_sorted, slot_sorted] = OP_DELETE
            keys_sw[shard_sorted, slot_sorted] = del_keys[order]
            self._ensure_capacity(w)
            self._point_phases(
                jnp.asarray(ops_sw),
                jnp.asarray(keys_sw, KEY_DTYPE),
                jnp.zeros((self.n_shards, w), VAL_DTYPE),
            )
        self._rounds += 1
        return ScanOutput(
            keys=jnp.asarray(k_),
            vals=jnp.asarray(v_),
            count=jnp.asarray(c_),
            truncated=jnp.asarray(t_),
        )

    def scan_stream(self, lo, hi, cap: int = 128):
        """Stream all (key, value) pairs in ``[lo, hi)`` ascending by
        chaining per-shard cursors: each page queries only the shard holding
        the cursor, so arbitrarily long cross-shard scans stay bounded at
        ``cap`` entries (and one shard's gather) per round."""
        if cap <= 0:
            raise ValueError(f"scan_stream: cap must be positive, got {cap}")
        return self._scan_stream(int(lo), int(hi), cap)

    def _scan_stream(self, cur: int, hi: int, cap: int):
        while cur < hi:
            s = int(np.searchsorted(self._splits, cur, side="right"))
            s_hi = min(hi, self._bounds[s + 1])
            out = self.scan_round([cur], [s_hi], cap=cap)
            n = int(np.asarray(out.count)[0])
            ks = np.asarray(out.keys)[0, :n]
            vs = np.asarray(out.vals)[0, :n]
            for k, v in zip(ks.tolist(), vs.tolist()):
                yield int(k), int(v)
            if bool(np.asarray(out.truncated)[0]):
                cur = int(ks[-1]) + 1
            else:
                cur = s_hi  # shard exhausted: jump to the next shard's range

    def find(self, key) -> Optional[int]:
        out = self.apply_round([elim.OP_FIND], [key])
        return int(out.results[0]) if bool(out.found[0]) else None

    def insert(self, key, val):
        out = self.apply_round([OP_INSERT], [key], [val])
        return int(out.results[0]) if bool(out.found[0]) else None

    def delete(self, key):
        out = self.apply_round([OP_DELETE], [key])
        return int(out.results[0]) if bool(out.found[0]) else None

    def items(self) -> dict:
        """Host-side snapshot of the forest contents (sorted by key)."""
        st = self.state
        keys = np.asarray(st.keys)
        vals = np.asarray(st.vals)
        leaf = np.asarray(st.is_leaf) & np.asarray(st.alloc)
        out = {}
        for s in range(self.n_shards):
            for nid in np.nonzero(leaf[s])[0]:
                for j in range(self.cfg.b):
                    k = int(keys[s, nid, j])
                    if k != int(EMPTY):
                        out[k] = int(vals[s, nid, j])
        return dict(sorted(out.items()))

    def shard_state(self, s: int) -> TreeState:
        """One shard's (unstacked) TreeState — for invariant checks and the
        coming per-shard durability layer."""
        return jax.tree_util.tree_map(lambda x: x[s], self.state)

    def stats(self) -> dict:
        """Forest-level stats: device counters summed over shards;
        ``rounds`` counts forest rounds (one vmapped round = 1, however many
        shards it spans) and ``scan_retries`` counts retried *lanes* — the
        per-op conflict cost per-shard validation buys down."""
        agg = {
            k: int(np.asarray(v).sum())
            for k, v in self.state.stats._asdict().items()
        }
        agg["rounds"] = self._rounds
        agg["scans"] = self._scans
        agg["scan_retries"] = self._scan_retries
        return agg

    def stats_per_shard(self) -> List[dict]:
        return [
            {k: int(np.asarray(v)[s]) for k, v in self.state.stats._asdict().items()}
            for s in range(self.n_shards)
        ]

    @property
    def splits(self) -> np.ndarray:
        return self._splits.copy()

    # -- scan phase (per-shard optimistic validation) --------------------------

    def _scan_lanes(self, lo_np, hi_np, cap, *, n_scan_ops, max_retries: int = 8):
        """Split lanes ``[lo_i, hi_i)`` at shard boundaries, run one vmapped
        scan phase, stitch sub-lane rows back per lane in key order.
        Returns numpy ``(keys (B,cap), vals, count, truncated)``."""
        bsz = int(lo_np.size)
        out_k = np.full((bsz, cap), int(EMPTY), np.int64)
        out_v = np.zeros((bsz, cap), np.int64)
        out_c = np.zeros((bsz,), np.int32)
        out_t = np.zeros((bsz,), bool)
        sub_lo: List[List[int]] = [[] for _ in range(self.n_shards)]
        sub_hi: List[List[int]] = [[] for _ in range(self.n_shards)]
        lane_subs: List[List[Tuple[int, int]]] = [[] for _ in range(bsz)]
        for i in range(bsz):
            lo, hi = int(lo_np[i]), int(hi_np[i])
            if hi <= lo:
                continue
            s0 = int(np.searchsorted(self._splits, lo, side="right"))
            s1 = int(np.searchsorted(self._splits, hi - 1, side="right"))
            for s in range(s0, s1 + 1):
                slo = max(lo, self._bounds[s])
                shi = min(hi, self._bounds[s + 1])
                if shi <= slo:
                    continue
                lane_subs[i].append((s, len(sub_lo[s])))
                sub_lo[s].append(slo)
                sub_hi[s].append(shi)
        n_per = np.array([len(x) for x in sub_lo], np.int64)
        self._scans += int(n_scan_ops)
        if int(n_per.sum()) == 0:
            return out_k, out_v, out_c, out_t
        # Shards linked by a cross-shard lane form one validation component:
        # all of a lane's sub-lanes must be accepted against ONE snapshot
        # (else the stitched row could mix states that never coexisted).
        comp = np.arange(self.n_shards)

        def _find(x):
            while comp[x] != x:
                comp[x] = comp[comp[x]]
                x = comp[x]
            return x

        for subs in lane_subs:
            for s, _ in subs[1:]:
                comp[_find(subs[0][0])] = _find(s)
        groups = np.array([_find(s) for s in range(self.n_shards)])
        w = _pow2(int(n_per.max()))
        lo_sw = np.full((self.n_shards, w), int(EMPTY), np.int64)
        hi_sw = np.full((self.n_shards, w), int(EMPTY), np.int64)
        for s in range(self.n_shards):
            lo_sw[s, : n_per[s]] = sub_lo[s]
            hi_sw[s, : n_per[s]] = sub_hi[s]
        g_k, g_v, g_c, g_t = self._run_scan_phase(
            jnp.asarray(lo_sw, KEY_DTYPE),
            jnp.asarray(hi_sw, KEY_DTYPE),
            cap,
            n_per,
            max_retries,
            groups,
        )
        for i in range(bsz):
            if not lane_subs[i]:
                continue
            parts_k, parts_v, truncated = [], [], False
            for s, j in lane_subs[i]:  # shards ascending ⇒ keys ascending
                c = int(g_c[s, j])
                truncated = truncated or bool(g_t[s, j])
                parts_k.append(g_k[s, j, :c])
                parts_v.append(g_v[s, j, :c])
            cat_k = np.concatenate(parts_k)
            cat_v = np.concatenate(parts_v)
            n = min(cat_k.size, cap)
            out_k[i, :n] = cat_k[:n]
            out_v[i, :n] = cat_v[:n]
            out_c[i] = n
            out_t[i] = truncated or cat_k.size > cap
        return out_k, out_v, out_c, out_t

    def _run_scan_phase(
        self, lo_sw, hi_sw, cap, n_per_shard, max_retries: int = 8, groups=None
    ):
        """One vmapped gather over all shards + per-*component* version
        validation: shards linked by a cross-shard lane (``groups``) accept
        or retry TOGETHER, so every lane's stitched row comes from one
        snapshot (the single-tree linearization guarantee); independent
        shards validate independently, which is the conflict-window shrink
        sharding buys.  An accepted component's rows are frozen (its scans
        linearized at that validation point); only failed components' lanes
        retry — ``scan_retries`` accrues the retried lane count."""
        n_s, w = int(lo_sw.shape[0]), int(lo_sw.shape[1])
        if groups is None:
            groups = np.arange(n_s)
        buf_k = np.full((n_s, w, cap), int(EMPTY), np.int64)
        buf_v = np.zeros((n_s, w, cap), np.int64)
        buf_c = np.zeros((n_s, w), np.int32)
        buf_t = np.zeros((n_s, w), bool)
        n_per_shard = np.asarray(n_per_shard)
        pending = n_per_shard > 0  # lane-less shards are trivially done
        retried = 0
        # a scan_hook writer may push a shard past max_keys_per_shard: the
        # split (which restacks to S+1 shards) must not fire under this
        # loop's (S, w) lane routing — defer it to the next update round.
        self._scan_active += 1
        try:
            for _attempt in range(max_retries):
                snap = self.state
                out, touched = gather_until_frontier_fits(
                    self,
                    lambda fc: _v_scan(
                        snap, self.cfg, lo_sw, hi_sw, fc, cap,
                        self.narrow_scan, self.narrow,
                    ),
                )
                if self.scan_hook is not None:
                    self.scan_hook()
                snap_ver = np.asarray(snap.ver)
                live_ver = np.asarray(self.state.ver)
                touched_np = np.asarray(touched)
                shard_ok = np.zeros(n_s, bool)
                for s in np.nonzero(pending)[0]:
                    ids = np.unique(touched_np[s])
                    shard_ok[s] = np.array_equal(snap_ver[s][ids], live_ver[s][ids])
                accept = np.zeros(n_s, bool)
                for g in np.unique(groups[pending]):
                    members = pending & (groups == g)
                    if shard_ok[members].all():
                        accept |= members
                    else:  # whole component re-gathers next attempt
                        retried += int(n_per_shard[members].sum())
                if accept.any():
                    k_np = np.asarray(out.keys)
                    v_np = np.asarray(out.vals)
                    c_np = np.asarray(out.count)
                    t_np = np.asarray(out.truncated)
                    for s in np.nonzero(accept)[0]:
                        buf_k[s] = k_np[s]
                        buf_v[s] = v_np[s]
                        buf_c[s] = c_np[s]
                        buf_t[s] = t_np[s]
                    pending &= ~accept
                if not pending.any():
                    self._scan_retries += retried
                    return buf_k, buf_v, buf_c, buf_t
            raise ScanConflictError(
                f"forest scan phase: version validation failed {max_retries} "
                f"times on shards {np.nonzero(pending)[0].tolist()}"
            )
        finally:
            self._scan_active -= 1

    # -- point phases (vmapped search/combine → apply → retry → rebalance) -----

    def _point_phases(self, ops_sw, keys_sw, vals_sw):
        if self.mode == "elim":
            return self._combine_apply(ops_sw, keys_sw, vals_sw)
        return self._occ_round(ops_sw, keys_sw, vals_sw)

    def _combine_apply(self, ops_sw, keys_sw, vals_sw):
        self.state, pack = _v_search_combine(
            self.state, (ops_sw, keys_sw, vals_sw), self.cfg, self.narrow
        )
        ks, arrival, leaf_ids, slot, res, results, found = pack
        self.state, deferred = _v_apply(
            self.state, self.cfg, ks, arrival, leaf_ids, slot, res
        )
        self._drain_deferred(ks, res.final_val, arrival, deferred)
        self._fix_underfull_all()
        return results, found

    def _occ_round(self, ops_sw, keys_sw, vals_sw):
        """OCC baseline: per-shard duplicate-rank sub-rounds, executed as
        max-over-shards vmapped sub-rounds.  A shard whose own duplicate
        rank is exhausted runs all-NOP lanes in the tail sub-rounds — those
        are *not* sub-rounds it executes: its lanes are masked out, its
        ``subrounds`` counter stays put, and its durable/validation cost is
        zero (the per-shard early-exit of the ROADMAP follow-up; the vmap
        itself still spans all shards, as any SPMD program must)."""
        on = np.asarray(ops_sw)
        kn = np.asarray(keys_sw)
        n_s, w = on.shape
        rank = np.stack([_duplicate_ranks(on[s], kn[s]) for s in range(n_s)])
        # per-shard sub-round budget: rank r of a real op executes in
        # sub-round r, so shard s is live only while r ≤ max(rank[s]).
        live = on != OP_NOP  # (S, w)
        shard_max = np.where(
            live.any(axis=1), np.where(live, rank, 0).max(axis=1), -1
        )
        n_sub = int(rank.max()) + 1
        results = jnp.full((n_s, w), NOTFOUND, VAL_DTYPE)
        found = jnp.zeros((n_s, w), bool)
        rank_j = jnp.asarray(rank)
        for r in range(n_sub):
            active = shard_max >= r  # (S,) host bools: shard executes r
            m = (rank_j == r) & (ops_sw != OP_NOP)
            sub_ops = jnp.where(m, ops_sw, OP_NOP).astype(jnp.int32)
            sub_res, sub_found = self._combine_apply(sub_ops, keys_sw, vals_sw)
            results = jnp.where(m, sub_res, results)
            found = jnp.where(m, sub_found, found)
            st = self.state.stats
            self.state = self.state._replace(
                stats=st._replace(
                    subrounds=st.subrounds + jnp.asarray(active, jnp.int64)
                )
            )
        return results, found

    def _drain_deferred(self, ks, final_vals, arrival, deferred):
        guard = 0
        while bool(jnp.any(deferred)):
            guard += 1
            assert guard < 512 * self.cfg.max_height, "split loop diverged"
            uniq = np.asarray(
                _v_overfull(self.state, self.cfg, ks, deferred, self.narrow)
            )
            per_shard = [row[row != INT_MAX].astype(np.int32) for row in uniq]
            if any(r.size for r in per_shard):
                self._split_cascade(per_shard)
            self.state, deferred = _v_retry_insert(
                self.state, self.cfg, ks, final_vals, arrival, deferred, self.narrow
            )

    def _split_cascade(self, ids_per_shard: List[np.ndarray]):
        """Split the given full nodes, all shards per wave (the forest form
        of ``rounds._split_cascade``: nodes blocked by a full parent wait
        for the parent's split; ≤ 1 active node per parent per wave)."""
        n_s = self.n_shards
        work = [set(int(i) for i in ids) for ids in ids_per_shard]
        guard = 0
        while any(work):
            guard += 1
            assert guard < 512 * self.cfg.max_height * n_s, "split cascade diverged"
            size = np.asarray(self.state.size)
            parent = np.asarray(self.state.parent)
            alloc = np.asarray(self.state.alloc)
            ready_rows: List[np.ndarray] = []
            blocked_rows: List[List[int]] = []
            for s in range(n_s):
                ws = {n for n in work[s] if alloc[s, n] and size[s, n] >= self.cfg.b}
                work[s] = ws
                ready, blocked = [], []
                for n in sorted(ws):
                    p = int(parent[s, n])
                    if p >= 0 and size[s, p] >= self.cfg.b:
                        blocked.append(p)
                    else:
                        ready.append(n)
                if not ready:
                    # all blocked: queue the blocking parents for splitting
                    work[s] |= set(blocked)
                    ready_rows.append(np.zeros((0,), np.int32))
                    blocked_rows.append([])
                    continue
                rd = _independent_by_parent_np(
                    parent[s], np.asarray(ready, np.int32)
                )[: self._wave_w]
                ready_rows.append(rd)
                blocked_rows.append(blocked)
            if not any(r.size for r in ready_rows):
                continue
            self._ensure_capacity(2 * max(int(r.size) for r in ready_rows))
            node_ids = np.zeros((n_s, self._wave_w), np.int32)
            active = np.zeros((n_s, self._wave_w), bool)
            for s, rd in enumerate(ready_rows):
                node_ids[s, : rd.size] = rd
                active[s, : rd.size] = True
            self.state = _v_split(
                self.state, self.cfg, self._wave_w,
                jnp.asarray(node_ids), jnp.asarray(active),
            )
            for s, rd in enumerate(ready_rows):
                for n in rd.tolist():
                    work[s].discard(int(n))
                work[s] |= set(blocked_rows[s])

    def _fix_underfull_all(self):
        """Rebalance every shard's underfull non-root nodes, bottom-up
        vmapped waves; root shrink once a shard has no actionable wave."""
        guard = 0
        while True:
            guard += 1
            assert guard < 512 * self.cfg.max_height * self.n_shards, (
                "underfull loop diverged"
            )
            st = self.state
            alloc = np.asarray(st.alloc)
            size = np.asarray(st.size)
            parent = np.asarray(st.parent)
            level = np.asarray(st.level)
            is_leaf = np.asarray(st.is_leaf)
            root = np.asarray(st.root)
            sel_rows: List[np.ndarray] = []
            any_wave = False
            want_shrink = False
            for s in range(self.n_shards):
                r = int(root[s])
                under = alloc[s] & (size[s] < self.cfg.a) & (parent[s] >= 0)
                under[r] = False
                ids = np.nonzero(under)[0].astype(np.int32)
                actionable = ids[size[s][parent[s][ids]] >= 2] if ids.size else ids
                if actionable.size:
                    lv = level[s][actionable].min()
                    sel = actionable[level[s][actionable] == lv]
                    sel = _independent_by_parent_np(parent[s], sel)[: self._wave_w]
                    sel_rows.append(sel)
                    any_wave = True
                else:
                    sel_rows.append(np.zeros((0,), np.int32))
                    if (not is_leaf[s, r]) and int(size[s, r]) == 1:
                        want_shrink = True
            if any_wave:
                node_ids = np.zeros((self.n_shards, self._wave_w), np.int32)
                active = np.zeros((self.n_shards, self._wave_w), bool)
                for s, sel in enumerate(sel_rows):
                    node_ids[s, : sel.size] = sel
                    active[s, : sel.size] = True
                self.state = _v_underfull(
                    self.state, self.cfg, self._wave_w,
                    jnp.asarray(node_ids), jnp.asarray(active),
                )
                continue
            if want_shrink:
                # per-shard `can` guard inside shrink_root makes the vmapped
                # call exact: only single-child internal roots collapse.
                self.state = _v_shrink(self.state, self.cfg)
                continue
            break

    # -- shard-overflow splitting ---------------------------------------------

    def _live_key_counts(self) -> np.ndarray:
        st = self.state
        leaf = np.asarray(st.is_leaf) & np.asarray(st.alloc)
        return np.sum(np.where(leaf, np.asarray(st.size), 0), axis=1)

    def _maybe_split_shards(self):
        if self.max_keys_per_shard is None or self._in_split or self._scan_active:
            return
        guard = 0
        while True:
            counts = self._live_key_counts()
            s = int(np.argmax(counts))
            if int(counts[s]) <= self.max_keys_per_shard:
                return
            guard += 1
            assert guard < 64, "shard split diverged"
            self._split_shard(s)

    def _split_shard(self, s: int):
        """Split shard ``s`` at its median key: sweep the upper half off with
        fused scan+delete rounds, restack with a fresh shard at ``s + 1``,
        and re-insert the swept keys through the router (which now targets
        the new shard)."""
        self._in_split = True
        try:
            st = self.state
            leaf = np.asarray(st.is_leaf)[s] & np.asarray(st.alloc)[s]
            krows = np.asarray(st.keys)[s][leaf]
            ks = krows[krows != int(EMPTY)]
            if ks.size < 2:
                return
            ks.sort()
            m = int(ks[ks.size // 2])  # > ks[0] ≥ bounds[s]; < bounds[s+1]
            hi_bound = self._bounds[s + 1]
            moved_k: List[int] = []
            moved_v: List[int] = []
            cap = max(256, self.cfg.b)
            while True:
                out = self.scan_delete_round([m], [hi_bound], cap=cap)
                n = int(np.asarray(out.count)[0])
                moved_k.extend(int(k) for k in np.asarray(out.keys)[0, :n])
                moved_v.extend(int(v) for v in np.asarray(out.vals)[0, :n])
                if not bool(np.asarray(out.truncated)[0]):
                    break
            per = [self.shard_state(i) for i in range(self.n_shards)]
            per.insert(s + 1, make_tree(self.cfg))
            self.state = _stack_states(per)
            self.n_shards += 1
            self._splits = np.insert(self._splits, s, m)
            self._rebuild_bounds()
            bs = 1024
            for i in range(0, len(moved_k), bs):
                ck = moved_k[i : i + bs]
                cv = moved_v[i : i + bs]
                self.apply_round(np.full(len(ck), OP_INSERT, np.int32), ck, cv)
        finally:
            self._in_split = False

    # -- pool management --------------------------------------------------------

    def _ensure_capacity(self, need_nodes: int):
        """Grow every shard's pool when the *fullest* shard has fewer than
        ``need + slack`` free nodes (stacked pools share one capacity).
        The 2·wave_w term keeps each pool large enough for a full-width
        split wave's allocation (see ``ABTree._ensure_capacity``)."""
        need = 2 * need_nodes + 4 * self.cfg.max_height + 2 * self._wave_w + 8
        n_alloc = int(jnp.max(jnp.sum(self.state.alloc, axis=1)))
        cap = self.cfg.capacity
        if cap - n_alloc >= need:
            return
        self._grow(max(cap * 2, cap + need))

    def _grow(self, new_cap: int):
        # node axis is 1 on the stacked state (axis 0 is the shard axis)
        self.state = grow_pool(self.state, new_cap - self.cfg.capacity, axis=1)
        self.cfg = self.cfg._replace(capacity=new_cap)


def check_forest_invariants(forest: ABForest) -> None:
    """Per-shard structural invariants plus the forest's own: every key in
    shard ``s`` lies within ``[bounds[s], bounds[s+1])``."""
    from repro.core.oracle import check_invariants, tree_contents

    for s in range(forest.n_shards):
        st = forest.shard_state(s)
        check_invariants(st, forest.cfg)
        lo, hi = forest._bounds[s], forest._bounds[s + 1]
        for k in tree_contents(st, forest.cfg):
            assert lo <= k < hi, (
                f"shard {s}: key {k} outside shard range [{lo}, {hi})"
            )
