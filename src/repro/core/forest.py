"""ABForest: a key-partitioned forest of (a,b)-trees on the unified engine.

The round-based OCC/elimination design is embarrassingly shardable: lanes on
disjoint key ranges never conflict, so partitioning the key space by split
points turns one contended tree into ``n_shards`` independent ones — and the
SPMD formulation makes the partition *free* on device: every shard's round
is the same program, so all shards execute as ONE ``jax.vmap`` of the
round-engine phase kernels.

Since PR 5 this module contains NO round execution of its own: every host
loop (split cascade, rebalance waves, deferred-insert drain, occ
sub-rounds, the optimistic scan retry) lives in ``core/rounds.py`` in its
leading-shard ``(S, wave_w)`` form, shared verbatim with ``ABTree`` (the
S = 1 case).  What remains here is what is genuinely *forest*: the key
partition (split points → router bounds), the shard lifecycle (overflow
splits / restacks), the stacked-state representation, and the per-shard
durability surface (``shard_state`` / ``take_dirty``).

Representation
    All shard trees live in one stacked ``TreeState`` whose every leaf array
    carries a leading shard axis (``keys``: (S, N, b), ``root``: (S,), …).
    This is the layout every later scaling step wants: multi-device
    placement is ``shard_map`` over axis 0, per-shard durability
    (``core/durable.py``'s ``DurableForest``) journals slices of it.

Routing (host, per round — performed inside ``rounds.execute_plan``)
    ``elimination.lane_masks`` classifies the batch's lanes; point lanes go
    to ``shard = searchsorted(splits, key)``; OP_RANGE lanes are split at
    shard boundaries into per-shard sub-lanes.  Sub-lane scan rows are
    stitched back in key order (shards are ordered by key range, rows within
    a shard are ascending, so concatenation is globally sorted).

Ragged-width bucketing contract
    Scan lanes are flat-packed: all shards' sub-lanes concatenate into ONE
    1-D block whose width is pow2(true sub-lane count), and each lane
    gathers through its own shard id on the stacked state — no per-shard
    rectangle, no max-over-shards padding, and a retry re-packs only the
    lanes of still-conflicted shard components.  Point lanes keep the
    (S, W) rectangle (arrival-order packing needs per-shard slots) with
    W = pow2(max per-shard lane count); the repartition actions below
    exist precisely to keep that max — and hence the padding every later
    ``shard_map`` step would ship over the wire — low.  The occ mode's
    duplicate-rank passes re-pack only their live lanes the same way.
    Widths always bucket to powers of two (bounded recompiles), and pad
    waste is observable via the ``router_pack_width`` /
    ``pad_waste_frac`` gauges and per-pack tracer span args.

Load-aware repartitioning
    The router feeds two host-side signals: per-shard routed-lane counts
    (the windowed hot-shard detector behind ``hot_shard_hook``) and a ring
    buffer of recently routed keys (``_note_key_sample``).  With
    ``auto_repartition=True``, a window fire also queues ONE pending
    action; it is consumed at a round boundary when no scan is in flight
    and no restack is running.  The state machine:

        IDLE --window fire (hot frac ≥ max(hot_shard_frac, 1.5/S))--> PENDING
        PENDING --round boundary, quiescent--> MERGE | REBALANCE --> IDLE

    REBALANCE moves the boundary between the hot shard and its colder
    neighbor to the load-weighted quantile of the sampled keys (NOT the
    key-count median — skew lives in traffic, not population): the moved
    range is swept off with fused scan+delete rounds and re-inserted
    through the router, reusing the shard-overflow split machinery.
    MERGE instead retires the coldest shard (window share ≤
    ``cold_shard_frac``) into a neighbor the same way, shrinking S.
    Either way ``repartition_hook(kind, a, b)`` fires after the partition
    changes — the durable layer's journal re-keying point (mirrors
    ``split_hook``).  Overflow splits also prefer the sampled-load
    quantile as their split point, falling back to the key median when
    the sample is thin.  Uniform traffic never reaches PENDING: no shard
    dominates a window, so the partition stays put.

Semantics
    Identical to ``ABTree`` — they run the same engine: a forest round is
    one round, scans linearize before the round's net writes, point lanes
    apply in arrival order per key (stable packing preserves arrival order
    within a shard, and all ops on one key land in one shard).
    ``DictOracle`` remains the single reference: a forest with ANY shard
    count must be oracle-equivalent.

Conflict granularity
    Scan validation is per shard *component* (see the scan phase in
    ``core/rounds.py``): shards linked by a cross-shard lane validate
    jointly, independent shards independently, so a concurrent writer
    (``scan_hook``, modeling other engine replicas) invalidates only the
    components whose versions it bumped.  ``scan_retries`` counts retried
    *lanes* (ops), the honest per-op cost the sharding is buying down.

Shard overflow
    With ``max_keys_per_shard`` set, a shard growing past the threshold is
    split: the median key becomes a new split point, the upper half is swept
    off the hot shard with fused scan+delete rounds, a fresh shard is
    restacked in at the new position, and the swept keys re-insert through
    the normal router (which now targets the new shard).  ``split_hook``
    fires after the restack — the durable layer uses it to re-key its
    per-shard journals and force snapshots of the two affected shards.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elimination as elim
from repro.core import rounds
from repro.core.abtree import (
    EMPTY,
    KEY_MIN,
    OP_DELETE,
    OP_INSERT,
    RoundOutput,
    ScanOutput,
    TreeConfig,
    TreeState,
    grow_pool,
    make_tree,
)
from repro.obs.metrics import (
    MetricsRegistry,
    RegistryBackedCounters,
    engine_collector,
)
from repro.obs.recorder import Recorder
from repro.obs.tracer import NULL_TRACER


def _stack_states(states: List[TreeState]) -> TreeState:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


class ABForest(RegistryBackedCounters):
    """Key-partitioned forest of batched (a,b)-trees; ``ABTree``-compatible
    round API (``apply_round`` / ``scan_round`` / ``scan_delete_round`` /
    ``scan_stream``), one vmapped round across all shards per call."""

    def __init__(
        self,
        n_shards: int = 2,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        *,
        splits=None,
        key_space: Optional[Tuple[int, int]] = None,
        narrow_scan: bool = False,
        narrow: bool = False,
        max_keys_per_shard: Optional[int] = None,
        hot_shard_frac: float = 0.5,
        hot_shard_window: int = 256,
        auto_repartition: bool = False,
        cold_shard_frac: float = 0.05,
    ):
        assert mode in ("elim", "occ")
        assert 2 <= cfg.a <= cfg.b // 2, "(a,b) requires 2 ≤ a ≤ b/2"
        assert n_shards >= 1
        self.cfg = cfg
        self.mode = mode
        self.n_shards = int(n_shards)
        # same contracts as ABTree: narrow_scan = int32 keys/values on the
        # scan gather; narrow = the whole search path (vmapped fused
        # descent+probe kernel + Pallas frontier compaction per shard).
        self.narrow = narrow
        self.narrow_scan = narrow_scan or narrow
        if splits is not None:
            splits = np.asarray(splits, np.int64).reshape(-1)
            assert splits.size == self.n_shards - 1, (
                f"need {self.n_shards - 1} split points, got {splits.size}"
            )
            assert np.all(np.diff(splits) > 0), "splits must be strictly ascending"
        else:
            lo, hi = key_space if key_space is not None else (0, 1 << 63)
            assert hi - lo >= self.n_shards, "key_space too small for n_shards"
            step = (hi - lo) // self.n_shards
            splits = lo + step * np.arange(1, self.n_shards, dtype=np.int64)
        self._splits = splits.astype(np.int64)
        self._rebuild_bounds()
        self.state: TreeState = _stack_states(
            [make_tree(cfg) for _ in range(self.n_shards)]
        )
        self.max_keys_per_shard = max_keys_per_shard
        self._in_split = False
        self._scan_active = 0  # defers shard splits while a scan is in flight
        self._wave_w = 64  # pad width for structural waves (recompile-bounded)
        self._scan_frontier = 8  # leaf-frontier pad width (doubles on overflow)
        # optimistic-reader hook, as on ABTree: called between a scan's
        # gather and its per-shard version validation (models update rounds
        # from other engine replicas).
        self.scan_hook = None
        # durability hook, as on ABTree: fires after every executed occ
        # sub-round (DurableForest commits per sub-round in occ mode).
        self.subround_hook = None
        # shard-lifecycle hook: split_hook(s) fires after shard s has been
        # split and the fresh shard restacked at s + 1 (before the swept
        # keys re-insert) — the durable layer's journal re-keying point.
        self.split_hook = None
        # telemetry: the registry is the one store behind the legacy
        # counter properties; the tracer defaults to the strict no-op.
        # The flight recorder is always on (bounded ring; install
        # ``Recorder(enabled=False)`` to opt out).
        self.metrics = MetricsRegistry()
        self.metrics.add_collector(engine_collector(self))
        self.tracer = NULL_TRACER
        self.recorder = Recorder()
        # forest-level counters (device stats stay per shard; see stats()).
        self._rounds = 0
        self._scans = 0
        self._scan_retries = 0
        # hot-shard detection (fed by the router via _note_shard_load):
        # over each window of ``hot_shard_window`` routed lanes, if one
        # shard received ≥ ``hot_shard_frac`` of them the hook fires with
        # (shard, info) — the detection primitive for load-aware
        # re-partitioning (ROADMAP item 2).
        self.hot_shard_hook = None
        self.hot_shard_frac = float(hot_shard_frac)
        self.hot_shard_window = int(hot_shard_window)
        self._shard_load = np.zeros(self.n_shards, np.int64)
        # load-aware repartitioning (see module docstring): a window fire
        # queues ONE pending action; consumed at a quiescent round boundary.
        self.auto_repartition = bool(auto_repartition)
        self.cold_shard_frac = float(cold_shard_frac)
        self._repartition_pending = None
        # shard-lifecycle hook: repartition_hook(kind, a, b) fires after a
        # boundary rebalance ("rebalance", hot, neighbor) or a cold-shard
        # merge ("merge", retired, survivor-after-restack) — the durable
        # layer's journal re-keying point, mirroring split_hook.
        self.repartition_hook = None
        # ring buffer of recently routed keys: the weighted-quantile sample
        # behind load-aware split points and boundary moves.
        self._key_sample = np.zeros(4096, np.int64)
        self._key_sample_n = 0

    # -- unified-engine holder protocol ---------------------------------------

    @property
    def stacked(self) -> TreeState:
        """The (S, …) stacked state the unified engine executes on — for the
        forest this IS the canonical representation."""
        return self.state

    @stacked.setter
    def stacked(self, st: TreeState):
        self.state = st

    # -- routing --------------------------------------------------------------

    def _rebuild_bounds(self):
        self._bounds = (
            [int(KEY_MIN)] + [int(x) for x in self._splits] + [int(EMPTY)]
        )

    def _shard_of(self, keys: np.ndarray) -> np.ndarray:
        return np.searchsorted(self._splits, keys, side="right")

    def _note_shard_load(self, counts):
        """Router callback: accumulate per-shard routed-lane counts and
        fire ``hot_shard_hook(shard, info)`` when one shard dominates the
        current window (see __init__).  The window resets either way once
        full, so sustained skew fires repeatedly and transient skew ages
        out.  With ``auto_repartition`` the fire also queues the pending
        repartition action (window snapshot included) for the next
        quiescent round boundary."""
        if self.hot_shard_hook is None and not self.auto_repartition:
            return
        if self._in_split:
            # sweep/re-insert lanes of a shard split or repartition in
            # progress are internal traffic, not offered load — counting
            # them would make every action look like a fresh hot spot.
            return
        counts = np.asarray(counts, np.int64)
        if counts.size != self._shard_load.size:
            # shard count changed mid-window (shard split): restart clean
            self._shard_load = np.zeros(self.n_shards, np.int64)
        self._shard_load[: counts.size] += counts
        total = int(self._shard_load.sum())
        if total < self.hot_shard_window:
            return
        s = int(np.argmax(self._shard_load))
        frac = float(self._shard_load[s]) / total
        lanes = int(self._shard_load[s])
        win = self._shard_load.copy()
        self._shard_load[:] = 0
        # "hot" is relative to fair share: a fixed fraction reads very
        # differently at S=2 (fair share 0.5) than at S=8 (0.125), so the
        # trip point is the larger of the configured frac and 1.5x fair
        # share — with a bare 0.5 frac a 2-shard forest fires on almost
        # every window and the boundary thrashes.
        thresh = max(self.hot_shard_frac, 1.5 / self.n_shards)
        if frac >= thresh and self.n_shards > 1:
            self.metrics.inc("hot_shard_events", shard=s)
            info = {
                "shard": s,
                "frac": frac,
                "lanes": lanes,
                "window": total,
                "bounds": (self._bounds[s], self._bounds[s + 1]),
                "window_loads": win,
            }
            if self.hot_shard_hook is not None:
                self.hot_shard_hook(s, info)
            if self.auto_repartition:
                self._repartition_pending = info
                if self.recorder.enabled:
                    self.recorder.transition(
                        "repartition_pending",
                        shard=s,
                        frac=round(float(frac), 4),
                        window_loads=[int(x) for x in win],
                    )

    def _note_key_sample(self, keys):
        """Router callback: fold routed keys (point keys and scan lower
        bounds) into the fixed-size ring sample behind ``_load_quantile``."""
        if self._in_split:
            return  # internal sweep/re-insert keys are not offered load
        keys = np.asarray(keys, np.int64).reshape(-1)
        if keys.size == 0:
            return
        cap = self._key_sample.size
        if keys.size >= cap:
            self._key_sample[:] = keys[-cap:]
        else:
            start = self._key_sample_n % cap
            end = start + keys.size
            if end <= cap:
                self._key_sample[start:end] = keys
            else:
                k = cap - start
                self._key_sample[start:] = keys[:k]
                self._key_sample[: end - cap] = keys[k:]
        self._key_sample_n += keys.size

    def _load_quantile(self, lo, hi, q, default=None):
        """q-quantile of the *observed* (routed) keys inside ``[lo, hi)`` —
        the load-weighted split point.  Falls back to ``default`` when the
        sample holds fewer than 32 in-range keys."""
        n = min(self._key_sample_n, self._key_sample.size)
        sel = self._key_sample[:n]
        sel = np.sort(sel[(sel >= lo) & (sel < hi)])
        if sel.size < 32:
            return default
        return int(sel[min(int(q * sel.size), sel.size - 1)])

    # -- public API -----------------------------------------------------------

    def apply_round(self, ops, keys, vals=None, *, scan_cap: int = 128) -> RoundOutput:
        """Apply one round of concurrent ops (semantics of
        ``ABTree.apply_round``, including fused OP_RANGE lanes): the router
        partitions lanes by key range, all shards execute as one vmapped
        round, and per-lane results come back batch-aligned.  Cross-shard
        range lanes are split into per-shard sub-lanes and their rows
        stitched back in key order."""
        plan = rounds.build_plan(ops, keys, vals, scan_cap=scan_cap)
        return rounds.execute_plan(self, plan)

    def scan_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        """Batched range scans (semantics of ``ABTree.scan_round``): per
        query the ≤ ``cap`` smallest keys in ``[lo_i, hi_i)``, ascending,
        stitched across shards in key order."""
        return rounds.execute_scan(self, lo, hi, cap=cap, max_retries=max_retries)

    def scan_delete_round(
        self, lo, hi, cap: int = 128, max_retries: int = 8
    ) -> ScanOutput:
        """ONE fused forest round that gathers every key in ``[lo_i, hi_i)``
        (≤ ``cap`` smallest per query, stitched across shards) and deletes
        exactly the *emitted* keys — keys a truncated page did not emit
        survive for the caller's next chunk, preserving the
        one-fused-round-per-chunk sweep contract of ``SessionIndex``."""
        return rounds.execute_scan_delete(self, lo, hi, cap=cap, max_retries=max_retries)

    def scan_stream(self, lo, hi, cap: int = 128):
        """Stream all (key, value) pairs in ``[lo, hi)`` ascending by
        chaining per-shard cursors: each page queries only the shard holding
        the cursor, so arbitrarily long cross-shard scans stay bounded at
        ``cap`` entries (and one shard's gather) per round."""
        return rounds.execute_scan_stream(self, lo, hi, cap)

    def find(self, key) -> Optional[int]:
        out = self.apply_round([elim.OP_FIND], [key])
        return int(out.results[0]) if bool(out.found[0]) else None

    def insert(self, key, val):
        out = self.apply_round([OP_INSERT], [key], [val])
        return int(out.results[0]) if bool(out.found[0]) else None

    def delete(self, key):
        out = self.apply_round([OP_DELETE], [key])
        return int(out.results[0]) if bool(out.found[0]) else None

    def items(self) -> dict:
        """Host-side snapshot of the forest contents (sorted by key)."""
        st = self.state
        keys = np.asarray(st.keys)
        vals = np.asarray(st.vals)
        leaf = np.asarray(st.is_leaf) & np.asarray(st.alloc)
        out = {}
        for s in range(self.n_shards):
            for nid in np.nonzero(leaf[s])[0]:
                for j in range(self.cfg.b):
                    k = int(keys[s, nid, j])
                    if k != int(EMPTY):
                        out[k] = int(vals[s, nid, j])
        return dict(sorted(out.items()))

    def shard_state(self, s: int) -> TreeState:
        """One shard's (unstacked) TreeState — for invariant checks and the
        per-shard durability layer (``DurableForest`` journals these
        slices)."""
        return jax.tree_util.tree_map(lambda x: x[s], self.state)

    def take_dirty(self) -> List[np.ndarray]:
        """Per-shard node ids dirtied since the last durable commit (then
        reset) — each shard's journal segment is exactly one of these
        lists, so an untouched shard flushes nothing."""
        d = np.asarray(self.state.dirty)
        self.state = self.state._replace(dirty=jnp.zeros_like(self.state.dirty))
        return [
            np.nonzero(d[s])[0].astype(np.int32) for s in range(self.n_shards)
        ]

    def stats(self) -> dict:
        """Forest-level stats: device counters summed over shards;
        ``rounds`` counts forest rounds (one vmapped round = 1, however many
        shards it spans) and ``scan_retries`` counts retried *lanes* — the
        per-op conflict cost per-shard validation buys down."""
        agg = {
            k: int(np.asarray(v).sum())
            for k, v in self.state.stats._asdict().items()
        }
        agg["rounds"] = self._rounds
        agg["scans"] = self._scans
        agg["scan_retries"] = self._scan_retries
        return agg

    def stats_per_shard(self) -> List[dict]:
        return [
            {k: int(np.asarray(v)[s]) for k, v in self.state.stats._asdict().items()}
            for s in range(self.n_shards)
        ]

    @property
    def splits(self) -> np.ndarray:
        return self._splits.copy()

    # -- shard-overflow splitting ---------------------------------------------

    def _live_key_counts(self) -> np.ndarray:
        st = self.state
        leaf = np.asarray(st.is_leaf) & np.asarray(st.alloc)
        return np.sum(np.where(leaf, np.asarray(st.size), 0), axis=1)

    def _maybe_split_shards(self):
        self._maybe_repartition()
        if self.max_keys_per_shard is None or self._in_split or self._scan_active:
            return
        guard = 0
        while True:
            counts = self._live_key_counts()
            s = int(np.argmax(counts))
            if int(counts[s]) <= self.max_keys_per_shard:
                return
            guard += 1
            assert guard < 64, "shard split diverged"
            self._split_shard(s)

    def _sweep_range(self, lo: int, hi: int) -> Tuple[List[int], List[int]]:
        """Sweep every key in ``[lo, hi)`` off the forest with fused
        scan+delete rounds (the shared move primitive behind overflow
        splits, boundary rebalances and cold-shard merges); returns the
        evicted (keys, vals)."""
        moved_k: List[int] = []
        moved_v: List[int] = []
        cap = max(256, self.cfg.b)
        # The bulk sweep needs a far wider leaf frontier than steady-state
        # point scans; _scan_frontier is sticky, so restore it afterwards or
        # every later scan round pays the sweep's width forever (the wide
        # executable stays jit-cached for the next sweep regardless).
        frontier0 = self._scan_frontier
        try:
            while True:
                out = self.scan_delete_round([lo], [hi], cap=cap)
                n = int(np.asarray(out.count)[0])
                moved_k.extend(int(k) for k in np.asarray(out.keys)[0, :n])
                moved_v.extend(int(v) for v in np.asarray(out.vals)[0, :n])
                if not bool(np.asarray(out.truncated)[0]):
                    break
        finally:
            self._scan_frontier = frontier0
        return moved_k, moved_v

    def _reinsert(self, moved_k: List[int], moved_v: List[int]):
        bs = 1024
        for i in range(0, len(moved_k), bs):
            ck = moved_k[i : i + bs]
            cv = moved_v[i : i + bs]
            self.apply_round(np.full(len(ck), OP_INSERT, np.int32), ck, cv)

    def _split_shard(self, s: int):
        """Split shard ``s``: sweep the upper part off with fused
        scan+delete rounds, restack with a fresh shard at ``s + 1``, and
        re-insert the swept keys through the router (which now targets the
        new shard).  The split point prefers the load-weighted quantile of
        observed keys (skew-aware: balances *traffic*, not population) and
        falls back to the shard's key median when the sample is thin."""
        self._in_split = True
        try:
            st = self.state
            leaf = np.asarray(st.is_leaf)[s] & np.asarray(st.alloc)[s]
            krows = np.asarray(st.keys)[s][leaf]
            ks = krows[krows != int(EMPTY)]
            if ks.size < 2:
                return
            ks.sort()
            m = int(ks[ks.size // 2])  # > ks[0] ≥ bounds[s]; < bounds[s+1]
            lm = self._load_quantile(self._bounds[s], self._bounds[s + 1], 0.5)
            if lm is not None and int(ks[0]) < lm <= int(ks[-1]):
                m = lm  # both sides stay non-empty
            hi_bound = self._bounds[s + 1]
            moved_k, moved_v = self._sweep_range(m, hi_bound)
            per = [self.shard_state(i) for i in range(self.n_shards)]
            per.insert(s + 1, make_tree(self.cfg))
            self.state = _stack_states(per)
            self.n_shards += 1
            self._splits = np.insert(self._splits, s, m)
            self._rebuild_bounds()
            # keep telemetry attribution aligned with the restack: shift
            # per-shard metric cells ≥ s+1 up one, reset the load window.
            self.metrics.inc("shard_splits", shard=s)
            self.metrics.insert_shard(s + 1)
            self._shard_load = np.zeros(self.n_shards, np.int64)
            if self.recorder.enabled:
                self.recorder.transition(
                    "split", shard=s, split_key=int(m),
                    n_shards=self.n_shards, moved=len(moved_k),
                )
            if self.split_hook is not None:
                self.split_hook(s)
            self._reinsert(moved_k, moved_v)
        finally:
            self._in_split = False

    # -- load-aware repartitioning (see module docstring) -----------------------

    def _maybe_repartition(self):
        """Consume the pending repartition action, if any, at a quiescent
        round boundary: prefer retiring a cold shard (window share ≤
        ``cold_shard_frac``), otherwise move the hot boundary."""
        info = self._repartition_pending
        if info is None or self._in_split or self._scan_active:
            return
        self._repartition_pending = None
        if self.n_shards < 2:
            return
        win = np.asarray(info.get("window_loads"), np.int64)
        if win.size != self.n_shards:
            return  # shard count changed since detection: signal is stale
        s = int(info["shard"])
        total = int(win.sum())
        c = int(np.argmin(win))
        # engine-track span (``shard=`` would route it onto the per-shard
        # attribution track): the hot shard rides as a plain arg instead.
        with self.tracer.span("repartition", hot_shard=s, hot_frac=info["frac"]) as sp:
            if (
                c != s
                and total > 0
                and float(win[c]) / total <= self.cold_shard_frac
                and self._merge_cold(c)
            ):
                sp.note(action="merge", cold=c)
                # the merge restacked the shards: the hot shard's cell is
                # s - 1 when the retired shard sat below it.
                self.metrics.inc("repartitions", shard=s if c > s else s - 1)
                if self.recorder.enabled:
                    self.recorder.transition(
                        "repartition", action="merge", cold=c, hot_shard=s,
                        n_shards=self.n_shards,
                    )
            elif self._rebalance_boundary(s, win):
                sp.note(action="rebalance")
                self.metrics.inc("repartitions", shard=s)
                if self.recorder.enabled:
                    self.recorder.transition(
                        "repartition", action="rebalance", hot_shard=s,
                        n_shards=self.n_shards,
                    )
            else:
                sp.note(action="noop")
                if self.recorder.enabled:
                    self.recorder.transition(
                        "repartition", action="noop", hot_shard=s,
                        n_shards=self.n_shards,
                    )

    def _rebalance_boundary(self, s: int, win: np.ndarray) -> bool:
        """Move the boundary between hot shard ``s`` and its colder
        neighbor ``t`` to the load-weighted quantile that would even their
        observed loads: sweep the moved range off ``s``, shift the split
        point, re-insert through the router (keys now land on ``t``)."""
        nbrs = [t for t in (s - 1, s + 1) if 0 <= t < self.n_shards]
        if not nbrs:
            return False
        t = min(nbrs, key=lambda i: int(win[i]))
        load_s, load_t = int(win[s]), int(win[t])
        if load_s <= load_t or load_s == 0:
            return False
        phi = (load_s - load_t) / (2.0 * load_s)  # load share to hand over
        lo_b, hi_b = self._bounds[s], self._bounds[s + 1]
        q = (1.0 - phi) if t == s + 1 else phi
        m = self._load_quantile(lo_b, hi_b, q)
        if m is None or not (lo_b < m < hi_b):
            return False
        self._in_split = True
        try:
            if t == s + 1:
                moved_k, moved_v = self._sweep_range(m, hi_b)
                self._splits[s] = m
            else:
                moved_k, moved_v = self._sweep_range(lo_b, m)
                self._splits[s - 1] = m
            self._rebuild_bounds()
            self.metrics.inc("boundary_moves", shard=s)
            self._shard_load = np.zeros(self.n_shards, np.int64)
            if self.repartition_hook is not None:
                self.repartition_hook("rebalance", s, t)
            self._reinsert(moved_k, moved_v)
        finally:
            self._in_split = False
        return True

    def _merge_cold(self, c: int) -> bool:
        """Retire cold shard ``c`` into a neighbor: sweep its whole range
        off, drop the shard from the stack and the boundary between the
        pair, re-insert through the router (keys land on the survivor)."""
        nbrs = [t for t in (c - 1, c + 1) if 0 <= t < self.n_shards]
        if not nbrs:
            return False
        t = nbrs[0] if len(nbrs) == 1 else min(
            nbrs, key=lambda i: int(self._live_key_counts()[i])
        )
        if self.max_keys_per_shard is not None:
            counts = self._live_key_counts()
            if int(counts[c]) + int(counts[t]) > self.max_keys_per_shard:
                return False  # survivor would overflow: not worth merging
        self._in_split = True
        try:
            moved_k, moved_v = self._sweep_range(
                self._bounds[c], self._bounds[c + 1]
            )
            per = [self.shard_state(i) for i in range(self.n_shards)]
            per.pop(c)
            self.state = _stack_states(per)
            self.n_shards -= 1
            self._splits = np.delete(self._splits, c - 1 if t == c - 1 else c)
            self._rebuild_bounds()
            # re-key BEFORE attributing: remove_shard(c) pops cell c and
            # shifts the cells above it down, so incrementing the survivor
            # first would land on cell c when t == c + 1 (the survivor's
            # post-restack index equals the retired index) and be orphaned
            # by the pop.  Mirror of insert_shard's re-keying on splits.
            self.metrics.remove_shard(c)
            self.metrics.inc("shard_merges", shard=t if t < c else t - 1)
            self._shard_load = np.zeros(self.n_shards, np.int64)
            if self.repartition_hook is not None:
                self.repartition_hook("merge", c, t if t < c else t - 1)
            self._reinsert(moved_k, moved_v)
        finally:
            self._in_split = False
        return True

    # -- pool management --------------------------------------------------------

    def _ensure_capacity(self, need_nodes: int):
        """Grow every shard's pool when the *fullest* shard has fewer than
        ``need + slack`` free nodes (stacked pools share one capacity).
        The 2·wave_w term keeps each pool large enough for a full-width
        split wave's allocation (see ``ABTree._ensure_capacity``)."""
        need = 2 * need_nodes + 4 * self.cfg.max_height + 2 * self._wave_w + 8
        n_alloc = int(jnp.max(jnp.sum(self.state.alloc, axis=1)))
        cap = self.cfg.capacity
        if cap - n_alloc >= need:
            return
        self._grow(max(cap * 2, cap + need))

    def _grow(self, new_cap: int):
        # node axis is 1 on the stacked state (axis 0 is the shard axis)
        self.state = grow_pool(self.state, new_cap - self.cfg.capacity, axis=1)
        self.cfg = self.cfg._replace(capacity=new_cap)


def check_forest_invariants(forest: ABForest) -> None:
    """Per-shard structural invariants plus the forest's own: every key in
    shard ``s`` lies within ``[bounds[s], bounds[s+1])``."""
    from repro.core.oracle import check_invariants, tree_contents

    for s in range(forest.n_shards):
        st = forest.shard_state(s)
        check_invariants(st, forest.cfg)
        lo, hi = forest._bounds[s], forest._bounds[s + 1]
        for k in tree_contents(st, forest.cfg):
            assert lo <= k < hi, (
                f"shard {s}: key {k} outside shard range [{lo}, {hi})"
            )
