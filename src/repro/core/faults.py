"""Fault-injection failpoints for the durable path (seeded, deterministic).

The paper's durability argument assumes fail-stop crashes at arbitrary
points; ``CrashPoint`` (below) injects exactly those.  A production durable
forest also faces *transient* I/O faults — EIO on fsync, ENOSPC mid-segment,
torn/short writes that a lying volatile cache "persisted", rename failures,
pathological fsync latency.  ``FaultPlan`` is the failpoint registry that
injects all of them at the named I/O sites of ``core/durable.py``, so the
retry / circuit-breaker / corruption-recovery machinery can be driven
deterministically under load (``benchmarks/fault_soak.py``).

Failpoint sites (each consulted once per I/O operation of a commit):

  ``segment_write``    serializing + writing one shard's journal file
  ``segment_fsync``    fsync of a journal file (runs on the flush pool)
  ``sidecar_write``    the audit forensics sidecar write + fsync
  ``manifest_write``   writing MANIFEST.tmp
  ``manifest_fsync``   fsync of MANIFEST.tmp
  ``manifest_rename``  the atomic os.replace (the commit point)
  ``dir_fsync``        the directory-entry fsync after the rename

Fault kinds:

  ``eio``          OSError(EIO) — transient I/O error (retryable)
  ``enospc``       OSError(ENOSPC) — disk full (retryable; clears when the
                   spec's ``times`` budget is exhausted)
  ``torn``         SILENT short write: the write "succeeds" but only
                   ``torn_frac`` of the bytes reach disk (models a volatile
                   cache lost after fsync returned) — only meaningful at
                   ``segment_write``/``sidecar_write``; detected at
                   recovery by the journal CRCs
  ``rename_fail``  OSError(EIO) out of os.replace
  ``latency``      sleeps ``latency_s`` then succeeds (a sick-disk stall)
  ``crash``        raises SimulatedCrash (fail-stop kill at an I/O site)

Determinism: whether a spec fires NEVER depends on wall clock or thread
scheduling.  Selection is a pure function of ``(plan seed, site, commit
index, shard, attempt)`` — probabilistic specs hash that tuple into a
uniform draw, windowed specs compare the commit index — so a seeded soak
run injects the identical fault schedule on every machine, even though the
per-shard journal writes run on a thread pool.  (The only shared mutable
state, the per-spec ``times`` budget, is decremented under a lock; specs
used with parallel writers should prefer commit windows over ``times`` when
exact cross-thread determinism matters.)

``CrashPoint`` is the original one-shot fail-stop injector; ``FaultPlan``
generalizes it — a plan carries any number of crash points (plus fault
specs), and ``as_fault_plan`` lifts a bare ``CrashPoint`` (or ``None``)
into a plan so ``core/durable.py`` handles exactly one injection surface.
"""
from __future__ import annotations

import errno
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

__all__ = [
    "SimulatedCrash",
    "InjectedFault",
    "CrashPoint",
    "FaultSpec",
    "FaultPlan",
    "as_fault_plan",
    "FAULT_SITES",
    "FAULT_KINDS",
]

FAULT_SITES = (
    "segment_write",
    "segment_fsync",
    "sidecar_write",
    "manifest_write",
    "manifest_fsync",
    "manifest_rename",
    "dir_fsync",
)

FAULT_KINDS = ("eio", "enospc", "torn", "rename_fail", "latency", "crash")

_ERRNO = {"eio": errno.EIO, "enospc": errno.ENOSPC, "rename_fail": errno.EIO}


class SimulatedCrash(RuntimeError):
    """Fail-stop: the process is considered dead at this point.  Never
    retried — recovery happens from disk via ``recover``/``recover_forest``."""


class InjectedFault(OSError):
    """An injected transient I/O fault.  Subclasses OSError with a real
    errno so the durable layer's retry path treats injected and genuine
    disk faults identically; tests can still tell them apart by type."""

    def __init__(self, kind: str, site: str, detail: str = ""):
        super().__init__(
            _ERRNO.get(kind, errno.EIO),
            f"injected {kind} at {site}" + (f" ({detail})" if detail else ""),
        )
        self.kind = kind
        self.site = site


@dataclass
class CrashPoint:
    """Injects a fail-stop crash at the named step of the given commit index.

    Steps: ``after_segment`` (shard files flushed, manifest not yet
    written), ``mid_manifest`` (torn manifest tmp), ``before_dirsync``
    (manifest renamed, directory not yet synced), ``mid_split`` (a shard
    split restacked the forest; nothing of the surrounding round has
    committed — ``at_commit`` is the NEXT commit index at that moment),
    ``mid_repartition`` (a load-aware boundary rebalance or cold-shard
    merge just re-keyed the journals; same NEXT-commit-index convention
    as ``mid_split``), ``mid_group`` (a round was ABSORBED into a pending
    commit group — ``group_commit_every`` > 1 — and no boundary I/O has
    started; same NEXT-commit-index convention: the absorbed rounds would
    have committed as ``at_commit``, so recovery lands on the last
    complete group boundary)."""

    step: str = ""  # "after_segment" | "mid_manifest" | "before_dirsync"
    #              | "mid_split" | "mid_repartition" | "mid_group"
    at_commit: int = -1  # commit index at which to fire (-1 = never)
    _count: int = field(default=0, repr=False)

    def maybe_fire(self, step: str, commit_idx: int):
        if self.step == step and self.at_commit == commit_idx:
            raise SimulatedCrash(f"simulated crash at {step} (commit {commit_idx})")


@dataclass
class FaultSpec:
    """One failpoint rule.  Matches hits at ``site`` (or ``"*"``) whose
    commit index falls in the half-open ``commits`` window (``None`` =
    every commit); of the matching hits, fires with probability ``p``
    (deterministically hashed from the hit's identity — see module
    docstring), at most ``times`` times total (``None`` = unbounded).

    A spec with a finite ``times`` models a *transient* fault: it clears
    once the budget is spent, which is what the commit retry loop needs to
    eventually succeed."""

    site: str  # failpoint name, or "*" for every site
    kind: str  # one of FAULT_KINDS
    p: float = 1.0  # fire probability per matching hit
    commits: Optional[Tuple[int, int]] = None  # [lo, hi) commit window
    times: Optional[int] = None  # total fire budget (None = unbounded)
    latency_s: float = 0.0  # kind="latency": injected stall
    torn_frac: float = 0.5  # kind="torn": fraction of bytes that survive
    _fired: int = field(default=0, repr=False)

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, f"unknown fault kind {self.kind!r}"

    def matches(self, site: str, commit: int) -> bool:
        if self.site != "*" and self.site != site:
            return False
        if self.commits is not None and not (
            self.commits[0] <= commit < self.commits[1]
        ):
            return False
        return self.times is None or self._fired < self.times


def _hash_draw(seed: int, site: str, commit: int, shard: int, attempt: int) -> float:
    """Uniform [0, 1) draw as a pure function of the hit's identity."""
    key = f"{seed}:{site}:{commit}:{shard}:{attempt}".encode()
    return (zlib.crc32(key) & 0xFFFFFFFF) / 2**32


class FaultPlan:
    """Deterministic failpoint registry for the durable path.

    ``fail(site, commit=, shard=, attempt=)`` is the single injection
    surface: it raises (``InjectedFault`` / ``SimulatedCrash``), sleeps
    (latency kind), or returns a ``torn_frac`` float the caller must apply
    to its byte payload (silent short write) — ``None`` means no fault.
    ``maybe_fire(step, commit_idx)`` is the ``CrashPoint`` passthrough for
    the protocol-step crash sites.  ``on_inject`` (if set) is called as
    ``on_inject(site, kind)`` for every injected fault — the durable layer
    hooks the ``fault_injected`` counter and the flight recorder there."""

    def __init__(
        self,
        seed: int = 0,
        specs: Optional[List[FaultSpec]] = None,
        crash: Optional[CrashPoint] = None,
    ):
        self.seed = int(seed)
        self.specs: List[FaultSpec] = list(specs or [])
        self.crashes: List[CrashPoint] = [crash] if crash is not None else []
        self.on_inject: Optional[Callable[[str, str], None]] = None
        self.injected = 0  # total faults injected (all kinds, all sites)
        self._lock = threading.Lock()

    # -- registration ----------------------------------------------------------

    def add(self, spec: FaultSpec) -> "FaultPlan":
        self.specs.append(spec)
        return self

    def add_crash(self, crash: CrashPoint) -> "FaultPlan":
        self.crashes.append(crash)
        return self

    def clear(self) -> None:
        """Drop every spec (the disk 'healed') — crash points stay."""
        with self._lock:
            self.specs = []

    @property
    def enabled(self) -> bool:
        return bool(self.specs or self.crashes)

    # -- crash-point surface (protocol steps) ----------------------------------

    def maybe_fire(self, step: str, commit_idx: int) -> None:
        for c in self.crashes:
            c.maybe_fire(step, commit_idx)

    # -- failpoint surface (I/O sites) -----------------------------------------

    def _note(self, site: str, kind: str) -> None:
        self.injected += 1
        if self.on_inject is not None:
            self.on_inject(site, kind)

    def fail(
        self, site: str, *, commit: int = -1, shard: int = -1, attempt: int = 0
    ) -> Optional[float]:
        """Consult every spec for this hit.  Raises / sleeps on a firing
        fault; returns the ``torn_frac`` for a silent torn write, else
        ``None``.  Thread-safe; selection is deterministic (see module
        docstring)."""
        if not self.specs:  # fast path: disabled plan is one attribute check
            return None
        torn: Optional[float] = None
        for spec in self.specs:
            with self._lock:
                if not spec.matches(site, commit):
                    continue
                if spec.p < 1.0 and (
                    _hash_draw(self.seed, site, commit, shard, attempt) >= spec.p
                ):
                    continue
                spec._fired += 1
            self._note(site, spec.kind)
            if spec.kind == "latency":
                time.sleep(spec.latency_s)
                continue
            if spec.kind == "torn":
                torn = spec.torn_frac if torn is None else min(torn, spec.torn_frac)
                continue
            if spec.kind == "crash":
                raise SimulatedCrash(
                    f"simulated kill at {site} (commit {commit}, shard {shard})"
                )
            raise InjectedFault(spec.kind, site, f"commit {commit}, shard {shard}")
        return torn

    def stats(self) -> dict:
        return {
            "injected": self.injected,
            "specs": [
                {"site": s.site, "kind": s.kind, "fired": s._fired}
                for s in self.specs
            ],
        }


def as_fault_plan(x) -> FaultPlan:
    """Lift the durable constructors' ``crash=`` argument — ``None``, a
    bare ``CrashPoint``, or a full ``FaultPlan`` — into a plan, so the
    commit protocol handles exactly one injection surface."""
    if x is None:
        return FaultPlan()
    if isinstance(x, FaultPlan):
        return x
    if isinstance(x, CrashPoint):
        return FaultPlan(crash=x)
    raise TypeError(f"expected CrashPoint | FaultPlan | None, got {type(x)!r}")
