"""Durable (strictly linearizable) trees — the paper's §5, adapted to a
framework durability substrate (DESIGN.md §2, row "clwb+sfence"), for the
single tree and the sharded forest alike.

The paper's p-OCC-ABtree persists only keys/values/pointers, ordering writes
with clwb+sfence so that (i) new nodes are persistent *before* the single
pointer that links them ("link-and-persist": the pointer is written marked,
flushed, then unmarked — readers never follow an unpersisted pointer), and
(ii) a simple insert/delete becomes durable exactly when its key reaches
persistent memory.

On a distributed training/serving system the persistence domain is a
filesystem, not NVRAM, and the update unit is a *round*, not a single store.
The protocol maps 1:1 — per shard:

  paper                           this module
  ----------------------------    ------------------------------------------
  flush new nodes (clwb+sfence)   write round segment file + fsync
                                  (per SHARD: one journal lane per shard,
                                  fsyncs issued in parallel on a PERSISTENT
                                  flush pool; an untouched shard flushes
                                  nothing)
  batch stores before the fence   GROUP COMMIT: with ``group_commit_every``
                                  > 1, rounds are *absorbed* (dirty bitmaps
                                  accumulate, zero I/O) until the group
                                  boundary — ``group_commit_every`` rounds
                                  or ``group_commit_max_wait_s`` of age —
                                  then ONE commit flushes the union of the
                                  group's dirty rows and ONE rename
                                  linearizes the whole group
  write marked pointer            write MANIFEST.tmp naming every shard's
                                  snapshot + segment chain and its commit
                                  index (ONE vector commit for all shards)
  flush pointer, unmark           fsync tmp, os.replace → MANIFEST, fsync dir
                                  (with ``commit_async=True`` the whole
                                  boundary commit runs on a background
                                  thread — the caller only captures host
                                  state; structural hooks and the next
                                  boundary JOIN the in-flight commit first,
                                  so journal bookkeeping stays
                                  single-writer)
  snapshot only live rows         INCREMENTAL SNAPSHOTS: a periodic
                                  "snapshot" writes only the rows dirtied
                                  since the shard's last FULL snapshot (a
                                  ``_delta_`` file that *replaces* the
                                  segment chain — replay = full snapshot +
                                  delta + later segments); a full snapshot
                                  is forced every ``full_snapshot_every``
                                  deltas, on pool growth, on splits/
                                  repartitions, and after recovery
  recovery: walk from root,       recovery: walk the manifest generation
    rebuild size/ver/locks          ladder (``manifest_retain`` retained
                                    generations), replay each shard's
                                    chain, rebuild size/ver/dirty, restack
                                    the shards and restore the split points

The commit point (durable linearization point) is the atomic rename: a round
is in the abstract *persistent* dictionary iff its manifest committed —
exactly the paper's "a key is in the p-tree iff it reached persistent
memory", lifted to round granularity.  The manifest carries a *vector* of
per-shard commit indices, so one rename atomically commits every shard's
journal advance; shard splits interact with the journal by forcing a
snapshot of exactly the two affected shards (journals are keyed by a stable
shard uid, so the restack leaves every other shard's segment chain valid).
Strict linearizability: ops of an uncommitted round took no externally
visible effect (results are only released to callers after commit), so
removing them from the crashed execution is legal; ops of committed rounds
are linearized before the crash.  Mid-restack states never commit: occ
sub-round commits are suppressed while a shard split is sweeping/re-
inserting, so recovery always lands on a round (or sub-round) boundary.

Publishing elimination reduces durability cost exactly as in the paper:
eliminated ops dirty no nodes, so fewer node images are flushed per round
(`flush_bytes`, `fsyncs` counters below reproduce the Table-1-style
accounting).  Old journal files a committed manifest no longer references
are garbage-collected after each commit (`gc_removed`).

Failure model (hardening beyond the paper's fail-stop assumption):

  threat                          defence
  ----------------------------    ------------------------------------------
  fail-stop crash at any step     atomic manifest rename (above); recovery
                                  lands on the last committed round boundary
  transient EIO / ENOSPC /        every commit I/O step retried with
    rename failure                  backoff (``commit_retries`` counter);
                                  ``SimulatedCrash`` is never retried
  sick disk (persistent faults)   circuit breaker: after ``degrade_after``
                                  consecutive failed commits the holder
                                  enters DEGRADED VOLATILE MODE — serving
                                  continues, commits are suspended
                                  (``commits_suspended``), every
                                  ``reattach_every``-th commit probes the
                                  disk with a full-snapshot commit and
                                  re-attaches on success
                                  (``durability_degraded`` /
                                  ``durability_reattached`` counters +
                                  recorder transitions)
  torn/short journal write        CRC32 of every journal file and sidecar
    (lying volatile cache)          in the manifest (``file_crcs``);
                                  recovery truncates each shard's replay at
                                  the first invalid record and QUARANTINES
                                  bad files under ``quarantine/``
                                  (``segments_quarantined``)
  bit flips / torn manifest       manifest self-checksum; an invalid or
                                  unreadable generation falls back down the
                                  retention ring — ``MANIFEST.prev``,
                                  ``MANIFEST.prev2``, … (``manifest_retain``
                                  generations kept as renames + one
                                  hardlink per commit — O(1) data, no extra
                                  fsync), whose files GC retains while any
                                  retained generation references them; a
                                  torn SNAPSHOT or DELTA now has
                                  ``manifest_retain - 1`` older generations
                                  to land on instead of being
                                  unrecoverable-by-design
  crash with rounds absorbed      rounds absorbed into a pending group took
    but no boundary commit          zero I/O — recovery lands on the last
                                  COMPLETE group boundary (the previous
                                  manifest); the ``mid_group`` crash step
                                  models exactly this window
  no consistent cut anywhere      ``RecoveryError`` (never silent garbage)

Fault injection: ``CrashPoint`` (fail-stop at a protocol step) and the
``FaultPlan`` failpoint registry (transient EIO/ENOSPC/torn/rename/latency
faults at every I/O site, seeded + deterministic) both live in
``repro.core.faults``; the ``crash=`` / ``faults=`` constructor arguments
accept either.
"""
from __future__ import annotations

import io
import json
import os
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.abtree import ABTree, RoundOutput, ScanOutput, TreeConfig, TreeState, make_tree
from repro.core.faults import (  # noqa: F401  (re-exported for back-compat)
    CrashPoint,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    SimulatedCrash,
    as_fault_plan,
)
from repro.core.forest import ABForest, _stack_states

_PERSISTED_FIELDS = ("keys", "vals", "children", "is_leaf", "level")
# NOT persisted (volatile; rebuilt by recovery, as in the paper §5 — only
# keys/values/child pointers are persistent):
#   size (recomputed from keys/children), parent/pidx (rebuilt from the
#   recovery walk), ver (reset), rec_* (reset), alloc (recomputed), dirty,
#   stats.

_MANIFEST_VERSION = 3  # v3: file_crcs + checksum + per-file root/height


class RecoveryError(RuntimeError):
    """No manifest generation yields a consistent committed prefix."""


class _GenerationInvalid(Exception):
    """This manifest generation cannot produce a committed prefix
    (internal: recovery falls back to the previous generation)."""


def _resolve_faults(crash, faults) -> FaultPlan:
    """Merge the legacy ``crash=`` argument and the new ``faults=`` one
    into a single FaultPlan (either may be a CrashPoint or a FaultPlan)."""
    if faults is None:
        return as_fault_plan(crash)
    plan = as_fault_plan(faults)
    if crash is not None:
        if isinstance(crash, CrashPoint):
            plan.add_crash(crash)
        else:
            for c in as_fault_plan(crash).crashes:
                plan.add_crash(c)
    return plan


def _manifest_checksum(manifest: dict) -> int:
    """CRC32 over the canonical JSON of the manifest minus its checksum
    field — recomputable bit-exactly from the parsed manifest."""
    body = {k: v for k, v in manifest.items() if k != "checksum"}
    return zlib.crc32(json.dumps(body, sort_keys=True).encode()) & 0xFFFFFFFF


def _load_manifest(directory: str, name: str) -> Optional[dict]:
    """Parse + checksum-verify one manifest generation; None if missing,
    unparseable, or corrupt (v2 manifests have no checksum and are
    trusted, as before)."""
    try:
        with open(os.path.join(directory, name)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError):
        return None
    if "checksum" in manifest and _manifest_checksum(manifest) != manifest["checksum"]:
        return None
    return manifest


def _file_commit_idx(fname: str) -> int:
    """Commit index encoded in a journal file name
    (``{uid}_{snapshot|segment|delta}_{idx:08d}.npz``)."""
    return int(fname.rsplit("_", 1)[1].split(".")[0])


def _file_valid(path: str, crc: Optional[int]) -> bool:
    """Is this journal file's on-disk content intact?  With a recorded
    CRC (v3 manifests) the check is exact; without one (legacy v2) a
    load attempt still catches torn/truncated zip archives."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError:
        return False
    if crc is not None:
        return (zlib.crc32(data) & 0xFFFFFFFF) == crc
    try:
        with np.load(io.BytesIO(data)) as z:
            z.files
        return True
    except Exception:
        return False


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclass
class DurableStats:
    commits: int = 0
    flush_bytes: int = 0  # bytes of node images made durable
    fsyncs: int = 0
    nodes_flushed: int = 0
    gc_removed: int = 0  # journal files unlinked after losing all references
    commit_retries: int = 0  # commit attempts that failed with an I/O error
    commits_suspended: int = 0  # commits skipped while in degraded mode
    gc_skipped: int = 0  # GC unlinks skipped (file already gone / busy)


class _DurableBase:
    """The ONE commit-protocol implementation (link-and-persist at round
    granularity, per-shard journal lanes, single vector manifest).  The
    concrete classes below only bind it to their backing structure."""

    backend = ""  # "tree" | "forest"

    # -- backend surface (provided by subclasses) ------------------------------

    def _holder(self):
        """The backing round-engine holder (the ABTree or ABForest)."""
        raise NotImplementedError

    def _n_shards(self) -> int:
        raise NotImplementedError

    def _take_dirty_all(self) -> List[np.ndarray]:
        raise NotImplementedError

    def _persisted_host_arrays(self) -> List[Dict[str, np.ndarray]]:
        """Per-shard persisted-field arrays.  Each device array crosses to
        the host ONCE per commit; per-shard entries are views of it."""
        raise NotImplementedError

    def _shard_root_height(self, s: int):
        raise NotImplementedError

    def _capacity(self) -> int:
        raise NotImplementedError

    def _mode(self) -> str:
        raise NotImplementedError

    def _in_split_now(self) -> bool:
        return False

    def _manifest_extra(self) -> dict:
        return {}

    # -- telemetry (shared with the backing holder) ----------------------------
    # The durable wrapper has no registry of its own: journal metrics land
    # in the backing holder's registry, so ``holder.metrics`` is ONE
    # surface across volatile and durable variants, and installing a
    # tracer on the wrapper also times the engine phases underneath.

    @property
    def metrics(self):
        return self._holder().metrics

    @property
    def tracer(self):
        return self._holder().tracer

    @tracer.setter
    def tracer(self, t):
        self._holder().tracer = t

    @property
    def recorder(self):
        return self._holder().recorder

    @recorder.setter
    def recorder(self, r):
        self._holder().recorder = r

    def forensics_records(self):
        """The audit records recovered from the committed forensics
        sidecar (empty on a fresh journal): the last-K rounds of the
        crashed execution's *committed* prefix, for the explain-report."""
        return list(getattr(self, "_forensics", []))

    # -- fault / degradation surface -------------------------------------------

    @property
    def crash(self) -> FaultPlan:
        """Back-compat alias: the fault plan (still has ``maybe_fire``)."""
        return self.faults

    @crash.setter
    def crash(self, value):
        self.faults = as_fault_plan(value)
        self.faults.on_inject = self._on_fault_injected

    @property
    def degraded(self) -> bool:
        """True while the durability circuit breaker is open: serving
        continues on the volatile holder, commits are suspended."""
        return self._degraded

    def durability_status(self) -> dict:
        return {
            "degraded": self._degraded,
            "consecutive_failures": self._consec_failures,
            "commit_retries": self.dstats.commit_retries,
            "commits_suspended": self.dstats.commits_suspended,
            "faults_injected": self.faults.injected,
            "quarantined": list(self._quarantined),
            # group-commit surface: a stalled group is observable as
            # pending rounds that never drain / an age that keeps growing
            "group_commit_every": self.group_commit_every,
            "pending_rounds": self._group_rounds,
            "pending_age_s": (
                time.perf_counter() - self._group_start
                if self._group_start is not None
                else 0.0
            ),
            "rounds_per_commit": self.metrics.histogram_summary(
                "rounds_per_commit"
            ),
        }

    def _init_fault_state(
        self,
        faults: FaultPlan,
        commit_retries: int,
        commit_backoff_s: float,
        degrade_after: int,
        reattach_every: int,
    ):
        self.faults = faults
        self.faults.on_inject = self._on_fault_injected
        self.commit_retries = commit_retries
        self.commit_backoff_s = commit_backoff_s
        self.degrade_after = degrade_after
        self.reattach_every = max(1, reattach_every)
        self._degraded = False
        self._consec_failures = 0
        self._degraded_skipped = 0
        self._file_crcs: Dict[str, int] = {}
        self._quarantined: List[str] = []
        self._manifest_good = True  # on-disk MANIFEST == our generation?

    def _on_fault_injected(self, site: str, kind: str):
        # May run on a flush-pool thread: counter inc + one deque append,
        # both safe under the GIL.
        self.metrics.inc("fault_injected")
        rec = getattr(self._holder(), "recorder", None)
        if rec is not None and rec.enabled:
            rec.fault(site, kind)

    def _init_commit_state(
        self,
        group_commit_every: int,
        group_commit_max_wait_s: float,
        commit_async: bool,
        incremental_snapshots: bool,
        full_snapshot_every: int,
        manifest_retain: int,
    ):
        """Group-commit / async-commit / delta-snapshot knobs and their
        runtime state (shared by fresh and recovered instances)."""
        self.group_commit_every = max(1, group_commit_every)
        self.group_commit_max_wait_s = group_commit_max_wait_s
        self.commit_async = commit_async
        self.incremental_snapshots = incremental_snapshots
        self.full_snapshot_every = max(1, full_snapshot_every)
        self.manifest_retain = max(1, manifest_retain)
        self._group_rounds = 0  # rounds absorbed since the last boundary
        self._group_start: Optional[float] = None
        self._commit_future = None  # in-flight async boundary commit
        self._flush_pool: Optional[ThreadPoolExecutor] = None
        self._commit_pool: Optional[ThreadPoolExecutor] = None
        # per-uid delta-chain bookkeeping: rows dirtied since the shard's
        # last FULL snapshot, and how many deltas that full has absorbed
        self._delta_rows: Dict[str, np.ndarray] = {}
        self._delta_count: Dict[str, int] = {}

    # -- journal lifecycle -----------------------------------------------------

    def _init_journal(
        self,
        directory: str,
        faults: FaultPlan,
        snapshot_every: int,
        commit_retries: int = 2,
        commit_backoff_s: float = 0.002,
        degrade_after: int = 3,
        reattach_every: int = 4,
        group_commit_every: int = 1,
        group_commit_max_wait_s: float = 0.05,
        commit_async: bool = False,
        incremental_snapshots: bool = True,
        full_snapshot_every: int = 8,
        manifest_retain: int = 3,
    ):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self._init_fault_state(
            faults, commit_retries, commit_backoff_s, degrade_after, reattach_every
        )
        self._init_commit_state(
            group_commit_every, group_commit_max_wait_s, commit_async,
            incremental_snapshots, full_snapshot_every, manifest_retain,
        )
        self.snapshot_every = snapshot_every
        self.dstats = DurableStats()
        self._commit_idx = 0
        uids = [f"s{i:04d}" for i in range(self._n_shards())]
        self._uids: List[str] = uids
        self._next_uid = len(uids)
        self._snapshots: Dict[str, Optional[str]] = {u: None for u in uids}
        self._segments: Dict[str, List[str]] = {u: [] for u in uids}
        self._shard_commits: Dict[str, int] = {u: -1 for u in uids}
        self._force_snapshot = set(uids)
        self._snap_capacity: Optional[int] = None
        self._forensics: List[dict] = []
        # initial durable state: commit round 0 (empty snapshots, all shards)
        self._commit(force_snapshot=True)

    def _new_shard_uid(self) -> str:
        uid = f"s{self._next_uid:04d}"
        self._next_uid += 1
        self._snapshots[uid] = None
        self._segments[uid] = []
        self._shard_commits[uid] = -1
        self._delta_rows[uid] = np.empty(0, np.int32)
        self._delta_count[uid] = 0
        return uid

    # -- commit protocol (link-and-persist) ------------------------------------

    def _commit(self, force_snapshot: bool = False):
        if self._in_split_now():
            # a shard split is mid-restack (sweep / re-insert rounds run
            # through the same engine): those intermediate states are not
            # round boundaries and must never become the durable prefix.
            return
        reg = self.metrics
        # -- group-commit gate: absorb this round into the pending group
        # (dirty bitmaps keep accumulating in the holder — zero I/O) and
        # return unless a boundary condition fires: the group filled, aged
        # past the deadline, needs a forced snapshot, or the breaker is
        # open (degraded bookkeeping must stay per-round).
        self._group_rounds += 1
        now = time.perf_counter()
        if self._group_start is None:
            self._group_start = now
        if (
            not force_snapshot
            and not self._degraded
            and self.group_commit_every > 1
            and self._group_rounds < self.group_commit_every
            and now - self._group_start < self.group_commit_max_wait_s
        ):
            # the only crash window with rounds pending and no I/O started:
            # dying here loses exactly the absorbed rounds — recovery lands
            # on the last complete group boundary (the previous manifest).
            self.faults.maybe_fire("mid_group", self._commit_idx)
            reg.set_gauge("group_pending_rounds", self._group_rounds)
            reg.set_gauge("group_pending_age_s", now - self._group_start)
            return
        self._commit_group(force_snapshot)

    def _commit_group(self, force_snapshot: bool = False):
        """Commit the pending group: capture host state synchronously, then
        run the link-and-persist sequence (inline, or on the background
        commit thread with ``commit_async``)."""
        reg = self.metrics
        if self._degraded:
            # circuit breaker open: serving continues on the volatile
            # holder; every reattach_every-th commit probes the disk with
            # a single full-snapshot attempt (dirty tracking was reset by
            # the failed commits, so only a snapshot is sound anyway).
            self._degraded_skipped += 1
            self.dstats.commits_suspended += 1
            reg.inc("commits_suspended")
            if self._degraded_skipped % self.reattach_every:
                return
            force_snapshot, max_attempts = True, 1
        else:
            max_attempts = 1 + max(0, self.commit_retries)
        # serialize with a still-flying async boundary: journal bookkeeping
        # is single-writer, so the previous commit must land first.
        self._join_commit()
        absorbed = self._group_rounds
        self._group_rounds = 0
        self._group_start = None
        reg.set_gauge("group_pending_rounds", 0)
        reg.set_gauge("group_pending_age_s", 0.0)
        # -- synchronous capture: everything the commit reads from the
        # LIVE holder (which keeps mutating under async commits) is pinned
        # here; jnp arrays are immutable, so the host views stay valid.
        cap = {
            "idx": self._commit_idx,
            "force_snapshot": force_snapshot,
            "dirty": self._take_dirty_all(),
            "shard_arrays": self._persisted_host_arrays(),
            "roots": [
                self._shard_root_height(s) for s in range(self._n_shards())
            ],
            "capacity": self._capacity(),
            "mode": self._mode(),
            "extra": self._manifest_extra(),
            "absorbed": absorbed,
            "max_attempts": max_attempts,
            "was_degraded": self._degraded,
            "t_start": time.perf_counter(),
            "sidecar": None,
        }
        rec = getattr(self._holder(), "recorder", None)
        if rec is not None and rec.enabled:
            # the sidecar must describe the COMMITTED prefix, not whatever
            # rounds run while an async commit is in flight — capture the
            # ring now, at the group boundary.
            cap["sidecar"] = (int(self._holder()._rounds), rec.dump_records())
        if self.commit_async and not self._degraded:
            if self._commit_pool is None:
                self._commit_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="durable-commit"
                )
            self._commit_future = self._commit_pool.submit(
                self._commit_finish, cap
            )
        else:
            self._commit_finish(cap)

    def _join_commit(self):
        """Wait for the in-flight async boundary commit (if any).  Called
        before the next boundary, before structural hooks re-key the
        journal, and from ``drain()``.  A ``SimulatedCrash`` raised on the
        commit thread re-raises here (fail-stop is fail-stop)."""
        fut, self._commit_future = self._commit_future, None
        if fut is not None:
            fut.result()

    def drain(self):
        """Make every applied round durable NOW: flush the pending group
        (if any) and join the in-flight async commit.  The group-commit
        analogue of the paper's explicit persist fence."""
        if self._group_rounds:
            self._commit_group()
        self._join_commit()

    def close(self):
        """Drain and shut down the persistent flush/commit pools."""
        self.drain()
        for pool in (self._flush_pool, self._commit_pool):
            if pool is not None:
                pool.shutdown(wait=True)
        self._flush_pool = self._commit_pool = None

    def _commit_finish(self, cap: dict):
        """Retry loop + breaker bookkeeping around ``_commit_once`` —
        everything downstream of the synchronous capture.  Runs inline, or
        on the commit thread (``commit_async``); I/O errors never escape
        (the breaker absorbs them), only ``SimulatedCrash`` does."""
        reg = self.metrics
        idx = cap["idx"]
        manifest = None
        for attempt in range(cap["max_attempts"]):
            try:
                manifest = self._commit_once(cap, attempt)
                break
            except OSError:
                # transient fault (injected or real). SimulatedCrash is a
                # RuntimeError and deliberately NOT caught: fail-stop means
                # dead, and recovery happens from disk.
                self.dstats.commit_retries += 1
                reg.inc("commit_retries")
                if attempt + 1 < cap["max_attempts"] and self.commit_backoff_s > 0:
                    time.sleep(self.commit_backoff_s * (2**attempt))
        rec = getattr(self._holder(), "recorder", None)
        if manifest is None:
            # this commit's dirty set is lost (taken at capture) — make the
            # next successful commit a full snapshot of every shard so no
            # round can slip out of the journal.
            self._force_snapshot.update(self._uids)
            self._consec_failures += 1
            if not self._degraded and self._consec_failures >= self.degrade_after:
                self._degraded = True
                self._degraded_skipped = 0
                reg.inc("durability_degraded")
                if rec is not None and rec.enabled:
                    rec.transition(
                        "durability",
                        state="degraded",
                        commit=idx,
                        failures=self._consec_failures,
                    )
            return
        was_degraded = cap["was_degraded"]
        self._degraded = False
        self._consec_failures = 0
        self._commit_idx = idx + 1
        self.dstats.commits += 1
        reg.inc("commits")
        reg.observe("rounds_per_commit", cap["absorbed"])
        if was_degraded:
            reg.inc("durability_reattached")
            if rec is not None and rec.enabled:
                rec.transition("durability", state="reattached", commit=idx)
        self._last_audit = manifest.get("audit")
        if rec is not None and rec.enabled:
            # commit marker: links the audit stream to the journal's commit
            # index (lands in the NEXT sidecar — this one is already
            # durable, matching the committed prefix exactly).
            rounds = (
                cap["sidecar"][0]
                if cap["sidecar"] is not None
                else int(self._holder()._rounds)
            )
            rec.commit(idx, rounds, rounds_absorbed=cap["absorbed"])
        reg.observe("commit_latency_s", time.perf_counter() - cap["t_start"])
        self._gc(manifest)

    _EMPTY_IDS = np.empty(0, np.int32)

    def _commit_once(self, cap: dict, attempt: int) -> dict:
        """One attempt at the full link-and-persist sequence.  All journal
        bookkeeping is computed into candidates and installed on ``self``
        only after the rename + directory sync land, so a failed attempt
        (raise anywhere) leaves the in-memory generation exactly as
        committed — a retry rebuilds the identical candidates."""
        tr = self.tracer
        reg = self.metrics
        plan = self.faults
        idx = cap["idx"]
        dirty, shard_arrays, roots = cap["dirty"], cap["shard_arrays"], cap["roots"]
        # a pool growth invalidates segment node indexing → force snapshots
        grown = self._snap_capacity != cap["capacity"]
        periodic = idx % self.snapshot_every == 0
        jobs = []  # (kind, shard, uid, fname, node_ids, arrays, root, height)
        for s in range(self._n_shards()):
            uid = self._uids[s]
            full = (
                cap["force_snapshot"]
                or grown
                or uid in self._force_snapshot
                or self._snapshots[uid] is None
                or (
                    periodic
                    and (
                        not self.incremental_snapshots
                        or self._delta_count.get(uid, 0)
                        >= self.full_snapshot_every
                    )
                )
            )
            if full:
                jobs.append(("snap", s, uid, f"{uid}_snapshot_{idx:08d}.npz",
                             None, shard_arrays[s], *roots[s]))
                continue
            if periodic and self.incremental_snapshots:
                # incremental snapshot: every row dirtied since the shard's
                # last FULL snapshot, in one ``_delta_`` file that REPLACES
                # the segment chain (replay = full + delta + later segs)
                rows = np.union1d(
                    self._delta_rows.get(uid, self._EMPTY_IDS), dirty[s]
                ).astype(np.int32)
                if rows.size:
                    arrs = {f: a[rows] for f, a in shard_arrays[s].items()}
                    jobs.append(("delta", s, uid,
                                 f"{uid}_delta_{idx:08d}.npz", rows, arrs,
                                 *roots[s]))
                # rows empty → untouched since its last full snapshot:
                # nothing to consolidate, the lane stays quiet
                continue
            if dirty[s].size:
                arrs = {f: a[dirty[s]] for f, a in shard_arrays[s].items()}
                jobs.append(("seg", s, uid, f"{uid}_segment_{idx:08d}.npz",
                             dirty[s], arrs, *roots[s]))
            # untouched shard: its journal lane is quiet this commit
        with tr.span("journal_flush", commit=idx, files=len(jobs)):
            written = self._write_shard_files(jobs, idx, attempt)
        # candidate bookkeeping — installed only after the commit point
        snapshots = dict(self._snapshots)
        segments = {u: list(v) for u, v in self._segments.items()}
        shard_commits = dict(self._shard_commits)
        file_crcs = dict(self._file_crcs)
        delta_rows = dict(self._delta_rows)
        delta_count = dict(self._delta_count)
        for (kind, s, uid, fname, node_ids, _, _, _), (nbytes, nnodes, dt_w, crc) in zip(
            jobs, written
        ):
            self.dstats.flush_bytes += nbytes
            self.dstats.fsyncs += 1
            self.dstats.nodes_flushed += nnodes
            reg.inc("flush_bytes", nbytes, shard=s)
            reg.inc("fsyncs", shard=s)
            reg.inc("nodes_flushed", nnodes, shard=s)
            reg.observe("fsync_latency_s", dt_w)
            if kind == "snap":
                snapshots[uid] = fname
                segments[uid] = []
                delta_rows[uid] = self._EMPTY_IDS
                delta_count[uid] = 0
                reg.inc("full_snapshots")
            elif kind == "delta":
                segments[uid] = [fname]  # supersedes the chain (and GC's it)
                delta_rows[uid] = node_ids
                delta_count[uid] = delta_count.get(uid, 0) + 1
                reg.inc("delta_snapshots")
            else:
                segments[uid].append(fname)
                delta_rows[uid] = np.union1d(
                    delta_rows.get(uid, self._EMPTY_IDS), node_ids
                ).astype(np.int32)
            shard_commits[uid] = idx
            file_crcs[fname] = crc
        plan.maybe_fire("after_segment", idx)

        # -- forensics sidecar: flush the recorder's ring next to the
        # journal BEFORE the manifest, and commit the *reference* through
        # the manifest's atomic rename — a crash anywhere in this commit
        # leaves the previous manifest pointing at the previous sidecar,
        # so the recovered sidecar always matches the committed round
        # prefix (same link-and-persist argument as the node images).
        audit_ref = getattr(self, "_last_audit", None)
        if cap["sidecar"] is not None:
            rounds, records = cap["sidecar"]
            audit_ref = f"audit_{idx:08d}.jsonl"
            apath = os.path.join(self.dir, audit_ref)
            tmp_a = apath + ".tmp"
            header = json.dumps(
                {
                    "kind": "sidecar",
                    "commit_idx": idx,
                    "backend": self.backend,
                    "rounds": rounds,
                }
            )
            data_a = ("\n".join([header, *records]) + "\n").encode()
            file_crcs[audit_ref] = zlib.crc32(data_a) & 0xFFFFFFFF
            torn = plan.fail("sidecar_write", commit=idx, attempt=attempt)
            if torn is not None:
                data_a = data_a[: max(1, int(len(data_a) * torn))]
            with open(tmp_a, "wb") as f:
                f.write(data_a)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp_a, apath)

        shard_entries = []
        referenced = set()
        for s, uid in enumerate(self._uids):
            shard_entries.append(
                {
                    "uid": uid,
                    "snapshot": snapshots[uid],
                    "segments": segments[uid],
                    "root": roots[s][0],
                    "height": roots[s][1],
                    "commit": shard_commits[uid],
                }
            )
            if snapshots[uid]:
                referenced.add(snapshots[uid])
            referenced.update(segments[uid])
        if audit_ref:
            referenced.add(audit_ref)
        manifest = {
            "version": _MANIFEST_VERSION,
            "backend": self.backend,
            "commit": idx,
            "mode": cap["mode"],
            "snapshot_every": self.snapshot_every,
            "capacity": cap["capacity"],
            "b": self._cfg().b,
            "a": self._cfg().a,
            "max_height": self._cfg().max_height,
            "shards": shard_entries,
            "audit": audit_ref,
            "file_crcs": {f: c for f, c in file_crcs.items() if f in referenced},
            **cap["extra"],
        }
        manifest["checksum"] = _manifest_checksum(manifest)
        tmp = os.path.join(self.dir, "MANIFEST.tmp")
        mpath = os.path.join(self.dir, "MANIFEST")
        payload = json.dumps(manifest)
        with tr.span("manifest_commit", commit=idx):
            plan.fail("manifest_write", commit=idx, attempt=attempt)
            t0 = time.perf_counter()
            with open(tmp, "w") as f:
                f.write(payload[: len(payload) // 2])
                f.flush()
                plan.maybe_fire("mid_manifest", idx)
                f.write(payload[len(payload) // 2 :])
                f.flush()
                plan.fail("manifest_fsync", commit=idx, attempt=attempt)
                os.fsync(f.fileno())
            self.dstats.fsyncs += 1
            reg.observe("fsync_latency_s", time.perf_counter() - t0)
            # retention ring: rotate MANIFEST.prev → .prev2 → … and
            # hardlink the committed manifest to MANIFEST.prev before the
            # rename replaces it, keeping ``manifest_retain`` generations —
            # renames + one link, no data writes, no extra fsync (the
            # clean-path fsync count is gated).  Skipped entirely when the
            # on-disk MANIFEST is not our generation (recovery fell back /
            # truncated), so a known-good ring is never rotated under the
            # corrupt manifest we recovered around.
            if self._manifest_good and os.path.exists(mpath):
                for k in range(self.manifest_retain - 1, 1, -1):
                    src = mpath + (".prev" if k == 2 else f".prev{k - 1}")
                    try:
                        os.replace(src, mpath + f".prev{k}")
                    except FileNotFoundError:
                        pass
                if self.manifest_retain > 1:
                    prev = mpath + ".prev"
                    try:
                        os.unlink(prev)
                    except FileNotFoundError:
                        pass
                    os.link(mpath, prev)
            plan.fail("manifest_rename", commit=idx, attempt=attempt)
            os.replace(tmp, mpath)  # the "link" step — THE commit point
            plan.maybe_fire("before_dirsync", idx)
            plan.fail("dir_fsync", commit=idx, attempt=attempt)
            t1 = time.perf_counter()
            _fsync_dir(self.dir)  # the "persist" step
            reg.observe("fsync_latency_s", time.perf_counter() - t1)
        self.dstats.fsyncs += 1
        reg.inc("fsyncs", 2)  # manifest file + directory entry
        # the commit point landed: install the candidate bookkeeping
        self._snapshots = snapshots
        self._segments = segments
        self._shard_commits = shard_commits
        self._file_crcs = {f: c for f, c in file_crcs.items() if f in referenced}
        self._delta_rows = delta_rows
        self._delta_count = delta_count
        self._force_snapshot.clear()
        self._snap_capacity = cap["capacity"]
        self._manifest_good = True
        return manifest

    def _pool(self) -> ThreadPoolExecutor:
        """The persistent flush pool — created once, reused by every commit
        (spinning a pool up per commit cost ~ a fsync on fast disks)."""
        if self._flush_pool is None:
            self._flush_pool = ThreadPoolExecutor(
                max_workers=8, thread_name_prefix="durable-flush"
            )
        return self._flush_pool

    def _write_shard_files(self, jobs, idx: int, attempt: int):
        """Write + fsync every shard's journal file for this commit —
        the parallel fsync lanes (one thread per shard file; a single
        file is written inline).  Every future is gathered before this
        returns, so per-file fsync/flush accounting attributes to exactly
        one commit even though the pool outlives it."""
        if len(jobs) <= 1:
            return [
                self._write_npz(f, ids, a, r, h, s, idx, attempt)
                for _, s, _, f, ids, a, r, h in jobs
            ]
        # explicit submit + gather (NOT ex.map): map's result iterator
        # cancels still-pending futures when one write raises, which would
        # make the set of I/O sites actually hit — and therefore fault
        # accounting under injection — depend on thread scheduling.  Every
        # submitted write runs to completion; the first error is re-raised
        # only after all lanes have settled.
        ex = self._pool()
        futs = [
            ex.submit(self._write_npz, f, ids, a, r, h, s, idx, attempt)
            for _, s, _, f, ids, a, r, h in jobs
        ]
        results, first_err = [], None
        for fut in futs:
            try:
                results.append(fut.result())
            except (OSError, SimulatedCrash) as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err
        return results

    def _write_npz(self, fname: str, node_ids, arrs, root: int, height: int,
                   shard: int, commit: int, attempt: int):
        path = os.path.join(self.dir, fname)
        tmp = path + ".tmp"
        save = dict(arrs)
        if node_ids is not None:
            save["node_ids"] = node_ids
        # flush_bytes counts node-image payload only (deterministic across
        # runs; zip framing and the root/height scalars are excluded)
        nbytes = sum(a.nbytes for a in save.values())
        # root/height ride in every journal file so a truncated replay can
        # land on the root of ITS cut, not the manifest's newer one
        save["root"] = np.int32(root)
        save["height"] = np.int32(height)
        t0 = time.perf_counter()
        buf = io.BytesIO()
        np.savez(buf, **save)
        data = buf.getvalue()
        crc = zlib.crc32(data) & 0xFFFFFFFF  # CRC of the INTENDED bytes
        torn = self.faults.fail(
            "segment_write", commit=commit, shard=shard, attempt=attempt
        )
        if torn is not None:
            # silent short write: fsync will "succeed" but the tail never
            # reached disk — only the manifest CRC can catch this later
            data = data[: max(1, int(len(data) * torn))]
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            self.faults.fail(
                "segment_fsync", commit=commit, shard=shard, attempt=attempt
            )
            os.fsync(f.fileno())  # the paper's clwb+sfence of new nodes
        os.replace(tmp, path)
        dt = time.perf_counter() - t0
        nnodes = (
            int(node_ids.size) if node_ids is not None else int(arrs["keys"].shape[0])
        )
        return nbytes, nnodes, dt, crc

    @staticmethod
    def _manifest_refs(manifest: dict) -> set:
        refs = set()
        for sh in manifest["shards"]:
            if sh["snapshot"]:
                refs.add(sh["snapshot"])
            refs.update(sh["segments"])
        if manifest.get("audit"):
            refs.add(manifest["audit"])
        return refs

    def _gc(self, manifest: dict):
        """Unlink journal files no RETAINED manifest generation references
        (a snapshot/delta supersedes the shard's previous chain; a GC'd
        shard uid loses its whole chain; prev-generation files survive
        until their generation rotates off the retention ring, so every
        fallback manifest stays replayable).  Runs strictly after the
        directory sync, so a crash can never resurrect a collected file
        into the durable prefix.  Tolerant of concurrent or missing files:
        a lost unlink is counted (``gc_skipped``), never raised — a
        crashed-then-recovered directory with partial GC must not fail the
        next commit."""
        referenced = self._manifest_refs(manifest)
        removed = skipped = 0
        try:
            entries = os.listdir(self.dir)
        except OSError:
            entries = []
            skipped += 1
        for name in entries:
            if name.startswith("MANIFEST.prev"):
                prev = _load_manifest(self.dir, name)
                if prev is not None:
                    referenced |= self._manifest_refs(prev)
        for fname in entries:
            is_audit = fname.endswith(".jsonl") and fname.startswith("audit_")
            is_journal = fname.endswith(".npz") and (
                "_segment_" in fname
                or "_snapshot_" in fname
                or "_delta_" in fname
            )
            if not (is_audit or is_journal) or fname in referenced:
                continue
            try:
                os.unlink(os.path.join(self.dir, fname))
                removed += 1
            except FileNotFoundError:
                skipped += 1  # already gone (recovered-over directory)
            except OSError:
                skipped += 1  # busy / transient — retried next commit
        self.dstats.gc_removed += removed
        if removed:
            self.metrics.inc("gc_removed", removed)
        if skipped:
            self.dstats.gc_skipped += skipped
            self.metrics.inc("gc_skipped", skipped)

    def _durable_stats_dict(self) -> Dict[str, int]:
        return dict(
            commits=self.dstats.commits,
            flush_bytes=self.dstats.flush_bytes,
            fsyncs=self.dstats.fsyncs,
            nodes_flushed=self.dstats.nodes_flushed,
            gc_removed=self.dstats.gc_removed,
            commit_retries=self.dstats.commit_retries,
            commits_suspended=self.dstats.commits_suspended,
            gc_skipped=self.dstats.gc_skipped,
        )


class DurableABTree(_DurableBase):
    """ABTree + round-granular link-and-persist durability — the S = 1 case
    of the per-shard journal protocol (one journal lane)."""

    backend = "tree"

    def __init__(
        self,
        directory: str,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        crash=None,
        snapshot_every: int = 64,
        *,
        faults=None,
        commit_retries: int = 2,
        commit_backoff_s: float = 0.002,
        degrade_after: int = 3,
        reattach_every: int = 4,
        group_commit_every: int = 1,
        group_commit_max_wait_s: float = 0.05,
        commit_async: bool = False,
        incremental_snapshots: bool = True,
        full_snapshot_every: int = 8,
        manifest_retain: int = 3,
    ):
        self.tree = ABTree(cfg, mode=mode)
        if mode == "occ":
            # p-OCC: per-update flush discipline → per-sub-round commits
            # (with group commit, sub-rounds are absorbed at group
            # granularity — the group boundary is the persist fence)
            self.tree.subround_hook = self._commit
        self._init_journal(
            directory,
            _resolve_faults(crash, faults),
            snapshot_every,
            commit_retries,
            commit_backoff_s,
            degrade_after,
            reattach_every,
            group_commit_every,
            group_commit_max_wait_s,
            commit_async,
            incremental_snapshots,
            full_snapshot_every,
            manifest_retain,
        )

    # -- backend surface -------------------------------------------------------

    def _holder(self):
        return self.tree

    def _n_shards(self) -> int:
        return 1

    def _take_dirty_all(self):
        return [self.tree.take_dirty()]

    def _persisted_host_arrays(self):
        st = self.tree.state
        return [{f: np.asarray(getattr(st, f)) for f in _PERSISTED_FIELDS}]

    def _shard_root_height(self, s: int):
        return int(self.tree.state.root), int(self.tree.state.height)

    def _capacity(self) -> int:
        return self.tree.cfg.capacity

    def _cfg(self) -> TreeConfig:
        return self.tree.cfg

    def _mode(self) -> str:
        return self.tree.mode

    # -- public API -----------------------------------------------------------

    def apply_round(self, ops, keys, vals=None) -> RoundOutput:
        """Apply a round and make it durable.  Results are only returned
        after the commit — the durable linearization discipline.  (In occ
        mode the sub-round hook has already committed each sub-round; no
        further flush is needed.)"""
        out = self.tree.apply_round(ops, keys, vals)
        if self.tree.mode != "occ":
            self._commit()
        return out

    def stats(self) -> Dict[str, int]:
        s = self.tree.stats()
        s.update(self._durable_stats_dict())
        return s


class DurableForest(_DurableBase):
    """ABForest + per-shard link-and-persist durability: one journal lane
    per shard (independent dirty tracking, parallel fsyncs), one manifest
    committing the vector of per-shard commit indices atomically.  A shard
    split forces snapshots of exactly the two affected shards — journals
    are keyed by stable shard uids, so every other shard's segment chain
    survives the restack."""

    backend = "forest"

    def __init__(
        self,
        directory: str,
        n_shards: int = 1,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        crash=None,
        snapshot_every: int = 64,
        *,
        splits=None,
        key_space=None,
        max_keys_per_shard: Optional[int] = None,
        narrow_scan: bool = False,
        narrow: bool = False,
        auto_repartition: bool = False,
        faults=None,
        commit_retries: int = 2,
        commit_backoff_s: float = 0.002,
        degrade_after: int = 3,
        reattach_every: int = 4,
        group_commit_every: int = 1,
        group_commit_max_wait_s: float = 0.05,
        commit_async: bool = False,
        incremental_snapshots: bool = True,
        full_snapshot_every: int = 8,
        manifest_retain: int = 3,
    ):
        self.forest = ABForest(
            n_shards=n_shards,
            cfg=cfg,
            mode=mode,
            splits=splits,
            key_space=key_space,
            max_keys_per_shard=max_keys_per_shard,
            narrow_scan=narrow_scan,
            narrow=narrow,
            auto_repartition=auto_repartition,
        )
        self._wire_hooks()
        self._init_journal(
            directory,
            _resolve_faults(crash, faults),
            snapshot_every,
            commit_retries,
            commit_backoff_s,
            degrade_after,
            reattach_every,
            group_commit_every,
            group_commit_max_wait_s,
            commit_async,
            incremental_snapshots,
            full_snapshot_every,
            manifest_retain,
        )

    def _wire_hooks(self):
        if self.forest.mode == "occ":
            # p-OCC: per-update flush discipline → per-sub-round commits
            self.forest.subround_hook = self._commit
        self.forest.split_hook = self._on_shard_split
        self.forest.repartition_hook = self._on_repartition

    def _on_shard_split(self, s: int):
        """Journal re-keying for a shard split: the fresh shard at ``s + 1``
        gets a new uid, and both affected shards are marked for a forced
        snapshot at the next commit (shard ``s`` halved its contents; the
        new shard has no journal yet).  Every other uid's chain is
        untouched.  An in-flight async commit reads the journal keying —
        it must land before the restack mutates it."""
        self._join_commit()
        self._uids.insert(s + 1, self._new_shard_uid())
        self._force_snapshot.add(self._uids[s])
        self.crash.maybe_fire("mid_split", self._commit_idx)

    def _on_repartition(self, kind: str, a: int, b: int):
        """Journal re-keying for a load-aware repartition.  A boundary
        rebalance keeps every shard's uid (contents moved between two
        chains) but forces both affected shards' snapshots — their replay
        prefixes no longer reproduce the moved keys.  A cold-shard merge
        retires the dead shard's uid (its chain is garbage after the
        restack) and forces the survivor's snapshot.  Either way the
        next manifest commit records the new split points."""
        self._join_commit()
        if kind == "merge":
            dead = self._uids.pop(a)
            self._snapshots.pop(dead, None)
            self._segments.pop(dead, None)
            self._shard_commits.pop(dead, None)
            self._delta_rows.pop(dead, None)
            self._delta_count.pop(dead, None)
            self._force_snapshot.discard(dead)
            self._force_snapshot.add(self._uids[b])
        else:
            self._force_snapshot.add(self._uids[a])
            self._force_snapshot.add(self._uids[b])
        self.crash.maybe_fire("mid_repartition", self._commit_idx)

    # -- backend surface -------------------------------------------------------

    def _holder(self):
        return self.forest

    def _n_shards(self) -> int:
        return self.forest.n_shards

    def _take_dirty_all(self):
        return self.forest.take_dirty()

    def _persisted_host_arrays(self):
        st = self.forest.state
        stacked = {f: np.asarray(getattr(st, f)) for f in _PERSISTED_FIELDS}
        return [
            {f: a[s] for f, a in stacked.items()}
            for s in range(self.forest.n_shards)
        ]

    def _shard_root_height(self, s: int):
        st = self.forest.state
        return int(np.asarray(st.root)[s]), int(np.asarray(st.height)[s])

    def _capacity(self) -> int:
        return self.forest.cfg.capacity

    def _cfg(self) -> TreeConfig:
        return self.forest.cfg

    def _mode(self) -> str:
        return self.forest.mode

    def _in_split_now(self) -> bool:
        return self.forest._in_split

    def _manifest_extra(self) -> dict:
        return {
            "splits": [int(x) for x in self.forest._splits],
            "max_keys_per_shard": self.forest.max_keys_per_shard,
            "narrow": self.forest.narrow,
            "narrow_scan": self.forest.narrow_scan,
            "auto_repartition": self.forest.auto_repartition,
        }

    # -- public API -----------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return self.forest.n_shards

    def apply_round(self, ops, keys, vals=None, *, scan_cap: int = 128) -> RoundOutput:
        """Apply one forest round and make it durable (results released
        only after the commit).  In occ mode each sub-round has already
        committed via the hook; a shard split triggered by the round is
        journaled as forced snapshots of the two affected shards."""
        out = self.forest.apply_round(ops, keys, vals, scan_cap=scan_cap)
        if self.forest.mode != "occ":
            self._commit()
        return out

    def scan_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        """Read-only: scans flush nothing (they dirty no nodes)."""
        return self.forest.scan_round(lo, hi, cap=cap, max_retries=max_retries)

    def scan_delete_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        out = self.forest.scan_delete_round(lo, hi, cap=cap, max_retries=max_retries)
        if self.forest.mode != "occ":
            self._commit()
        return out

    def items(self) -> dict:
        return self.forest.items()

    def stats(self) -> Dict[str, int]:
        s = self.forest.stats()
        s.update(self._durable_stats_dict())
        return s


# ----------------------------------------------------------------------------
# Recovery
# ----------------------------------------------------------------------------


def _validate_chain(directory: str, sh: dict, crcs: Dict[str, int]) -> dict:
    """Validate one shard's journal chain against the manifest CRCs and
    build its replay plan, truncated at the first torn/invalid record.
    Segments past the first invalid one are unreachable (replay cannot
    cross the gap) and are marked for quarantine.  An invalid snapshot
    sinks the whole generation — there is nothing to replay onto."""
    snap = sh["snapshot"]
    if not snap or not _file_valid(os.path.join(directory, snap), crcs.get(snap)):
        raise _GenerationInvalid(f"shard {sh['uid']}: snapshot {snap!r} invalid")
    valid: List[str] = []
    invalid: List[str] = []
    for i, seg in enumerate(sh["segments"]):
        if _file_valid(os.path.join(directory, seg), crcs.get(seg)):
            valid.append(seg)
        else:
            if "_delta_" in seg:
                # an invalid DELTA sinks the generation: its rows
                # consolidated (and GC'd) the shard's earlier segments, so
                # truncating at it would silently roll the shard — and the
                # global cut — back to its last full snapshot; an older
                # retained generation still references the
                # pre-consolidation chain and recovers a better prefix.
                raise _GenerationInvalid(
                    f"shard {sh['uid']}: delta {seg!r} invalid"
                )
            invalid = sh["segments"][i:]
            break
    return {
        "entry": sh,
        "snapshot": snap,
        "snap_commit": _file_commit_idx(snap),
        "valid": valid,
        "invalid": invalid,
        "truncated": bool(invalid),
        "max_commit": _file_commit_idx(valid[-1]) if valid else _file_commit_idx(snap),
    }


def _plan_generation(directory: str, manifest: dict):
    """Validate a whole manifest generation and compute the CONSISTENT CUT:
    the highest commit index C such that every shard's state at C is
    reproducible from its validated chain.  A shard truncated at commit c
    caps C at c; every other shard is then rolled back to C by dropping
    its (valid) segments past C — sound because a shard with no journal
    file in (c', C] was untouched there, so its replay-to-c' state IS its
    state at C.  A shard whose snapshot postdates C cannot be rolled back
    below it, which sinks the generation (fall back to MANIFEST.prev);
    snapshots forced at splits/repartitions guarantee a cut never lands
    inside a structural change, so the manifest's split points stay valid
    for any accepted cut."""
    crcs = manifest.get("file_crcs", {})
    plans = [_validate_chain(directory, sh, crcs) for sh in manifest["shards"]]
    cut = manifest["commit"]
    for p in plans:
        if p["truncated"]:
            cut = min(cut, p["max_commit"])
    for p in plans:
        if p["snap_commit"] > cut:
            raise _GenerationInvalid(
                f"shard {p['entry']['uid']}: snapshot commit "
                f"{p['snap_commit']} is past the consistent cut {cut}"
            )
        if any(
            "_delta_" in s for s in p["valid"] if _file_commit_idx(s) > cut
        ):
            # a delta past the cut covers commits ≤ cut whose segments it
            # consolidated away — dropping it would NOT reproduce the
            # shard's state at the cut (unlike a plain segment, which only
            # carries its own commit)
            raise _GenerationInvalid(
                f"shard {p['entry']['uid']}: delta past the consistent cut {cut}"
            )
        p["replay"] = [s for s in p["valid"] if _file_commit_idx(s) <= cut]
        p["commit"] = (
            _file_commit_idx(p["replay"][-1]) if p["replay"] else p["snap_commit"]
        )
    return cut, plans


def _load_shard_plan(directory: str, plan: dict):
    """Replay one shard's validated chain: snapshot, then surviving
    segments in commit order.  Root/height come from the LAST APPLIED
    file (journaled per-file since manifest v3), so a truncated replay
    lands on the root of its cut, not the manifest's newer one; legacy
    journals fall back to the manifest values."""

    def load(fname):
        with np.load(os.path.join(directory, fname)) as z:
            return {k: z[k] for k in z.files}

    snap = load(plan["snapshot"])
    arrs = {f: snap[f].copy() for f in _PERSISTED_FIELDS}
    root = int(snap["root"]) if "root" in snap else None
    height = int(snap["height"]) if "height" in snap else None
    for seg in plan["replay"]:
        z = load(seg)
        ids = z["node_ids"]
        for f in _PERSISTED_FIELDS:
            arrs[f][ids] = z[f]
        if "root" in z:
            root, height = int(z["root"]), int(z["height"])
    if root is None:
        root, height = plan["entry"]["root"], plan["entry"]["height"]
    return arrs, root, height


def _quarantine(directory: str, fnames: List[str]) -> List[str]:
    """Move invalid journal files into ``<dir>/quarantine/`` — preserved
    as forensic evidence (and CI artifacts), never silently deleted, and
    out of the way of future same-name journal writes."""
    if not fnames:
        return []
    qdir = os.path.join(directory, "quarantine")
    os.makedirs(qdir, exist_ok=True)
    moved = []
    for fname in fnames:
        try:
            os.replace(os.path.join(directory, fname), os.path.join(qdir, fname))
            moved.append(os.path.join("quarantine", fname))
        except OSError:
            pass  # already gone — nothing left to preserve
    return moved


def _rebuild_state(arrs: Dict[str, np.ndarray], root: int, height: int,
                   cfg: TreeConfig) -> TreeState:
    """Rebuild one shard's volatile fields from its persisted arrays
    (paper §5): size recount, versions and records reset, allocation and
    parent/pidx recomputed by the reachability walk from the root."""
    keys = arrs["keys"]
    children = arrs["children"]
    is_leaf = arrs["is_leaf"]
    from repro.core.abtree import EMPTY, NULL  # local import to avoid cycle

    n = keys.shape[0]  # pool rows = capacity + 1 (scratch row, see make_tree)
    assert n == cfg.capacity + 1
    size = np.zeros((n,), np.int32)
    size[is_leaf] = (keys[is_leaf] != int(EMPTY)).sum(axis=1)
    size[~is_leaf] = (children[~is_leaf] != int(NULL)).sum(axis=1)
    alloc = np.zeros((n,), bool)
    parent_arr = np.full((n,), int(NULL), np.int32)
    pidx_arr = np.zeros((n,), np.int32)
    stack = [root]
    while stack:
        nid = stack.pop()
        if nid < 0 or alloc[nid]:
            continue
        alloc[nid] = True
        if not is_leaf[nid]:
            for j in range(int(size[nid])):
                c = int(children[nid][j])
                parent_arr[c] = nid
                pidx_arr[c] = j
                stack.append(c)

    state = make_tree(cfg)
    return state._replace(
        keys=jnp.asarray(arrs["keys"]),
        vals=jnp.asarray(arrs["vals"]),
        children=jnp.asarray(arrs["children"]),
        parent=jnp.asarray(parent_arr),
        pidx=jnp.asarray(pidx_arr),
        is_leaf=jnp.asarray(arrs["is_leaf"]),
        level=jnp.asarray(arrs["level"]),
        size=jnp.asarray(size),
        alloc=jnp.asarray(alloc),
        root=jnp.int32(root),
        height=jnp.int32(height),
        dirty=jnp.zeros((n,), bool),
    )


def _restore_journal(out: _DurableBase, directory: str, manifest: dict,
                     shard_plans: List[dict], faults: FaultPlan, full: bool,
                     commit_retries: int, commit_backoff_s: float,
                     degrade_after: int, reattach_every: int,
                     commit_knobs: Optional[dict] = None):
    """Restore the journal bookkeeping of a recovered durable instance so
    it resumes committing where the crashed one left off — with the
    chains truncated to the consistent cut, invalid files quarantined,
    and (unless the recovery was full-fidelity) a forced full snapshot at
    the next commit plus ``_manifest_good = False`` so the corrupt
    on-disk MANIFEST is never hardlinked over a good ``MANIFEST.prev``."""
    out.dir = directory
    out._init_fault_state(
        faults, commit_retries, commit_backoff_s, degrade_after, reattach_every
    )
    knobs = dict(
        group_commit_every=1, group_commit_max_wait_s=0.05,
        commit_async=False, incremental_snapshots=True,
        full_snapshot_every=8, manifest_retain=3,
    )
    knobs.update(commit_knobs or {})
    out._init_commit_state(**knobs)
    out.snapshot_every = manifest["snapshot_every"]
    out.dstats = DurableStats()
    out._commit_idx = manifest["commit"] + 1
    out._uids = [p["entry"]["uid"] for p in shard_plans]
    out._next_uid = max(int(u[1:]) for u in out._uids) + 1
    out._snapshots = {p["entry"]["uid"]: p["snapshot"] for p in shard_plans}
    out._segments = {p["entry"]["uid"]: list(p["replay"]) for p in shard_plans}
    out._shard_commits = {p["entry"]["uid"]: p["commit"] for p in shard_plans}
    out._force_snapshot = set() if full else set(out._uids)
    out._manifest_good = full
    out._snap_capacity = manifest["capacity"]
    # the rows-since-last-full bookkeeping did not survive the crash: a
    # delta written without it would silently drop rows, so force the
    # next periodic snapshot to be FULL (recovery-ladder fallback rule) —
    # the chain restarts cleanly from there.
    out._delta_rows = {u: out._EMPTY_IDS for u in out._uids}
    out._delta_count = {u: out.full_snapshot_every for u in out._uids}
    crcs = manifest.get("file_crcs", {})
    surviving = set(out._snapshots.values())
    for segs in out._segments.values():
        surviving.update(segs)
    out._file_crcs = {f: crcs[f] for f in surviving if f in crcs}
    bad = [f for p in shard_plans for f in p["invalid"]]
    # crash forensics: load the committed audit sidecar so recovery can
    # explain the committed round prefix (repro.obs.report / witness).
    out._last_audit = manifest.get("audit")
    out._forensics = []
    if out._last_audit:
        from repro.obs.recorder import Recorder

        apath = os.path.join(directory, out._last_audit)
        acrc = crcs.get(out._last_audit)
        intact = True
        if acrc is not None:
            try:
                with open(apath, "rb") as f:
                    intact = (zlib.crc32(f.read()) & 0xFFFFFFFF) == acrc
            except OSError:
                intact = False
        if intact:
            try:
                out._forensics = Recorder.load(apath)
            except (OSError, ValueError):
                out._forensics = []  # sidecar lost: forensics degrade, state doesn't
        else:
            bad.append(out._last_audit)  # torn sidecar: quarantine it too
            out._file_crcs.pop(out._last_audit, None)
    out._quarantined = _quarantine(directory, bad)
    if out._quarantined:
        out.metrics.inc("segments_quarantined", len(out._quarantined))


def _generation_names(directory: str) -> List[str]:
    """The manifest generation ladder, newest first: MANIFEST, then the
    retention ring (MANIFEST.prev, MANIFEST.prev2, …) as deep as files
    exist on disk — recovery does not need to know the writer's
    ``manifest_retain``."""
    names = ["MANIFEST", "MANIFEST.prev"]
    extra = []
    try:
        for f in os.listdir(directory):
            suffix = f[len("MANIFEST.prev"):] if f.startswith("MANIFEST.prev") else ""
            if suffix.isdigit():
                extra.append((int(suffix), f))
    except OSError:
        pass
    return names + [f for _, f in sorted(extra)]


def _build_recovered(directory: str, manifest: dict, shard_plans: List[dict],
                     full: bool, faults: FaultPlan, commit_retries: int,
                     commit_backoff_s: float, degrade_after: int,
                     reattach_every: int, commit_knobs: Optional[dict] = None):
    cfg = TreeConfig(
        capacity=manifest["capacity"],
        b=manifest["b"],
        a=manifest["a"],
        max_height=manifest["max_height"],
    )
    mode = manifest["mode"]
    states = [
        _rebuild_state(arrs, root, height, cfg)
        for arrs, root, height in (
            _load_shard_plan(directory, p) for p in shard_plans
        )
    ]
    knobs = (commit_retries, commit_backoff_s, degrade_after, reattach_every,
             commit_knobs)

    if manifest["backend"] == "forest":
        out = DurableForest.__new__(DurableForest)
        forest = ABForest(
            n_shards=len(states),
            cfg=cfg,
            mode=mode,
            splits=np.asarray(manifest["splits"], np.int64),
            max_keys_per_shard=manifest["max_keys_per_shard"],
            narrow=manifest["narrow"],
            narrow_scan=manifest["narrow_scan"],
            auto_repartition=manifest.get("auto_repartition", False),
        )
        forest.state = _stack_states(states)
        out.forest = forest
        _restore_journal(out, directory, manifest, shard_plans, faults, full, *knobs)
        out._wire_hooks()
        return out

    out = DurableABTree.__new__(DurableABTree)
    out.tree = ABTree(cfg, mode=mode)
    out.tree.state = states[0]
    _restore_journal(out, directory, manifest, shard_plans, faults, full, *knobs)
    if mode == "occ":
        # a recovered p-OCC tree keeps per-sub-round durability
        out.tree.subround_hook = out._commit
    return out


def recover(directory: str, crash=None, *, faults=None, commit_retries: int = 2,
            commit_backoff_s: float = 0.002, degrade_after: int = 3,
            reattach_every: int = 4, **commit_knobs):
    """Recovery procedure (paper §5, corruption-hardened): walk the
    generation ladder — the committed MANIFEST first, then the retained
    ``MANIFEST.prev`` — and for the first checksum-valid manifest whose
    files admit a consistent cut, replay each shard's node images
    (truncating at the first torn/invalid record, quarantining bad
    files), rebuild volatile fields (size recount, versions and records
    reset, allocation recomputed by reachability), and restack the shards
    at the recorded split points.  Returns a ``DurableABTree`` or
    ``DurableForest`` according to what was journaled; the recovered
    instance is fully operational — occ mode re-installs the per-sub-round
    commit hook and ``snapshot_every`` is restored from the manifest.
    Raises ``RecoveryError`` if no generation yields a committed prefix
    (``FileNotFoundError`` if no manifest was ever committed)."""
    plan = _resolve_faults(crash, faults)
    failures = []
    for name in _generation_names(directory):
        manifest = _load_manifest(directory, name)
        if manifest is None:
            failures.append(f"{name}: missing or corrupt")
            continue
        try:
            cut, shard_plans = _plan_generation(directory, manifest)
        except _GenerationInvalid as e:
            failures.append(f"{name}: {e}")
            continue
        full = (
            name == "MANIFEST"
            and cut == manifest["commit"]
            and not any(p["truncated"] for p in shard_plans)
        )
        return _build_recovered(
            directory, manifest, shard_plans, full, plan,
            commit_retries, commit_backoff_s, degrade_after, reattach_every,
            commit_knobs,
        )
    if not os.path.exists(os.path.join(directory, "MANIFEST")):
        raise FileNotFoundError(f"no MANIFEST in {directory!r}")
    raise RecoveryError(
        f"no manifest generation in {directory!r} yields a committed prefix: "
        + "; ".join(failures)
    )


def recover_forest(directory: str, crash=None, **kwargs) -> DurableForest:
    """Typed convenience wrapper: recover a ``DurableForest`` journal."""
    out = recover(directory, crash, **kwargs)
    assert isinstance(out, DurableForest), (
        f"journal at {directory!r} is backend {out.backend!r}, not a forest"
    )
    return out
