"""Durable (strictly linearizable) trees — the paper's §5, adapted to a
framework durability substrate (DESIGN.md §2, row "clwb+sfence"), for the
single tree and the sharded forest alike.

The paper's p-OCC-ABtree persists only keys/values/pointers, ordering writes
with clwb+sfence so that (i) new nodes are persistent *before* the single
pointer that links them ("link-and-persist": the pointer is written marked,
flushed, then unmarked — readers never follow an unpersisted pointer), and
(ii) a simple insert/delete becomes durable exactly when its key reaches
persistent memory.

On a distributed training/serving system the persistence domain is a
filesystem, not NVRAM, and the update unit is a *round*, not a single store.
The protocol maps 1:1 — per shard:

  paper                           this module
  ----------------------------    ------------------------------------------
  flush new nodes (clwb+sfence)   write round segment file + fsync
                                  (per SHARD: one journal lane per shard,
                                  fsyncs issued in parallel; an untouched
                                  shard flushes nothing)
  write marked pointer            write MANIFEST.tmp naming every shard's
                                  snapshot + segment chain and its commit
                                  index (ONE vector commit for all shards)
  flush pointer, unmark           fsync tmp, os.replace → MANIFEST, fsync dir
  recovery: walk from root,       recovery: load last committed manifest,
    rebuild size/ver/locks          replay each shard's segments, rebuild
                                    size/ver/dirty, restack the shards and
                                    restore the split points

The commit point (durable linearization point) is the atomic rename: a round
is in the abstract *persistent* dictionary iff its manifest committed —
exactly the paper's "a key is in the p-tree iff it reached persistent
memory", lifted to round granularity.  The manifest carries a *vector* of
per-shard commit indices, so one rename atomically commits every shard's
journal advance; shard splits interact with the journal by forcing a
snapshot of exactly the two affected shards (journals are keyed by a stable
shard uid, so the restack leaves every other shard's segment chain valid).
Strict linearizability: ops of an uncommitted round took no externally
visible effect (results are only released to callers after commit), so
removing them from the crashed execution is legal; ops of committed rounds
are linearized before the crash.  Mid-restack states never commit: occ
sub-round commits are suppressed while a shard split is sweeping/re-
inserting, so recovery always lands on a round (or sub-round) boundary.

Publishing elimination reduces durability cost exactly as in the paper:
eliminated ops dirty no nodes, so fewer node images are flushed per round
(`flush_bytes`, `fsyncs` counters below reproduce the Table-1-style
accounting).  Old journal files a committed manifest no longer references
are garbage-collected after each commit (`gc_removed`).

Crash injection: ``CrashPoint`` raises ``SimulatedCrash`` at a chosen step
(after-segment / mid-manifest / after-manifest-before-dir-sync /
mid-shard-split) so tests can assert recovery lands on the last committed
round boundary.
"""
from __future__ import annotations

import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.abtree import ABTree, RoundOutput, ScanOutput, TreeConfig, TreeState, make_tree
from repro.core.forest import ABForest, _stack_states

_PERSISTED_FIELDS = ("keys", "vals", "children", "is_leaf", "level")
# NOT persisted (volatile; rebuilt by recovery, as in the paper §5 — only
# keys/values/child pointers are persistent):
#   size (recomputed from keys/children), parent/pidx (rebuilt from the
#   recovery walk), ver (reset), rec_* (reset), alloc (recomputed), dirty,
#   stats.

_MANIFEST_VERSION = 2


class SimulatedCrash(RuntimeError):
    pass


@dataclass
class CrashPoint:
    """Injects a crash at the named step of the given commit index.

    Steps: ``after_segment`` (shard files flushed, manifest not yet
    written), ``mid_manifest`` (torn manifest tmp), ``before_dirsync``
    (manifest renamed, directory not yet synced), ``mid_split`` (a shard
    split restacked the forest; nothing of the surrounding round has
    committed — ``at_commit`` is the NEXT commit index at that moment),
    ``mid_repartition`` (a load-aware boundary rebalance or cold-shard
    merge just re-keyed the journals; same NEXT-commit-index convention
    as ``mid_split``)."""

    step: str = ""  # "after_segment" | "mid_manifest" | "before_dirsync"
    #              | "mid_split" | "mid_repartition"
    at_commit: int = -1  # commit index at which to fire (-1 = never)
    _count: int = field(default=0, repr=False)

    def maybe_fire(self, step: str, commit_idx: int):
        if self.step == step and self.at_commit == commit_idx:
            raise SimulatedCrash(f"simulated crash at {step} (commit {commit_idx})")


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclass
class DurableStats:
    commits: int = 0
    flush_bytes: int = 0  # bytes of node images made durable
    fsyncs: int = 0
    nodes_flushed: int = 0
    gc_removed: int = 0  # journal files unlinked after losing all references


class _DurableBase:
    """The ONE commit-protocol implementation (link-and-persist at round
    granularity, per-shard journal lanes, single vector manifest).  The
    concrete classes below only bind it to their backing structure."""

    backend = ""  # "tree" | "forest"

    # -- backend surface (provided by subclasses) ------------------------------

    def _holder(self):
        """The backing round-engine holder (the ABTree or ABForest)."""
        raise NotImplementedError

    def _n_shards(self) -> int:
        raise NotImplementedError

    def _take_dirty_all(self) -> List[np.ndarray]:
        raise NotImplementedError

    def _persisted_host_arrays(self) -> List[Dict[str, np.ndarray]]:
        """Per-shard persisted-field arrays.  Each device array crosses to
        the host ONCE per commit; per-shard entries are views of it."""
        raise NotImplementedError

    def _shard_root_height(self, s: int):
        raise NotImplementedError

    def _capacity(self) -> int:
        raise NotImplementedError

    def _mode(self) -> str:
        raise NotImplementedError

    def _in_split_now(self) -> bool:
        return False

    def _manifest_extra(self) -> dict:
        return {}

    # -- telemetry (shared with the backing holder) ----------------------------
    # The durable wrapper has no registry of its own: journal metrics land
    # in the backing holder's registry, so ``holder.metrics`` is ONE
    # surface across volatile and durable variants, and installing a
    # tracer on the wrapper also times the engine phases underneath.

    @property
    def metrics(self):
        return self._holder().metrics

    @property
    def tracer(self):
        return self._holder().tracer

    @tracer.setter
    def tracer(self, t):
        self._holder().tracer = t

    @property
    def recorder(self):
        return self._holder().recorder

    @recorder.setter
    def recorder(self, r):
        self._holder().recorder = r

    def forensics_records(self):
        """The audit records recovered from the committed forensics
        sidecar (empty on a fresh journal): the last-K rounds of the
        crashed execution's *committed* prefix, for the explain-report."""
        return list(getattr(self, "_forensics", []))

    # -- journal lifecycle -----------------------------------------------------

    def _init_journal(self, directory: str, crash: Optional[CrashPoint],
                      snapshot_every: int):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.crash = crash or CrashPoint()
        self.snapshot_every = snapshot_every
        self.dstats = DurableStats()
        self._commit_idx = 0
        uids = [f"s{i:04d}" for i in range(self._n_shards())]
        self._uids: List[str] = uids
        self._next_uid = len(uids)
        self._snapshots: Dict[str, Optional[str]] = {u: None for u in uids}
        self._segments: Dict[str, List[str]] = {u: [] for u in uids}
        self._shard_commits: Dict[str, int] = {u: -1 for u in uids}
        self._force_snapshot = set(uids)
        self._snap_capacity: Optional[int] = None
        self._forensics: List[dict] = []
        # initial durable state: commit round 0 (empty snapshots, all shards)
        self._commit(force_snapshot=True)

    def _new_shard_uid(self) -> str:
        uid = f"s{self._next_uid:04d}"
        self._next_uid += 1
        self._snapshots[uid] = None
        self._segments[uid] = []
        self._shard_commits[uid] = -1
        return uid

    # -- commit protocol (link-and-persist) ------------------------------------

    def _commit(self, force_snapshot: bool = False):
        if self._in_split_now():
            # a shard split is mid-restack (sweep / re-insert rounds run
            # through the same engine): those intermediate states are not
            # round boundaries and must never become the durable prefix.
            return
        idx = self._commit_idx
        tr = self.tracer
        reg = self.metrics
        # a pool growth invalidates segment node indexing → force snapshots
        grown = self._snap_capacity != self._capacity()
        dirty = self._take_dirty_all()
        shard_arrays = self._persisted_host_arrays()
        jobs = []  # (shard, uid, fname, node_ids, arrays)
        for s in range(self._n_shards()):
            uid = self._uids[s]
            snap = (
                force_snapshot
                or grown
                or (idx % self.snapshot_every == 0)
                or uid in self._force_snapshot
                or self._snapshots[uid] is None
            )
            if snap:
                jobs.append((s, uid, f"{uid}_snapshot_{idx:08d}.npz", None,
                             shard_arrays[s]))
            elif dirty[s].size:
                arrs = {f: a[dirty[s]] for f, a in shard_arrays[s].items()}
                jobs.append(
                    (s, uid, f"{uid}_segment_{idx:08d}.npz", dirty[s], arrs)
                )
            # untouched shard: its journal lane is quiet this commit
        with tr.span("journal_flush", commit=idx, files=len(jobs)):
            written = self._write_shard_files(jobs)
        for (s, uid, fname, node_ids, _), (nbytes, nnodes, dt_w) in zip(
            jobs, written
        ):
            self.dstats.flush_bytes += nbytes
            self.dstats.fsyncs += 1
            self.dstats.nodes_flushed += nnodes
            reg.inc("flush_bytes", nbytes, shard=s)
            reg.inc("fsyncs", shard=s)
            reg.inc("nodes_flushed", nnodes, shard=s)
            reg.observe("fsync_latency_s", dt_w)
            if node_ids is None:
                self._snapshots[uid] = fname
                self._segments[uid] = []
            else:
                self._segments[uid].append(fname)
            self._shard_commits[uid] = idx
        self._force_snapshot.clear()
        self._snap_capacity = self._capacity()
        self.crash.maybe_fire("after_segment", idx)

        # -- forensics sidecar: flush the recorder's ring next to the
        # journal BEFORE the manifest, and commit the *reference* through
        # the manifest's atomic rename — a crash anywhere in this commit
        # leaves the previous manifest pointing at the previous sidecar,
        # so the recovered sidecar always matches the committed round
        # prefix (same link-and-persist argument as the node images).
        audit_ref = getattr(self, "_last_audit", None)
        rec = getattr(self._holder(), "recorder", None)
        if rec is not None and rec.enabled:
            audit_ref = f"audit_{idx:08d}.jsonl"
            apath = os.path.join(self.dir, audit_ref)
            tmp_a = apath + ".tmp"
            header = json.dumps(
                {
                    "kind": "sidecar",
                    "commit_idx": idx,
                    "backend": self.backend,
                    "rounds": int(self._holder()._rounds),
                }
            )
            with open(tmp_a, "w") as f:
                f.write(header + "\n")
                for line in rec.dump_records():
                    f.write(line + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp_a, apath)

        shard_entries = []
        for s, uid in enumerate(self._uids):
            root, height = self._shard_root_height(s)
            shard_entries.append(
                {
                    "uid": uid,
                    "snapshot": self._snapshots[uid],
                    "segments": self._segments[uid],
                    "root": root,
                    "height": height,
                    "commit": self._shard_commits[uid],
                }
            )
        manifest = {
            "version": _MANIFEST_VERSION,
            "backend": self.backend,
            "commit": idx,
            "mode": self._mode(),
            "snapshot_every": self.snapshot_every,
            "capacity": self._capacity(),
            "b": self._cfg().b,
            "a": self._cfg().a,
            "max_height": self._cfg().max_height,
            "shards": shard_entries,
            "audit": audit_ref,
            **self._manifest_extra(),
        }
        tmp = os.path.join(self.dir, "MANIFEST.tmp")
        payload = json.dumps(manifest)
        with tr.span("manifest_commit", commit=idx):
            t0 = time.perf_counter()
            with open(tmp, "w") as f:
                f.write(payload[: len(payload) // 2])
                f.flush()
                self.crash.maybe_fire("mid_manifest", idx)
                f.write(payload[len(payload) // 2 :])
                f.flush()
                os.fsync(f.fileno())
            self.dstats.fsyncs += 1
            reg.observe("fsync_latency_s", time.perf_counter() - t0)
            os.replace(tmp, os.path.join(self.dir, "MANIFEST"))  # the "link" step
            self.crash.maybe_fire("before_dirsync", idx)
            _fsync_dir(self.dir)  # the "persist" step
        self.dstats.fsyncs += 1
        reg.inc("fsyncs", 2)  # manifest file + directory entry
        self.dstats.commits += 1
        reg.inc("commits")
        self._commit_idx += 1
        self._last_audit = audit_ref
        if rec is not None and rec.enabled:
            # commit marker: links the audit stream to the journal's commit
            # index (lands in the NEXT sidecar — this one is already
            # durable, matching the committed prefix exactly).
            rec.commit(idx, int(self._holder()._rounds))
        self._gc(manifest)

    def _write_shard_files(self, jobs):
        """Write + fsync every shard's journal file for this commit —
        the parallel fsync lanes (one thread per shard file; a single
        file is written inline)."""
        if len(jobs) <= 1:
            return [self._write_npz(f, ids, a) for _, _, f, ids, a in jobs]
        with ThreadPoolExecutor(max_workers=min(len(jobs), 8)) as ex:
            return list(
                ex.map(lambda j: self._write_npz(j[2], j[3], j[4]), jobs)
            )

    def _write_npz(self, fname: str, node_ids, arrs):
        path = os.path.join(self.dir, fname)
        tmp = path + ".tmp"
        save = dict(arrs)
        if node_ids is not None:
            save["node_ids"] = node_ids
        t0 = time.perf_counter()
        with open(tmp, "wb") as f:
            np.savez(f, **save)
            f.flush()
            os.fsync(f.fileno())  # the paper's clwb+sfence of new nodes
        os.replace(tmp, path)
        dt = time.perf_counter() - t0
        nbytes = sum(a.nbytes for a in save.values())
        nnodes = (
            int(node_ids.size) if node_ids is not None else int(arrs["keys"].shape[0])
        )
        return nbytes, nnodes, dt

    def _gc(self, manifest: dict):
        """Unlink journal files the committed manifest no longer references
        (a snapshot supersedes the shard's previous snapshot + segments;
        a GC'd shard uid loses its whole chain).  Runs strictly after the
        directory sync, so a crash can never resurrect a collected file
        into the durable prefix."""
        referenced = set()
        for sh in manifest["shards"]:
            if sh["snapshot"]:
                referenced.add(sh["snapshot"])
            referenced.update(sh["segments"])
        if manifest.get("audit"):
            referenced.add(manifest["audit"])
        removed = 0
        for fname in os.listdir(self.dir):
            if fname.endswith(".jsonl") and fname.startswith("audit_"):
                if fname not in referenced:
                    try:
                        os.unlink(os.path.join(self.dir, fname))
                        removed += 1
                    except OSError:
                        pass
                continue
            if not fname.endswith(".npz"):
                continue
            if ("_segment_" in fname or "_snapshot_" in fname) and (
                fname not in referenced
            ):
                try:
                    os.unlink(os.path.join(self.dir, fname))
                    removed += 1
                except OSError:
                    pass
        self.dstats.gc_removed += removed
        if removed:
            self.metrics.inc("gc_removed", removed)

    def _durable_stats_dict(self) -> Dict[str, int]:
        return dict(
            commits=self.dstats.commits,
            flush_bytes=self.dstats.flush_bytes,
            fsyncs=self.dstats.fsyncs,
            nodes_flushed=self.dstats.nodes_flushed,
            gc_removed=self.dstats.gc_removed,
        )


class DurableABTree(_DurableBase):
    """ABTree + round-granular link-and-persist durability — the S = 1 case
    of the per-shard journal protocol (one journal lane)."""

    backend = "tree"

    def __init__(
        self,
        directory: str,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        crash: Optional[CrashPoint] = None,
        snapshot_every: int = 64,
    ):
        self.tree = ABTree(cfg, mode=mode)
        if mode == "occ":
            # p-OCC: per-update flush discipline → per-sub-round commits
            self.tree.subround_hook = self._commit
        self._init_journal(directory, crash, snapshot_every)

    # -- backend surface -------------------------------------------------------

    def _holder(self):
        return self.tree

    def _n_shards(self) -> int:
        return 1

    def _take_dirty_all(self):
        return [self.tree.take_dirty()]

    def _persisted_host_arrays(self):
        st = self.tree.state
        return [{f: np.asarray(getattr(st, f)) for f in _PERSISTED_FIELDS}]

    def _shard_root_height(self, s: int):
        return int(self.tree.state.root), int(self.tree.state.height)

    def _capacity(self) -> int:
        return self.tree.cfg.capacity

    def _cfg(self) -> TreeConfig:
        return self.tree.cfg

    def _mode(self) -> str:
        return self.tree.mode

    # -- public API -----------------------------------------------------------

    def apply_round(self, ops, keys, vals=None) -> RoundOutput:
        """Apply a round and make it durable.  Results are only returned
        after the commit — the durable linearization discipline.  (In occ
        mode the sub-round hook has already committed each sub-round; no
        further flush is needed.)"""
        out = self.tree.apply_round(ops, keys, vals)
        if self.tree.mode != "occ":
            self._commit()
        return out

    def stats(self) -> Dict[str, int]:
        s = self.tree.stats()
        s.update(self._durable_stats_dict())
        return s


class DurableForest(_DurableBase):
    """ABForest + per-shard link-and-persist durability: one journal lane
    per shard (independent dirty tracking, parallel fsyncs), one manifest
    committing the vector of per-shard commit indices atomically.  A shard
    split forces snapshots of exactly the two affected shards — journals
    are keyed by stable shard uids, so every other shard's segment chain
    survives the restack."""

    backend = "forest"

    def __init__(
        self,
        directory: str,
        n_shards: int = 1,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        crash: Optional[CrashPoint] = None,
        snapshot_every: int = 64,
        *,
        splits=None,
        key_space=None,
        max_keys_per_shard: Optional[int] = None,
        narrow_scan: bool = False,
        narrow: bool = False,
        auto_repartition: bool = False,
    ):
        self.forest = ABForest(
            n_shards=n_shards,
            cfg=cfg,
            mode=mode,
            splits=splits,
            key_space=key_space,
            max_keys_per_shard=max_keys_per_shard,
            narrow_scan=narrow_scan,
            narrow=narrow,
            auto_repartition=auto_repartition,
        )
        self._wire_hooks()
        self._init_journal(directory, crash, snapshot_every)

    def _wire_hooks(self):
        if self.forest.mode == "occ":
            # p-OCC: per-update flush discipline → per-sub-round commits
            self.forest.subround_hook = self._commit
        self.forest.split_hook = self._on_shard_split
        self.forest.repartition_hook = self._on_repartition

    def _on_shard_split(self, s: int):
        """Journal re-keying for a shard split: the fresh shard at ``s + 1``
        gets a new uid, and both affected shards are marked for a forced
        snapshot at the next commit (shard ``s`` halved its contents; the
        new shard has no journal yet).  Every other uid's chain is
        untouched."""
        self._uids.insert(s + 1, self._new_shard_uid())
        self._force_snapshot.add(self._uids[s])
        self.crash.maybe_fire("mid_split", self._commit_idx)

    def _on_repartition(self, kind: str, a: int, b: int):
        """Journal re-keying for a load-aware repartition.  A boundary
        rebalance keeps every shard's uid (contents moved between two
        chains) but forces both affected shards' snapshots — their replay
        prefixes no longer reproduce the moved keys.  A cold-shard merge
        retires the dead shard's uid (its chain is garbage after the
        restack) and forces the survivor's snapshot.  Either way the
        next manifest commit records the new split points."""
        if kind == "merge":
            dead = self._uids.pop(a)
            self._snapshots.pop(dead, None)
            self._segments.pop(dead, None)
            self._shard_commits.pop(dead, None)
            self._force_snapshot.discard(dead)
            self._force_snapshot.add(self._uids[b])
        else:
            self._force_snapshot.add(self._uids[a])
            self._force_snapshot.add(self._uids[b])
        self.crash.maybe_fire("mid_repartition", self._commit_idx)

    # -- backend surface -------------------------------------------------------

    def _holder(self):
        return self.forest

    def _n_shards(self) -> int:
        return self.forest.n_shards

    def _take_dirty_all(self):
        return self.forest.take_dirty()

    def _persisted_host_arrays(self):
        st = self.forest.state
        stacked = {f: np.asarray(getattr(st, f)) for f in _PERSISTED_FIELDS}
        return [
            {f: a[s] for f, a in stacked.items()}
            for s in range(self.forest.n_shards)
        ]

    def _shard_root_height(self, s: int):
        st = self.forest.state
        return int(np.asarray(st.root)[s]), int(np.asarray(st.height)[s])

    def _capacity(self) -> int:
        return self.forest.cfg.capacity

    def _cfg(self) -> TreeConfig:
        return self.forest.cfg

    def _mode(self) -> str:
        return self.forest.mode

    def _in_split_now(self) -> bool:
        return self.forest._in_split

    def _manifest_extra(self) -> dict:
        return {
            "splits": [int(x) for x in self.forest._splits],
            "max_keys_per_shard": self.forest.max_keys_per_shard,
            "narrow": self.forest.narrow,
            "narrow_scan": self.forest.narrow_scan,
            "auto_repartition": self.forest.auto_repartition,
        }

    # -- public API -----------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return self.forest.n_shards

    def apply_round(self, ops, keys, vals=None, *, scan_cap: int = 128) -> RoundOutput:
        """Apply one forest round and make it durable (results released
        only after the commit).  In occ mode each sub-round has already
        committed via the hook; a shard split triggered by the round is
        journaled as forced snapshots of the two affected shards."""
        out = self.forest.apply_round(ops, keys, vals, scan_cap=scan_cap)
        if self.forest.mode != "occ":
            self._commit()
        return out

    def scan_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        """Read-only: scans flush nothing (they dirty no nodes)."""
        return self.forest.scan_round(lo, hi, cap=cap, max_retries=max_retries)

    def scan_delete_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        out = self.forest.scan_delete_round(lo, hi, cap=cap, max_retries=max_retries)
        if self.forest.mode != "occ":
            self._commit()
        return out

    def items(self) -> dict:
        return self.forest.items()

    def stats(self) -> Dict[str, int]:
        s = self.forest.stats()
        s.update(self._durable_stats_dict())
        return s


# ----------------------------------------------------------------------------
# Recovery
# ----------------------------------------------------------------------------


def _load_shard_arrays(directory: str, shard_entry: dict) -> Dict[str, np.ndarray]:
    """Replay one shard's journal: snapshot, then segments in commit order."""

    def load(fname):
        with np.load(os.path.join(directory, fname)) as z:
            return {k: z[k] for k in z.files}

    snap = load(shard_entry["snapshot"])
    arrs = {f: snap[f].copy() for f in _PERSISTED_FIELDS}
    for seg in shard_entry["segments"]:
        z = load(seg)
        ids = z["node_ids"]
        for f in _PERSISTED_FIELDS:
            arrs[f][ids] = z[f]
    return arrs


def _rebuild_state(arrs: Dict[str, np.ndarray], root: int, height: int,
                   cfg: TreeConfig) -> TreeState:
    """Rebuild one shard's volatile fields from its persisted arrays
    (paper §5): size recount, versions and records reset, allocation and
    parent/pidx recomputed by the reachability walk from the root."""
    keys = arrs["keys"]
    children = arrs["children"]
    is_leaf = arrs["is_leaf"]
    from repro.core.abtree import EMPTY, NULL  # local import to avoid cycle

    n = keys.shape[0]  # pool rows = capacity + 1 (scratch row, see make_tree)
    assert n == cfg.capacity + 1
    size = np.zeros((n,), np.int32)
    size[is_leaf] = (keys[is_leaf] != int(EMPTY)).sum(axis=1)
    size[~is_leaf] = (children[~is_leaf] != int(NULL)).sum(axis=1)
    alloc = np.zeros((n,), bool)
    parent_arr = np.full((n,), int(NULL), np.int32)
    pidx_arr = np.zeros((n,), np.int32)
    stack = [root]
    while stack:
        nid = stack.pop()
        if nid < 0 or alloc[nid]:
            continue
        alloc[nid] = True
        if not is_leaf[nid]:
            for j in range(int(size[nid])):
                c = int(children[nid][j])
                parent_arr[c] = nid
                pidx_arr[c] = j
                stack.append(c)

    state = make_tree(cfg)
    return state._replace(
        keys=jnp.asarray(arrs["keys"]),
        vals=jnp.asarray(arrs["vals"]),
        children=jnp.asarray(arrs["children"]),
        parent=jnp.asarray(parent_arr),
        pidx=jnp.asarray(pidx_arr),
        is_leaf=jnp.asarray(arrs["is_leaf"]),
        level=jnp.asarray(arrs["level"]),
        size=jnp.asarray(size),
        alloc=jnp.asarray(alloc),
        root=jnp.int32(root),
        height=jnp.int32(height),
        dirty=jnp.zeros((n,), bool),
    )


def _restore_journal(out: _DurableBase, directory: str, manifest: dict,
                     crash: Optional[CrashPoint]):
    """Restore the journal bookkeeping of a recovered durable instance so
    it resumes committing where the crashed one left off."""
    out.dir = directory
    out.crash = crash or CrashPoint()
    out.snapshot_every = manifest["snapshot_every"]
    out.dstats = DurableStats()
    out._commit_idx = manifest["commit"] + 1
    out._uids = [sh["uid"] for sh in manifest["shards"]]
    out._next_uid = max(int(u[1:]) for u in out._uids) + 1
    out._snapshots = {sh["uid"]: sh["snapshot"] for sh in manifest["shards"]}
    out._segments = {sh["uid"]: list(sh["segments"]) for sh in manifest["shards"]}
    out._shard_commits = {sh["uid"]: sh["commit"] for sh in manifest["shards"]}
    out._force_snapshot = set()
    out._snap_capacity = manifest["capacity"]
    # crash forensics: load the committed audit sidecar so recovery can
    # explain the committed round prefix (repro.obs.report / witness).
    out._last_audit = manifest.get("audit")
    out._forensics = []
    if out._last_audit:
        from repro.obs.recorder import Recorder

        try:
            out._forensics = Recorder.load(
                os.path.join(directory, out._last_audit)
            )
        except OSError:
            out._forensics = []  # sidecar lost: forensics degrade, state doesn't


def recover(directory: str, crash: Optional[CrashPoint] = None):
    """Recovery procedure (paper §5): load the last *committed* manifest,
    replay each shard's node images, rebuild volatile fields (size recount,
    versions and records reset, allocation recomputed by reachability), and
    restack the shards at the recorded split points.  Returns a
    ``DurableABTree`` or ``DurableForest`` according to what was journaled;
    the recovered instance is fully operational — occ mode re-installs the
    per-sub-round commit hook and ``snapshot_every`` is restored from the
    manifest."""
    mpath = os.path.join(directory, "MANIFEST")
    with open(mpath) as f:
        manifest = json.load(f)  # a torn manifest never commits (rename is atomic)

    cfg = TreeConfig(
        capacity=manifest["capacity"],
        b=manifest["b"],
        a=manifest["a"],
        max_height=manifest["max_height"],
    )
    mode = manifest["mode"]
    states = [
        _rebuild_state(
            _load_shard_arrays(directory, sh), sh["root"], sh["height"], cfg
        )
        for sh in manifest["shards"]
    ]

    if manifest["backend"] == "forest":
        out = DurableForest.__new__(DurableForest)
        forest = ABForest(
            n_shards=len(states),
            cfg=cfg,
            mode=mode,
            splits=np.asarray(manifest["splits"], np.int64),
            max_keys_per_shard=manifest["max_keys_per_shard"],
            narrow=manifest["narrow"],
            narrow_scan=manifest["narrow_scan"],
            auto_repartition=manifest.get("auto_repartition", False),
        )
        forest.state = _stack_states(states)
        out.forest = forest
        _restore_journal(out, directory, manifest, crash)
        out._wire_hooks()
        return out

    out = DurableABTree.__new__(DurableABTree)
    out.tree = ABTree(cfg, mode=mode)
    out.tree.state = states[0]
    _restore_journal(out, directory, manifest, crash)
    if mode == "occ":
        # a recovered p-OCC tree keeps per-sub-round durability
        out.tree.subround_hook = out._commit
    return out


def recover_forest(directory: str, crash: Optional[CrashPoint] = None) -> DurableForest:
    """Typed convenience wrapper: recover a ``DurableForest`` journal."""
    out = recover(directory, crash)
    assert isinstance(out, DurableForest), (
        f"journal at {directory!r} is backend {out.backend!r}, not a forest"
    )
    return out
