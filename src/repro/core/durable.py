"""Durable (strictly linearizable) tree — the paper's §5, adapted to a
framework durability substrate (DESIGN.md §2, row "clwb+sfence").

The paper's p-OCC-ABtree persists only keys/values/pointers, ordering writes
with clwb+sfence so that (i) new nodes are persistent *before* the single
pointer that links them ("link-and-persist": the pointer is written marked,
flushed, then unmarked — readers never follow an unpersisted pointer), and
(ii) a simple insert/delete becomes durable exactly when its key reaches
persistent memory.

On a distributed training/serving system the persistence domain is a
filesystem, not NVRAM, and the update unit is a *round*, not a single store.
The protocol maps 1:1:

  paper                           this module
  ----------------------------    ------------------------------------------
  flush new nodes (clwb+sfence)   write round segment file + fsync
  write marked pointer            write MANIFEST.tmp naming the segment
  flush pointer, unmark           fsync tmp, os.replace → MANIFEST, fsync dir
  recovery: walk from root,       recovery: load last committed manifest,
    rebuild size/ver/locks          replay segments, rebuild size/ver/dirty

The commit point (durable linearization point) is the atomic rename: a round
is in the abstract *persistent* dictionary iff its manifest committed —
exactly the paper's "a key is in the p-tree iff it reached persistent
memory", lifted to round granularity.  Strict linearizability: ops of an
uncommitted round took no externally visible effect (results are only
released to callers after commit in `DurableABTree.apply_round`), so
removing them from the crashed execution is legal; ops of committed rounds
are linearized before the crash.

Publishing elimination reduces durability cost exactly as in the paper:
eliminated ops dirty no nodes, so fewer node images are flushed per round
(`flush_bytes`, `fsyncs` counters below reproduce the Table-1-style
accounting).

Crash injection: ``CrashPoint`` raises ``SimulatedCrash`` at a chosen step
(after-segment / mid-manifest / after-manifest-before-dir-sync) so tests can
assert recovery lands on the last committed round boundary.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.abtree import ABTree, RoundOutput, TreeConfig, TreeState, make_tree

_PERSISTED_FIELDS = ("keys", "vals", "children", "is_leaf", "level")
# NOT persisted (volatile; rebuilt by recovery, as in the paper §5 — only
# keys/values/child pointers are persistent):
#   size (recomputed from keys/children), parent/pidx (rebuilt from the
#   recovery walk), ver (reset), rec_* (reset), alloc (recomputed), dirty,
#   stats.


class SimulatedCrash(RuntimeError):
    pass


@dataclass
class CrashPoint:
    """Injects a crash at the n-th occurrence of the named step."""

    step: str = ""  # "after_segment" | "mid_manifest" | "before_dirsync"
    at_commit: int = -1  # commit index at which to fire (-1 = never)
    _count: int = field(default=0, repr=False)

    def maybe_fire(self, step: str, commit_idx: int):
        if self.step == step and self.at_commit == commit_idx:
            raise SimulatedCrash(f"simulated crash at {step} (commit {commit_idx})")


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@dataclass
class DurableStats:
    commits: int = 0
    flush_bytes: int = 0  # bytes of node images made durable
    fsyncs: int = 0
    nodes_flushed: int = 0


class DurableABTree:
    """ABTree + round-granular link-and-persist durability."""

    def __init__(
        self,
        directory: str,
        cfg: TreeConfig = TreeConfig(),
        mode: str = "elim",
        crash: Optional[CrashPoint] = None,
        snapshot_every: int = 64,
    ):
        os.makedirs(directory, exist_ok=True)
        self.dir = directory
        self.tree = ABTree(cfg, mode=mode)
        if mode == "occ":
            # p-OCC: per-update flush discipline → per-sub-round commits
            self.tree.subround_hook = self._commit
        self.crash = crash or CrashPoint()
        self.snapshot_every = snapshot_every
        self.dstats = DurableStats()
        self._commit_idx = 0
        self._segments: list = []  # segment filenames since last snapshot
        self._snapshot_file: Optional[str] = None
        # initial durable state: commit round 0 (empty tree snapshot)
        self._commit(force_snapshot=True)

    # -- public API -----------------------------------------------------------

    def apply_round(self, ops, keys, vals=None) -> RoundOutput:
        """Apply a round and make it durable.  Results are only returned
        after the commit — the durable linearization discipline.  (In occ
        mode the sub-round hook has already committed each sub-round; the
        final commit below then flushes nothing new.)"""
        out = self.tree.apply_round(ops, keys, vals)
        if self.tree.mode != "occ":
            self._commit()
        return out

    def stats(self) -> Dict[str, int]:
        s = self.tree.stats()
        s.update(
            commits=self.dstats.commits,
            flush_bytes=self.dstats.flush_bytes,
            fsyncs=self.dstats.fsyncs,
            nodes_flushed=self.dstats.nodes_flushed,
        )
        return s

    # -- commit protocol (link-and-persist) ------------------------------------

    def _commit(self, force_snapshot: bool = False):
        idx = self._commit_idx
        # a pool growth invalidates segment indexing → force a snapshot
        grown = getattr(self, "_snap_capacity", None) != self.tree.cfg.capacity
        snap = force_snapshot or grown or (idx % self.snapshot_every == 0)
        if snap:
            fname = f"snapshot_{idx:08d}.npz"
            self._write_snapshot(fname)
            self._snapshot_file = fname
            self._segments = []
            self._snap_capacity = self.tree.cfg.capacity
        else:
            dirty = self.tree.take_dirty()
            fname = f"segment_{idx:08d}.npz"
            self._write_segment(fname, dirty)
            self._segments.append(fname)
        self.crash.maybe_fire("after_segment", idx)

        manifest = {
            "commit": idx,
            "snapshot": self._snapshot_file,
            "segments": self._segments,
            "root": int(self.tree.state.root),
            "height": int(self.tree.state.height),
            "capacity": self.tree.cfg.capacity,
            "b": self.tree.cfg.b,
            "a": self.tree.cfg.a,
            "max_height": self.tree.cfg.max_height,
            "mode": self.tree.mode,
        }
        tmp = os.path.join(self.dir, "MANIFEST.tmp")
        payload = json.dumps(manifest)
        with open(tmp, "w") as f:
            f.write(payload[: len(payload) // 2])
            f.flush()
            self.crash.maybe_fire("mid_manifest", idx)
            f.write(payload[len(payload) // 2 :])
            f.flush()
            os.fsync(f.fileno())
        self.dstats.fsyncs += 1
        os.replace(tmp, os.path.join(self.dir, "MANIFEST"))  # the "link" step
        self.crash.maybe_fire("before_dirsync", idx)
        _fsync_dir(self.dir)  # the "persist" step
        self.dstats.fsyncs += 1
        self.dstats.commits += 1
        self._commit_idx += 1

    def _write_snapshot(self, fname: str):
        s = self.tree.state
        arrs = {f: np.asarray(getattr(s, f)) for f in _PERSISTED_FIELDS}
        self._write_npz(fname, node_ids=None, **arrs)
        self.tree.take_dirty()  # snapshot covers everything

    def _write_segment(self, fname: str, dirty: np.ndarray):
        s = self.tree.state
        arrs = {f: np.asarray(getattr(s, f))[dirty] for f in _PERSISTED_FIELDS}
        self._write_npz(fname, node_ids=dirty, **arrs)

    def _write_npz(self, fname: str, node_ids, **arrs):
        path = os.path.join(self.dir, fname)
        tmp = path + ".tmp"
        save = dict(arrs)
        if node_ids is not None:
            save["node_ids"] = node_ids
        with open(tmp, "wb") as f:
            np.savez(f, **save)
            f.flush()
            os.fsync(f.fileno())  # the paper's clwb+sfence of new nodes
        os.replace(tmp, path)
        nbytes = sum(a.nbytes for a in save.values())
        self.dstats.flush_bytes += nbytes
        self.dstats.fsyncs += 1
        self.dstats.nodes_flushed += (
            int(node_ids.size) if node_ids is not None else int(arrs["keys"].shape[0])
        )


def recover(directory: str, crash: Optional[CrashPoint] = None) -> DurableABTree:
    """Recovery procedure (paper §5): load the last *committed* manifest,
    replay node images, rebuild volatile fields (size recount, versions and
    records reset, allocation recomputed by reachability)."""
    mpath = os.path.join(directory, "MANIFEST")
    with open(mpath) as f:
        manifest = json.load(f)  # a torn manifest never commits (rename is atomic)

    cfg = TreeConfig(
        capacity=manifest["capacity"],
        b=manifest["b"],
        a=manifest["a"],
        max_height=manifest["max_height"],
    )
    arrs = {f: None for f in _PERSISTED_FIELDS}

    def load(fname):
        with np.load(os.path.join(directory, fname)) as z:
            return {k: z[k] for k in z.files}

    snap = load(manifest["snapshot"])
    for f in _PERSISTED_FIELDS:
        arrs[f] = snap[f].copy()
    for seg in manifest["segments"]:
        z = load(seg)
        ids = z["node_ids"]
        for f in _PERSISTED_FIELDS:
            arrs[f][ids] = z[f]

    state = make_tree(cfg)
    # rebuild volatile fields -------------------------------------------------
    keys = arrs["keys"]
    children = arrs["children"]
    is_leaf = arrs["is_leaf"]
    from repro.core.abtree import EMPTY, NULL  # local import to avoid cycle

    n = keys.shape[0]  # pool rows = capacity + 1 (scratch row, see make_tree)
    assert n == cfg.capacity + 1
    size = np.zeros((n,), np.int32)
    size[is_leaf] = (keys[is_leaf] != int(EMPTY)).sum(axis=1)
    size[~is_leaf] = (children[~is_leaf] != int(NULL)).sum(axis=1)
    # allocation = reachability from root (paper: recovery walks the tree);
    # parent/pidx are volatile and rebuilt during the same walk.
    alloc = np.zeros((n,), bool)
    parent_arr = np.full((n,), int(NULL), np.int32)
    pidx_arr = np.zeros((n,), np.int32)
    stack = [manifest["root"]]
    while stack:
        nid = stack.pop()
        if nid < 0 or alloc[nid]:
            continue
        alloc[nid] = True
        if not is_leaf[nid]:
            for j in range(int(size[nid])):
                c = int(children[nid][j])
                parent_arr[c] = nid
                pidx_arr[c] = j
                stack.append(c)

    state = state._replace(
        keys=jnp.asarray(arrs["keys"]),
        vals=jnp.asarray(arrs["vals"]),
        children=jnp.asarray(arrs["children"]),
        parent=jnp.asarray(parent_arr),
        pidx=jnp.asarray(pidx_arr),
        is_leaf=jnp.asarray(arrs["is_leaf"]),
        level=jnp.asarray(arrs["level"]),
        size=jnp.asarray(size),
        alloc=jnp.asarray(alloc),
        root=jnp.int32(manifest["root"]),
        height=jnp.int32(manifest["height"]),
        dirty=jnp.zeros((n,), bool),
    )

    out = DurableABTree.__new__(DurableABTree)
    out.dir = directory
    out.tree = ABTree(cfg, mode=manifest["mode"])
    out.tree.state = state
    out.crash = crash or CrashPoint()
    out.snapshot_every = 64
    out.dstats = DurableStats()
    out._commit_idx = manifest["commit"] + 1
    out._segments = list(manifest["segments"])
    out._snapshot_file = manifest["snapshot"]
    out._snap_capacity = cfg.capacity
    return out
