"""Batched OCC-ABtree / Elim-ABtree on an array-backed node pool.

This is the TPU-native adaptation of the paper's concurrent relaxed
(a,b)-tree (see DESIGN.md §2/§4).  Concurrency is expressed as *rounds*: a
round applies a batch of dictionary operations that are all mutually
concurrent; per-key linearization order within a round is arrival order
(any order is legal per the paper's §4 argument — this is the freedom
publishing elimination exploits).

Two modes:

  * ``mode='elim'``   — Elim-ABtree: the elimination combine collapses all
    ops on a key to ≤ 1 physical slot write; eliminated ops compute their
    return values from the published per-key record (the combine), never
    touching tree arrays.
  * ``mode='occ'``    — OCC-ABtree baseline: every op executes physically.
    Duplicate keys force sub-rounds (duplicate-rank r executes in sub-round
    r), each with its own search + leaf write + version bump — mirroring the
    per-op work of the paper's OCC tree under contention.

Structure follows the paper:
  * unsorted leaves: insert writes the first free slot; delete blanks a slot
    (no shifting) — on TPU the probe is a lane-parallel compare (see
    kernels/leaf_probe).
  * per-node version counters (+2 per modifying round; record stamped with
    the odd intermediate) — used by the durable layer and by cross-round
    optimistic readers (serving).
  * per-leaf ElimRecord ⟨key, val, ver, op⟩ — the publishing record of the
    last modification, exposed to other engine replicas / later rounds.
  * relaxed rebalancing as independent-set *waves* of the Larsen–Fagerberg
    sub-operations (split / merge / distribute), each wave touching at most
    one violating child per parent.

NOTE on the paper's Figure 9 pseudocode: the distribute/merge branch
condition there is inverted relative to Larsen–Fagerberg (distributing two
nodes whose total is ≤ 2·MIN would leave one still underfull).  We implement
the standard relaxed-(a,b) rule: merge when total ≤ b, else distribute
evenly (each side ≥ a since total > b ≥ 2a).  See DESIGN.md §7.

This module holds the tree *state* and the device-level phase primitives
(descent, probe, net-op apply, structural waves, frontier expansion).
Round execution — lane classification, the ordered phase pipeline, and the
host orchestration of structural waves — lives in ``core/rounds.py``; the
``ABTree`` entry points below are thin wrappers over that engine.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elimination as elim
from repro.kernels.tree_descend.ops import frontier_compact
from repro.kernels.tree_descend.ref import descend_ref, probe_ref
from repro.obs.metrics import (
    MetricsRegistry,
    RegistryBackedCounters,
    engine_collector,
)
from repro.obs.recorder import Recorder
from repro.obs.tracer import NULL_TRACER

# ----------------------------------------------------------------------------
# Constants & state
# ----------------------------------------------------------------------------

KEY_DTYPE = jnp.int64
VAL_DTYPE = jnp.int64
EMPTY = jnp.iinfo(jnp.int64).max  # free-slot / unused-router sentinel (sorts last)
NOTFOUND = jnp.iinfo(jnp.int64).min  # ⊥ return value
NULL = jnp.int32(-1)  # null node id

OP_NOP = int(elim.OP_NOP)
OP_FIND = int(elim.OP_FIND)
OP_INSERT = int(elim.OP_INSERT)
OP_DELETE = int(elim.OP_DELETE)
# range scan [lo, lo+span) — served by the round engine's scan phase, which
# linearizes it before the round's net writes; never reaches the combine.
OP_RANGE = int(elim.OP_RANGE)

INT_MAX = np.int32(2**31 - 1)
KEY_MIN = jnp.iinfo(jnp.int64).min  # -inf bound for leftmost child ranges


class ScanConflictError(RuntimeError):
    """An optimistic range scan failed version validation repeatedly
    (concurrent update rounds kept touching the scanned subtree)."""


class TreeConfig(NamedTuple):
    capacity: int = 4096  # node pool size
    b: int = 8  # max keys per leaf == max children per internal
    a: int = 2  # min keys per leaf == min children per internal (a ≤ b/2)
    max_height: int = 24  # static bound for descent loops


class TreeStats(NamedTuple):
    slot_writes: jax.Array  # physical leaf slot writes (keys or vals)
    struct_ops: jax.Array  # split/merge/distribute sub-operations
    searches: jax.Array  # root-to-leaf descents (per lane)
    eliminated: jax.Array  # update ops eliminated (write avoided)
    rounds: jax.Array
    subrounds: jax.Array  # OCC sub-rounds executed
    scans: jax.Array  # range-scan ops served
    scan_retries: jax.Array  # scan rounds re-run after version conflicts


class TreeState(NamedTuple):
    # node pool (SoA) ---------------------------------------------------------
    keys: jax.Array  # (N, b) leaf keys (unsorted) | internal routers in [:, :b-1] (sorted)
    vals: jax.Array  # (N, b) leaf values
    children: jax.Array  # (N, b) int32 child ids (internal)
    parent: jax.Array  # (N,) int32
    pidx: jax.Array  # (N,) int32 index of node in parent.children
    is_leaf: jax.Array  # (N,) bool
    size: jax.Array  # (N,) int32: leaf → #keys; internal → #children
    level: jax.Array  # (N,) int32: leaf = 0
    ver: jax.Array  # (N,) int32: even ⇔ quiescent (paper's version discipline)
    alloc: jax.Array  # (N,) bool
    # per-leaf ElimRecord (paper §4.1) ---------------------------------------
    rec_key: jax.Array  # (N,)
    rec_val: jax.Array  # (N,)
    rec_ver: jax.Array  # (N,) int32 (odd when valid)
    rec_op: jax.Array  # (N,) int32
    # tree scalars ------------------------------------------------------------
    root: jax.Array  # int32
    height: jax.Array  # int32 (#levels; 1 = single leaf)
    dirty: jax.Array  # (N,) bool — touched since last durable commit
    stats: TreeStats


# Pool-row fill values per TreeState field (scalars root/height/stats are
# absent: they pass through pool growth untouched).  Shared by ABTree._grow
# (node axis 0) and ABForest._grow (node axis 1 of the stacked state).
_GROW_FILL = dict(
    keys=EMPTY, vals=0, children=NULL, parent=NULL, pidx=0, is_leaf=True,
    size=0, level=0, ver=0, alloc=False, rec_key=EMPTY, rec_val=0,
    rec_ver=0, rec_op=0, dirty=False,
)


def grow_pool(state: TreeState, pad_n: int, axis: int = 0) -> TreeState:
    """Append ``pad_n`` freshly-initialized node rows along ``axis`` of
    every per-node array (scalars untouched).  The old scratch row becomes
    an ordinary free node (it is kept all-initial by the masked-scatter
    discipline) and the new last row takes over as scratch."""
    out = {}
    for name, val in state._asdict().items():
        if name in _GROW_FILL:
            pad_shape = val.shape[:axis] + (pad_n,) + val.shape[axis + 1 :]
            out[name] = jnp.concatenate(
                [val, jnp.full(pad_shape, _GROW_FILL[name], val.dtype)], axis=axis
            )
        else:
            out[name] = val
    return TreeState(**out)


def make_tree(cfg: TreeConfig) -> TreeState:
    # Pool has capacity+1 rows: the last row is a write-off SCRATCH row that
    # absorbs all masked-out scatter lanes.  Routing inactive lanes to a
    # dedicated row (instead of row 0) avoids duplicate-index scatter races
    # with real writes (XLA scatter order for duplicates is unspecified).
    n, b = cfg.capacity + 1, cfg.b
    z64 = functools.partial(jnp.full, dtype=KEY_DTYPE)
    zi = functools.partial(jnp.zeros, dtype=jnp.int32)
    return TreeState(
        keys=z64((n, b), EMPTY),
        vals=z64((n, b), 0),
        children=jnp.full((n, b), NULL, jnp.int32),
        parent=jnp.full((n,), NULL, jnp.int32),
        pidx=zi((n,)),
        is_leaf=jnp.ones((n,), bool),
        size=zi((n,)),
        level=zi((n,)),
        ver=zi((n,)),
        alloc=jnp.zeros((n,), bool).at[0].set(True),  # node 0 = initial root leaf
        rec_key=z64((n,), EMPTY),
        rec_val=z64((n,), 0),
        rec_ver=zi((n,)),
        rec_op=zi((n,)),
        root=jnp.int32(0),
        height=jnp.int32(1),
        dirty=jnp.zeros((n,), bool).at[0].set(True),
        stats=TreeStats(*([jnp.int64(0)] * 8)),
    )


# ----------------------------------------------------------------------------
# Phase 1: vectorized descent + probe.  The implementations live in
# kernels/tree_descend/ref.py (the pure-jnp oracles of the fused Pallas
# descent+probe kernel); these wrappers bind them to the TreeState layout so
# the host path and the kernel oracle can never drift.
# ----------------------------------------------------------------------------


def descend(state: TreeState, keys: jax.Array, cfg: TreeConfig) -> jax.Array:
    """Root-to-leaf search for a batch of keys → leaf ids.  The per-level
    child choice mirrors the paper's ``search``: follow ptrs[#routers ≤ key]."""
    return descend_ref(
        state.keys, state.children, state.is_leaf, state.root, keys,
        max_height=cfg.max_height,
    )


def probe(state: TreeState, leaf_ids: jax.Array, keys: jax.Array):
    """Unsorted-leaf probe: lane-parallel compare across the b slots."""
    return probe_ref(state.keys, state.vals, leaf_ids, keys, notfound=NOTFOUND)


# ----------------------------------------------------------------------------
# Phase 3: in-place apply of net ops (the hot path the paper optimizes)
# ----------------------------------------------------------------------------


class ApplyOut(NamedTuple):
    state: TreeState
    deferred: jax.Array  # (B,) bool — net inserts that did not fit (leaf full)


def _segment_starts(x: jax.Array) -> jax.Array:
    return jnp.concatenate([jnp.ones((1,), bool), x[1:] != x[:-1]])


def _segmented_rank(mask: jax.Array, seg_id: jax.Array) -> jax.Array:
    """0-based rank of each True within its segment (junk elsewhere)."""
    c = jnp.cumsum(mask.astype(jnp.int32))
    seg_base = jnp.where(_segment_starts(seg_id), c - mask.astype(jnp.int32), 0)
    seg_base = jax.lax.associative_scan(jnp.maximum, seg_base)
    return c - 1 - seg_base


def apply_net_ops(
    state: TreeState,
    cfg: TreeConfig,
    leaf_ids: jax.Array,  # (B,) leaf per sorted op
    keys_sorted: jax.Array,
    slot_found: jax.Array,  # (B,) slot of key if present
    net_insert: jax.Array,  # (B,) bool (at segment heads)
    net_delete: jax.Array,
    net_overwrite: jax.Array,
    final_val: jax.Array,
    arrival_sorted: jax.Array,  # (B,) original position (for record priority)
) -> ApplyOut:
    """Apply per-key net effects.  All net flags are on distinct keys; keys
    are sorted, so ops on one leaf are contiguous (leaf key ranges partition
    the key space — invariants 1/7 of the paper)."""
    b = cfg.b
    scratch = state.keys.shape[0] - 1  # masked lanes write here (see make_tree)

    # --- deletes: blank the slot (unsorted leaves: no shifting — the paper's
    # fast delete), size -= 1.
    del_rows = jnp.where(net_delete, leaf_ids, scratch)
    del_slots = jnp.where(net_delete, slot_found, 0)
    keys_new = state.keys.at[del_rows, del_slots].set(
        jnp.where(net_delete, EMPTY, state.keys[del_rows, del_slots])
    )
    size_new = state.size.at[del_rows].add(jnp.where(net_delete, -1, 0))

    # --- overwrites: value-only write.
    ow_rows = jnp.where(net_overwrite, leaf_ids, scratch)
    ow_slots = jnp.where(net_overwrite, slot_found, 0)
    vals_new = state.vals.at[ow_rows, ow_slots].set(
        jnp.where(net_overwrite, final_val, state.vals[ow_rows, ow_slots])
    )

    # --- inserts: rank-th free slot of the leaf, ranking against the
    # *post-delete* keys (deletes in this round free slots first).
    ins = net_insert
    rank = _segmented_rank(ins, leaf_ids)
    leaf_rows = keys_new[leaf_ids]  # (B, b)
    free = leaf_rows == EMPTY
    # argsort(stable) of ~free puts free slots first, ascending slot order.
    free_order = jnp.argsort(~free, axis=1, stable=True).astype(jnp.int32)
    n_free = jnp.sum(free, axis=1).astype(jnp.int32)
    fits = ins & (rank < n_free)
    ins_slot = jnp.take_along_axis(
        free_order, jnp.clip(rank, 0, b - 1)[:, None], axis=1
    )[:, 0]

    ins_rows = jnp.where(fits, leaf_ids, scratch)
    ins_slots = jnp.where(fits, ins_slot, 0)
    keys_new = keys_new.at[ins_rows, ins_slots].set(
        jnp.where(fits, keys_sorted, keys_new[ins_rows, ins_slots])
    )
    vals_new = vals_new.at[ins_rows, ins_slots].set(
        jnp.where(fits, final_val, vals_new[ins_rows, ins_slots])
    )
    size_new = size_new.at[ins_rows].add(jnp.where(fits, 1, 0))

    deferred = ins & ~fits

    # --- version bump: +2 per modified leaf (even ⇔ quiescent, §3.1).
    modified = net_delete | net_overwrite | fits
    mod_rows = jnp.where(modified, leaf_ids, scratch)
    ver_bump = jnp.zeros_like(state.ver).at[mod_rows].max(
        jnp.where(modified, 1, 0).astype(jnp.int32)
    )
    ver_bump = ver_bump.at[scratch].set(0)
    ver_new = state.ver + 2 * ver_bump
    dirty_new = state.dirty | (ver_bump > 0)

    # --- publish ElimRecord: the net op with max arrival in each modified
    # leaf is the leaf's last modifier; rec_ver = new_ver - 1 (odd), §4.1.
    prio = jnp.where(modified, arrival_sorted.astype(jnp.int32), -1)
    best = jnp.full((state.keys.shape[0],), -1, jnp.int32).at[mod_rows].max(prio)
    is_best = modified & (prio == best[leaf_ids])
    rb_rows = jnp.where(is_best, leaf_ids, scratch)

    def publish(arr, values):
        return arr.at[rb_rows].set(jnp.where(is_best, values, arr[rb_rows]))

    rec_key = publish(state.rec_key, keys_sorted)
    rec_val = publish(state.rec_val, final_val)
    rec_op = publish(
        state.rec_op, jnp.where(net_delete, OP_DELETE, OP_INSERT).astype(jnp.int32)
    )
    rec_ver = publish(state.rec_ver, ver_new[leaf_ids] - 1)

    n_writes = (
        jnp.sum(net_delete) + jnp.sum(net_overwrite) + 2 * jnp.sum(fits)
    ).astype(jnp.int64)
    stats = state.stats._replace(slot_writes=state.stats.slot_writes + n_writes)

    return ApplyOut(
        state=state._replace(
            keys=keys_new,
            vals=vals_new,
            size=size_new,
            ver=ver_new,
            dirty=dirty_new,
            rec_key=rec_key,
            rec_val=rec_val,
            rec_op=rec_op,
            rec_ver=rec_ver,
            stats=stats,
        ),
        deferred=deferred,
    )


# ----------------------------------------------------------------------------
# Structural waves (relaxed-rebalancing sub-operations, batched)
# ----------------------------------------------------------------------------


def _alloc_ids(state: TreeState, k: int) -> jax.Array:
    """ids of k free nodes (deterministic: lowest ids first).  The last
    pool row (scratch) is never handed out."""
    order = jnp.argsort(state.alloc[:-1], stable=True)  # False (free) first
    return order[:k].astype(jnp.int32)


def _refresh_child_links(state: TreeState, parents: jax.Array, cfg: TreeConfig) -> TreeState:
    """Recompute parent/pidx for all children of the given (allocated,
    internal) parent ids.  Safe to call with junk ids: guarded by alloc &
    ~is_leaf & size."""
    ch = state.children[parents]  # (W, b)
    ok = (
        state.alloc[parents][:, None]
        & ~state.is_leaf[parents][:, None]
        & (jnp.arange(cfg.b)[None, :] < state.size[parents][:, None])
        & (ch >= 0)
    )
    scratch = state.keys.shape[0] - 1
    rows = jnp.where(ok, ch, scratch).reshape(-1)
    okf = ok.reshape(-1)
    jj = jnp.broadcast_to(jnp.arange(cfg.b, dtype=jnp.int32)[None, :], ch.shape).reshape(-1)
    pp = jnp.broadcast_to(parents[:, None], ch.shape).reshape(-1).astype(jnp.int32)
    pidx_new = state.pidx.at[rows].set(jnp.where(okf, jj, state.pidx[rows]))
    parent_new = state.parent.at[rows].set(jnp.where(okf, pp, state.parent[rows]))
    return state._replace(pidx=pidx_new, parent=parent_new)


def split_wave(
    state: TreeState, cfg: TreeConfig, node_ids: jax.Array, active: jax.Array
) -> TreeState:
    """One wave of split sub-operations.  Preconditions (caller-enforced):
    every active node is full (size == b); its parent is NOT full (or the
    node is the root); at most one active node per parent.

    Batched analog of the paper's splitting insert + fixTagged chain: we
    split eagerly instead of publishing a TaggedInternal, because wave
    execution is already atomic w.r.t. readers (no intra-round readers);
    tagging existed only to keep each lock-protected step small (DESIGN §7).
    """
    w = node_ids.shape[0]
    b = cfg.b
    scratch = state.keys.shape[0] - 1
    node_ids = jnp.where(active, node_ids, scratch)

    new_ids = _alloc_ids(state, 2 * w)
    right_ids = jnp.where(active, new_ids[:w], scratch)
    is_root = active & (state.parent[node_ids] == NULL)
    newroot_ids = jnp.where(is_root, new_ids[w:], scratch)

    leaf = state.is_leaf[node_ids]  # (W,)
    lh = (b + 1) // 2
    rh = b - lh
    iota = jnp.arange(b)[None, :]

    # ---- sort node contents (leaves are unsorted; internals already sorted).
    krows = state.keys[node_ids]
    vrows = state.vals[node_ids]
    crows = state.children[node_ids]
    order = jnp.argsort(krows, axis=1, stable=True).astype(jnp.int32)
    order = jnp.where(leaf[:, None], order, iota.astype(jnp.int32))
    ks = jnp.take_along_axis(krows, order, axis=1)
    vs = jnp.take_along_axis(vrows, order, axis=1)

    # ---- leaves: left ks[:lh], right ks[lh:]; router = ks[lh] (= min right).
    leaf_lk = jnp.where(iota < lh, ks, EMPTY)
    leaf_rk = jnp.where(iota < rh, jnp.roll(ks, -lh, axis=1), EMPTY)
    leaf_lv = vs
    leaf_rv = jnp.roll(vs, -lh, axis=1)

    # ---- internals: left lh children + lh-1 routers; right rh children +
    # rh-1 routers; router krows[lh-1] moves up.
    int_lk = jnp.where(iota < lh - 1, krows, EMPTY)
    int_rk = jnp.where(iota < rh - 1, jnp.roll(krows, -lh, axis=1), EMPTY)
    int_lc = jnp.where(iota < lh, crows, NULL)
    int_rc = jnp.where(iota < rh, jnp.roll(crows, -lh, axis=1), NULL)

    router = jnp.where(leaf, ks[:, lh], krows[:, lh - 1])

    def masked_set(arr, rows, values, act):
        cur = arr[rows]
        m = act[:, None] if values.ndim == 2 else act
        return arr.at[rows].set(jnp.where(m, values, cur))

    keys_new = masked_set(state.keys, node_ids, jnp.where(leaf[:, None], leaf_lk, int_lk), active)
    keys_new = masked_set(keys_new, right_ids, jnp.where(leaf[:, None], leaf_rk, int_rk), active)
    vals_new = masked_set(state.vals, node_ids, leaf_lv, active & leaf)
    vals_new = masked_set(vals_new, right_ids, leaf_rv, active & leaf)
    ch_new = masked_set(state.children, node_ids, int_lc, active & ~leaf)
    ch_new = masked_set(ch_new, right_ids, int_rc, active & ~leaf)

    size_new = state.size.at[node_ids].set(jnp.where(active, lh, state.size[node_ids]))
    size_new = size_new.at[right_ids].set(jnp.where(active, rh, size_new[right_ids]))
    isleaf_new = state.is_leaf.at[right_ids].set(
        jnp.where(active, leaf, state.is_leaf[right_ids])
    )
    level_new = state.level.at[right_ids].set(
        jnp.where(active, state.level[node_ids], state.level[right_ids])
    )
    alloc_new = state.alloc.at[right_ids].set(state.alloc[right_ids] | active)
    ver_new = state.ver.at[node_ids].add(jnp.where(active, 2, 0))

    state = state._replace(
        keys=keys_new, vals=vals_new, children=ch_new, size=size_new,
        is_leaf=isleaf_new, level=level_new, alloc=alloc_new, ver=ver_new,
    )

    # ---- grow root where needed: fresh internal with single child = node.
    state = state._replace(
        keys=state.keys.at[newroot_ids].set(
            jnp.where(is_root[:, None], jnp.full((w, b), EMPTY, KEY_DTYPE), state.keys[newroot_ids])
        ),
        children=state.children.at[newroot_ids, 0].set(
            jnp.where(is_root, node_ids, state.children[newroot_ids, 0])
        ),
        size=state.size.at[newroot_ids].set(jnp.where(is_root, 1, state.size[newroot_ids])),
        is_leaf=state.is_leaf.at[newroot_ids].set(
            state.is_leaf[newroot_ids] & ~is_root
        ),
        level=state.level.at[newroot_ids].set(
            jnp.where(is_root, state.level[node_ids] + 1, state.level[newroot_ids])
        ),
        alloc=state.alloc.at[newroot_ids].set(state.alloc[newroot_ids] | is_root),
        parent=state.parent.at[node_ids].set(
            jnp.where(is_root, newroot_ids, state.parent[node_ids])
        ),
        pidx=state.pidx.at[node_ids].set(jnp.where(is_root, 0, state.pidx[node_ids])),
    )
    any_root = jnp.any(is_root)
    root_new = jnp.where(
        any_root, jnp.max(jnp.where(is_root, newroot_ids, -1)), state.root
    ).astype(jnp.int32)
    height_new = state.height + any_root.astype(jnp.int32)

    # ---- link right sibling into parent: insert router at slot `at`,
    # child at `at+1` (shift tail right by one).
    pids = jnp.where(is_root, newroot_ids, state.parent[node_ids])
    pids = jnp.where(active, pids, scratch)
    at = state.pidx[node_ids][:, None]  # (W,1)
    pk = state.keys[pids]
    pc = state.children[pids]
    shifted_k = jnp.where(iota > at, jnp.roll(pk, 1, axis=1), pk)
    shifted_k = jnp.where(iota == at, router[:, None], shifted_k)
    shifted_c = jnp.where(iota > at + 1, jnp.roll(pc, 1, axis=1), pc)
    shifted_c = jnp.where(iota == at + 1, right_ids[:, None], shifted_c)

    keys_new = state.keys.at[pids].set(jnp.where(active[:, None], shifted_k, state.keys[pids]))
    ch_new = state.children.at[pids].set(jnp.where(active[:, None], shifted_c, state.children[pids]))
    size_new = state.size.at[pids].add(jnp.where(active, 1, 0))

    dirty_new = state.dirty
    for rows, m in ((node_ids, active), (right_ids, active), (pids, active), (newroot_ids, is_root)):
        r = jnp.where(m, rows, scratch)
        dirty_new = dirty_new.at[r].set(dirty_new[r] | m)

    stats = state.stats._replace(
        struct_ops=state.stats.struct_ops + jnp.sum(active).astype(jnp.int64)
    )
    state = state._replace(
        keys=keys_new, children=ch_new, size=size_new, root=root_new,
        height=height_new, dirty=dirty_new, stats=stats,
    )
    # fix child links of: parents (children shifted), the split node and its
    # new right sibling (internal splits reassign grandchildren).
    state = _refresh_child_links(state, pids, cfg)
    state = _refresh_child_links(state, node_ids, cfg)
    state = _refresh_child_links(state, right_ids, cfg)
    return state


def underfull_wave(
    state: TreeState, cfg: TreeConfig, node_ids: jax.Array, active: jax.Array
) -> TreeState:
    """One wave of merge/distribute sub-operations (paper's fixUnderfull).
    Preconditions (caller-enforced): each active node is underfull, not the
    root, its parent has ≥ 2 children, ≤ 1 active node per parent."""
    w = node_ids.shape[0]
    b = cfg.b
    scratch = state.keys.shape[0] - 1
    node_ids = jnp.where(active, node_ids, scratch)
    parents = jnp.where(active, state.parent[node_ids], scratch)
    at = jnp.clip(state.pidx[node_ids], 0, b - 1)
    sib_at = jnp.where(at == 0, 1, at - 1)  # paper: right sibling iff leftmost
    sibs = state.children[parents, sib_at]
    sibs = jnp.where(active, sibs, scratch)
    left_at = jnp.minimum(at, sib_at)
    left_is_node = at < sib_at
    lid = jnp.where(active, jnp.where(left_is_node, node_ids, sibs), scratch)
    rid = jnp.where(active, jnp.where(left_is_node, sibs, node_ids), scratch)

    leaf = state.is_leaf[node_ids]
    lsz = state.size[lid]
    rsz = state.size[rid]
    total = lsz + rsz
    sep = state.keys[parents, left_at]  # router between the pair

    do_merge = active & (total <= b)
    do_dist = active & (total > b)

    # ---- build merged content, width 2b ------------------------------------
    lk, lv, lc = state.keys[lid], state.vals[lid], state.children[lid]
    rk, rv, rc = state.keys[rid], state.vals[rid], state.children[rid]
    j2 = jnp.arange(2 * b)[None, :]

    # Leaves: concat + stable sort (EMPTY last) compacts `total` sorted keys.
    cat_k = jnp.concatenate([lk, rk], axis=1)
    cat_v = jnp.concatenate([lv, rv], axis=1)
    ordr = jnp.argsort(cat_k, axis=1, stable=True).astype(jnp.int32)
    leaf_mk = jnp.take_along_axis(cat_k, ordr, axis=1)
    leaf_mv = jnp.take_along_axis(cat_v, ordr, axis=1)

    # Internals: children = lc[0:lsz] ++ rc[0:rsz];
    #            routers  = lk[0:lsz-1] ++ [sep] ++ rk[0:rsz-1].
    r_idx = jnp.clip(j2 - lsz[:, None], 0, b - 1)
    lc2 = jnp.concatenate([lc, jnp.full_like(lc, NULL)], axis=1)
    lk2 = jnp.concatenate([lk, jnp.full_like(lk, EMPTY)], axis=1)
    int_mc = jnp.where(j2 < lsz[:, None], lc2, jnp.take_along_axis(rc, r_idx, axis=1))
    int_mc = jnp.where(j2 < total[:, None], int_mc, NULL)
    int_mk = jnp.where(
        j2 < lsz[:, None] - 1,
        lk2,
        jnp.where(
            j2 == lsz[:, None] - 1, sep[:, None], jnp.take_along_axis(rk, r_idx, axis=1)
        ),
    )
    int_mk = jnp.where(j2 < total[:, None] - 1, int_mk, EMPTY)

    merged_k = jnp.where(leaf[:, None], leaf_mk, int_mk)  # (W, 2b)
    merged_v = leaf_mv
    merged_c = int_mc

    def sel(act):
        return act[:, None]

    # ---- MERGE: all content into lid; drop rid + separator from parent -----
    keys_new = state.keys.at[lid].set(jnp.where(sel(do_merge), merged_k[:, :b], state.keys[lid]))
    vals_new = state.vals.at[lid].set(jnp.where(sel(do_merge & leaf), merged_v[:, :b], state.vals[lid]))
    ch_new = state.children.at[lid].set(
        jnp.where(sel(do_merge & ~leaf), merged_c[:, :b], state.children[lid])
    )
    size_new = state.size.at[lid].set(jnp.where(do_merge, total, state.size[lid]))
    ver_new = state.ver.at[lid].add(jnp.where(do_merge, 2, 0))
    # free rid (the paper marks unlinked nodes; we deallocate post-wave).
    alloc_new = state.alloc.at[rid].set(state.alloc[rid] & ~do_merge)
    b_iota = jnp.arange(b)[None, :]
    keys_new = keys_new.at[rid].set(
        jnp.where(sel(do_merge), jnp.full((w, b), EMPTY, KEY_DTYPE), keys_new[rid])
    )
    size_new = size_new.at[rid].set(jnp.where(do_merge, 0, size_new[rid]))

    # parent: remove router at left_at and child at max(at, sib_at).
    rm_child = jnp.maximum(at, sib_at)
    pk = state.keys[parents]
    pc = state.children[parents]
    pk_shift = jnp.where(b_iota >= left_at[:, None], jnp.roll(pk, -1, axis=1), pk)
    pk_shift = pk_shift.at[:, b - 1].set(EMPTY)
    pc_shift = jnp.where(b_iota >= rm_child[:, None], jnp.roll(pc, -1, axis=1), pc)
    pc_shift = pc_shift.at[:, b - 1].set(NULL)
    keys_new = keys_new.at[parents].set(jnp.where(sel(do_merge), pk_shift, keys_new[parents]))
    ch_new = ch_new.at[parents].set(jnp.where(sel(do_merge), pc_shift, ch_new[parents]))
    size_new = size_new.at[parents].add(jnp.where(do_merge, -1, 0))

    # ---- DISTRIBUTE: split merged content evenly; new separator up ---------
    ln = (total + 1) // 2
    rn = total - ln
    shift_k = jnp.take_along_axis(merged_k, jnp.clip(j2 + ln[:, None], 0, 2 * b - 1), axis=1)
    shift_v = jnp.take_along_axis(merged_v, jnp.clip(j2 + ln[:, None], 0, 2 * b - 1), axis=1)
    shift_c = jnp.take_along_axis(merged_c, jnp.clip(j2 + ln[:, None], 0, 2 * b - 1), axis=1)

    # leaves: left ln keys, right rn keys; router = merged_k[ln].
    dl_k = jnp.where(j2 < ln[:, None], merged_k, EMPTY)[:, :b]
    dr_k = jnp.where(j2 < rn[:, None], shift_k, EMPTY)[:, :b]
    dl_v = merged_v[:, :b]
    dr_v = shift_v[:, :b]
    router_leaf = jnp.take_along_axis(merged_k, jnp.clip(ln, 0, 2 * b - 1)[:, None], axis=1)[:, 0]
    # internals: left ln children (ln-1 routers); router merged_k[ln-1] up;
    # right rn children (rn-1 routers) starting at child index ln.
    di_lk = jnp.where(j2 < ln[:, None] - 1, merged_k, EMPTY)[:, :b]
    di_lc = jnp.where(j2 < ln[:, None], merged_c, NULL)[:, :b]
    di_rk = jnp.where(j2 < rn[:, None] - 1, shift_k, EMPTY)[:, :b]
    di_rc = jnp.where(j2 < rn[:, None], shift_c, NULL)[:, :b]
    router_int = jnp.take_along_axis(merged_k, jnp.clip(ln - 1, 0, 2 * b - 1)[:, None], axis=1)[:, 0]

    keys_new = keys_new.at[lid].set(
        jnp.where(sel(do_dist), jnp.where(leaf[:, None], dl_k, di_lk), keys_new[lid])
    )
    keys_new = keys_new.at[rid].set(
        jnp.where(sel(do_dist), jnp.where(leaf[:, None], dr_k, di_rk), keys_new[rid])
    )
    vals_new = vals_new.at[lid].set(jnp.where(sel(do_dist & leaf), dl_v, vals_new[lid]))
    vals_new = vals_new.at[rid].set(jnp.where(sel(do_dist & leaf), dr_v, vals_new[rid]))
    ch_new = ch_new.at[lid].set(jnp.where(sel(do_dist & ~leaf), di_lc, ch_new[lid]))
    ch_new = ch_new.at[rid].set(jnp.where(sel(do_dist & ~leaf), di_rc, ch_new[rid]))
    size_new = size_new.at[lid].set(jnp.where(do_dist, ln, size_new[lid]))
    size_new = size_new.at[rid].set(jnp.where(do_dist, rn, size_new[rid]))
    ver_new = ver_new.at[lid].add(jnp.where(do_dist, 2, 0))
    ver_new = ver_new.at[rid].add(jnp.where(do_dist, 2, 0))
    router_new = jnp.where(leaf, router_leaf, router_int)
    keys_new = keys_new.at[parents, left_at].set(
        jnp.where(do_dist, router_new, keys_new[parents, left_at])
    )

    dirty_new = state.dirty
    for rows, m in ((node_ids, active), (sibs, active), (parents, active)):
        r = jnp.where(m, rows, scratch)
        dirty_new = dirty_new.at[r].set(dirty_new[r] | m)

    stats = state.stats._replace(
        struct_ops=state.stats.struct_ops + jnp.sum(active).astype(jnp.int64)
    )
    state = state._replace(
        keys=keys_new, vals=vals_new, children=ch_new, size=size_new,
        alloc=alloc_new, ver=ver_new, dirty=dirty_new, stats=stats,
    )
    # refresh links: parents (child list shifted), lid/rid (grandchildren
    # reassigned for internal merges/distributes).
    state = _refresh_child_links(state, parents, cfg)
    state = _refresh_child_links(state, lid, cfg)
    state = _refresh_child_links(state, rid, cfg)
    return state


def shrink_root(state: TreeState, cfg: TreeConfig) -> TreeState:
    """If the root is internal with a single child, that child becomes the
    root (paper: entry.ptrs[0] replacement in fixUnderfull)."""
    r = state.root
    can = (~state.is_leaf[r]) & (state.size[r] == 1)
    child = state.children[r, 0]
    child = jnp.where(can, child, r)
    return state._replace(
        root=child.astype(jnp.int32),
        height=state.height - can.astype(jnp.int32),
        alloc=state.alloc.at[r].set(state.alloc[r] & ~can),
        size=state.size.at[r].set(jnp.where(can, 0, state.size[r])),
        parent=state.parent.at[child].set(
            jnp.where(can, NULL, state.parent[child])
        ),
        keys=state.keys.at[r].set(
            jnp.where(can, jnp.full((cfg.b,), EMPTY, KEY_DTYPE), state.keys[r])
        ),
        dirty=state.dirty.at[r].set(True),
    )


# ----------------------------------------------------------------------------
# Round outputs (produced by the core/rounds.py engine)
# ----------------------------------------------------------------------------


class ScanOutput(NamedTuple):
    keys: jax.Array  # (B, cap) ascending matches, EMPTY-padded
    vals: jax.Array  # (B, cap) values (0 where key slot is EMPTY)
    count: jax.Array  # (B,) int32 — entries emitted (≤ cap)
    truncated: jax.Array  # (B,) bool — more matches existed than cap


class RoundOutput(NamedTuple):
    results: jax.Array  # (B,) per-op return value (NOTFOUND = ⊥; range: #matches)
    found: jax.Array  # (B,) bool (range lanes: any match)
    # Per-lane scan rows for fused mixed-op rounds, aligned to the batch
    # (non-range rows scan the empty interval).  None when the round had no
    # OP_RANGE lane.
    scan: Optional[ScanOutput] = None


# ----------------------------------------------------------------------------
# Range-scan phase: frontier expansion + lane-parallel gather
# ----------------------------------------------------------------------------


def frontier_expand(
    state: TreeState, cfg: TreeConfig, lo: jax.Array, hi: jax.Array,
    frontier_cap: int, *, narrow: bool = False,
):
    """Expand each query's root into its leaf frontier — the set of leaves
    whose key range intersects ``[lo, hi)`` — level by level, wholly on
    device.  Internal nodes expand to the children whose range intersects
    the interval (the batched form of ``range_query``'s host DFS); leaves
    self-propagate, so after ``max_height`` iterations every frontier slot
    is a leaf.  Per-level compaction of the surviving candidates goes
    through ``kernels/tree_descend``'s segmented cumsum-rank compaction
    (the Pallas kernel under the ``narrow`` gate, the scatter-based jnp
    form otherwise) — no sort network on either path.

    Returns ``(leaves (B,F), cand_keys (B,F·b), cand_vals (B,F·b),
    touched (L,B,F), overflow (B,))``.  ``touched`` records every node id
    whose routers/slots the expansion read (scratch-padded) — the read set
    the optimistic reader validates versions against.  ``overflow`` marks
    queries whose intersecting-node count exceeded F at some level: their
    results may be missing keys and the caller must re-run with a larger
    frontier."""
    bsz = lo.shape[0]
    f, b = frontier_cap, cfg.b
    scratch = state.keys.shape[0] - 1  # empty pseudo-leaf; ver never bumps

    frontier0 = jnp.full((bsz, f), scratch, jnp.int32).at[:, 0].set(state.root)
    valid0 = jnp.zeros((bsz, f), bool).at[:, 0].set(True)
    touched0 = jnp.full((cfg.max_height, bsz, f), scratch, jnp.int32)
    overflow0 = jnp.zeros((bsz,), bool)

    def body(level, carry):
        frontier, valid, touched, overflow = carry
        node = jnp.where(valid, frontier, scratch)
        touched = touched.at[level].set(node)
        leaf = state.is_leaf[node]  # (B,F); scratch is a leaf
        routers = state.keys[node][:, :, : b - 1]  # (B,F,b-1); unused = EMPTY
        sz = state.size[node]  # (B,F)
        # child j covers [clo_j, chi_j): clo_0 = -inf, chi_{sz-1} = +inf
        # (stale routers beyond sz-1 are EMPTY, which acts as +inf).
        pad_lo = jnp.full((bsz, f, 1), KEY_MIN, KEY_DTYPE)
        pad_hi = jnp.full((bsz, f, 1), EMPTY, KEY_DTYPE)
        clo = jnp.concatenate([pad_lo, routers], axis=2)  # (B,F,b)
        chi = jnp.concatenate([routers, pad_hi], axis=2)
        j = jnp.arange(b, dtype=jnp.int32)[None, None, :]
        isect = (
            (j < sz[:, :, None])
            & (chi > lo[:, None, None])
            & (clo < hi[:, None, None])
        )
        expand = (valid & ~leaf)[:, :, None] & isect  # (B,F,b)
        keep = valid & leaf  # leaves ride along unchanged
        cand = jnp.concatenate(
            [
                jnp.where(expand, state.children[node], scratch),
                jnp.where(keep, frontier, scratch)[:, :, None],
            ],
            axis=2,
        ).reshape(bsz, f * (b + 1))
        cand_valid = jnp.concatenate(
            [expand, keep[:, :, None]], axis=2
        ).reshape(bsz, f * (b + 1))
        frontier, valid, of = frontier_compact(
            cand, cand_valid, f, scratch=scratch, use_pallas=narrow
        )
        return frontier, valid, touched, overflow | of

    frontier, valid, touched, overflow = jax.lax.fori_loop(
        0, cfg.max_height, body, (frontier0, valid0, touched0, overflow0)
    )
    leaves = jnp.where(valid, frontier, scratch)
    cand_keys = jnp.where(valid[:, :, None], state.keys[leaves], EMPTY)
    cand_vals = state.vals[leaves]
    return (
        leaves,
        cand_keys.reshape(bsz, f * b),
        cand_vals.reshape(bsz, f * b),
        touched,
        overflow,
    )


def frontier_expand_sharded(
    state: TreeState, cfg: TreeConfig, sid: jax.Array, lo: jax.Array,
    hi: jax.Array, frontier_cap: int, *, narrow: bool = False,
):
    """Flat ragged form of :func:`frontier_expand` over a STACKED ``(S, …)``
    state: lane ``i`` expands inside shard ``sid[i]``, so one launch covers
    every shard's sub-lanes packed side by side — no per-shard row padding.
    Every state access is the per-shard gather generalized to two index
    axes (``state.X[sid[:, None], node]``); the per-level compaction and
    the downstream gather kernels are shard-agnostic and unchanged.

    Returns the same tuple as :func:`frontier_expand`; ``touched`` records
    per-LANE node ids (the caller groups lanes by ``sid`` to build each
    shard's validated read set).  Padding lanes (``lo = hi = EMPTY``)
    expand into nothing past level 0."""
    bsz = lo.shape[0]
    f, b = frontier_cap, cfg.b
    scratch = state.keys.shape[1] - 1  # node axis is 1 on the stacked state
    sid2 = sid[:, None]  # broadcasts against (B, F) node-id blocks

    frontier0 = jnp.full((bsz, f), scratch, jnp.int32).at[:, 0].set(
        state.root[sid]
    )
    valid0 = jnp.zeros((bsz, f), bool).at[:, 0].set(True)
    touched0 = jnp.full((cfg.max_height, bsz, f), scratch, jnp.int32)
    overflow0 = jnp.zeros((bsz,), bool)

    def body(level, carry):
        frontier, valid, touched, overflow = carry
        node = jnp.where(valid, frontier, scratch)
        touched = touched.at[level].set(node)
        leaf = state.is_leaf[sid2, node]  # (B,F); scratch is a leaf
        routers = state.keys[sid2, node][:, :, : b - 1]
        sz = state.size[sid2, node]  # (B,F)
        pad_lo = jnp.full((bsz, f, 1), KEY_MIN, KEY_DTYPE)
        pad_hi = jnp.full((bsz, f, 1), EMPTY, KEY_DTYPE)
        clo = jnp.concatenate([pad_lo, routers], axis=2)  # (B,F,b)
        chi = jnp.concatenate([routers, pad_hi], axis=2)
        j = jnp.arange(b, dtype=jnp.int32)[None, None, :]
        isect = (
            (j < sz[:, :, None])
            & (chi > lo[:, None, None])
            & (clo < hi[:, None, None])
        )
        expand = (valid & ~leaf)[:, :, None] & isect  # (B,F,b)
        keep = valid & leaf
        cand = jnp.concatenate(
            [
                jnp.where(expand, state.children[sid2, node], scratch),
                jnp.where(keep, frontier, scratch)[:, :, None],
            ],
            axis=2,
        ).reshape(bsz, f * (b + 1))
        cand_valid = jnp.concatenate(
            [expand, keep[:, :, None]], axis=2
        ).reshape(bsz, f * (b + 1))
        frontier, valid, of = frontier_compact(
            cand, cand_valid, f, scratch=scratch, use_pallas=narrow
        )
        return frontier, valid, touched, overflow | of

    frontier, valid, touched, overflow = jax.lax.fori_loop(
        0, cfg.max_height, body, (frontier0, valid0, touched0, overflow0)
    )
    leaves = jnp.where(valid, frontier, scratch)
    cand_keys = jnp.where(valid[:, :, None], state.keys[sid2, leaves], EMPTY)
    cand_vals = state.vals[sid2, leaves]
    return (
        leaves,
        cand_keys.reshape(bsz, f * b),
        cand_vals.reshape(bsz, f * b),
        touched,
        overflow,
    )


# ----------------------------------------------------------------------------
# Host-orchestrated tree (thin wrappers over the core/rounds.py engine)
# ----------------------------------------------------------------------------


class ABTree(RegistryBackedCounters):
    """Host-orchestrated batched (a,b)-tree — the S = 1 case of the unified
    sharded round engine.  Every entry point builds a round plan and runs
    the ``core/rounds.py`` (S, wave_w) phase pipeline (the ``stacked``
    property views this tree's state as a one-shard stack); heavy phases
    are jitted and the host loop only sequences structural waves (rare —
    the paper notes splits are infrequent) and reads tiny control scalars."""

    def __init__(
        self, cfg: TreeConfig = TreeConfig(), mode: str = "elim",
        *, narrow_scan: bool = False, narrow: bool = False,
    ):
        assert mode in ("elim", "occ")
        assert 2 <= cfg.a <= cfg.b // 2, "(a,b) requires 2 ≤ a ≤ b/2"
        self.cfg = cfg
        self.mode = mode
        self.state = make_tree(cfg)
        # unified-engine holder protocol: the single tree is a one-shard
        # forest with an unpartitioned key space (see core/rounds.py).
        self.n_shards = 1
        self._splits = np.empty((0,), np.int64)
        self._bounds = [int(KEY_MIN), int(EMPTY)]
        # telemetry: metrics registry (the one store behind the legacy
        # ``_rounds``/``_scans``/``_scan_retries`` counter properties) and
        # the host-side phase tracer (NULL_TRACER = strict no-op; install a
        # ``repro.obs.Tracer()`` to record spans).  The flight recorder is
        # always on (bounded ring; ``Recorder(enabled=False)`` to opt out).
        self.metrics = MetricsRegistry()
        self.metrics.add_collector(engine_collector(self))
        self.tracer = NULL_TRACER
        self.recorder = Recorder()
        self._rounds = 0
        self._scans = 0
        self._scan_retries = 0
        self._scan_active = 0
        # narrow_scan=True is the caller's assertion that every key AND value
        # fits strictly inside int32 (|x| < 2**31 - 1): the round engine's
        # scan phase then routes fused-round gathers through the
        # kernels/range_scan Pallas kernel instead of the int64 jnp ref.
        # Keys at/above 2**31 - 1 would be conflated with the kernel's EMPTY
        # sentinel — leave False for unbounded key spaces (e.g. hash keys).
        #
        # narrow=True extends the same int32 assertion to the whole search
        # path: point-op descents (search / retry / overfull phases) run the
        # fused kernels/tree_descend descent+probe kernel with the pool
        # pinned in VMEM, and scan-phase frontier compaction uses its Pallas
        # form.  Implies narrow_scan.
        self.narrow = narrow
        self.narrow_scan = narrow_scan or narrow
        self._wave_w = 64  # pad width for structural waves (recompile-bounded)
        # durable layer hook: OCC durability commits after EVERY sub-round
        # (each sub-round's returns causally follow the previous one — the
        # batched analog of the paper's per-update flush+fence); Elim
        # commits once per round.  See core/durable.py.
        self.subround_hook = None
        # optimistic-reader hook: called between a scan's gather and its
        # version validation.  Models update rounds from other engine
        # replicas interleaving with the scan (tests use it to force the
        # retry/conflict paths); production single-replica use leaves None.
        self.scan_hook = None
        self._scan_frontier = 8  # leaf-frontier pad width (doubles on overflow)

    # -- unified-engine holder protocol ---------------------------------------

    # ``state`` (bare) and ``stacked`` (leading axis 1 — the form every
    # ``core/rounds.py`` phase executes on) are lazy views of one another:
    # each setter just invalidates the other form, and each getter converts
    # only when its form is stale.  A round's phases touch ``stacked``
    # a dozen times; eagerly re-deriving the 25-leaf tree_map on every
    # access cost more host time than the phases' device calls.

    @property
    def state(self) -> TreeState:
        if self._state is None:
            self._state = jax.tree_util.tree_map(lambda x: x[0], self._stacked)
        return self._state

    @state.setter
    def state(self, st: TreeState):
        self._state = st
        self._stacked = None

    @property
    def stacked(self) -> TreeState:
        """This tree's state as a one-shard stack (leading axis 1 on every
        array) — the form every ``core/rounds.py`` phase executes on."""
        if self._stacked is None:
            self._stacked = jax.tree_util.tree_map(lambda x: x[None], self._state)
        return self._stacked

    @stacked.setter
    def stacked(self, st: TreeState):
        self._stacked = st
        self._state = None

    def _maybe_split_shards(self):
        """Shard-overflow policy: the single tree never splits shards."""

    def _maybe_repartition(self):
        """Load rebalancing is a forest concern; S = 1 has one partition."""

    def _note_shard_load(self, counts):
        """Hot-shard accounting is a forest concern; S = 1 has no skew."""

    # -- public API -----------------------------------------------------------

    def apply_round(self, ops, keys, vals=None, *, scan_cap: int = 128) -> RoundOutput:
        """Apply one round of concurrent ops (1-D arrays, equal length).
        Returns per-op results in arrival order.

        Batches may freely mix point ops with OP_RANGE lanes (key = lo,
        val = span → scan ``[lo, lo + span)``): the round engine runs the
        scan phase before the round's net writes, so every range lane
        observes the pre-round dictionary.  Range-lane results land in
        ``RoundOutput.scan`` (≤ ``scan_cap`` smallest matches per lane);
        their ``results`` entry is the match count.  Malformed range lanes
        (negative span, i.e. hi < lo) raise ``ValueError``."""
        from repro.core import rounds

        plan = rounds.build_plan(ops, keys, vals, scan_cap=scan_cap)
        return rounds.execute_plan(self, plan)

    def scan_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        """Apply one round of concurrent range scans: for each query i,
        return the ≤ ``cap`` smallest keys in ``[lo[i], hi[i])`` with their
        values, ascending (``truncated[i]`` marks clipped results).

        Scans follow the paper's optimistic-reader discipline: the gather
        runs against a state snapshot, recording every node it reads; the
        node versions are then re-validated against the live state, and the
        scan re-runs if an interleaved update round bumped any of them
        (``ScanConflictError`` after ``max_retries``).  Scan rounds
        interleave legally with elim/occ update rounds at round granularity
        — each scan linearizes at its validation point."""
        from repro.core import rounds

        return rounds.execute_scan(self, lo, hi, cap=cap, max_retries=max_retries)

    def scan_delete_round(self, lo, hi, cap: int = 128, max_retries: int = 8) -> ScanOutput:
        """ONE fused round that gathers every key in ``[lo_i, hi_i)``
        (≤ ``cap`` smallest per query) and deletes the gathered keys —
        the scan linearizes before the round's deletes, which target
        exactly the snapshot it observed.  Returns the pre-delete scan
        (the evicted keys/values); ``truncated`` marks queries with more
        matches left to sweep."""
        from repro.core import rounds

        return rounds.execute_scan_delete(self, lo, hi, cap=cap, max_retries=max_retries)

    def scan_stream(self, lo, hi, cap: int = 128):
        """Stream all (key, value) pairs in ``[lo, hi)`` in ascending key
        order as a generator, issuing successive ``cap``-bounded scan
        rounds that resume from the last emitted key (the cursor /
        continuation API over ``scan_round``'s fixed-capacity pages).

        Each underlying round is individually validated; entries observed
        by different rounds may straddle interleaved update rounds, as any
        cursor over a concurrent map does."""
        from repro.core import rounds

        return rounds.execute_scan_stream(self, lo, hi, cap)

    def find(self, key) -> Optional[int]:
        out = self.apply_round([OP_FIND], [key])
        return int(out.results[0]) if bool(out.found[0]) else None

    def insert(self, key, val):
        out = self.apply_round([OP_INSERT], [key], [val])
        return int(out.results[0]) if bool(out.found[0]) else None

    def delete(self, key):
        out = self.apply_round([OP_DELETE], [key])
        return int(out.results[0]) if bool(out.found[0]) else None

    def items(self) -> dict:
        """Host-side snapshot of the dictionary contents (sorted by key)."""
        s = self.state
        keys = np.asarray(s.keys)
        vals = np.asarray(s.vals)
        leaf = np.asarray(s.is_leaf) & np.asarray(s.alloc)
        out = {}
        for nid in np.nonzero(leaf)[0]:
            for j in range(self.cfg.b):
                k = int(keys[nid, j])
                if k != int(EMPTY):
                    out[k] = int(vals[nid, j])
        return dict(sorted(out.items()))

    def take_dirty(self) -> np.ndarray:
        """Node ids dirtied since the last durable commit (then reset)."""
        d = np.nonzero(np.asarray(self.state.dirty))[0].astype(np.int32)
        self.state = self.state._replace(dirty=jnp.zeros_like(self.state.dirty))
        return d

    def stats(self) -> dict:
        """Device phase counters plus the engine's host-side round/scan
        counters (``rounds`` / ``scans`` / ``scan_retries`` are sequenced on
        the host by the unified engine; ``scan_retries`` counts retried
        *lanes* — ops re-gathered after a version conflict)."""
        s = {k: int(np.asarray(v).sum()) for k, v in self.state.stats._asdict().items()}
        s["rounds"] = self._rounds
        s["scans"] = self._scans
        s["scan_retries"] = self._scan_retries
        return s

    # -- pool management --------------------------------------------------------

    def _ensure_capacity(self, need_nodes: int):
        """Grow the pool if fewer than `need + slack` nodes are free.  The
        2·wave_w term keeps the pool large enough for a full-width split
        wave's allocation (``_alloc_ids(state, 2w)`` slices 2w rows
        unconditionally), which tiny ``capacity`` configs would violate."""
        need = 2 * need_nodes + 4 * self.cfg.max_height + 2 * self._wave_w + 8
        n_alloc = int(jnp.sum(self.state.alloc))
        cap = self.cfg.capacity
        if cap - n_alloc >= need:
            return
        self._grow(max(cap * 2, cap + need))

    def _grow(self, new_cap: int):
        self.state = grow_pool(self.state, new_cap - self.cfg.capacity, axis=0)
        self.cfg = self.cfg._replace(capacity=new_cap)


# ----------------------------------------------------------------------------
# Range queries (paper §3: "could be added using the techniques of [5]").
# Optimistic double-collect over the touched subtree: capture versions,
# walk, re-validate — the multi-node generalization of searchLeaf.
# ----------------------------------------------------------------------------


def range_query(tree: "ABTree", lo: int, hi: int, max_retries: int = 8):
    """All (k, v) with lo ≤ k < hi, validated against node versions (the
    paper's optimistic-reader discipline, [5]-style epoch elided because
    rounds are quiescent between calls; retries guard against interleaved
    rounds from other engine threads sharing the state)."""
    cfg = tree.cfg
    for _ in range(max_retries):
        s = tree.state
        ver_before = np.asarray(s.ver)
        keys = np.asarray(s.keys)
        vals = np.asarray(s.vals)
        children = np.asarray(s.children)
        is_leaf = np.asarray(s.is_leaf)
        size = np.asarray(s.size)
        root = int(s.root)
        out = []
        touched = []
        stack = [root]
        while stack:
            nid = stack.pop()
            touched.append(nid)
            if is_leaf[nid]:
                for j in range(cfg.b):
                    k = int(keys[nid, j])
                    if k != int(EMPTY) and lo <= k < hi:
                        out.append((k, int(vals[nid, j])))
                continue
            sz = int(size[nid])
            routers = keys[nid, : sz - 1]
            for j in range(sz):
                clo = -(2**63) if j == 0 else int(routers[j - 1])
                chi = int(EMPTY) if j == sz - 1 else int(routers[j])
                if chi > lo and clo < hi:  # child range intersects [lo, hi)
                    stack.append(int(children[nid, j]))
        ver_after = np.asarray(tree.state.ver)
        if all(ver_before[t] == ver_after[t] for t in touched):
            return sorted(out)
    raise ScanConflictError("range_query: version validation failed repeatedly")
