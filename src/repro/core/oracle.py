"""Sequential oracle + structural invariant checker for the batched trees.

The oracle applies a round's ops in arrival order against a plain dict —
this is a *valid linearization* of the round (all ops are concurrent), so
the batched tree's per-op results must match it exactly, in both elim and
occ modes.  (The paper's elimination argument, §4: reordering concurrent
same-key ops is legal; we always pick arrival order, so results are
deterministic and oracle-checkable.)

``check_invariants`` walks the array state on the host and asserts the
paper's Theorem 3.5 invariants in their batched form:
  1. reachable nodes form a relaxed (a,b)-tree (sizes within bounds except
     the root; uniform leaf depth — our waves maintain *strict* balance,
     which implies the relaxed invariant),
  4. a key appears at most once in a leaf,
  plus search-structure: router sortedness and key-range containment
  (invariants 2/7), parent/pidx link consistency, and size-field accuracy
  (invariant 6).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.abtree import (
    EMPTY,
    NOTFOUND,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_NOP,
    OP_RANGE,
    TreeState,
)

_EMPTY = int(EMPTY)
_NOTFOUND = int(NOTFOUND)


class DictOracle:
    """Reference dictionary with the paper's §3 semantics."""

    def __init__(self):
        self.d: Dict[int, int] = {}

    def _apply_point(self, op: int, k: int, v: int) -> Tuple[int, bool]:
        if op == OP_NOP:
            return _NOTFOUND, False
        if op == OP_FIND:
            r = self.d.get(k)
            return (_NOTFOUND if r is None else r), r is not None
        if op == OP_INSERT:
            r = self.d.get(k)
            if r is None:
                self.d[k] = v
                return _NOTFOUND, False
            return r, True  # paper: insert returns existing value
        if op == OP_DELETE:
            r = self.d.pop(k, None)
            return (_NOTFOUND if r is None else r), r is not None
        raise ValueError(f"bad op {op}")

    def apply_round(
        self, ops: Sequence[int], keys: Sequence[int], vals: Sequence[int]
    ) -> Tuple[List[int], List[bool]]:
        results, found = [], []
        for op, k, v in zip(ops, keys, vals):
            r, f = self._apply_point(int(op), int(k), int(v))
            results.append(r)
            found.append(f)
        return results, found

    def apply_mixed_round(
        self,
        ops: Sequence[int],
        keys: Sequence[int],
        vals: Sequence[int],
        cap: Optional[int] = None,
    ) -> Tuple[List[int], List[bool], List[Optional[List[Tuple[int, int]]]]]:
        """Reference semantics of one FUSED round (the round engine's
        linearization): every OP_RANGE lane (key = lo, val = span) scans the
        dictionary *as of round start* — scans linearize before the round's
        net writes — then point lanes apply in arrival order.

        Returns ``(results, found, scans)``: ``scans[i]`` is the ascending
        (k, v) list for lane i (clipped to ``cap``, matching a truncated
        device scan) or None on point lanes; a range lane's ``results``
        entry is its match count and ``found`` ⇔ non-empty.
        """
        snapshot = sorted(self.d.items())
        results: List[int] = []
        found: List[bool] = []
        scans: List[Optional[List[Tuple[int, int]]]] = []
        for op, k, v in zip(ops, keys, vals):
            op, k, v = int(op), int(k), int(v)
            if op == OP_RANGE:
                if v < 0:
                    raise ValueError(f"malformed OP_RANGE lane: negative span {v}")
                lo, hi = k, k + v
                items = [(kk, vv) for kk, vv in snapshot if lo <= kk < hi]
                if cap is not None:
                    items = items[:cap]
                scans.append(items)
                results.append(len(items))
                found.append(bool(items))
            else:
                r, f = self._apply_point(op, k, v)
                results.append(r)
                found.append(f)
                scans.append(None)
        return results, found, scans

    def items(self) -> dict:
        return dict(sorted(self.d.items()))

    def range(self, lo: int, hi: int) -> List[Tuple[int, int]]:
        """All (k, v) with lo ≤ k < hi, ascending — the linearized result a
        scan round must produce (clip to ``cap`` to compare truncated
        scans)."""
        return sorted((k, v) for k, v in self.d.items() if lo <= k < hi)


def check_invariants(state: TreeState, cfg) -> None:
    """Host walk asserting the paper's structural invariants (see module
    docstring).  Raises AssertionError with a precise message on violation."""
    keys = np.asarray(state.keys)
    children = np.asarray(state.children)
    parent = np.asarray(state.parent)
    pidx = np.asarray(state.pidx)
    is_leaf = np.asarray(state.is_leaf)
    size = np.asarray(state.size)
    level = np.asarray(state.level)
    alloc = np.asarray(state.alloc)
    root = int(state.root)
    height = int(state.height)
    a, b = cfg.a, cfg.b

    assert alloc[root], "root not allocated"
    assert parent[root] == -1, "root has a parent"

    seen = set()
    leaf_depths = set()
    all_keys: List[int] = []

    def walk(nid: int, lo: int, hi: int, depth: int):
        assert nid >= 0, "NULL child reached"
        assert alloc[nid], f"unallocated node {nid} reachable"
        assert nid not in seen, f"node {nid} reachable twice (cycle/shared)"
        seen.add(nid)
        sz = int(size[nid])
        if is_leaf[nid]:
            leaf_depths.add(depth)
            ks = [int(k) for k in keys[nid] if int(k) != _EMPTY]
            assert len(ks) == sz, f"leaf {nid}: size {sz} != #keys {len(ks)} (inv 6)"
            assert len(set(ks)) == len(ks), f"leaf {nid}: duplicate key (inv 4)"
            for k in ks:
                assert lo <= k < hi, f"leaf {nid}: key {k} outside range [{lo},{hi}) (inv 2/7)"
            assert level[nid] == 0, f"leaf {nid}: level {level[nid]} != 0"
            if nid != root:
                assert sz >= a, f"leaf {nid}: underfull size {sz} (inv 1)"
            assert sz <= b, f"leaf {nid}: overfull size {sz} (inv 1)"
            all_keys.extend(ks)
            return
        # internal
        assert 2 <= sz <= b or (nid == root and 1 <= sz <= b), (
            f"internal {nid}: bad size {sz}"
        )
        if nid != root:
            assert sz >= a, f"internal {nid}: underfull size {sz} (inv 1)"
        routers = [int(k) for k in keys[nid, : b - 1]]
        used = routers[: sz - 1]
        assert all(used[i] < used[i + 1] for i in range(len(used) - 1)), (
            f"internal {nid}: routers not strictly sorted: {used}"
        )
        assert all(int(r) == _EMPTY for r in routers[sz - 1 :]), (
            f"internal {nid}: stale router beyond size"
        )
        for j in range(sz):
            c = int(children[nid, j])
            assert c >= 0, f"internal {nid}: NULL child {j}"
            assert parent[c] == nid, f"child {c}: parent {parent[c]} != {nid}"
            assert pidx[c] == j, f"child {c}: pidx {pidx[c]} != {j}"
            clo = lo if j == 0 else used[j - 1]
            chi = hi if j == sz - 1 else used[j]
            assert level[c] == level[nid] - 1, (
                f"child {c} level {level[c]} != parent level {level[nid]} - 1"
            )
            walk(c, clo, chi, depth + 1)

    import sys

    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(100000)
    try:
        walk(root, -(2**63), _EMPTY, 0)
    finally:
        sys.setrecursionlimit(old_limit)

    assert len(leaf_depths) == 1, f"leaves at multiple depths: {leaf_depths}"
    assert leaf_depths == {height - 1}, (
        f"height {height} inconsistent with leaf depth {leaf_depths}"
    )
    assert len(all_keys) == len(set(all_keys)), "key present in two leaves"
    # every allocated node reachable (no leaks)
    alloc_ids = set(np.nonzero(alloc)[0].tolist())
    assert alloc_ids == seen, (
        f"allocation leak: allocated-but-unreachable {sorted(alloc_ids - seen)[:10]}"
    )


def tree_contents(state: TreeState, cfg) -> dict:
    """Dictionary contents by host walk (for oracle comparison)."""
    keys = np.asarray(state.keys)
    vals = np.asarray(state.vals)
    is_leaf = np.asarray(state.is_leaf)
    alloc = np.asarray(state.alloc)
    out = {}
    for nid in np.nonzero(is_leaf & alloc)[0]:
        for j in range(cfg.b):
            k = int(keys[nid, j])
            if k != _EMPTY:
                assert k not in out, f"key {k} in two leaves"
                out[k] = int(vals[nid, j])
    return dict(sorted(out.items()))
