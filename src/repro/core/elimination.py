"""Publishing elimination as a batched combine (the paper's §4, TPU-native).

In the paper, an operation O' on key k that is concurrent with the last
modifying operation O of k's leaf may linearize itself adjacent to O by
reading the leaf's published ``ElimRecord`` — returning *without writing the
data structure*.  In the SPMD setting every operation in a round is mutually
concurrent, so for each key we may choose *any* linearization order of the
round's ops on that key (we use batch arrival order, which is trivially
valid).  Folding the ops of one key over the key's pre-round state yields

  * the return value of every op  (computed from the *record*, not the tree),
  * the key's net effect          (at most ONE physical slot write),

which is exactly the write-collapse publishing elimination buys: of n ops on
a key, n-1 are *eliminated* — they never touch tree memory.

The fold is a function composition over the 2-state machine

    state ∈ { absent } ∪ { present(v) }

with per-op transitions (dictionary semantics from §3 of the paper):

    find       : id
    insert(v)  : absent → present(v)      ; present(w) → present(w)
    delete     : absent → absent          ; present(w) → absent

Every composite of such transitions is representable by a 4-tuple
``(a_kind, a_val, p_kind, p_val)`` describing its action on ``absent`` and on
``present(w)`` respectively, with kinds

    KIND_ABSENT  = 0   → absent
    KIND_CONST   = 1   → present(const val)
    KIND_KEEP    = 2   → present(w)        (only meaningful for the present leg)

Function composition of these tuples is associative, so the per-key fold is a
*segmented associative scan* — one ``lax.associative_scan`` over the
key-sorted batch.  This is the pure-jnp oracle for the ``elim_combine``
Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Op codes (shared with abtree).
OP_NOP = jnp.int32(0)
OP_FIND = jnp.int32(1)
OP_INSERT = jnp.int32(2)
OP_DELETE = jnp.int32(3)
# Range scan [lo, lo+span).  OP_RANGE lanes never enter the combine: they
# are read-only and linearize before the round's net writes (core/rounds.py
# runs the scan phase first), so `lane_masks`/`mask_range_lanes` below strip
# them from the batch the elimination fold sees.
OP_RANGE = jnp.int32(4)

KIND_ABSENT = jnp.int32(0)
KIND_CONST = jnp.int32(1)
KIND_KEEP = jnp.int32(2)


def lane_masks(ops: jax.Array):
    """Classify a mixed batch's lanes: ``(is_point, is_range)`` boolean masks.

    Point lanes (find/insert/delete) flow through search → combine → apply;
    range lanes are served by the scan phase.  OP_NOP lanes are in neither
    mask (they produce ⊥ without touching any phase).
    """
    ops = jnp.asarray(ops)
    is_range = ops == OP_RANGE
    is_point = (ops == OP_FIND) | (ops == OP_INSERT) | (ops == OP_DELETE)
    return is_point, is_range


def mask_range_lanes(ops: jax.Array) -> jax.Array:
    """OP_RANGE → OP_NOP, preserving lane positions.  Guarantees op code 4
    can never reach the combine (where it would silently act as a find)."""
    ops = jnp.asarray(ops)
    return jnp.where(ops == OP_RANGE, OP_NOP, ops).astype(jnp.int32)


class Transition(NamedTuple):
    """Composable transition of the {absent, present(v)} state machine.

    ``flag`` marks segment starts for the segmented scan (Blelloch-style
    segmented-scan monoid: once a segment boundary is crossed, the left
    operand is discarded).
    """

    a_kind: jax.Array  # action on `absent`:      KIND_ABSENT | KIND_CONST
    a_val: jax.Array
    p_kind: jax.Array  # action on `present(w)`:  KIND_ABSENT | KIND_CONST | KIND_KEEP
    p_val: jax.Array
    flag: jax.Array  # bool, True at segment starts


class EliminationResult(NamedTuple):
    """Per-op and per-segment outputs of the combine (in *sorted* order)."""

    before_present: jax.Array  # (B,) bool  — state seen by each op (exclusive prefix)
    before_val: jax.Array  # (B,)       — value seen by each op (valid iff present)
    after_present: jax.Array  # (B,) bool  — state after each op (inclusive prefix)
    after_val: jax.Array  # (B,)
    seg_head: jax.Array  # (B,) bool  — True at the first op of each key segment
    net_insert: jax.Array  # (B,) bool  — at seg head: key must be inserted (val=final)
    net_delete: jax.Array  # (B,) bool  — at seg head: key must be deleted
    net_overwrite: jax.Array  # (B,) bool — at seg head: value must be overwritten
    final_val: jax.Array  # (B,)       — at seg head: value after the round
    n_eliminated: jax.Array  # ()   — update-ops that required no physical write


def op_transition(op: jax.Array, val: jax.Array, is_start: jax.Array) -> Transition:
    """Lift one dictionary op to a Transition."""
    is_ins = op == OP_INSERT
    is_del = op == OP_DELETE
    # find / nop: identity.
    a_kind = jnp.where(is_ins, KIND_CONST, KIND_ABSENT)
    a_val = jnp.where(is_ins, val, jnp.zeros_like(val))
    p_kind = jnp.where(is_del, KIND_ABSENT, KIND_KEEP)
    p_val = jnp.zeros_like(val)
    return Transition(a_kind, a_val, p_kind, p_val, is_start)


def _apply_kind(kind, kval, in_present, in_val):
    """Apply one leg (kind, kval) given the input state."""
    out_present = jnp.where(kind == KIND_ABSENT, False, True)
    out_val = jnp.where(kind == KIND_CONST, kval, in_val)
    # KIND_KEEP with absent input cannot arise from well-formed compositions
    # applied to their own leg, but compose() below never generates it either:
    # we resolve KEEP eagerly during composition.
    del in_present
    return out_present, out_val


def compose(f: Transition, g: Transition) -> Transition:
    """h = g ∘ f  (f happens first).  Segmented: if g starts a segment, f is
    discarded.  Associativity: function composition + the standard segmented
    scan flag monoid."""

    # --- g∘f on the `absent` leg: feed f's absent-output into g.
    f_a_present = f.a_kind != KIND_ABSENT
    # g applied to (present, f.a_val):
    gp_on_fa_kind = jnp.where(g.p_kind == KIND_KEEP, KIND_CONST, g.p_kind)
    gp_on_fa_val = jnp.where(g.p_kind == KIND_KEEP, f.a_val, g.p_val)
    h_a_kind = jnp.where(f_a_present, gp_on_fa_kind, g.a_kind)
    h_a_val = jnp.where(f_a_present, gp_on_fa_val, g.a_val)

    # --- g∘f on the `present(w)` leg.
    # f(present(w)):  absent | const(f.p_val) | keep(w)
    # then g of that.
    f_p_present = f.p_kind != KIND_ABSENT
    # if f left state present: value is f.p_val (const) or w (keep)
    # g on present-input:
    g_keep = g.p_kind == KIND_KEEP
    # resulting kind when f leg was present:
    hp_kind_fp = jnp.where(
        g_keep,
        # g keeps f's output: const(f.p_val) or keep(w)
        jnp.where(f.p_kind == KIND_KEEP, KIND_KEEP, KIND_CONST),
        g.p_kind,
    )
    hp_val_fp = jnp.where(
        g_keep,
        f.p_val,  # only used when hp_kind_fp == KIND_CONST
        g.p_val,
    )
    h_p_kind = jnp.where(f_p_present, hp_kind_fp, g.a_kind)
    h_p_val = jnp.where(f_p_present, hp_val_fp, g.a_val)

    # --- segmented-scan flag handling: if g is a segment start, drop f.
    h = Transition(
        a_kind=jnp.where(g.flag, g.a_kind, h_a_kind),
        a_val=jnp.where(g.flag, g.a_val, h_a_val),
        p_kind=jnp.where(g.flag, g.p_kind, h_p_kind),
        p_val=jnp.where(g.flag, g.p_val, h_p_val),
        flag=jnp.logical_or(f.flag, g.flag),
    )
    return h


def apply_transition(t: Transition, present0: jax.Array, val0: jax.Array):
    """Apply a (composed) transition to an initial state."""
    out_p_on_absent, out_v_on_absent = _apply_kind(t.a_kind, t.a_val, False, val0)
    out_p_on_present, out_v_on_present = _apply_kind(t.p_kind, t.p_val, True, val0)
    present = jnp.where(present0, out_p_on_present, out_p_on_absent)
    val = jnp.where(present0, out_v_on_present, out_v_on_absent)
    return present, val


def eliminate_batch(
    ops_sorted: jax.Array,  # (B,) int32, key-sorted (stable ⇒ arrival order kept)
    vals_sorted: jax.Array,  # (B,)
    seg_head: jax.Array,  # (B,) bool, True at first op of each key segment
    present0: jax.Array,  # (B,) bool, per-op: pre-round presence of its key
    val0: jax.Array,  # (B,)     per-op: pre-round value of its key
) -> EliminationResult:
    """Run the publishing-elimination combine over one key-sorted batch.

    ``present0`` / ``val0`` need only be correct at segment heads; they are
    broadcast from the head within each segment here.
    """
    b = ops_sorted.shape[0]
    idx = jnp.arange(b)

    # Broadcast the segment head's initial state to every op in the segment.
    head_idx = jnp.where(seg_head, idx, 0)
    head_idx = jax.lax.associative_scan(jnp.maximum, head_idx)  # last head ≤ i
    present0 = present0[head_idx]
    val0 = val0[head_idx]

    trans = op_transition(ops_sorted, vals_sorted, seg_head)
    # Inclusive segmented scan of transition composition.
    inc = jax.lax.associative_scan(compose, trans)
    after_present, after_val = apply_transition(inc, present0, val0)

    # Exclusive state (what each op observed): shift the inclusive scan right
    # within segments; at segment heads the exclusive state is (present0, val0).
    prev_present = jnp.concatenate([jnp.zeros((1,), bool), after_present[:-1]])
    prev_val = jnp.concatenate([jnp.zeros((1,), after_val.dtype), after_val[:-1]])
    before_present = jnp.where(seg_head, present0, prev_present)
    before_val = jnp.where(seg_head, val0, prev_val)

    # Segment-final state, surfaced at the segment head (where apply acts).
    next_head = jnp.concatenate([seg_head[1:], jnp.ones((1,), bool)])
    seg_end = next_head  # position i is the last op of its segment
    # For each head, locate its segment end: scan max of (i if seg_end) from
    # the right.  Equivalently reverse-scan.
    end_idx = jnp.where(seg_end, idx, b - 1)
    end_idx = jax.lax.associative_scan(jnp.minimum, end_idx, reverse=True)
    final_present = after_present[end_idx]
    final_val = after_val[end_idx]

    net_insert = seg_head & ~present0 & final_present
    net_delete = seg_head & present0 & ~final_present
    net_overwrite = seg_head & present0 & final_present & (final_val != val0)
    n_net = jnp.sum(net_insert | net_delete | net_overwrite)
    # An op is *eliminated* iff it would have modified the tree given the
    # state it observed (successful insert or successful delete) but is not
    # covered by the single net write.  This matches the paper's accounting:
    # unsuccessful updates return without writing in the OCC tree too.
    would_write = ((ops_sorted == OP_INSERT) & ~before_present) | (
        (ops_sorted == OP_DELETE) & before_present
    )
    n_eliminated = jnp.sum(would_write) - n_net

    return EliminationResult(
        before_present=before_present,
        before_val=before_val,
        after_present=after_present,
        after_val=after_val,
        seg_head=seg_head,
        net_insert=net_insert,
        net_delete=net_delete,
        net_overwrite=net_overwrite,
        final_val=final_val,
        n_eliminated=n_eliminated,
    )


def op_return_values(
    ops_sorted: jax.Array,
    res: EliminationResult,
    notfound,
) -> jax.Array:
    """Dictionary return values per §3 semantics, in sorted order.

    find/insert/delete all return the value associated with the key in the
    state the op observed, or ⊥ (= ``notfound``) if absent.  (A successful
    insert returns ⊥; an insert that found the key returns the value; a
    successful delete returns the removed value.)
    """
    ret = jnp.where(res.before_present, res.before_val, notfound)
    return jnp.where(ops_sorted == OP_NOP, notfound, ret)
