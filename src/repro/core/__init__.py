# Core library: the paper's primary contribution — batched OCC-ABtree and
# Elim-ABtree (publishing elimination) with durable (link-and-persist)
# commits — adapted from shared-memory threads to SPMD batch rounds.
#
# Keys/values are 8 bytes as in the paper, which requires x64 mode. Model
# code elsewhere in the package is dtype-explicit and unaffected.
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.abtree import (  # noqa: E402
    ABTree,
    TreeConfig,
    TreeState,
    OP_NOP,
    OP_FIND,
    OP_INSERT,
    OP_DELETE,
    OP_RANGE,
    EMPTY,
    NOTFOUND,
    RoundOutput,
    ScanConflictError,
    ScanOutput,
    range_query,
)
from repro.core.rounds import RoundPlan, build_plan  # noqa: E402
from repro.core.forest import ABForest, check_forest_invariants  # noqa: E402
from repro.core.elimination import eliminate_batch, EliminationResult  # noqa: E402
from repro.core.oracle import DictOracle, check_invariants  # noqa: E402
from repro.core.durable import (  # noqa: E402
    DurableABTree,
    DurableForest,
    RecoveryError,
    recover,
    recover_forest,
)
from repro.core.faults import (  # noqa: E402
    CrashPoint,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    SimulatedCrash,
)

__all__ = [
    "ABTree",
    "ABForest",
    "check_forest_invariants",
    "TreeConfig",
    "TreeState",
    "OP_NOP",
    "OP_FIND",
    "OP_INSERT",
    "OP_DELETE",
    "OP_RANGE",
    "EMPTY",
    "NOTFOUND",
    "RoundOutput",
    "ScanConflictError",
    "ScanOutput",
    "RoundPlan",
    "build_plan",
    "range_query",
    "eliminate_batch",
    "EliminationResult",
    "DictOracle",
    "check_invariants",
    "DurableABTree",
    "DurableForest",
    "CrashPoint",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "SimulatedCrash",
    "RecoveryError",
    "recover",
    "recover_forest",
]
