"""Fault-tolerant training loop.

Production posture (DESIGN.md §6):
  * durable checkpoints via the link-and-persist manifest protocol
    (checkpoint/manager.py) at a configurable cadence;
  * auto-resume: on construction the Trainer restores the latest committed
    manifest (elastic: the restore re-shards to the *current* mesh, which
    may differ from the mesh that wrote the checkpoint);
  * preemption handling: SIGTERM/SIGINT request a final checkpoint + clean
    exit (the cluster scheduler restarts the job, which auto-resumes);
  * failure injection: `fail_at_step` simulates a hard crash (tests drive
    the crash→restart→resume path);
  * straggler monitor: per-step wall time EMA; steps slower than
    `straggler_factor`× the EMA are counted and surfaced in metrics — on a
    real fleet this feeds the health service that evicts slow hosts (on a
    single host it degrades to detection + logging).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.models import backbone, init_params
from repro.models.config import ModelConfig
from repro.optim.adamw import OptState, adamw_init
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    lr_peak: float = 3e-4
    grad_clip: float = 1.0
    microbatch: Optional[int] = None
    fail_at_step: Optional[int] = None  # simulate a hard crash
    straggler_factor: float = 3.0
    log_every: int = 10


class SimulatedFailure(RuntimeError):
    pass


class StragglerMonitor:
    def __init__(self, factor: float):
        self.factor = factor
        self.ema: Optional[float] = None
        self.count = 0

    def record(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.count += int(slow)
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        return slow


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        tcfg: TrainerConfig,
        mesh,
        data_iter_factory: Callable[[int], Iterator[dict]],
    ):
        self.cfg = model_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.data_iter_factory = data_iter_factory
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.monitor = StragglerMonitor(tcfg.straggler_factor)
        self._stop = False

        jit_maker, self.shardings = make_train_step(
            model_cfg,
            mesh,
            lr_peak=tcfg.lr_peak,
            grad_clip=tcfg.grad_clip,
            microbatch=tcfg.microbatch,
        )
        self._jit_maker = jit_maker
        self._step_fn = None

        # ---- init or resume ---------------------------------------------
        self.step = 0
        params = init_params(backbone.model_spec(model_cfg))
        opt = adamw_init(params)
        last = latest_step(tcfg.ckpt_dir)
        if last is not None:
            state = restore(
                tcfg.ckpt_dir,
                last,
                {"params": params, "opt": opt},
                {"params": self.shardings["params"], "opt": self.shardings["opt"]},
            )
            params, opt = state["params"], state["opt"]
            self.step = last
            self.resumed_from = last
        else:
            self.resumed_from = None
            params = jax.device_put(params, self.shardings["params"])
            opt = jax.device_put(opt, self.shardings["opt"])
        self.params, self.opt = params, opt

    # ---- signals --------------------------------------------------------

    def _install_signals(self):
        def handler(signum, frame):  # noqa: ARG001
            self._stop = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not in main thread (tests)

    # ---- main loop ------------------------------------------------------

    def run(self) -> dict:
        self._install_signals()
        it = self.data_iter_factory(self.step)
        history = []
        while self.step < self.tcfg.max_steps and not self._stop:
            batch = next(it)
            batch = jax.tree.map(
                lambda x: jax.device_put(x, None), batch
            )
            if self._step_fn is None:
                self._step_fn = self._jit_maker(
                    jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
                )
            t0 = time.time()
            out = self._step_fn(
                self.params, self.opt, batch, jnp.asarray(self.step, jnp.int32)
            )
            jax.block_until_ready(out.metrics["loss"])
            dt = time.time() - t0
            slow = self.monitor.record(dt)
            self.params, self.opt = out.params, out.opt_state
            self.step += 1

            if self.tcfg.fail_at_step is not None and self.step == self.tcfg.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {self.step}")

            if self.step % self.tcfg.log_every == 0 or slow:
                history.append(
                    {
                        "step": self.step,
                        "loss": float(out.metrics["loss"]),
                        "grad_norm": float(out.metrics["grad_norm"]),
                        "sec_per_step": dt,
                        "straggler_events": self.monitor.count,
                    }
                )
            if self.step % self.tcfg.ckpt_every == 0:
                self._save()
        self._save()
        return {
            "final_step": self.step,
            "final_loss": float(out.metrics["loss"]) if self.step else None,
            "history": history,
            "straggler_events": self.monitor.count,
            "resumed_from": self.resumed_from,
        }

    def _save(self):
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt},
            extra={"step": self.step},
        )
