from repro.train.step import make_serve_step, make_train_step, make_prefill_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [
    "make_train_step",
    "make_serve_step",
    "make_prefill_step",
    "Trainer",
    "TrainerConfig",
]
