"""jit-compiled train / prefill / serve steps with explicit shardings.

These are the functions the multi-pod dry-run lowers for every
(arch × shape × mesh) cell, and the functions the Trainer executes.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import backbone
from repro.models.config import ModelConfig
from repro.models.params import RULE_SETS, param_shardings
from repro.optim import adamw_update, clip_by_global_norm, warmup_cosine
from repro.parallel.sharding import batch_shardings, cache_shardings, data_axes


class TrainStepOut(NamedTuple):
    params: Any
    opt_state: Any
    metrics: Any


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    lr_peak: float = 3e-4,
    grad_clip: float = 1.0,
    microbatch: Optional[int] = None,
    donate: bool = True,
):
    """Returns (step_fn, shardings) where step_fn(params, opt, batch, step)
    is jit-compiled with explicit in/out shardings.

    `microbatch`: if set, the global batch is split into
    batch//microbatch accumulation steps (scanned) — activation memory ∝
    microbatch while keeping the same global batch semantics.
    """
    rules = RULE_SETS[cfg.rules]
    spec_tree = backbone.model_spec(cfg)
    p_shard = param_shardings(spec_tree, mesh, rules)
    rep = NamedSharding(mesh, PartitionSpec())

    def loss(params, batch):
        return backbone.loss_fn(params, batch, cfg)

    def grads_of(params, batch):
        if microbatch:
            b = jax.tree.leaves(batch)[0].shape[0]
            n_acc = max(1, b // microbatch)

            def mb(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * microbatch, microbatch),
                    batch,
                )

            def body(carry, i):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, mb(i))
                g_acc = jax.tree.map(lambda a, b_: a + b_, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, lsum), _ = jax.lax.scan(body, (g0, 0.0), jnp.arange(n_acc))
            g = jax.tree.map(lambda x: x / n_acc, g)
            return lsum / n_acc, g
        (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
        return l, g

    def step_fn(params, opt_state, batch, step):
        l, g = grads_of(params, batch)
        g, gnorm = clip_by_global_norm(g, grad_clip)
        lr = warmup_cosine(step, peak=lr_peak)
        params, opt_state = adamw_update(g, opt_state, params, lr)
        metrics = {"loss": l, "grad_norm": gnorm, "lr": lr}
        return TrainStepOut(params, opt_state, metrics)

    def opt_shard(ps):
        from repro.optim.adamw import OptState

        return OptState(m=ps, v=ps, count=rep)

    def batch_shard(batch_tree):
        return batch_shardings(cfg, batch_tree, mesh)

    shardings = {
        "params": p_shard,
        "opt": opt_shard(p_shard),
        "replicated": rep,
        "batch_fn": batch_shard,
    }

    def jitted(batch_tree):
        return jax.jit(
            step_fn,
            in_shardings=(p_shard, opt_shard(p_shard), batch_shard(batch_tree), rep),
            out_shardings=TrainStepOut(p_shard, opt_shard(p_shard), rep),
            donate_argnums=(0, 1) if donate else (),
        )

    return jitted, shardings


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """Inference forward over a full prompt (logits for every position).
    KV-cache emission is elided in the lowered artifact (roofline notes the
    additional cache-write bytes separately)."""
    rules = RULE_SETS[cfg.rules]
    spec_tree = backbone.model_spec(cfg)
    p_shard = param_shardings(spec_tree, mesh, rules)
    rep = NamedSharding(mesh, PartitionSpec())

    def prefill(params, batch):
        tokens = batch["tokens"]
        extra = {k: v for k, v in batch.items() if k != "tokens"} or None
        logits = backbone.forward_train(params, tokens, cfg, extra)
        # return only the final position (the sampling entry point)
        return logits[:, -1, :]

    def jitted(batch_tree):
        out_s = NamedSharding(mesh, PartitionSpec(data_axes(mesh) or None, None))
        return jax.jit(
            prefill,
            in_shardings=(p_shard, batch_shardings(cfg, batch_tree, mesh)),
            out_shardings=out_s,
        )

    return jitted, {"params": p_shard}


def make_serve_step(cfg: ModelConfig, mesh: Mesh, batch: int, s_max: int):
    """Single-token decode step over a KV cache of length s_max."""
    rules = RULE_SETS[cfg.rules]
    spec_tree = backbone.model_spec(cfg)
    p_shard = param_shardings(spec_tree, mesh, rules)
    rep = NamedSharding(mesh, PartitionSpec())
    c_shard = cache_shardings(cfg, batch, s_max, mesh)
    da = data_axes(mesh)
    da_size = 1
    for a in da:
        da_size *= mesh.shape[a]
    tok_s = NamedSharding(mesh, PartitionSpec(da if batch % da_size == 0 else None))

    def serve(params, cache, tokens, pos):
        logits, cache = backbone.forward_decode(params, cache, tokens, pos, cfg)
        return logits, cache

    jitted = jax.jit(
        serve,
        in_shardings=(p_shard, c_shard, tok_s, rep),
        out_shardings=(NamedSharding(mesh, PartitionSpec(tok_s.spec[0], None)), c_shard),
        donate_argnums=(1,),
    )
    return jitted, {"params": p_shard, "cache": c_shard}
