"""internvl2-2b [vlm] — InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; hf].  24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The ViT provides 256 precomputed patch embeddings per image
(input_specs supplies them; only the projection is learned here)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=8,
    d_ff=8192,
    vocab=92553,
    vis_tokens=256,
    rules="tp",
)
