"""Architecture registry + assigned input shapes.

40 cells = 10 archs × 4 shapes.  ``long_500k`` requires sub-quadratic
sequence mixing and is SKIPPED for pure full-attention archs (recorded, not
silently dropped — see DESIGN.md §5)."""
from __future__ import annotations

import importlib
from typing import Dict, NamedTuple

from repro.models.config import ModelConfig

_MODULES = {
    "internvl2-2b": "internvl2_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "yi-9b": "yi_9b",
    "yi-34b": "yi_34b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "xlstm-350m": "xlstm_350m",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-tiny": "whisper_tiny",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


class ShapeSpec(NamedTuple):
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> str:
    """'run' or a skip reason for an (arch × shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skip: pure full-attention arch (long_500k needs sub-quadratic)"
    return "run"


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield arch, cfg, shape, cell_status(cfg, shape)
