"""qwen2-0.5b [dense] — GQA kv=2 with QKV bias [arXiv:2407.10671; hf].
24L d_model=896 14H d_ff=4864 vocab=151936, tied embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    rules="tp",
)
