"""The paper's own data-structure configs: (a,b) presets from the paper
(MIN_SIZE=2 with MAX 8/11/16) for the microbenchmarks."""
from repro.core.abtree import TreeConfig

PAPER = TreeConfig(capacity=1 << 16, b=11, a=2, max_height=24)  # paper's b=11
TPU8 = TreeConfig(capacity=1 << 16, b=8, a=2, max_height=24)  # VREG-lane aligned
WIDE16 = TreeConfig(capacity=1 << 16, b=16, a=2, max_height=24)
