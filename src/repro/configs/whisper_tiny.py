"""whisper-tiny [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified].  4L enc + 4L dec, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865.  input_specs supplies 1500 precomputed frame
embeddings; LayerNorm + GELU + sinusoidal positions (no rope)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51865,
    enc_layers=4,
    enc_frames=1500,
    norm="ln",
    act="gelu",
    use_rope=False,
    tie_embeddings=True,
    scan_layers=False,
    rules="tp",
)
