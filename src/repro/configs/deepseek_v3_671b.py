"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8
[arXiv:2412.19437; hf].  61L d_model=7168 128H kv=128 (MLA: q_lora=1536,
kv_lora=512, rope_head=64), expert d_ff=2048, first 3 layers dense
(d_ff=18432), vocab=129280.  256 % 16 == 0 → expert parallelism over the
model axis + FSDP-style param sharding (rules='ep_fsdp').  MTP head
omitted (see DESIGN.md §7)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv=128,
    d_head=128,
    d_ff=18432,
    vocab=129280,
    attn="mla",
    q_lora=1536,
    kv_lora=512,
    rope_head=64,
    n_experts=256,
    top_k=8,
    d_ff_expert=2048,
    n_shared=1,
    first_k_dense=3,
    rules="ep_fsdp",
    remat="dots",
)
