"""§Perf hillclimb configurations — beyond-paper optimized variants of the
three chosen cells (EXPERIMENTS.md §Perf records baseline vs these).

Keys: (arch, shape) → dict of ModelConfig overrides (+ the special key
``param_dtype`` consumed by the dry-run: serving-weight dtype)."""

OPTIMIZED = {
    # worst roofline fraction: sequential mLSTM scan → chunkwise (state
    # traffic ÷ chunk, outer products → MXU matmuls)
    ("xlstm-350m", "train_4k"): {"mlstm_chunk": 64},
    # most collective-bound: global MoE dispatch reshards every token →
    # shard-local grouped dispatch (32 groups align with pod×data batch
    # sharding on both meshes)
    ("granite-moe-3b-a800m", "train_4k"): {"moe_groups": 32},
    # most technique-representative (serving): fp32 resident weights stream
    # through HBM every decode step → bf16 serving weights (master weights
    # stay fp32 in the training checkpoints; serving loads a cast copy)
    ("deepseek-v3-671b", "decode_32k"): {"param_dtype": "bfloat16"},
}


def overrides_for(arch: str, shape: str) -> dict:
    return dict(OPTIMIZED.get((arch, shape), {}))
