"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].  38L d_model=2048, shared block: 32H (kv=32)
d_ff=8192; ssm_state=64.  The shared transformer block is applied every 6
mamba layers over [hidden ‖ embeddings] (2d → d in-proj)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    shared_attn_every=6,
    rules="tp",
)
