"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
24L d_model=1024 4H vocab=50304, d_ff=0 (cells carry their own up/down
projections).  Every 6th block is an sLSTM (paper's mixed ratio)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    slstm_every=6,
    rules="tp",
)
