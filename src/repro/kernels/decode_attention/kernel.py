"""Pallas TPU kernel: GQA decode attention (flash-decoding split over KV).

The decode hot spot is memory-bound: one query token must stream the whole
KV cache (S up to 512k).  Grid = (batch·kv_heads, kv_tiles): the kv axis is
innermost/sequential so the per-(batch, kv-head) online-softmax state for
the `group` query heads lives in VMEM scratch, and the KV cache is read
exactly once from HBM — the roofline-optimal schedule.  A `kv_len` scalar
masks the tail (ragged caches from the paging layer).

q is reshaped to (B·KH, G, D): all G query heads of one kv head are carried
in a single MXU-friendly (G, block_k) score tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar prefetch: (1,) int32 kv_len
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, block_k: int, nk: int, sm_scale: float,
):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]
    live = ki * block_k < kv_len

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (G, D)
        k = k_ref[0].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (G, block_k)
        cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_k", "interpret")
)
def decode_attention_pallas(
    q: jax.Array,  # (B, H, D) — single decode token per sequence
    k: jax.Array,  # (B, KH, S, D) KV cache
    v: jax.Array,  # (B, KH, S, D)
    kv_len: jax.Array | int | None = None,  # valid cache length (≤ S)
    *,
    sm_scale: float | None = None,
    block_k: int = 256,
    interpret: bool = True,
):
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    assert h % kh == 0
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    if kv_len is None:
        kv_len = s
    kv_len = jnp.asarray([kv_len], jnp.int32)

    pad = (-s) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nk = sp // block_k

    # (B, H, D) → (B·KH, G, D): group q heads by their kv head.
    qr = q.reshape(b, kh, group, d).reshape(b * kh, group, d)
    kr = k.reshape(b * kh, sp, d)
    vr = v.reshape(b * kh, sp, d)

    grid = (b * kh, nk)
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel, block_k=block_k, nk=nk, sm_scale=sm_scale
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, group, d), lambda bh, ki, lens: (bh, 0, 0)),
                pl.BlockSpec((1, block_k, d), lambda bh, ki, lens: (bh, ki, 0)),
                pl.BlockSpec((1, block_k, d), lambda bh, ki, lens: (bh, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, group, d), lambda bh, ki, lens: (bh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, d), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b * kh, group, d), q.dtype),
        interpret=interpret,
    )(kv_len, qr, kr, vr)
    return out.reshape(b, h, d)
