"""Pure-jnp oracle for decode_attention."""
import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, KH, S, D)
    v: jax.Array,  # (B, KH, S, D)
    kv_len=None,
    *,
    sm_scale: float | None = None,
):
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    if kv_len is None:
        kv_len = s
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kx.astype(jnp.float32))
    scores = scores * sm_scale
    mask = jnp.arange(s)[None, None, :] < kv_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
