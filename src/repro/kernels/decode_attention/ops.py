"""Public wrapper for decode attention."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_len=None,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
    block_k: int = 256,
):
    if use_pallas:
        return decode_attention_pallas(
            q, k, v, kv_len, block_k=block_k, interpret=interpret
        )
    return decode_attention_ref(q, k, v, kv_len)
