"""jit'd wrapper: Pallas forward + XLA backward (custom_vjp over the ref).

The Pallas kernel is forward-only; for training we register the oracle's
VJP so gradients are exact while the forward pays kernel cost.  On real TPU
hardware the flash backward kernel would replace it; on this CPU container
the ref path is used in train_step anyway (use_pallas=False default in
model configs) and the kernel is exercised in interpret mode by tests and
benchmarks."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def flash_attention(q, k, v, causal=True, window=0, sm_scale=None, interpret=True):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale, interpret=interpret
    )


def _fwd(q, k, v, causal, window, sm_scale, interpret):
    out = flash_attention(q, k, v, causal, window, sm_scale, interpret)
    return out, (q, k, v)


def _bwd(causal, window, sm_scale, interpret, resid, g):
    q, k, v = resid
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(
            q_, k_, v_, causal=causal, window=window, sm_scale=sm_scale
        ),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
