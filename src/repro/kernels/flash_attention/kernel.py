"""Pallas TPU kernel: tiled online-softmax attention (causal / GQA / SWA).

Standard TPU flash pattern: grid = (batch·q_heads, q_tiles, kv_tiles); the
kv dimension is innermost so the (m, l, acc) running-softmax state persists
in VMEM scratch across kv tiles; output is written once on the last kv tile.
Causal and sliding-window masks skip fully-masked tiles via `pl.when`.

GQA is expressed in the BlockSpec index maps: the k/v block row is
`(bh // H) * KH + (bh % H) // group`, so q heads sharing a kv head stream
the same K/V tiles (VMEM reuse, no HBM duplication).

Block sizes default to MXU-aligned (128, 128) tiles; D is kept whole per
block (≤ 256 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, block_q: int, block_k: int, nk: int, causal: bool, window: int, sm_scale: float,
    s_orig: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile-level skip: under causality a kv tile strictly above the diagonal
    # contributes nothing; under SWA a tile entirely left of the window does
    # not either.
    q_lo = qi * block_q
    k_lo = ki * block_k
    live = True
    if causal:
        live = k_lo <= q_lo + block_q - 1
    if window > 0:
        live = jnp.logical_and(live, k_lo + block_k - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (block_q, block_k)

        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = cols < s_orig  # padded key columns never receive mass
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (block_q, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KH, S, D)
    v: jax.Array,  # (B, KH, S, D)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unbounded; >0 = sliding window (SWA)
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    b, h, s, d = q.shape
    kh = k.shape[1]
    assert h % kh == 0, "GQA requires H % KH == 0"
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)

    pad_q = (-s) % block_q
    pad_k = (-s) % block_k
    sq, sk = s + pad_q, s + pad_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * kh, sk, d)
    vr = v.reshape(b * kh, sk, d)
    nq, nk = sq // block_q, sk // block_k

    def kv_row(bh):
        return (bh // h) * kh + (bh % h) // group

    # Padded kv columns (beyond original s) must be masked: padding keys are
    # zeros → scores 0, which would beat NEG_INF.  Under causal they are only
    # visible to padded q rows (discarded).  For non-causal use we mask via
    # window==0 & causal==False ⇒ disallow pad: handled by masking cols < s.
    grid = (b * h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=block_q,
            block_k=block_k,
            nk=nk,
            causal=causal,
            window=window,
            sm_scale=sm_scale,
            s_orig=s,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)[:, :, :s, :]
