"""Pure-jnp oracle for flash_attention (GQA + causal + sliding window)."""
import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KH, S, D)
    v: jax.Array,  # (B, KH, S, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: float | None = None,
):
    b, h, s, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    group = h // kh
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    scores = scores * sm_scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
