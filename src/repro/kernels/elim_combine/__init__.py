from repro.kernels.elim_combine.kernel import elim_combine_pallas
from repro.kernels.elim_combine.ops import elim_combine
from repro.kernels.elim_combine.ref import elim_combine_ref

__all__ = ["elim_combine", "elim_combine_pallas", "elim_combine_ref"]
