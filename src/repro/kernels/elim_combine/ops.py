"""Public wrapper for the elimination combine."""
from __future__ import annotations

import jax

from repro.kernels.elim_combine.kernel import elim_combine_pallas
from repro.kernels.elim_combine.ref import elim_combine_ref


def elim_combine(
    ops: jax.Array,
    vals: jax.Array,
    seg_head: jax.Array,
    present0: jax.Array,
    val0: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
    tile: int = 256,
):
    """Segmented publishing-elimination fold.  Returns
    (before_present, before_val, after_present, after_val)."""
    if use_pallas:
        return elim_combine_pallas(
            ops, vals, seg_head, present0, val0, tile=tile, interpret=interpret
        )
    return elim_combine_ref(ops, vals, seg_head, present0, val0)
