"""Pure-jnp oracle for elim_combine: the segmented associative scan from
core/elimination.py restricted to the kernel's (before/after) outputs."""
import jax
import jax.numpy as jnp

from repro.core import elimination as elim


def elim_combine_ref(ops, vals, seg_head, present0, val0):
    res = elim.eliminate_batch(
        ops.astype(jnp.int32),
        vals,
        seg_head,
        present0,
        val0,
    )
    return (
        res.before_present,
        res.before_val.astype(vals.dtype),
        res.after_present,
        res.after_val.astype(vals.dtype),
    )
