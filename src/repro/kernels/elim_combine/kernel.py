"""Pallas TPU kernel: publishing-elimination combine (segmented scan).

This is the device-side hot loop of the Elim-ABtree round (DESIGN.md §4,
core/elimination.py is the pure-jnp oracle).  Input ops are key-sorted; each
op is lifted to a transition of the {absent, present(v)} state machine and
the per-key fold is a *segmented inclusive scan* of transition composition.

TPU mapping:
  * within a tile: Hillis–Steele doubling scan (log2(TILE) vectorized
    compose steps — `jnp.roll` + select, no gathers),
  * across tiles: the TPU grid iterates sequentially, so a carry transition
    lives in VMEM scratch and is composed into each tile (the segmented-scan
    flag monoid makes the carry self-neutralizing across key boundaries).

The same kernel powers the EmbedElim sparse-update combine (optim/sparse.py)
where "insert/delete" become "accumulate/clear" on embedding rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# op codes (match core.elimination)
OP_NOP, OP_FIND, OP_INSERT, OP_DELETE = 0, 1, 2, 3
# NOTE: kind selects below pin .astype(jnp.int32) — under jax_enable_x64 a
# jnp.where whose branches are both weak Python ints resolves to int64, and
# the resulting transition tuples then fail the int32 ref stores.
K_ABSENT, K_CONST, K_KEEP = 0, 1, 2


def _compose(f, g):
    """h = g∘f on transition 5-tuples of int32 arrays (see core/elimination).
    Inlined for the kernel: identical algebra, int32 kinds."""
    fa_k, fa_v, fp_k, fp_v, f_fl = f
    ga_k, ga_v, gp_k, gp_v, g_fl = g

    f_a_present = fa_k != K_ABSENT
    gp_on_fa_k = jnp.where(gp_k == K_KEEP, K_CONST, gp_k)
    gp_on_fa_v = jnp.where(gp_k == K_KEEP, fa_v, gp_v)
    h_a_k = jnp.where(f_a_present, gp_on_fa_k, ga_k)
    h_a_v = jnp.where(f_a_present, gp_on_fa_v, ga_v)

    f_p_present = fp_k != K_ABSENT
    g_keep = gp_k == K_KEEP
    hp_k_fp = jnp.where(
        g_keep, jnp.where(fp_k == K_KEEP, K_KEEP, K_CONST).astype(jnp.int32), gp_k
    )
    hp_v_fp = jnp.where(g_keep, fp_v, gp_v)
    h_p_k = jnp.where(f_p_present, hp_k_fp, ga_k)
    h_p_v = jnp.where(f_p_present, hp_v_fp, ga_v)

    return (
        jnp.where(g_fl == 1, ga_k, h_a_k),
        jnp.where(g_fl == 1, ga_v, h_a_v),
        jnp.where(g_fl == 1, gp_k, h_p_k),
        jnp.where(g_fl == 1, gp_v, h_p_v),
        jnp.maximum(f_fl, g_fl),
    )


def _apply(t, present0, val0):
    a_k, a_v, p_k, p_v, _ = t
    on_a_p = (a_k != K_ABSENT).astype(jnp.int32)
    on_a_v = jnp.where(a_k == K_CONST, a_v, val0)
    on_p_p = (p_k != K_ABSENT).astype(jnp.int32)
    on_p_v = jnp.where(p_k == K_CONST, p_v, val0)
    present = jnp.where(present0 == 1, on_p_p, on_a_p)
    val = jnp.where(present0 == 1, on_p_v, on_a_v)
    return present, val


def _identity_like(x):
    z = jnp.zeros_like(x)
    return (z + K_ABSENT, z, z + K_KEEP, z, z)


def _combine_kernel(
    ops_ref, vals_ref, head_ref, p0_ref, v0_ref,
    bp_ref, bv_ref, ap_ref, av_ref,
    carry_ref,
    *, tile: int,
):
    i = pl.program_id(0)

    ops = ops_ref[...]  # (TILE, 1) int32
    vals = vals_ref[...]
    head = head_ref[...]
    p0 = p0_ref[...]
    v0 = v0_ref[...]

    # lift ops → transitions
    is_ins = (ops == OP_INSERT).astype(jnp.int32)
    is_del = ops == OP_DELETE
    a_k = jnp.where(is_ins == 1, K_CONST, K_ABSENT).astype(jnp.int32)
    a_v = jnp.where(is_ins == 1, vals, 0)
    p_k = jnp.where(is_del, K_ABSENT, K_KEEP).astype(jnp.int32)
    p_v = jnp.zeros_like(vals)
    t = (a_k, a_v, p_k, p_v, head)

    # Hillis–Steele inclusive scan over the tile (axis 0), log2 steps.
    d = 1
    while d < tile:
        shifted = tuple(jnp.roll(x, d, axis=0) for x in t)
        idx = jax.lax.broadcasted_iota(jnp.int32, ops.shape, 0)
        ident = _identity_like(ops)
        left = tuple(jnp.where(idx >= d, s, ii) for s, ii in zip(shifted, ident))
        t = _compose(left, t)
        d *= 2

    # initialize / read tile carry (identity at tile 0)
    @pl.when(i == 0)
    def _():
        ident = _identity_like(carry_ref[...][:, 0:1])
        for j, x in enumerate(ident):
            carry_ref[..., j : j + 1] = x

    carry = tuple(carry_ref[...][:, j : j + 1] for j in range(5))
    inc = _compose(tuple(jnp.broadcast_to(c, x.shape) for c, x in zip(carry, t)), t)

    after_p, after_v = _apply(inc, p0, v0)

    # exclusive state = inclusive of previous element (carry for element 0);
    # at segment heads the observed state is simply (p0, v0).
    exc = tuple(jnp.roll(x, 1, axis=0) for x in inc)
    idx = jax.lax.broadcasted_iota(jnp.int32, ops.shape, 0)
    exc = tuple(
        jnp.where(idx >= 1, e, jnp.broadcast_to(c, e.shape))
        for e, c in zip(exc, carry)
    )
    exc_p, exc_v = _apply(exc, p0, v0)
    before_p = jnp.where(head == 1, p0, exc_p)
    before_v = jnp.where(head == 1, v0, exc_v)

    bp_ref[...] = before_p
    bv_ref[...] = before_v
    ap_ref[...] = after_p
    av_ref[...] = after_v

    # new carry = inclusive transition of the tile's last element
    last = tuple(x[tile - 1 : tile, :] for x in inc)
    for j, x in enumerate(last):
        carry_ref[..., j : j + 1] = x


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def elim_combine_pallas(
    ops: jax.Array,  # (B,) int32, key-sorted
    vals: jax.Array,  # (B,) int32
    seg_head: jax.Array,  # (B,) bool
    present0: jax.Array,  # (B,) bool  (valid everywhere, broadcast per segment)
    val0: jax.Array,  # (B,) int32
    *,
    tile: int = 256,
    interpret: bool = True,
):
    b = ops.shape[0]
    pad = (-b) % tile
    if pad:
        ops = jnp.pad(ops, (0, pad))  # NOP
        vals = jnp.pad(vals, (0, pad))
        seg_head = jnp.pad(seg_head, (0, pad), constant_values=True)
        present0 = jnp.pad(present0, (0, pad))
        val0 = jnp.pad(val0, (0, pad))
    n = ops.shape[0]
    col = lambda x: x.astype(jnp.int32)[:, None]
    grid = (n // tile,)
    spec = pl.BlockSpec((tile, 1), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_combine_kernel, tile=tile),
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((n, 1), jnp.int32)] * 4,
        scratch_shapes=[pltpu.VMEM((1, 5), jnp.int32)],
        interpret=interpret,
    )(col(ops), col(vals), col(seg_head), col(present0), col(val0))
    bp, bv, ap, av = (o[:b, 0] for o in outs)
    return bp.astype(bool), bv, ap.astype(bool), av
