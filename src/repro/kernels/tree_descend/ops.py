"""Public wrappers for tree_descend: dispatch between the Pallas kernels
(int32 device keys) and the dtype-generic jnp references.

The tree's host index uses int64 keys; the TPU kernels operate on int32
lanes.  ``descend_probe`` therefore routes int64 pools to the reference
implementation unless the caller asserts the keys AND values lie strictly
inside the int32 range (``narrow=True`` casts and uses the kernel — the
same contract as ``kernels/range_scan``'s narrow gate: the int32 max is
the device EMPTY sentinel, so a key/value at ±(2**31 - 1) would be
conflated with a free slot).

``frontier_compact`` operates on node *ids* (always int32), so both of its
paths are sort-free: the default jnp path compacts by exclusive-cumsum
rank + one batched scatter (replacing the per-level stable ``argsort`` of
the original frontier expansion), and the ``use_pallas`` path runs the
masked-select Pallas kernel, keeping the whole scan descent in VMEM.  The
argsort formulation survives only as ``ref.frontier_compact_ref``, the
test oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.tree_descend.kernel import (
    INT32_MAX,
    descend_probe_pallas,
    frontier_compact_pallas,
)
from repro.kernels.tree_descend.ref import (
    descend_probe_ref,
    descend_ref,
    probe_ref,
)

# Pool planes past this many rows exceed the per-core VMEM budget for the
# resident-pool layout (keys+vals+children ≈ 3·rows·b·4 B); larger pools
# take the ref path even under the narrow gate.
MAX_POOL_ROWS = 1 << 17


def descend_probe(
    pool_keys: jax.Array,  # (N, b) EMPTY-padded keys/routers
    pool_vals: jax.Array,  # (N, b)
    children: jax.Array,  # (N, b) int32
    is_leaf: jax.Array,  # (N,) bool
    root,  # int32 scalar
    queries: jax.Array,  # (B,)
    *,
    max_height: int,
    notfound,
    narrow: bool = False,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Fused search phase: root-to-leaf descent + unsorted-leaf probe.

    Returns ``(leaf_ids (B,) int32, found (B,) bool, slot (B,) int32,
    val (B,))`` with ``val == notfound`` where absent — exactly the
    ``descend_ref``/``probe_ref`` composition on every path.
    """
    eligible = narrow or pool_keys.dtype == jnp.int32
    if use_pallas and eligible and pool_keys.shape[0] <= MAX_POOL_ROWS:
        empty = jnp.iinfo(pool_keys.dtype).max
        pk = jnp.where(pool_keys == empty, INT32_MAX, pool_keys).astype(jnp.int32)
        q = jnp.where(queries == empty, INT32_MAX, queries).astype(jnp.int32)
        leaf_ids, found, slot, val32 = descend_probe_pallas(
            pk,
            pool_vals.astype(jnp.int32),
            children.astype(jnp.int32),
            is_leaf,
            root,
            q,
            max_height=max_height,
            interpret=interpret,
        )
        val = jnp.where(found, val32.astype(pool_vals.dtype), notfound)
        return leaf_ids, found, slot, val
    return descend_probe_ref(
        pool_keys, pool_vals, children, is_leaf, root, queries,
        max_height=max_height, notfound=notfound,
    )


def frontier_compact(
    cand: jax.Array,  # (B, M) int32 candidate node ids
    valid: jax.Array,  # (B, M) bool
    f: int,  # static output frontier width
    *,
    scratch: int,
    use_pallas: bool = False,
    interpret: bool = True,
):
    """Stable, sort-free compaction of each row's valid candidates into a
    width-``f`` frontier.  Returns ``(frontier (B, f) int32, valid (B, f)
    bool, overflow (B,))``; invalid output slots hold ``scratch``.
    Bit-identical to the argsort oracle (``ref.frontier_compact_ref``) on
    both paths."""
    if use_pallas:
        raw, fvalid, total = frontier_compact_pallas(
            cand, valid, f=f, interpret=interpret
        )
        return jnp.where(fvalid, raw, jnp.int32(scratch)), fvalid, total > f
    vi = valid.astype(jnp.int32)
    rank = jnp.cumsum(vi, axis=1, dtype=jnp.int32) - vi  # exclusive rank
    total = jnp.sum(vi, axis=1, dtype=jnp.int32)
    # one batched scatter: lane → its rank slot; invalid / overflow lanes
    # land in the dropped column f (duplicate writes there are discarded).
    idx = jnp.where(valid, jnp.minimum(rank, f), f)
    rows = jnp.broadcast_to(jnp.arange(cand.shape[0])[:, None], cand.shape)
    raw = (
        jnp.zeros((cand.shape[0], f + 1), jnp.int32)
        .at[rows, idx]
        .set(cand, mode="drop")[:, :f]
    )
    fvalid = jnp.arange(f, dtype=jnp.int32)[None, :] < total[:, None]
    return jnp.where(fvalid, raw, jnp.int32(scratch)), fvalid, total > f


__all__ = [
    "descend_probe",
    "descend_probe_pallas",
    "descend_probe_ref",
    "descend_ref",
    "probe_ref",
    "frontier_compact",
    "frontier_compact_pallas",
    "MAX_POOL_ROWS",
]
