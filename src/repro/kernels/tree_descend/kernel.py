"""Pallas TPU kernels: device-resident search phase.

Two kernels make the whole root-to-leaf search path device-resident:

``descend_probe_pallas`` — fused descent + probe.  The node pool's key /
value / child / leaf planes are mapped whole into VMEM with a constant
index map, so the hot upper levels of the tree stay pinned on-chip across
every grid step instead of being re-gathered from HBM once per level per
batch (the ``max_height`` separate batched gathers of the jnp path).  Each
level is one lane-parallel router count (``#routers ≤ key``) plus a child
gather out of the resident pool; the unsorted-leaf probe is fused into the
final level, so one kernel launch returns ``(leaf, found, slot, val)``.

``frontier_compact_pallas`` — segmented frontier compaction.  The scan
descent expands each query's frontier level by level; compacting the valid
candidates used a per-level stable XLA ``argsort`` (the "24× sort" — one
per level per scan round).  The kernel replaces the sort network with a
cumsum rank: each row's valid candidates get their exclusive prefix count,
and output slot ``c`` selects the candidate with rank ``c`` by masked sum —
stable, scatter-free, and VPU-friendly.  Output slots are processed in
chunks so the one-hot select never materializes an (M × f) plane wider
than ``chunk`` lanes.

Keys are int32 on device (TPU has no int64 vector support) — the tree's
64-bit host index takes the pure-jnp ref path; see ops.py for the narrow
gate.  VMEM contract: the pool planes must fit on-chip (~16 MB/core); the
dispatcher falls back to the ref path for pools past ``max_pool_rows``.

Dtype discipline: the host package enables jax_enable_x64, under which
integer reductions of int32 promote to int64 — every reduction here pins
``dtype=jnp.int32`` (the weak-typing trap that bit leaf_probe/elim_combine
in PR 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT32_MAX = jnp.iinfo(jnp.int32).max  # EMPTY sentinel for device keys


# ----------------------------------------------------------------------------
# fused descent + probe
# ----------------------------------------------------------------------------


def _descend_probe_kernel(
    pool_keys_ref, pool_vals_ref, children_ref, is_leaf_ref, start_ref, q_ref,
    leaf_ref, found_ref, slot_ref, val_ref,
    *, b: int, max_height: int,
):
    """One (TB,) query tile against the VMEM-resident pool."""
    pk = pool_keys_ref[...]  # (N, b) int32; EMPTY = INT32_MAX
    pv = pool_vals_ref[...]  # (N, b) int32
    ch = children_ref[...]  # (N, b) int32; NULL < 0 wraps to scratch
    lf = is_leaf_ref[...]  # (N, 1) int32
    q = q_ref[...]  # (TB, 1) int32
    node0 = start_ref[...][:, 0]  # (TB,) int32 (root broadcast)

    # mode="wrap" mirrors the jnp path's negative-index gather: NULL child
    # ids (-1) park the lane on the scratch row (an empty pseudo-leaf).
    def rows_at(arr, idx):
        return jnp.take(arr, idx, axis=0, mode="wrap")

    def body(_, node):
        routers = rows_at(pk, node)[:, : b - 1]  # (TB, b-1)
        idx = jnp.sum((routers <= q).astype(jnp.int32), axis=1, dtype=jnp.int32)
        child = jnp.take_along_axis(rows_at(ch, node), idx[:, None], axis=1)[:, 0]
        return jnp.where(rows_at(lf, node)[:, 0] > 0, node, child)

    node = jax.lax.fori_loop(0, max_height, body, node0)

    # fused unsorted-leaf probe on the final level's resident rows.
    rows = rows_at(pk, node)  # (TB, b)
    vals = rows_at(pv, node)
    eq = rows == q
    iota = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    slot = jnp.min(jnp.where(eq, iota, jnp.int32(b)), axis=1)  # first match
    found = slot < b
    val = jnp.sum(
        jnp.where(iota == slot[:, None], vals, 0), axis=1, dtype=jnp.int32
    )
    leaf_ref[...] = node[:, None]
    found_ref[...] = found.astype(jnp.int32)[:, None]
    slot_ref[...] = jnp.where(found, slot, 0).astype(jnp.int32)[:, None]
    val_ref[...] = jnp.where(found, val, 0).astype(jnp.int32)[:, None]


@functools.partial(
    jax.jit, static_argnames=("max_height", "block_b", "interpret")
)
def descend_probe_pallas(
    pool_keys: jax.Array,  # (N, b) int32, EMPTY = INT32_MAX
    pool_vals: jax.Array,  # (N, b) int32
    children: jax.Array,  # (N, b) int32
    is_leaf: jax.Array,  # (N,) bool
    root,  # int32 scalar
    queries: jax.Array,  # (B,) int32
    *,
    max_height: int,
    block_b: int = 256,
    interpret: bool = True,
):
    """Returns ``(leaf_ids (B,), found (B,), slot (B,), val (B,))`` —
    exactly the jnp ``descend_probe_ref`` semantics on int32 keys (``val``
    raw int32; the dispatcher applies the NOTFOUND sentinel)."""
    bsz = queries.shape[0]
    n, b = pool_keys.shape
    m = max(8, 1 << (max(bsz, 1) - 1).bit_length())  # pow2 pad (≥ one VREG row)
    block = min(block_b, m)
    m = m if m % block == 0 else m + (-m) % block
    if m != bsz:
        queries = jnp.pad(queries, (0, m - bsz), constant_values=INT32_MAX)
    start = jnp.full((m, 1), root, jnp.int32)
    grid = (m // block,)
    pool_spec = lambda w: pl.BlockSpec((n, w), lambda i: (0, 0))  # pinned
    out_shape = [jax.ShapeDtypeStruct((m, 1), jnp.int32) for _ in range(4)]
    leaf, found, slot, val = pl.pallas_call(
        functools.partial(_descend_probe_kernel, b=b, max_height=max_height),
        grid=grid,
        in_specs=[
            pool_spec(b),  # keys: whole pool resident across grid steps
            pool_spec(b),  # vals
            pool_spec(b),  # children
            pool_spec(1),  # is_leaf
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((block, 1), lambda i: (i, 0)) for _ in range(4)],
        out_shape=out_shape,
        interpret=interpret,
    )(
        pool_keys,
        pool_vals,
        children,
        is_leaf.astype(jnp.int32)[:, None],
        start,
        queries[:, None],
    )
    return (
        leaf[:bsz, 0],
        found[:bsz, 0].astype(bool),
        slot[:bsz, 0],
        val[:bsz, 0],
    )


# ----------------------------------------------------------------------------
# segmented frontier compaction
# ----------------------------------------------------------------------------


def _frontier_compact_kernel(
    cand_ref, valid_ref, frontier_ref, fvalid_ref, total_ref,
    *, f: int, chunk: int,
):
    """One (TB, M) tile: exclusive cumsum rank + chunked one-hot select."""
    cand = cand_ref[...]  # (TB, M) int32
    valid = valid_ref[...] > 0  # (TB, M)
    vi = valid.astype(jnp.int32)
    rank = jnp.cumsum(vi, axis=1, dtype=jnp.int32) - vi  # exclusive rank
    total = jnp.sum(vi, axis=1, keepdims=True, dtype=jnp.int32)

    outs_k, outs_hit = [], []
    tb, m = cand.shape
    for c0 in range(0, f, chunk):  # static unroll: ≤ f/chunk select planes
        cw = min(chunk, f - c0)
        c_iota = jax.lax.broadcasted_iota(jnp.int32, (tb, m, cw), 2) + c0
        sel = valid[:, :, None] & (rank[:, :, None] == c_iota)  # (TB, M, cw)
        outs_hit.append(
            jnp.sum(sel.astype(jnp.int32), axis=1, dtype=jnp.int32) > 0
        )
        outs_k.append(
            jnp.sum(jnp.where(sel, cand[:, :, None], 0), axis=1, dtype=jnp.int32)
        )
    frontier_ref[...] = jnp.concatenate(outs_k, axis=1)
    fvalid_ref[...] = jnp.concatenate(outs_hit, axis=1).astype(jnp.int32)
    total_ref[...] = total


@functools.partial(
    jax.jit, static_argnames=("f", "block_b", "chunk", "interpret")
)
def frontier_compact_pallas(
    cand: jax.Array,  # (B, M) int32 candidate ids
    valid: jax.Array,  # (B, M) bool
    *,
    f: int,
    block_b: int = 8,
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns ``(frontier (B, f) int32, valid (B, f) bool, total (B,))``:
    row-stable compaction of the valid candidates (invalid output slots are
    0 — callers mask them via the returned valid plane)."""
    bsz, m = cand.shape
    pad = (-bsz) % block_b
    if pad:
        cand = jnp.pad(cand, ((0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, pad), (0, 0)))
    mb = cand.shape[0]
    grid = (mb // block_b,)
    out_shape = [
        jax.ShapeDtypeStruct((mb, f), jnp.int32),  # frontier
        jax.ShapeDtypeStruct((mb, f), jnp.int32),  # valid
        jax.ShapeDtypeStruct((mb, 1), jnp.int32),  # total
    ]
    frontier, fvalid, total = pl.pallas_call(
        functools.partial(_frontier_compact_kernel, f=f, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(cand, valid.astype(jnp.int32))
    return frontier[:bsz], fvalid[:bsz].astype(bool), total[:bsz, 0]
