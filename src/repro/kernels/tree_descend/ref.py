"""Pure-jnp oracles for the tree_descend kernels.

Array-based (no ``TreeState`` dependency) and dtype-generic: the tree's
int64 host index and the kernel's int32 device keys both route through
these.  ``core/abtree.py``'s ``descend``/``probe`` are thin wrappers over
``descend_ref``/``probe_ref``, so the oracle and the host path can never
drift.

Sentinel conventions match the tree: the key dtype's max is the EMPTY
free-slot / unused-router marker (it sorts last and is never a user key);
NULL child ids are negative and wrap to the scratch row under gather, which
is how masked-out lanes park on the write-off node.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def descend_ref(
    pool_keys: jax.Array,  # (N, b) leaf keys | internal routers in [:, :b-1]
    children: jax.Array,  # (N, b) int32 child ids
    is_leaf: jax.Array,  # (N,) bool
    root,  # int32 scalar
    queries: jax.Array,  # (B,) key dtype
    *,
    max_height: int,
) -> jax.Array:
    """Root-to-leaf search: per level follow ``ptrs[#routers ≤ key]``
    (unused routers are EMPTY = dtype max, never counted for user keys)."""
    b = pool_keys.shape[-1]

    def body(_, node_ids):
        routers = pool_keys[node_ids][:, : b - 1]
        idx = jnp.sum(routers <= queries[:, None], axis=1).astype(jnp.int32)
        child = children[node_ids, idx]
        return jnp.where(is_leaf[node_ids], node_ids, child)

    start = jnp.zeros(queries.shape, jnp.int32) + root
    return jax.lax.fori_loop(0, max_height, body, start)


def probe_ref(
    pool_keys: jax.Array,  # (N, b)
    pool_vals: jax.Array,  # (N, b)
    leaf_ids: jax.Array,  # (B,) int32
    queries: jax.Array,  # (B,)
    *,
    notfound,
):
    """Unsorted-leaf probe: lane-parallel compare across the b slots;
    ``slot`` is the first match (0 when absent, masked by ``found``)."""
    rows = pool_keys[leaf_ids]
    eq = rows == queries[:, None]
    found = jnp.any(eq, axis=1)
    slot = jnp.argmax(eq, axis=1).astype(jnp.int32)
    val = pool_vals[leaf_ids, slot]
    return found, slot, jnp.where(found, val, notfound)


def descend_probe_ref(
    pool_keys: jax.Array,
    pool_vals: jax.Array,
    children: jax.Array,
    is_leaf: jax.Array,
    root,
    queries: jax.Array,
    *,
    max_height: int,
    notfound,
):
    """Fused oracle: descent followed by the leaf probe (the ``search``
    phase of one round for a batch of point ops)."""
    leaf_ids = descend_ref(
        pool_keys, children, is_leaf, root, queries, max_height=max_height
    )
    found, slot, val = probe_ref(
        pool_keys, pool_vals, leaf_ids, queries, notfound=notfound
    )
    return leaf_ids, found, slot, val


def frontier_compact_ref(
    cand: jax.Array,  # (B, M) int32 candidate node ids
    valid: jax.Array,  # (B, M) bool
    f: int,  # static output frontier width
    *,
    scratch: int,
):
    """Stable compaction oracle (the XLA-argsort formulation the kernel
    replaces): valid candidates keep their order and land in slots
    ``0..total-1``; invalid output slots hold ``scratch``.

    Returns ``(frontier (B, f) int32, valid (B, f) bool, overflow (B,))``
    with ``overflow`` marking rows whose valid count exceeded ``f``.
    """
    order = jnp.argsort(~valid, axis=1, stable=True).astype(jnp.int32)
    frontier = jnp.take_along_axis(cand, order, axis=1)[:, :f].astype(jnp.int32)
    valid_out = jnp.take_along_axis(valid, order, axis=1)[:, :f]
    total = jnp.sum(valid, axis=1)
    return (
        jnp.where(valid_out, frontier, jnp.int32(scratch)),
        valid_out,
        total > f,
    )
