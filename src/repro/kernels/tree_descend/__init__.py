from repro.kernels.tree_descend.kernel import (
    descend_probe_pallas,
    frontier_compact_pallas,
)
from repro.kernels.tree_descend.ops import descend_probe, frontier_compact
from repro.kernels.tree_descend.ref import (
    descend_probe_ref,
    descend_ref,
    frontier_compact_ref,
    probe_ref,
)

__all__ = [
    "descend_probe",
    "descend_probe_pallas",
    "descend_probe_ref",
    "descend_ref",
    "frontier_compact",
    "frontier_compact_pallas",
    "frontier_compact_ref",
    "probe_ref",
]
