from repro.kernels.leaf_probe.kernel import leaf_probe_pallas
from repro.kernels.leaf_probe.ops import leaf_probe, leaf_probe_i64
from repro.kernels.leaf_probe.ref import leaf_probe_ref

__all__ = ["leaf_probe", "leaf_probe_i64", "leaf_probe_pallas", "leaf_probe_ref"]
