"""jit'd public wrapper for leaf_probe: gathers leaf rows from the node pool
then runs the Pallas probe (or the jnp oracle when use_pallas=False).

64-bit host keys are probed as (hi, lo) int32 pairs: two compares + AND —
the TPU-native encoding of the paper's 8-byte keys (DESIGN.md §2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.leaf_probe.kernel import leaf_probe_pallas
from repro.kernels.leaf_probe.ref import leaf_probe_ref


def leaf_probe(
    leaf_keys: jax.Array,
    leaf_vals: jax.Array,
    queries: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
):
    if use_pallas:
        return leaf_probe_pallas(leaf_keys, leaf_vals, queries, interpret=interpret)
    return leaf_probe_ref(leaf_keys, leaf_vals, queries)


def leaf_probe_i64(
    leaf_keys64: jax.Array,  # (B, b) int64
    leaf_vals32: jax.Array,  # (B, b) int32
    queries64: jax.Array,  # (B,) int64
    *,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Probe 64-bit keys via hi/lo split: slot matches iff both halves match.
    Returns (slot, val) with slot = -1 when absent."""
    hi = (leaf_keys64 >> 32).astype(jnp.int32)
    lo = (leaf_keys64 & 0xFFFFFFFF).astype(jnp.int32)
    qhi = (queries64 >> 32).astype(jnp.int32)
    qlo = (queries64 & 0xFFFFFFFF).astype(jnp.int32)
    b = leaf_keys64.shape[1]
    # compare lo; verify hi at the matched slot.  Duplicated lo halves across
    # slots are possible, so match on a combined predicate instead: encode
    # slot-match as (hi match) & (lo match) with a two-plane probe.
    eq = (hi == qhi[:, None]) & (lo == qlo[:, None])
    # reuse the kernel on a synthesized 1/0 plane: probe for value 1
    plane = eq.astype(jnp.int32)
    slot, val = leaf_probe(
        plane, leaf_vals32, jnp.ones_like(qlo), use_pallas=use_pallas, interpret=interpret
    )
    del b
    return slot, val
