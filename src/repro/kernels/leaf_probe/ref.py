"""Pure-jnp oracle for the leaf_probe kernel."""
import jax
import jax.numpy as jnp


def leaf_probe_ref(leaf_keys: jax.Array, leaf_vals: jax.Array, queries: jax.Array):
    eq = leaf_keys == queries[:, None]
    found = jnp.any(eq, axis=1)
    slot = jnp.argmax(eq, axis=1).astype(jnp.int32)
    val = jnp.take_along_axis(leaf_vals, slot[:, None], axis=1)[:, 0]
    return (
        jnp.where(found, slot, jnp.int32(-1)),
        jnp.where(found, val, jnp.int32(0)),
    )
