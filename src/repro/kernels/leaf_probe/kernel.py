"""Pallas TPU kernel: batched unsorted-leaf probe.

The paper's key structural choice — *unsorted leaves* — maps directly onto
the TPU VPU: probing a leaf is a lane-parallel compare of the query key
against all b slots (one VREG op for b ≤ 128), followed by a masked
reduction.  A CPU implementation scans slot-by-slot; the TPU-native form
compares the whole leaf at once.  This kernel probes a *batch* of
(leaf row, key) pairs, the shape used by the round's search phase and by
the serving engine's page-table lookups.

Layout: leaf key rows are gathered (HBM → VMEM tiles of (TB, b)) by the
caller; the kernel is the compare/select hot loop.  Keys are int32 on
device (TPU has no int64 vector support; the host index uses int64 — 64-bit
keys are split hi/lo by ops.py when needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _probe_kernel(leaf_keys_ref, leaf_vals_ref, query_ref, slot_ref, val_ref, *, b: int):
    """One (TB, b) tile: lane-parallel compare + masked argmin reduction."""
    rows = leaf_keys_ref[...]  # (TB, b) int32
    vals = leaf_vals_ref[...]  # (TB, b) int32
    q = query_ref[...]  # (TB, 1) int32
    eq = rows == q  # broadcast compare across slots (VPU)
    # slot = first matching index; b+1 ⇒ not found
    iota = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    slot = jnp.min(jnp.where(eq, iota, jnp.int32(b + 1)), axis=1, keepdims=True)
    found = slot < b + 1
    # select value at slot (masked sum avoids a gather)
    sel = iota == slot
    # dtype pinned: under jax_enable_x64 an un-pinned int32 sum promotes to
    # int64 and the store into the int32 output ref fails.
    val = jnp.sum(jnp.where(sel, vals, 0), axis=1, keepdims=True, dtype=jnp.int32)
    slot_ref[...] = jnp.where(found, slot, -1)
    val_ref[...] = jnp.where(found, val, jnp.int32(0))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def leaf_probe_pallas(
    leaf_keys: jax.Array,  # (B, b) int32 — gathered leaf key rows
    leaf_vals: jax.Array,  # (B, b) int32
    queries: jax.Array,  # (B,) int32
    *,
    block_b: int = 256,
    interpret: bool = True,
):
    bsz, b = leaf_keys.shape
    pad = (-bsz) % block_b
    if pad:
        leaf_keys = jnp.pad(leaf_keys, ((0, pad), (0, 0)), constant_values=0)
        leaf_vals = jnp.pad(leaf_vals, ((0, pad), (0, 0)))
        queries = jnp.pad(queries, (0, pad), constant_values=-1)
    n = leaf_keys.shape[0]
    grid = (n // block_b,)
    out_shape = [
        jax.ShapeDtypeStruct((n, 1), jnp.int32),  # slot
        jax.ShapeDtypeStruct((n, 1), jnp.int32),  # val
    ]
    slot, val = pl.pallas_call(
        functools.partial(_probe_kernel, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, b), lambda i: (i, 0)),
            pl.BlockSpec((block_b, b), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(leaf_keys, leaf_vals, queries[:, None])
    return slot[:bsz, 0], val[:bsz, 0]
