from repro.kernels.range_scan.kernel import range_scan_pallas
from repro.kernels.range_scan.ops import range_scan
from repro.kernels.range_scan.ref import range_scan_ref

__all__ = ["range_scan", "range_scan_pallas", "range_scan_ref"]
