"""Pallas TPU kernel: batched range-scan gather over unsorted leaf slots.

The tree's unsorted leaves make a range scan a *mask + compact* problem: the
leaf frontier for a query ``[lo, hi)`` is gathered by the caller (HBM → VMEM
rows, exactly the ``leaf_probe`` layout) and flattened to ``n`` candidate
slots per query; the kernel then

  1. lane-parallel compares every candidate against the interval (one VPU
     op per VREG of slots),
  2. compacts the matches into a fixed-capacity, *ascending* output via
     rank-selection: the rank of a matching key is the number of smaller
     matching keys, computed as a masked pairwise compare-reduce.  Output
     lane ``c`` then selects the key with rank ``c`` by masked sum — no
     scatter, no sort network, all VPU-friendly ops.

The pairwise rank is O(n²) per query.  For small frontiers (n = a few
hundred candidate slots) the full (n, n) compare runs at VREG width and the
kernel stays memory-bound on the leaf gather; for large frontiers the
quadratic plane blows past VMEM, so ``tile_n`` blocks the rank into
(n/T)×(n/T) VREG tiles — per-tile partial ranks accumulate into the same
integer rank vector (exact: sums of disjoint 0/1 tiles), and the rank-c
selection walks candidate tiles the same way, so peak live memory drops
from n² to n·T while staying bit-identical to the pairwise kernel.  Keys
are int32 on device (TPU has no int64 vector support — the tree's 64-bit
keys take the pure-jnp ref path; see ops.py).

Dtype discipline: the host package enables jax_enable_x64, under which
integer reductions of int32 promote to int64 — every reduction here pins
``dtype=jnp.int32`` so stores match the int32 output refs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT32_MAX = jnp.iinfo(jnp.int32).max  # EMPTY sentinel for device keys


def _range_scan_kernel(
    cand_keys_ref, cand_vals_ref, lo_ref, hi_ref,
    keys_ref, vals_ref, count_ref, trunc_ref,
    *, cap: int,
):
    """One (TB, n) tile: interval mask + rank-select compaction."""
    rows = cand_keys_ref[...]  # (TB, n) int32
    vals = cand_vals_ref[...]  # (TB, n) int32
    lo = lo_ref[...]  # (TB, 1)
    hi = hi_ref[...]  # (TB, 1)

    match = (rows >= lo) & (rows < hi) & (rows != INT32_MAX)  # (TB, n)
    key_m = jnp.where(match, rows, INT32_MAX)

    # rank of each matching key = #matching keys strictly smaller (keys are
    # unique within a tree, and non-matches sit at INT32_MAX, never smaller).
    lt = key_m[:, :, None] > key_m[:, None, :]  # (TB, n, n): j smaller than i
    rank = jnp.sum(lt.astype(jnp.int32), axis=2, dtype=jnp.int32)  # (TB, n)

    # output lane c takes the key of rank c (masked sum — no gather/scatter).
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (rows.shape[0], rows.shape[1], cap), 2)
    sel = match[:, :, None] & (rank[:, :, None] == c_iota)  # (TB, n, cap)
    hit = jnp.sum(sel.astype(jnp.int32), axis=1, dtype=jnp.int32) > 0  # (TB, cap)
    out_k = jnp.sum(jnp.where(sel, rows[:, :, None], jnp.int32(0)), axis=1, dtype=jnp.int32)
    out_v = jnp.sum(jnp.where(sel, vals[:, :, None], jnp.int32(0)), axis=1, dtype=jnp.int32)

    total = jnp.sum(match.astype(jnp.int32), axis=1, keepdims=True, dtype=jnp.int32)
    keys_ref[...] = jnp.where(hit, out_k, jnp.int32(INT32_MAX))
    vals_ref[...] = jnp.where(hit, out_v, jnp.int32(0))
    count_ref[...] = jnp.minimum(total, jnp.int32(cap))
    trunc_ref[...] = (total > cap).astype(jnp.int32)


def _range_scan_kernel_tiled(
    cand_keys_ref, cand_vals_ref, lo_ref, hi_ref,
    keys_ref, vals_ref, count_ref, trunc_ref,
    *, cap: int, tile_n: int,
):
    """One (TB, n) tile with the rank blocked into (n/T)×(n/T) sub-tiles:
    bit-identical outputs to ``_range_scan_kernel`` at n·T peak memory."""
    rows = cand_keys_ref[...]  # (TB, n) int32
    vals = cand_vals_ref[...]  # (TB, n) int32
    lo = lo_ref[...]  # (TB, 1)
    hi = hi_ref[...]  # (TB, 1)
    tb, n = rows.shape
    n_tiles = n // tile_n

    match = (rows >= lo) & (rows < hi) & (rows != INT32_MAX)  # (TB, n)
    key_m = jnp.where(match, rows, INT32_MAX)

    # rank accumulation: tile t contributes #{j ∈ tile : key_m[j] < key_m[i]}
    # — integer partial sums, so tiling is exact (same rank as pairwise).
    def rank_tile(t, acc):
        tile = jax.lax.dynamic_slice_in_dim(key_m, t * tile_n, tile_n, axis=1)
        gt = key_m[:, :, None] > tile[:, None, :]  # (TB, n, T)
        return acc + jnp.sum(gt.astype(jnp.int32), axis=2, dtype=jnp.int32)

    rank = jax.lax.fori_loop(0, n_tiles, rank_tile, jnp.zeros((tb, n), jnp.int32))

    # rank-c selection, also walked tile by tile: each output lane sums at
    # most one candidate across all tiles (ranks of matches are unique).
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (tb, tile_n, cap), 2)

    def sel_tile(t, carry):
        hit, out_k, out_v = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, t * tile_n, tile_n, axis=1)
        sel = sl(match)[:, :, None] & (sl(rank)[:, :, None] == c_iota)  # (TB,T,cap)
        hit = hit + jnp.sum(sel.astype(jnp.int32), axis=1, dtype=jnp.int32)
        out_k = out_k + jnp.sum(
            jnp.where(sel, sl(rows)[:, :, None], 0), axis=1, dtype=jnp.int32
        )
        out_v = out_v + jnp.sum(
            jnp.where(sel, sl(vals)[:, :, None], 0), axis=1, dtype=jnp.int32
        )
        return hit, out_k, out_v

    z = jnp.zeros((tb, cap), jnp.int32)
    hit, out_k, out_v = jax.lax.fori_loop(0, n_tiles, sel_tile, (z, z, z))
    hit = hit > 0

    total = jnp.sum(match.astype(jnp.int32), axis=1, keepdims=True, dtype=jnp.int32)
    keys_ref[...] = jnp.where(hit, out_k, jnp.int32(INT32_MAX))
    vals_ref[...] = jnp.where(hit, out_v, jnp.int32(0))
    count_ref[...] = jnp.minimum(total, jnp.int32(cap))
    trunc_ref[...] = (total > cap).astype(jnp.int32)


# Candidate widths past this auto-route to the tiled kernel (the pairwise
# (n, n) plane at 512² × 4 B ≈ 1 MB/row-block is where VMEM pressure starts).
TILE_AUTO_THRESHOLD = 256
_DEFAULT_TILE = 128


@functools.partial(
    jax.jit, static_argnames=("cap", "block_b", "tile_n", "interpret")
)
def range_scan_pallas(
    cand_keys: jax.Array,  # (B, n) int32 gathered leaf slots, INT32_MAX-padded
    cand_vals: jax.Array,  # (B, n) int32
    lo: jax.Array,  # (B,) int32 inclusive
    hi: jax.Array,  # (B,) int32 exclusive
    *,
    cap: int = 128,
    block_b: int = 8,
    tile_n: int = 0,
    interpret: bool = True,
):
    """Returns ``(keys (B,cap), vals (B,cap), count (B,), truncated (B,))``
    with keys ascending and INT32_MAX-padded.

    ``tile_n`` selects the rank-select variant: 0 (default) auto-routes —
    pairwise for n ≤ ``TILE_AUTO_THRESHOLD``, tiled otherwise; a positive
    value forces that tile width; -1 forces the pairwise kernel."""
    bsz, n = cand_keys.shape
    if tile_n == 0:
        tile_n = _DEFAULT_TILE if n > TILE_AUTO_THRESHOLD else -1
    if tile_n > 0:
        pad_n = (-n) % tile_n
        if pad_n:  # INT32_MAX pad: never matches, never outranks a real key
            cand_keys = jnp.pad(
                cand_keys, ((0, 0), (0, pad_n)), constant_values=INT32_MAX
            )
            cand_vals = jnp.pad(cand_vals, ((0, 0), (0, pad_n)))
        n = cand_keys.shape[1]
        kernel = functools.partial(
            _range_scan_kernel_tiled, cap=cap, tile_n=tile_n
        )
    else:
        kernel = functools.partial(_range_scan_kernel, cap=cap)
    pad = (-bsz) % block_b
    if pad:
        cand_keys = jnp.pad(cand_keys, ((0, pad), (0, 0)), constant_values=INT32_MAX)
        cand_vals = jnp.pad(cand_vals, ((0, pad), (0, 0)))
        lo = jnp.pad(lo, (0, pad))
        hi = jnp.pad(hi, (0, pad))
    m = cand_keys.shape[0]
    grid = (m // block_b,)
    out_shape = [
        jax.ShapeDtypeStruct((m, cap), jnp.int32),  # keys
        jax.ShapeDtypeStruct((m, cap), jnp.int32),  # vals
        jax.ShapeDtypeStruct((m, 1), jnp.int32),  # count
        jax.ShapeDtypeStruct((m, 1), jnp.int32),  # truncated
    ]
    keys, vals, count, trunc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, cap), lambda i: (i, 0)),
            pl.BlockSpec((block_b, cap), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(cand_keys, cand_vals, lo[:, None].astype(jnp.int32), hi[:, None].astype(jnp.int32))
    return (
        keys[:bsz],
        vals[:bsz],
        count[:bsz, 0],
        trunc[:bsz, 0].astype(bool),
    )
