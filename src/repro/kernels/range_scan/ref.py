"""Pure-jnp oracle for the range_scan kernel.

Dtype-generic (works on the tree's int64 keys as well as the kernel's
int32 device keys): the EMPTY sentinel is derived from the key dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def range_scan_ref(
    cand_keys: jax.Array,  # (B, n) gathered leaf slots, EMPTY-padded
    cand_vals: jax.Array,  # (B, n)
    lo: jax.Array,  # (B,) inclusive lower bound
    hi: jax.Array,  # (B,) exclusive upper bound
    cap: int,  # static output capacity per query
):
    """Select the ≤ ``cap`` smallest candidate keys in [lo, hi) per query.

    Returns ``(keys, vals, count, truncated)``:
      keys      (B, cap) — ascending, EMPTY-padded
      vals      (B, cap) — 0 where the key slot is EMPTY
      count     (B,) int32 — number of emitted entries (≤ cap)
      truncated (B,) bool — more than ``cap`` keys matched
    """
    empty = jnp.iinfo(cand_keys.dtype).max
    match = (cand_keys >= lo[:, None]) & (cand_keys < hi[:, None]) & (cand_keys != empty)
    key_m = jnp.where(match, cand_keys, empty)
    order = jnp.argsort(key_m, axis=1, stable=True).astype(jnp.int32)
    sk = jnp.take_along_axis(key_m, order, axis=1)[:, :cap]
    sv = jnp.take_along_axis(cand_vals, order, axis=1)[:, :cap]
    if sk.shape[1] < cap:  # fewer candidates than cap: keep the (B, cap) contract
        pad = ((0, 0), (0, cap - sk.shape[1]))
        sk = jnp.pad(sk, pad, constant_values=int(empty))
        sv = jnp.pad(sv, pad)
    emitted = sk != empty
    total = jnp.sum(match, axis=1).astype(jnp.int32)
    return (
        sk,
        jnp.where(emitted, sv, jnp.zeros_like(sv)),
        jnp.minimum(total, jnp.int32(cap)),
        total > cap,
    )
