"""Public wrapper for range_scan: dispatches between the Pallas kernel
(int32 device keys) and the dtype-generic jnp reference.

The tree's host index uses int64 keys; the TPU kernel operates on int32
lanes (no int64 vector support).  ``range_scan`` therefore routes int64
candidates to the reference implementation unless the caller asserts the
keys lie strictly inside the int32 range (``narrow=True`` casts and uses
the kernel).  The round engine's scan phase (``core/rounds.py``, serving
both ``scan_round`` and fused mixed-op rounds) calls this wrapper from
inside its jitted gather: the tree's int64 host index takes the ref path,
while int32 device keys and bounded-key serving/benchmark paths take the
kernel.

Narrow-path key domain: user keys must satisfy ``-2**31 < k < 2**31 - 1``.
``INT32_MAX`` itself is the kernel's EMPTY sentinel (exactly as the tree
reserves the int64 max as its own EMPTY) — a key equal to 2**31 - 1 would
be conflated with an empty slot and silently dropped, so callers with an
unbounded key space must leave ``narrow=False``.  ``lo``/``hi`` bounds are
clipped into the int32 range, which under this contract excludes no valid
key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.range_scan.kernel import INT32_MAX, range_scan_pallas
from repro.kernels.range_scan.ref import range_scan_ref


def range_scan(
    cand_keys: jax.Array,  # (B, n) EMPTY-padded gathered leaf slots
    cand_vals: jax.Array,  # (B, n)
    lo: jax.Array,  # (B,)
    hi: jax.Array,  # (B,)
    *,
    cap: int = 128,
    use_pallas: bool = True,
    narrow: bool = False,
    interpret: bool = True,
):
    """Fixed-capacity ascending gather of candidate keys in [lo, hi).

    Returns ``(keys, vals, count, truncated)``; see ref.py for semantics.
    """
    if use_pallas and (narrow or cand_keys.dtype == jnp.int32):
        empty = jnp.iinfo(cand_keys.dtype).max
        ck = jnp.where(cand_keys == empty, INT32_MAX, cand_keys).astype(jnp.int32)
        keys, vals, count, trunc = range_scan_pallas(
            ck,
            cand_vals.astype(jnp.int32),
            jnp.clip(lo, -INT32_MAX, INT32_MAX).astype(jnp.int32),
            jnp.clip(hi, -INT32_MAX, INT32_MAX).astype(jnp.int32),
            cap=cap,
            interpret=interpret,
        )
        # widen back to the caller's dtypes, restoring the EMPTY sentinel
        out_keys = jnp.where(
            keys == INT32_MAX, empty, keys.astype(cand_keys.dtype)
        )
        return out_keys, vals.astype(cand_vals.dtype), count, trunc
    return range_scan_ref(cand_keys, cand_vals, lo, hi, cap)
