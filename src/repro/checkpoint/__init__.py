from repro.checkpoint.manager import CheckpointManager, latest_step, restore

__all__ = ["CheckpointManager", "restore", "latest_step"]
