"""Durable training checkpoints — the paper's link-and-persist protocol
(core/durable.py) applied to the framework's train state.

Protocol per checkpoint step:
  1. write every pytree leaf to `step_<n>.tmp/<leaf>.npy` + fsync  (flush)
  2. fsync the tmp dir, os.replace → `step_<n>/`                   (link)
  3. write MANIFEST.tmp naming the step, fsync, os.replace → MANIFEST,
     fsync dir                                                     (persist)

A crash at any point recovers to the last committed manifest — the same
strict-linearizability argument as §5 of the paper (uncommitted steps left
no externally visible effect; committed steps are durable).

Checkpoints are **mesh-agnostic** (elastic): leaves are stored as global
host arrays; `restore(..., shardings=...)` re-device_puts them under any
mesh whose axes divide the shapes — scale-up/down across restarts.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree, prefix=""):
    flat = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            flat.update(_flatten(v, f"{prefix}{k}."))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            flat.update(_flatten(getattr(tree, k), f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            flat.update(_flatten(v, f"{prefix}{i}."))
    else:
        flat[prefix[:-1]] = tree
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        flat = _flatten(state)
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        index = {}
        for name, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            fn = re.sub(r"[^\w.]", "_", name) + ".npy"
            with open(os.path.join(tmp, fn), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())  # flush before link
            index[name] = fn
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump({"index": index, "extra": extra or {}}, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)  # link
        # persist: manifest commit
        mtmp = os.path.join(self.dir, "MANIFEST.tmp")
        with open(mtmp, "w") as f:
            json.dump({"latest_step": step}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, os.path.join(self.dir, "MANIFEST"))
        _fsync_dir(self.dir)
        self._gc(step)

    def _gc(self, latest: int):
        steps = sorted(self._steps())
        for s in steps[: -self.keep]:
            if s != latest:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def _steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return out


def latest_step(directory: str) -> Optional[int]:
    mpath = os.path.join(directory, "MANIFEST")
    if not os.path.exists(mpath):
        return None
    with open(mpath) as f:
        return json.load(f)["latest_step"]


def restore(directory: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Rebuild the pytree `like` (structure template) from a checkpoint.
    If `shardings` (matching pytree of NamedSharding) is given, leaves are
    device_put with those shardings — elastic re-scaling on restore."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)["index"]

    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for name in flat_like:
        arr = np.load(os.path.join(d, index[name]))
        if name in flat_shard and flat_shard[name] is not None:
            loaded[name] = jax.device_put(arr, flat_shard[name])
        else:
            loaded[name] = jax.numpy.asarray(arr)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}.") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(
                **{k: rebuild(getattr(tree, k), f"{prefix}{k}.") for k in tree._fields}
            )
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, f"{prefix}{i}.") for i, v in enumerate(tree))
        return loaded[prefix[:-1]]

    return rebuild(like)


def checkpoint_extra(directory: str, step: int) -> dict:
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        return json.load(f)["extra"]
