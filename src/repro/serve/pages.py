"""Paged KV-cache management with the Elim-ABtree as the prefix/session
index — the paper's data structure doing its production job.

The block manager is host-side control logic (as in vLLM); device memory
holds the page pool.  Two index workloads hit the tree:

  * **prefix cache**: hash-chain of token blocks → page id.  Skewed (hot
    system prompts dominate) and update-heavy under churn: the elimination
    path collapses repeated insert/delete of hot prefixes.
  * **session index**: request/session id → page-table id, constant churn
    at request granularity.

Both run as batched rounds (one round per scheduler tick), which is exactly
the tree's batch-concurrent API.  The durable variant journals the index so
a restarted engine recovers its prefix cache (warm restart).
"""
from __future__ import annotations

import hashlib
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.abtree import ABTree, OP_DELETE, OP_FIND, OP_INSERT, TreeConfig
from repro.core.durable import DurableForest, recover_forest
from repro.core.forest import ABForest

PAGE = 256  # tokens per KV page


def _hash_chain(prev: int, block_tokens: Tuple[int, ...]) -> int:
    h = hashlib.blake2b(digest_size=8)
    h.update(prev.to_bytes(8, "little", signed=False))
    h.update(np.asarray(block_tokens, np.int32).tobytes())
    # keep positive and below the tree's EMPTY sentinel
    return int.from_bytes(h.digest(), "little") >> 1


class PagedKVCache:
    """Fixed pool of KV pages + free list + per-request page tables."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free: List[int] = list(range(n_pages))
        self.page_tables: Dict[int, List[int]] = {}
        self.ref: np.ndarray = np.zeros(n_pages, np.int32)  # prefix sharing

    def alloc(self, rid: int, n: int) -> Optional[List[int]]:
        if len(self.free) < n:
            return None
        pages = [self.free.pop() for _ in range(n)]
        for p in pages:
            self.ref[p] += 1
        self.page_tables.setdefault(rid, []).extend(pages)
        return pages

    def share(self, rid: int, pages: List[int]):
        for p in pages:
            self.ref[p] += 1
        self.page_tables.setdefault(rid, []).extend(pages)

    def release(self, rid: int):
        for p in self.page_tables.pop(rid, []):
            self.ref[p] -= 1
            if self.ref[p] == 0:
                self.free.append(p)

    @property
    def used(self) -> int:
        return self.n_pages - len(self.free)


class PrefixIndex:
    """Prefix-block hash → page id, on the Elim-ABtree.

    ``shards > 1`` backs the index with a key-partitioned ``ABForest``
    instead of a single tree: every batched round routes through the
    forest's vmapped per-shard pipeline, so hot-prefix churn on one key
    range stops contending with the rest of the index.  ``key_space``
    seeds the shard split points (defaults to the full 63-bit hash
    domain; session-id indexes pass their id range instead).

    ``durable_dir`` backs the index with a ``DurableForest`` instead (any
    shard count, per-shard journals): every update round commits before
    its results are released, and a restarted engine pointing at the same
    directory recovers the index from the journal (warm restart) — shard
    count and split points come back from the manifest."""

    def __init__(
        self,
        mode: str = "elim",
        capacity: int = 1 << 14,
        *,
        shards: int = 1,
        key_space: Optional[Tuple[int, int]] = None,
        max_keys_per_shard: Optional[int] = None,
        durable_dir: Optional[str] = None,
        snapshot_every: int = 64,
        auto_repartition: bool = False,
        faults=None,
        group_commit_every: int = 1,
        group_commit_max_wait_s: float = 0.05,
        commit_async: bool = False,
    ):
        cfg = TreeConfig(capacity=capacity, b=8, a=2)
        if durable_dir is not None:
            if os.path.exists(os.path.join(durable_dir, "MANIFEST")):
                # warm restart; ``faults`` (a FaultPlan / CrashPoint) is
                # installed on the recovered journal for fault-soak runs
                self.tree = recover_forest(
                    durable_dir, faults=faults,
                    group_commit_every=group_commit_every,
                    group_commit_max_wait_s=group_commit_max_wait_s,
                    commit_async=commit_async,
                )
                # shard count / splits legitimately come from the manifest
                # (the forest may have re-partitioned); a mode switch would
                # silently change the durability discipline — refuse it.
                if self.tree.forest.mode != mode:
                    raise ValueError(
                        f"durable index at {durable_dir!r} was journaled in "
                        f"{self.tree.forest.mode!r} mode; requested {mode!r}"
                    )
            else:
                self.tree = DurableForest(
                    durable_dir, n_shards=shards, cfg=cfg, mode=mode,
                    snapshot_every=snapshot_every,
                    key_space=key_space if key_space is not None else (0, 1 << 63),
                    max_keys_per_shard=max_keys_per_shard,
                    auto_repartition=auto_repartition,
                    faults=faults,
                    group_commit_every=group_commit_every,
                    group_commit_max_wait_s=group_commit_max_wait_s,
                    commit_async=commit_async,
                )
        elif shards > 1:
            self.tree = ABForest(
                n_shards=shards, cfg=cfg, mode=mode,
                key_space=key_space if key_space is not None else (0, 1 << 63),
                max_keys_per_shard=max_keys_per_shard,
                auto_repartition=auto_repartition,
            )
        else:
            self.tree = ABTree(cfg, mode=mode)

    def lookup_batch(self, hashes: List[int]) -> List[Optional[int]]:
        if not hashes:
            return []
        out = self.tree.apply_round(
            [OP_FIND] * len(hashes), hashes, [0] * len(hashes)
        )
        res = np.asarray(out.results)
        fnd = np.asarray(out.found)
        return [int(r) if f else None for r, f in zip(res, fnd)]

    def publish_batch(self, hashes: List[int], pages: List[int]):
        if hashes:
            self.tree.apply_round([OP_INSERT] * len(hashes), hashes, pages)

    def evict_batch(self, hashes: List[int]):
        if hashes:
            self.tree.apply_round([OP_DELETE] * len(hashes), hashes, [0] * len(hashes))

    def stats(self) -> dict:
        return self.tree.stats()


class SessionIndex(PrefixIndex):
    """Session/request id → page-table id, on the batched tree.

    Batched point lookups/publishes are inherited from PrefixIndex (the
    keys are session ids rather than prefix hashes).  Session ids are
    allocated monotonically, so retired sessions pile up in a contiguous
    low range of the key space — eviction is therefore a *range*
    operation: ``evict_range`` collects AND removes every live session id
    in ``[lo, hi)`` with ONE fused scan+delete round per chunk (the round
    engine linearizes the scan before the round's deletes), replacing the
    per-key delete loop an id-keyed index would otherwise run on every
    sweep — and halving the round count of the former scan-round-then-
    delete-round sweep.

    With ``shards > 1`` the index is forest-backed; ``evict_range`` keeps
    its contract unchanged: the forest's ``scan_delete_round`` is ONE
    fused round per chunk even when ``[lo, hi)`` straddles shard
    boundaries (sub-lane scans are stitched in key order and only the
    emitted keys are deleted, so a truncated chunk leaves the remainder
    for the next sweep exactly as the single tree does).  ``key_space``
    should span the expected session-id range so monotone ids spread
    across shards; since ids are monotone, pair it with
    ``max_keys_per_shard`` so the forest re-partitions the live id range
    adaptively instead of relying on the static split points alone."""

    def __init__(
        self,
        mode: str = "elim",
        capacity: int = 1 << 12,
        *,
        shards: int = 1,
        key_space: Optional[Tuple[int, int]] = None,
        max_keys_per_shard: Optional[int] = None,
        durable_dir: Optional[str] = None,
        snapshot_every: int = 64,
        auto_repartition: bool = False,
        faults=None,
        group_commit_every: int = 1,
        group_commit_max_wait_s: float = 0.05,
        commit_async: bool = False,
    ):
        super().__init__(
            mode=mode, capacity=capacity, shards=shards, key_space=key_space,
            max_keys_per_shard=max_keys_per_shard, durable_dir=durable_dir,
            snapshot_every=snapshot_every, auto_repartition=auto_repartition,
            faults=faults, group_commit_every=group_commit_every,
            group_commit_max_wait_s=group_commit_max_wait_s,
            commit_async=commit_async,
        )

    def evict_range(self, lo: int, hi: int, cap: int = 256) -> List[int]:
        """Evict all sessions with lo ≤ rid < hi: one fused scan+delete
        round per ``cap``-sized chunk (loops only when > cap sessions
        match).  Returns the evicted (rid-sorted) page-table ids for the
        caller to free."""
        freed: List[int] = []
        while True:
            out = self.tree.scan_delete_round([lo], [hi], cap=cap)
            n = int(np.asarray(out.count)[0])
            if n == 0:
                return freed
            freed.extend(int(v) for v in np.asarray(out.vals)[0, :n])
            if not bool(np.asarray(out.truncated)[0]):
                return freed


def prefix_hashes(tokens: List[int]) -> List[Tuple[int, Tuple[int, ...]]]:
    """Hash-chain per full PAGE block of the prompt."""
    out = []
    prev = 0
    for i in range(0, len(tokens) - len(tokens) % PAGE, PAGE):
        block = tuple(tokens[i : i + PAGE])
        h = _hash_chain(prev, block)
        out.append((h, block))
        prev = h
    return out
