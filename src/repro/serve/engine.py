"""Continuous-batching serving engine.

Scheduler tick:
  1. admit waiting requests while KV pages are available; prefix-cache
     lookups are issued as ONE batched round against the Elim-ABtree index
     (hits share pages — ref-counted);
  2. run one fused decode step for all running requests (static max_batch
     slots; finished slots are masked) via the jitted serve_step;
  3. retire finished requests: their page-table pages are released and
     their prefix blocks (un)published in a second batched round — under
     session churn these rounds are the paper's skewed update-heavy
     workload.

The model step is exactly launch/serve_step; this module is the host-side
control plane (the part of the system vLLM calls the scheduler + block
manager)."""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import backbone, init_params
from repro.models.config import ModelConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import NULL_TRACER
from repro.serve.pages import (
    PAGE,
    PagedKVCache,
    PrefixIndex,
    SessionIndex,
    prefix_hashes,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None
    cache_hit_blocks: int = 0


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        max_batch: int = 8,
        s_max: int = 512,
        n_pages: int = 1024,
        index_mode: str = "elim",
        index_shards: int = 1,
        index_durable_dir: Optional[str] = None,
        index_faults=None,
        pipelined: bool = False,
        group_commit_every: int = 1,
        group_commit_max_wait_s: float = 0.05,
        commit_async: Optional[bool] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.max_batch = max_batch
        self.s_max = s_max
        # pipelined=True double-buffers the tick: round N's decode is
        # DISPATCHED (JAX async dispatch — no block), round N+1's
        # admit/classify work runs on the host while the device is busy,
        # and only then does the tick fence on the decode result.  The
        # host-work-under-flight fraction is the tick_overlap_frac gauge.
        self.pipelined = pipelined
        # group_commit_every > 1 batches that many index rounds per
        # manifest rename on BOTH journals; commit_async (default: on
        # whenever grouping is on) moves the boundary commit I/O to the
        # durable layer's background thread so no tick pays the fsyncs
        # inline.  run_until_done() drains pending groups at exit.
        if commit_async is None:
            commit_async = group_commit_every > 1
        self.params = init_params(backbone.model_spec(cfg))
        self.kv = PagedKVCache(n_pages)
        # index_shards > 1 partitions both indexes' key spaces into an
        # ABForest (one vmapped round per scheduler tick, per index).
        # Prefix hashes are uniform over the 63-bit domain, so static even
        # splits suffice; session ids are MONOTONE, so the static splits
        # alone would route every live id to one shard — max_keys_per_shard
        # makes the forest re-partition the live id range adaptively (live
        # sessions are bounded by the page pool, so n_pages is the scale).
        # index_durable_dir journals both indexes as DurableForests (one
        # journal lane per shard): a restarted engine pointing at the same
        # directory recovers its prefix cache warm.  index_faults (a
        # FaultPlan / CrashPoint) is installed on both journals; the
        # durable layer's retry + circuit breaker guarantee tick() never
        # raises on a sick disk — it degrades to volatile serving instead
        # (visible via stats()["durability"]).
        self.index = PrefixIndex(
            mode=index_mode,
            shards=index_shards,
            durable_dir=(
                None if index_durable_dir is None
                else os.path.join(index_durable_dir, "prefix")
            ),
            faults=index_faults,
            group_commit_every=group_commit_every,
            group_commit_max_wait_s=group_commit_max_wait_s,
            commit_async=commit_async,
        )
        self.sessions = SessionIndex(
            mode=index_mode,
            shards=index_shards,
            key_space=(0, 1 << 31),
            max_keys_per_shard=(
                None if index_shards == 1 else max(64, n_pages // index_shards)
            ),
            durable_dir=(
                None if index_durable_dir is None
                else os.path.join(index_durable_dir, "sessions")
            ),
            faults=index_faults,
            group_commit_every=group_commit_every,
            group_commit_max_wait_s=group_commit_max_wait_s,
            commit_async=commit_async,
        )
        # engine-level telemetry: tick latency + scheduler counters live in
        # the engine's own registry; the index holders keep theirs (round
        # phases, journal flushes) — stats() stitches both surfaces.
        self.metrics = MetricsRegistry()
        self._tracer = NULL_TRACER
        self._evict_floor = 0  # session ids below this are already swept
        self._retired_since_sweep = 0
        self._max_rid = -1  # highest session id ever admitted
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self.slots: List[Optional[int]] = [None] * max_batch  # slot → rid
        self.pos = np.zeros(max_batch, np.int64)
        self.cache = backbone.init_cache(cfg, max_batch, s_max)
        self.done: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, q: backbone.forward_decode(p, c, t, q, cfg)
        )
        self._prefill_tok = jax.jit(
            lambda p, c, t, q: backbone.forward_decode(p, c, t, q, cfg)
        )

    # ------------------------------------------------------------------ --

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, t):
        # one tracer for the whole stack: installing it here also times the
        # round-engine phases (and journal commits) under both indexes.
        self._tracer = t
        self.index.tree.tracer = t
        self.sessions.tree.tracer = t

    @property
    def recorder(self):
        """The prefix index's flight recorder (the audit-critical surface:
        publish/lookup rounds).  Assigning installs one recorder on BOTH
        index holders, mirroring the tracer's whole-stack convention."""
        return self.index.tree.recorder

    @recorder.setter
    def recorder(self, r):
        self.index.tree.recorder = r
        self.sessions.tree.recorder = r

    def submit(self, req: Request):
        req.t_submit = time.time()
        self.waiting.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting.pop(0)
            # prefix-cache lookup: one batched round per request admission
            chain = prefix_hashes(req.prompt)
            hits = self.index.lookup_batch([h for h, _ in chain])
            n_hit = 0
            for h in hits:
                if h is None:
                    break
                n_hit += 1
            req.cache_hit_blocks = n_hit
            self.metrics.inc("cache_hit_blocks", n_hit)
            need_pages = max(1, (len(req.prompt) + req.max_new + PAGE - 1) // PAGE)
            pages = self.kv.alloc(req.rid, need_pages)
            if pages is None:
                self.waiting.insert(0, req)
                return
            # publish the prompt's prefix blocks (batched insert round)
            self.index.publish_batch(
                [h for h, _ in chain[n_hit:]], pages[: len(chain) - n_hit] or [0]
            ) if chain[n_hit:] else None
            # session index: rid → first page of the request's page table
            self.sessions.publish_batch([req.rid], [pages[0]])
            self._max_rid = max(self._max_rid, req.rid)
            # teacher-forced prefill through the decode path (simple engine:
            # prompt tokens streamed token-by-token into the slot's cache)
            self.slots[slot] = req.rid
            self.running[req.rid] = req
            self.metrics.inc("admitted")
            self.pos[slot] = 0
            for tok in req.prompt[:-1]:
                self._step_slot(slot, tok)
            req._last_tok = req.prompt[-1]

    def _step_slot(self, slot: int, tok: int):
        tokens = np.zeros(self.max_batch, np.int32)
        tokens[slot] = tok
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.int32(int(self.pos[slot]))
        )
        self.pos[slot] += 1
        return logits

    def tick(self):
        """One scheduler iteration: admit + fused decode for all running.
        Pipelined mode dispatches the decode first and admits under it."""
        t0 = time.perf_counter()
        tr = self._tracer
        overlap = 0.0
        with tr.span("serve.tick"):
            if self.pipelined:
                overlap = self._tick_pipelined(tr)
            else:
                self._tick_body(tr)
        dt = time.perf_counter() - t0
        self.metrics.inc("ticks")
        self.metrics.observe("tick_latency_s", dt)
        if self.pipelined:
            frac = overlap / dt if dt > 0 else 0.0
            self.metrics.set_gauge("tick_overlap_frac", frac)
            self.metrics.observe("tick_overlap_frac", frac)

    def _tick_body(self, tr):
        with tr.span("serve.admit", waiting=len(self.waiting)):
            self._admit()
        active = [s for s in range(self.max_batch) if self.slots[s] is not None]
        if not active:
            return
        tokens = np.zeros(self.max_batch, np.int32)
        for s in active:
            req = self.running[self.slots[s]]
            tokens[s] = getattr(req, "_last_tok", 0)
        # NOTE: single shared `pos` per fused step; the simple engine keeps
        # per-slot positions aligned by admitting same-length prompts or by
        # per-slot stepping during prefill.  Fused decode uses max pos.
        pos = int(self.pos[active].max())
        with tr.span("serve.decode", lanes=len(active)) as sp:
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos)
            )
            nxt = np.asarray(jnp.argmax(logits, -1))
            sp.fence(self.cache)
        self.metrics.inc("decode_tokens", len(active))
        for s in active:
            rid = self.slots[s]
            req = self.running[rid]
            req.out.append(int(nxt[s]))
            req._last_tok = int(nxt[s])
            self.pos[s] = pos + 1
            if len(req.out) >= req.max_new or self.pos[s] >= self.s_max - 1:
                with tr.span("serve.retire", slot=s):
                    self._retire(s)

    def _tick_pipelined(self, tr) -> float:
        """Double-buffered tick: DISPATCH round N's fused decode (JAX async
        dispatch returns immediately), run round N+1's admit — prefix
        lookups, page allocation, publish rounds — on the host while the
        device works, then fence on the decode and retire.  Admitted
        requests join the decode from the NEXT tick (their prefill steps
        chain onto the in-flight cache, so per-slot KV stays exact).
        Returns the seconds of host work overlapped with the in-flight
        decode (0 when nothing was running)."""
        active = [s for s in range(self.max_batch) if self.slots[s] is not None]
        logits = None
        pos = 0
        if active:
            tokens = np.zeros(self.max_batch, np.int32)
            for s in active:
                tokens[s] = getattr(self.running[self.slots[s]], "_last_tok", 0)
            pos = int(self.pos[active].max())
            with tr.span("serve.decode.dispatch", lanes=len(active)):
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos)
                )
        t0 = time.perf_counter()
        with tr.span(
            "serve.admit", waiting=len(self.waiting), overlapped=bool(active)
        ):
            self._admit()
        overlap = time.perf_counter() - t0 if active else 0.0
        if logits is None:
            return 0.0
        with tr.span("serve.decode", lanes=len(active)) as sp:
            nxt = np.asarray(jnp.argmax(logits, -1))  # the fence: blocks here
            sp.fence(self.cache)
        self.metrics.inc("decode_tokens", len(active))
        for s in active:
            rid = self.slots[s]
            req = self.running[rid]
            req.out.append(int(nxt[s]))
            req._last_tok = int(nxt[s])
            self.pos[s] = pos + 1
            if len(req.out) >= req.max_new or self.pos[s] >= self.s_max - 1:
                with tr.span("serve.retire", slot=s):
                    self._retire(s)
        return overlap

    def _retire(self, slot: int):
        rid = self.slots[slot]
        req = self.running.pop(rid)
        req.t_done = time.time()
        self.done.append(req)
        self.metrics.inc("retired")
        self.slots[slot] = None
        self.kv.release(rid)
        # session churn: hot prompts get re-inserted by the next request —
        # eviction + re-publish of the same keys is the elimination workload
        chain = prefix_hashes(req.prompt)
        if chain and self.kv.used > self.kv.n_pages // 2:
            self.index.evict_batch([h for h, _ in chain])
        # session-range sweep: retired ids accumulate below the lowest live
        # id, so ONE fused scan+delete round clears them in bulk (the round
        # engine linearizes the scan before the same round's deletes;
        # amortized — no per-rid delete round at retire time).
        self._retired_since_sweep += 1
        if self._retired_since_sweep >= 8 or not self.running:
            # with nothing running, sweep past the highest id ever admitted
            # (the last retiree may have a lower rid than earlier ones)
            live_floor = min(self.running.keys(), default=self._max_rid + 1)
            if live_floor > self._evict_floor:
                self.sessions.evict_range(self._evict_floor, live_floor)
                self._evict_floor = live_floor
            self._retired_since_sweep = 0

    def drain_durability(self):
        """Flush both journals' pending commit groups and join any
        in-flight async commits — the engine-level persist fence (a
        no-op for volatile or non-grouped indexes)."""
        for h in (self.index.tree, self.sessions.tree):
            drain = getattr(h, "drain", None)
            if drain is not None:
                drain()

    def run_until_done(self, max_ticks: int = 10000):
        t = 0
        while (self.waiting or self.running) and t < max_ticks:
            self.tick()
            t += 1
        # workload done: pending groups would otherwise stay volatile until
        # the next tick that never comes
        self.drain_durability()
        return self.done

    def stats(self) -> dict:
        s = dict(self.index.stats())
        s["pages_used"] = self.kv.used
        s["session_scans"] = self.sessions.stats()["scans"]
        lat = [r.t_done - r.t_submit for r in self.done if r.t_done]
        s["n_done"] = len(self.done)
        s["mean_latency_s"] = float(np.mean(lat)) if lat else 0.0
        s["cache_hit_blocks"] = sum(r.cache_hit_blocks for r in self.done)
        s["ticks"] = self.metrics.value("ticks")
        s["tick_latency"] = self.metrics.histogram_summary("tick_latency_s")
        s["metrics"] = self.metrics.snapshot()
        s["index_metrics"] = self.index.tree.metrics.snapshot()
        s["recorder"] = self.recorder.snapshot()
        # durability degradation surface: present only when the indexes are
        # journaled; "degraded" is True if EITHER index's circuit breaker
        # is open (serving continues volatile, commits suspended).
        holders = [
            ("prefix", self.index.tree),
            ("sessions", self.sessions.tree),
        ]
        durable = {
            name: h.durability_status()
            for name, h in holders
            if hasattr(h, "durability_status")
        }
        if durable:
            durable["degraded"] = any(v["degraded"] for v in durable.values())
            s["durability"] = durable
        return s
