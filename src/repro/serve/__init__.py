from repro.serve.pages import PagedKVCache, PrefixIndex
from repro.serve.engine import ServeEngine, Request

__all__ = ["PagedKVCache", "PrefixIndex", "ServeEngine", "Request"]
