"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 → d_model // n_heads

    # attention
    attn: str = "full"  # full | swa | mla
    window: int = 0  # sliding-window size (swa)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True

    # MLA (deepseek-v3)
    q_lora: int = 0
    kv_lora: int = 0
    rope_head: int = 0  # decoupled rope head dim
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0  # 0 = global dispatch; >0 = shard-local groups (§Perf)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    slstm_every: int = 0  # xlstm: every k-th block is an sLSTM block
    mlstm_chunk: int = 0  # 0 = sequential scan (paper form); >0 = chunkwise
    shared_attn_every: int = 0  # zamba2: shared attn block cadence

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_frames: int = 0

    # vlm
    vis_tokens: int = 0

    # misc
    norm: str = "rms"  # rms | ln
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # execution policy
    dtype: str = "bfloat16"  # compute dtype
    remat: str = "none"  # none | full | dots
    use_pallas: bool = False
    rules: str = "tp"  # logical→physical sharding rule set (models/params.py)
    scan_layers: bool = True

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k (sub-quadratic sequence mixing)?"""
        return self.family in ("ssm", "hybrid") or self.attn == "swa"

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.family not in ("hybrid",) else 4),
        d_model=128,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        d_head=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 64) if cfg.window else 0,
        q_lora=64 if cfg.q_lora else 0,
        kv_lora=32 if cfg.kv_lora else 0,
        rope_head=16 if cfg.rope_head else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_shared=cfg.n_shared,
        first_k_dense=min(cfg.first_k_dense, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
        shared_attn_every=min(cfg.shared_attn_every, 2) if cfg.shared_attn_every else 0,
        enc_layers=min(cfg.enc_layers, 2),
        enc_frames=min(cfg.enc_frames, 32),
        vis_tokens=min(cfg.vis_tokens, 8),
        dtype="float32",
        scan_layers=cfg.scan_layers,
    )
    base.update(overrides)
    return cfg.replace(**base)
