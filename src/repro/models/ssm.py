"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode.  Used by zamba2 (hybrid).

The SSD form: per head h with state N and head dim P,
    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t        (state (N, P))
    y_t = C_t · h_t + D · x_t
computed chunkwise: intra-chunk (quadratic in chunk len, MXU-friendly) +
inter-chunk state carry via lax.scan — the standard TPU-native schedule
(sequential scan over 4k steps would underuse the MXU).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import cdt
from repro.models.params import P


class SSMCache(NamedTuple):
    h: jax.Array  # (B, H, N, P) state
    conv: jax.Array  # (B, K-1, conv_dim) conv tail


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_headdim
    return d_in, heads


def mamba2_spec(cfg):
    d = cfg.d_model
    d_in, heads = _dims(cfg)
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n
    return {
        "in_proj": P((d, 2 * d_in + 2 * n + heads), ("embed", "ffn")),
        "conv_w": P((cfg.ssm_conv, conv_dim), (None, "conv")),
        "conv_b": P((conv_dim,), ("conv",), "zeros"),
        "A_log": P((heads,), (None,), "zeros"),
        "dt_bias": P((heads,), (None,), "zeros"),
        "D": P((heads,), (None,), "ones"),
        "norm_w": P((d_in,), ("ffn",), "ones"),
        "out_proj": P((d_in, d), ("ffn", "embed")),
    }


def _split_proj(z, cfg):
    d_in, heads = _dims(cfg)
    n = cfg.ssm_state
    zx, xbc, dt = jnp.split(z, [d_in, 2 * d_in + 2 * n], axis=-1)
    return zx, xbc, dt  # gate (d_in) | conv-input (d_in + 2N) | dt (heads)


def _causal_conv(xbc, w, b, cfg, tail=None):
    """Depthwise causal conv1d (kernel K).  tail: (B, K-1, C) history for
    decode; returns (out, new_tail)."""
    k = cfg.ssm_conv
    pad = tail if tail is not None else jnp.zeros(
        (xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype
    )
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, K-1+T, C)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    out = jax.nn.silu(out + b[None, None, :])
    new_tail = xp[:, -(k - 1) :, :]
    return out, new_tail


def _segsum(log_a):
    """(..., T) → (..., T, T) lower-triangular cumulative log-decay."""
    t = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_train(p, x, cfg):
    """x: (B, T, d) → (B, T, d) via chunked SSD."""
    dt_ = cdt(cfg)
    b, t, d = x.shape
    d_in, heads = _dims(cfg)
    n, hp = cfg.ssm_state, cfg.ssm_headdim
    cs = min(cfg.ssm_chunk, t)
    assert t % cs == 0, f"seq {t} % chunk {cs} != 0"
    nc = t // cs

    z = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(dt_))
    gate, xbc, dtp = _split_proj(z, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), cfg)
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xh = xs.reshape(b, t, heads, hp)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"][None, None])  # (B,T,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    da = dt * a[None, None]  # (B,T,H) log-decay
    # chunk
    dac = da.reshape(b, nc, cs, heads).transpose(0, 3, 1, 2)  # (B,H,nc,cs)
    xc = xh.reshape(b, nc, cs, heads, hp)
    bc = bmat.reshape(b, nc, cs, n)
    cc = cmat.reshape(b, nc, cs, n)
    dtc = dt.reshape(b, nc, cs, heads)

    # --- intra-chunk (diagonal) term
    l = jnp.exp(_segsum(dac))  # (B,H,nc,cs,cs)
    scores = jnp.einsum("bcin,bcjn->bcij", cc.astype(jnp.float32), bc.astype(jnp.float32))
    att = scores[:, None] * l.transpose(0, 1, 2, 3, 4)  # (B,H,nc,cs,cs)
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (B,nc,cs,H,P)
    y_diag = jnp.einsum("bhcij,bcjhp->bcihp", att, xdt)

    # --- chunk states + inter-chunk recurrence
    # state_c = sum_i exp(sum_{j>i} da_j) * dt_i * B_i ⊗ x_i
    cum = jnp.cumsum(dac, axis=-1)
    decay_rest = jnp.exp(cum[..., -1:] - cum)  # (B,H,nc,cs): exp(sum_{j>i} da_j)
    states = jnp.einsum(
        "bhci,bcin,bcihp->bchnp", decay_rest, bc.astype(jnp.float32), xdt
    )  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[..., -1])  # (B,H,nc)

    def scan_fn(h, inp):
        st, dec = inp  # st (B,H,N,P), dec (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    sts = states.transpose(1, 0, 2, 3, 4)  # (nc,B,H,N,P)
    decs = chunk_decay.transpose(2, 0, 1)  # (nc,B,H)
    h0 = jnp.zeros((b, heads, n, hp), jnp.float32)
    _, h_prev = jax.lax.scan(scan_fn, h0, (sts, decs))  # h before each chunk

    # --- inter-chunk output: y_off_i = C_i · exp(cum_i) · h_prev
    decay_in = jnp.exp(cum)  # (B,H,nc,cs) decay from chunk start through i
    y_off = jnp.einsum(
        "bcin,bhci,cbhnp->bcihp", cc.astype(jnp.float32), decay_in, h_prev
    )

    y = (y_diag + y_off).reshape(b, t, heads, hp)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, t, d_in).astype(dt_)
    # gated RMS norm (Mamba2)
    y = y * jax.nn.silu(gate)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * p["norm_w"]).astype(dt_)
    return jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt_))


def mamba2_decode(p, x, cfg, cache: SSMCache):
    """Single-token step.  x: (B, 1, d)."""
    dt_ = cdt(cfg)
    b = x.shape[0]
    d_in, heads = _dims(cfg)
    n, hp = cfg.ssm_state, cfg.ssm_headdim

    z = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(dt_))
    gate, xbc, dtp = _split_proj(z, cfg)
    xbc, conv_tail = _causal_conv(
        xbc, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), cfg, tail=cache.conv
    )
    xs, bmat, cmat = jnp.split(xbc[:, 0], [d_in, d_in + n], axis=-1)
    xh = xs.reshape(b, heads, hp)
    dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"][None])  # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a[None])  # (B,H)
    h = cache.h * dec[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", bmat.astype(jnp.float32), xh.astype(jnp.float32), dt
    )
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), h)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, d_in).astype(dt_)
    y = y * jax.nn.silu(gate[:, 0])
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * p["norm_w"]).astype(dt_)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(dt_))
    return out[:, None, :], SSMCache(h=h, conv=conv_tail)


def ssm_cache_spec(cfg, batch, layers=None):
    d_in, heads = _dims(cfg)
    n, hp = cfg.ssm_state, cfg.ssm_headdim
    conv_dim = d_in + 2 * n
    hshape = (batch, heads, n, hp)
    cshape = (batch, cfg.ssm_conv - 1, conv_dim)
    if layers:
        hshape = (layers,) + hshape
        cshape = (layers,) + cshape
    return SSMCache(
        h=jax.ShapeDtypeStruct(hshape, jnp.float32),
        conv=jax.ShapeDtypeStruct(cshape, jnp.dtype(cfg.dtype)),
    )
