from repro.models.backbone import (
    cache_spec,
    forward_decode,
    forward_train,
    init_cache,
    loss_fn,
    model_spec,
)
from repro.models.config import ModelConfig, reduced
from repro.models.params import (
    RULE_SETS,
    abstract_params,
    count_params,
    init_params,
    param_shardings,
)

__all__ = [
    "ModelConfig",
    "reduced",
    "model_spec",
    "forward_train",
    "forward_decode",
    "loss_fn",
    "cache_spec",
    "init_cache",
    "init_params",
    "abstract_params",
    "param_shardings",
    "count_params",
    "RULE_SETS",
]
