"""Shared layers: norms, rotary embedding, MLPs, embeddings.

All modules are (spec, apply) pairs over plain dict param trees (see
models/params.py).  Params are stored fp32 and cast to the compute dtype at
use (mixed-precision policy)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import P


def cdt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_spec(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "ln":
        return {"scale": P((d,), (None,), "ones"), "bias": P((d,), (None,), "zeros")}
    return {"scale": P((d,), (None,), "ones")}


def norm_apply(p, x, cfg):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(xf * xf, -1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float):
    """x: (..., S, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_spec(cfg, d_in=None, d_ff=None):
    d_in = d_in or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi": P((d_in, d_ff), ("embed", "ffn")),
            "wg": P((d_in, d_ff), ("embed", "ffn")),
            "wo": P((d_ff, d_in), ("ffn", "embed")),
        }
    return {
        "wi": P((d_in, d_ff), ("embed", "ffn")),
        "wo": P((d_ff, d_in), ("ffn", "embed")),
    }


def mlp_apply(p, x, cfg):
    dt = cdt(cfg)
    if cfg.act == "swiglu":
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_spec(cfg):
    s = {"tok": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed")}
    if not cfg.tie_embeddings:
        s["unembed"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return s


def embed_apply(p, tokens, cfg):
    return p["tok"].astype(cdt(cfg))[tokens]


def unembed_apply(p, x, cfg):
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    # logits in fp32 for a stable softmax/CE
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32), w.astype(jnp.float32))


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    pos = np.arange(n, dtype=np.float32)[:, None]
    dim = np.arange(0, d, 2, dtype=np.float32)[None, :]
    angle = pos / np.power(10000.0, dim / d)
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out
