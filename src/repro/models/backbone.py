"""Model assembly for all assigned architecture families.

Public API (used by train/serve/launch):
    model_spec(cfg)                         → param spec tree (models/params.P)
    forward_train(params, tokens, cfg, extra=None) → logits (B, S, V)
    loss_fn(params, batch, cfg)             → (loss, metrics)
    cache_spec(cfg, batch, s_max)           → decode cache (ShapeDtypeStructs)
    init_cache(cfg, batch, s_max)           → zero-filled decode cache
    forward_decode(params, cache, tokens, pos, cfg) → (logits (B,V), cache')

Layer stacks are scanned (`lax.scan` over stacked (L, …) params) wherever
layers are homogeneous — this keeps the HLO O(1) in depth (compile-time at
61 layers) and gives remat a natural boundary.  Heterogeneous patterns
(deepseek dense-prefix, zamba2 shared-attention cadence, xlstm sLSTM
cadence, whisper enc-dec) are grouped into homogeneous sub-stacks.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    cdt,
    embed_apply,
    embed_spec,
    mlp_apply,
    mlp_spec,
    norm_apply,
    norm_spec,
    sinusoidal_positions,
    unembed_apply,
)
from repro.models.params import P, map_specs


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------


def stack_specs(spec, n: int):
    """Add a leading stacked-layers dim to every leaf."""
    return map_specs(
        lambda path, s: P((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.dtype),
        spec,
    )


def _remat(f, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return f


# ---------------------------------------------------------------------------
# transformer block (dense / moe / mla / swa)
# ---------------------------------------------------------------------------


def _attn_spec(cfg):
    return attn.mla_spec(cfg) if cfg.attn == "mla" else attn.gqa_spec(cfg)


def _ffn_spec(cfg, moe: bool):
    return moe_mod.moe_spec(cfg) if moe else mlp_spec(cfg)


def block_spec(cfg, moe: bool = False):
    return {
        "ln1": norm_spec(cfg),
        "attn": _attn_spec(cfg),
        "ln2": norm_spec(cfg),
        "ffn": _ffn_spec(cfg, moe),
    }


def block_apply(p, x, cfg, moe: bool = False):
    h = norm_apply(p["ln1"], x, cfg)
    if cfg.attn == "mla":
        h = attn.mla_train(p["attn"], h, cfg)
    else:
        h = attn.gqa_train(p["attn"], h, cfg)
    x = x + h
    h = norm_apply(p["ln2"], x, cfg)
    h = moe_mod.moe_apply(p["ffn"], h, cfg) if moe else mlp_apply(p["ffn"], h, cfg)
    return x + h


def block_decode(p, x, cfg, cache, pos, moe: bool = False):
    h = norm_apply(p["ln1"], x, cfg)
    if cfg.attn == "mla":
        h, cache = attn.mla_decode(p["attn"], h, cfg, cache, pos)
    else:
        h, cache = attn.gqa_decode(p["attn"], h, cfg, cache, pos)
    x = x + h
    h = norm_apply(p["ln2"], x, cfg)
    h = moe_mod.moe_apply(p["ffn"], h, cfg) if moe else mlp_apply(p["ffn"], h, cfg)
    return x + h, cache


def _scan_stack(params, x, cfg, body):
    """lax.scan x through stacked-layer params."""

    def f(carry, lp):
        return body(lp, carry), None

    f = _remat(f, cfg)
    if cfg.scan_layers:
        out, _ = jax.lax.scan(f, x, params)
        return out
    n = jax.tree_util.tree_leaves(params)[0].shape[0]
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], params)
        x, _ = f(x, lp)
    return x


def _scan_stack_cache(params, caches, x, cfg, body, pos):
    """Scan with per-layer cache slices; returns (x, new caches)."""

    def f(carry, inp):
        lp, lc = inp
        y, nc = body(lp, carry, lc, pos)
        return y, nc

    if cfg.scan_layers:
        out, new_caches = jax.lax.scan(f, x, (params, caches))
        return out, new_caches
    n = jax.tree_util.tree_leaves(params)[0].shape[0]
    outs = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], params)
        lc = jax.tree.map(lambda a: a[i], caches)
        x, nc = f(x, (lp, lc))
        outs.append(nc)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, stacked


# ---------------------------------------------------------------------------
# family: dense / moe / vlm  (decoder-only transformer LM)
# ---------------------------------------------------------------------------


def _lm_spec(cfg: ModelConfig):
    s: Dict[str, Any] = {"embed": embed_spec(cfg), "ln_f": norm_spec(cfg)}
    n_moe = 0
    if cfg.n_experts:
        n_dense = cfg.first_k_dense
        n_moe = cfg.n_layers - n_dense
        if n_dense:
            s["dense_layers"] = stack_specs(block_spec(cfg, moe=False), n_dense)
        s["layers"] = stack_specs(block_spec(cfg, moe=True), n_moe)
    else:
        s["layers"] = stack_specs(block_spec(cfg, moe=False), cfg.n_layers)
    if cfg.family == "vlm":
        # modality frontend is a STUB per assignment: precomputed patch
        # embeddings arrive as inputs; only a projection is learned here.
        s["vis_proj"] = P((cfg.d_model, cfg.d_model), ("embed", "embed"))
    return s


def _lm_forward(params, tokens, cfg: ModelConfig, extra=None):
    x = embed_apply(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        vis = extra["vis_embeds"].astype(cdt(cfg))
        vis = jnp.einsum("bvd,de->bve", vis, params["vis_proj"].astype(cdt(cfg)))
        x = jnp.concatenate([vis, x], axis=1)
    moe = bool(cfg.n_experts)
    if moe and cfg.first_k_dense:
        x = _scan_stack(
            params["dense_layers"], x, cfg, lambda p, h: block_apply(p, h, cfg, False)
        )
    x = _scan_stack(params["layers"], x, cfg, lambda p, h: block_apply(p, h, cfg, moe))
    x = norm_apply(params["ln_f"], x, cfg)
    if cfg.family == "vlm":
        x = x[:, extra["vis_embeds"].shape[1] :]  # logits for text positions
    return unembed_apply(params["embed"], x, cfg)


def _lm_cache_spec(cfg, batch, s_max):
    mk = attn.mla_cache_spec if cfg.attn == "mla" else attn.gqa_cache_spec
    c = {}
    if cfg.n_experts and cfg.first_k_dense:
        c["dense_layers"] = mk(cfg, batch, s_max, layers=cfg.first_k_dense)
        c["layers"] = mk(cfg, batch, s_max, layers=cfg.n_layers - cfg.first_k_dense)
    else:
        c["layers"] = mk(cfg, batch, s_max, layers=cfg.n_layers)
    return c


def _lm_decode(params, cache, tokens, pos, cfg):
    x = embed_apply(params["embed"], tokens[:, None], cfg)
    moe = bool(cfg.n_experts)
    new_cache = dict(cache)
    if moe and cfg.first_k_dense:
        x, new_cache["dense_layers"] = _scan_stack_cache(
            params["dense_layers"], cache["dense_layers"], x, cfg,
            lambda p, h, c, q: block_decode(p, h, cfg, c, q, False), pos,
        )
    x, new_cache["layers"] = _scan_stack_cache(
        params["layers"], cache["layers"], x, cfg,
        lambda p, h, c, q: block_decode(p, h, cfg, c, q, moe), pos,
    )
    x = norm_apply(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# family: ssm (xlstm)
# ---------------------------------------------------------------------------


def _xlstm_counts(cfg):
    if cfg.slstm_every:
        n_s = cfg.n_layers // cfg.slstm_every
    else:
        n_s = 0
    return cfg.n_layers - n_s, n_s


def _xlstm_spec(cfg):
    n_m, n_s = _xlstm_counts(cfg)
    s = {
        "embed": embed_spec(cfg),
        "ln_f": norm_spec(cfg),
        "mblocks": stack_specs({"ln": norm_spec(cfg), "cell": xlstm_mod.mlstm_spec(cfg)}, n_m),
    }
    if n_s:
        s["sblocks"] = stack_specs(
            {"ln": norm_spec(cfg), "cell": xlstm_mod.slstm_spec(cfg)}, n_s
        )
    return s


def _xlstm_segments(cfg):
    """Segment pattern: (k-1) mLSTM blocks then 1 sLSTM, repeated."""
    n_m, n_s = _xlstm_counts(cfg)
    if not n_s:
        return [(n_m, False)]
    k = cfg.slstm_every
    segs = []
    for _ in range(n_s):
        segs.append((k - 1, False))
        segs.append((1, True))
    rem = cfg.n_layers - n_s * k
    if rem:
        segs.append((rem, False))
    return segs


def _slice_stack(params, lo, n):
    return jax.tree.map(lambda a: a[lo : lo + n], params)


def _xlstm_forward(params, tokens, cfg, extra=None):
    x = embed_apply(params["embed"], tokens, cfg)
    mi = si = 0
    for count, is_s in _xlstm_segments(cfg):
        if is_s:
            for j in range(count):
                lp = jax.tree.map(lambda a: a[si], params["sblocks"])
                x = x + xlstm_mod.slstm_train(
                    lp["cell"], norm_apply(lp["ln"], x, cfg), cfg
                )
                si += 1
        else:
            lp = _slice_stack(params["mblocks"], mi, count)
            if cfg.mlstm_chunk:
                cell = lambda p, h: h + xlstm_mod.mlstm_train_chunked(
                    p["cell"], norm_apply(p["ln"], h, cfg), cfg, chunk=cfg.mlstm_chunk
                )
            else:
                cell = lambda p, h: h + xlstm_mod.mlstm_train(
                    p["cell"], norm_apply(p["ln"], h, cfg), cfg
                )
            x = _scan_stack(lp, x, cfg, cell)
            mi += count
    x = norm_apply(params["ln_f"], x, cfg)
    return unembed_apply(params["embed"], x, cfg)


def _xlstm_cache_spec(cfg, batch, s_max):
    n_m, n_s = _xlstm_counts(cfg)
    c = {"m": xlstm_mod.mlstm_cache_spec(cfg, batch, layers=n_m)}
    if n_s:
        c["s"] = xlstm_mod.slstm_cache_spec(cfg, batch, layers=n_s)
    return c


def _xlstm_decode(params, cache, tokens, pos, cfg):
    x = embed_apply(params["embed"], tokens[:, None], cfg)
    mi = si = 0
    new_m, new_s = [], []
    for count, is_s in _xlstm_segments(cfg):
        if is_s:
            for _ in range(count):
                lp = jax.tree.map(lambda a: a[si], params["sblocks"])
                lc = jax.tree.map(lambda a: a[si], cache["s"])
                y, nc = xlstm_mod.slstm_decode(
                    lp["cell"], norm_apply(lp["ln"], x, cfg), cfg, lc
                )
                x = x + y
                new_s.append(nc)
                si += 1
        else:
            lp = _slice_stack(params["mblocks"], mi, count)
            lc = jax.tree.map(lambda a: a[mi : mi + count], cache["m"])

            def body(p, h, c, q):
                y, nc = xlstm_mod.mlstm_decode(
                    p["cell"], norm_apply(p["ln"], h, cfg), cfg, c
                )
                return h + y, nc

            x, ncs = _scan_stack_cache(lp, lc, x, cfg, body, pos)
            new_m.append(ncs)
            mi += count
    x = norm_apply(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    out = {"m": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_m)}
    if new_s:
        out["s"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_s)
    return logits[:, 0], out


# ---------------------------------------------------------------------------
# family: hybrid (zamba2 — mamba2 backbone + shared attention block)
# ---------------------------------------------------------------------------


def _z_invocations(cfg):
    return cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0


def _hybrid_spec(cfg):
    s = {
        "embed": embed_spec(cfg),
        "ln_f": norm_spec(cfg),
        "mamba": stack_specs({"ln": norm_spec(cfg), "ssm": ssm_mod.mamba2_spec(cfg)}, cfg.n_layers),
    }
    if cfg.shared_attn_every:
        s["shared"] = {
            "in_proj": P((2 * cfg.d_model, cfg.d_model), ("ffn", "embed")),
            "block": block_spec(cfg, moe=False),
        }
    return s


def _hybrid_forward(params, tokens, cfg, extra=None):
    x0 = embed_apply(params["embed"], tokens, cfg)
    x = x0
    k = cfg.shared_attn_every
    n_inv = _z_invocations(cfg)
    li = 0
    for seg in range(n_inv + 1):
        count = min(k, cfg.n_layers - li) if k else cfg.n_layers
        if count > 0:
            lp = _slice_stack(params["mamba"], li, count)
            x = _scan_stack(
                lp, x, cfg,
                lambda p, h: h + ssm_mod.mamba2_train(p["ssm"], norm_apply(p["ln"], h, cfg), cfg),
            )
            li += count
        if k and seg < n_inv:
            # zamba2: the SHARED transformer block sees [hidden ‖ embeddings]
            sp = params["shared"]
            inp = jnp.concatenate([x, x0], axis=-1)
            h = jnp.einsum("bte,ed->btd", inp, sp["in_proj"].astype(cdt(cfg)))
            x = x + block_apply(sp["block"], h, cfg, moe=False)
    x = norm_apply(params["ln_f"], x, cfg)
    return unembed_apply(params["embed"], x, cfg)


def _hybrid_cache_spec(cfg, batch, s_max):
    c = {"mamba": ssm_mod.ssm_cache_spec(cfg, batch, layers=cfg.n_layers)}
    n_inv = _z_invocations(cfg)
    if n_inv:
        c["shared"] = attn.gqa_cache_spec(cfg, batch, s_max, layers=n_inv)
    return c


def _hybrid_decode(params, cache, tokens, pos, cfg):
    x0 = embed_apply(params["embed"], tokens[:, None], cfg)
    x = x0
    k = cfg.shared_attn_every
    n_inv = _z_invocations(cfg)
    li = 0
    new_shared = []
    new_mamba = []
    for seg in range(n_inv + 1):
        count = min(k, cfg.n_layers - li) if k else cfg.n_layers
        if count > 0:
            lp = _slice_stack(params["mamba"], li, count)
            lc = jax.tree.map(lambda a: a[li : li + count], cache["mamba"])

            def body(p, h, c, q):
                y, nc = ssm_mod.mamba2_decode(p["ssm"], norm_apply(p["ln"], h, cfg), cfg, c)
                return h + y, nc

            x, ncs = _scan_stack_cache(lp, lc, x, cfg, body, pos)
            new_mamba.append(ncs)
            li += count
        if k and seg < n_inv:
            sp = params["shared"]
            lc = jax.tree.map(lambda a: a[seg], cache["shared"])
            inp = jnp.concatenate([x, x0], axis=-1)
            h = jnp.einsum("bte,ed->btd", inp, sp["in_proj"].astype(cdt(cfg)))
            y, nc = block_decode(sp["block"], h, cfg, lc, pos, moe=False)
            x = x + y
            new_shared.append(nc)
    x = norm_apply(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    out = {"mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_mamba)}
    if new_shared:
        out["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_shared)
    return logits[:, 0], out


# ---------------------------------------------------------------------------
# family: audio (whisper enc-dec; conv frontend is a stub per assignment)
# ---------------------------------------------------------------------------


def _audio_spec(cfg):
    enc_block = {
        "ln1": norm_spec(cfg),
        "attn": attn.gqa_spec(cfg),
        "ln2": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }
    dec_block = {
        "ln1": norm_spec(cfg),
        "attn": attn.gqa_spec(cfg),
        "lnx": norm_spec(cfg),
        "cross": attn.cross_spec(cfg),
        "ln2": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }
    return {
        "embed": embed_spec(cfg),
        "enc_layers": stack_specs(enc_block, cfg.enc_layers),
        "dec_layers": stack_specs(dec_block, cfg.n_layers),
        "enc_ln": norm_spec(cfg),
        "ln_f": norm_spec(cfg),
    }


def _audio_encode(params, frames, cfg):
    """frames: (B, T_enc, d) — precomputed conv-frontend embeddings (stub)."""
    pe = jnp.asarray(sinusoidal_positions(frames.shape[1], cfg.d_model))
    x = frames.astype(cdt(cfg)) + pe[None].astype(cdt(cfg))

    def body(p, h):
        a = attn.gqa_train(p["attn"], norm_apply(p["ln1"], h, cfg), cfg, causal=False)
        h = h + a
        m = mlp_apply(p["mlp"], norm_apply(p["ln2"], h, cfg), cfg)
        return h + m

    x = _scan_stack(params["enc_layers"], x, cfg, body)
    return norm_apply(params["enc_ln"], x, cfg)


def _audio_forward(params, tokens, cfg, extra=None):
    enc = _audio_encode(params, extra["frames"], cfg)
    pe = jnp.asarray(sinusoidal_positions(tokens.shape[1], cfg.d_model))
    x = embed_apply(params["embed"], tokens, cfg) + pe[None].astype(cdt(cfg))

    def body(p, h):
        h = h + attn.gqa_train(p["attn"], norm_apply(p["ln1"], h, cfg), cfg)
        h = h + attn.cross_apply(p["cross"], norm_apply(p["lnx"], h, cfg), enc, cfg)
        h = h + mlp_apply(p["mlp"], norm_apply(p["ln2"], h, cfg), cfg)
        return h

    x = _scan_stack(params["dec_layers"], x, cfg, body)
    x = norm_apply(params["ln_f"], x, cfg)
    return unembed_apply(params["embed"], x, cfg)


def _audio_cache_spec(cfg, batch, s_max):
    return {
        "self": attn.gqa_cache_spec(cfg, batch, s_max, layers=cfg.n_layers),
        "enc_out": jax.ShapeDtypeStruct(
            (batch, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype)
        ),
    }


def _audio_decode(params, cache, tokens, pos, cfg):
    enc = cache["enc_out"]
    pe = jnp.asarray(sinusoidal_positions(8192, cfg.d_model))
    pos_emb = jax.lax.dynamic_slice_in_dim(pe, jnp.minimum(pos, 8191), 1)[None]
    x = embed_apply(params["embed"], tokens[:, None], cfg) + pos_emb.astype(cdt(cfg))

    def body(p, h, c, q):
        y, nc = attn.gqa_decode(p["attn"], norm_apply(p["ln1"], h, cfg), cfg, c, q)
        h = h + y
        h = h + attn.cross_apply(p["cross"], norm_apply(p["lnx"], h, cfg), enc, cfg)
        h = h + mlp_apply(p["mlp"], norm_apply(p["ln2"], h, cfg), cfg)
        return h, nc

    x, new_self = _scan_stack_cache(params["dec_layers"], cache["self"], x, cfg, body, pos)
    x = norm_apply(params["ln_f"], x, cfg)
    logits = unembed_apply(params["embed"], x, cfg)
    return logits[:, 0], {"self": new_self, "enc_out": enc}


# ---------------------------------------------------------------------------
# public dispatch
# ---------------------------------------------------------------------------

_FWD = {
    "dense": _lm_forward,
    "moe": _lm_forward,
    "vlm": _lm_forward,
    "ssm": _xlstm_forward,
    "hybrid": _hybrid_forward,
    "audio": _audio_forward,
}
_SPEC = {
    "dense": _lm_spec,
    "moe": _lm_spec,
    "vlm": _lm_spec,
    "ssm": _xlstm_spec,
    "hybrid": _hybrid_spec,
    "audio": _audio_spec,
}
_CACHE = {
    "dense": _lm_cache_spec,
    "moe": _lm_cache_spec,
    "vlm": _lm_cache_spec,
    "ssm": _xlstm_cache_spec,
    "hybrid": _hybrid_cache_spec,
    "audio": _audio_cache_spec,
}
_DECODE = {
    "dense": _lm_decode,
    "moe": _lm_decode,
    "vlm": _lm_decode,
    "ssm": _xlstm_decode,
    "hybrid": _hybrid_decode,
    "audio": _audio_decode,
}


def model_spec(cfg: ModelConfig):
    return _SPEC[cfg.family](cfg)


def forward_train(params, tokens, cfg: ModelConfig, extra=None):
    return _FWD[cfg.family](params, tokens, cfg, extra)


def cache_spec(cfg: ModelConfig, batch: int, s_max: int):
    return _CACHE[cfg.family](cfg, batch, s_max)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_spec(cfg, batch, s_max)
    )


def forward_decode(params, cache, tokens, pos, cfg: ModelConfig):
    return _DECODE[cfg.family](params, cache, tokens, pos, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token CE.  batch: {tokens (B,S), [frames|vis_embeds]}."""
    tokens = batch["tokens"]
    extra = {k: v for k, v in batch.items() if k != "tokens"} or None
    logits = forward_train(params, tokens, cfg, extra)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "ppl": jnp.exp(loss)}
