"""xLSTM blocks (mLSTM matrix-memory + sLSTM scalar-memory), per
arXiv:2405.04517, with the stabilized exponential gating.

mLSTM recurrence (per head, head dim P):
    m_t = max(f̃_t + m_{t-1}, ĩ_t)                       (stabilizer)
    i'  = exp(ĩ_t − m_t);  f' = exp(f̃_t + m_{t-1} − m_t)
    C_t = f'·C_{t-1} + i'·(v_t ⊗ k_t)                    (P×P matrix memory)
    n_t = f'·n_{t-1} + i'·k_t
    h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)

Sequence mixing is a `lax.scan` over time (the recurrence is not
associative in stabilized form); decode is the same step with carried
(C, n, m) state — O(1) per token, which is why xlstm runs the long_500k
cell (DESIGN.md §5)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import cdt
from repro.models.params import P


class XLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, P, P)
    n: jax.Array  # (B, H, P)
    m: jax.Array  # (B, H)


class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, P)
    n: jax.Array  # (B, H, P)
    h: jax.Array  # (B, H, P)
    m: jax.Array  # (B, H)


def _dims(cfg):
    heads = cfg.n_heads
    d_head = cfg.d_model // heads
    return heads, d_head


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_spec(cfg):
    d = cfg.d_model
    h, p_ = _dims(cfg)
    return {
        "wq": P((d, h, p_), ("embed", "heads", None)),
        "wk": P((d, h, p_), ("embed", "heads", None)),
        "wv": P((d, h, p_), ("embed", "heads", None)),
        "wi": P((d, h), ("embed", "heads")),  # input gate pre-act
        "wf": P((d, h), ("embed", "heads")),  # forget gate pre-act
        "bi": P((h,), ("heads",), "zeros"),
        "bf": P((h,), ("heads",), "ones"),  # bias toward remembering
        "wo_gate": P((d, d), ("embed", "ffn")),
        "wo": P((h, p_, d), ("heads", None, "embed")),
    }


def _mlstm_gates(p, x, cfg):
    dt = cdt(cfg)
    q = jnp.einsum("btd,dhp->bthp", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhp->bthp", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhp->bthp", x, p["wv"].astype(dt))
    ig = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wi"]) + p["bi"]
    fg = jnp.einsum("btd,dh->bth", x.astype(jnp.float32), p["wf"]) + p["bf"]
    return q, k, v, ig, fg


def _mlstm_step(state, inp, d_head):
    c, n, m = state  # (B,H,P,P), (B,H,P), (B,H)
    q, k, v, ig, fg = inp  # q/k/v (B,H,P); gates (B,H)
    k = k / (d_head**0.5)
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(logf + m - m_new)
    qf, kf, vf = (z.astype(jnp.float32) for z in (q, k, v))
    c_new = f_p[..., None, None] * c + i_p[..., None, None] * (
        vf[..., :, None] * kf[..., None, :]
    )
    n_new = f_p[..., None] * n + i_p[..., None] * kf
    num = jnp.einsum("bhij,bhj->bhi", c_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, qf)), 1.0)
    h = num / den[..., None]
    return (c_new, n_new, m_new), h


def mlstm_train(p, x, cfg):
    dt = cdt(cfg)
    b, t, d = x.shape
    heads, d_head = _dims(cfg)
    q, k, v, ig, fg = _mlstm_gates(p, x, cfg)
    c0 = jnp.zeros((b, heads, d_head, d_head), jnp.float32)
    n0 = jnp.zeros((b, heads, d_head), jnp.float32)
    m0 = jnp.full((b, heads), -1e30, jnp.float32)
    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        ig.transpose(1, 0, 2),
        fg.transpose(1, 0, 2),
    )
    _, hs = jax.lax.scan(
        lambda s, i: _mlstm_step(s, i, d_head), (c0, n0, m0), xs
    )  # (T,B,H,P)
    h = hs.transpose(1, 0, 2, 3).astype(dt)
    gate = jax.nn.silu(jnp.einsum("btd,de->bte", x, p["wo_gate"].astype(dt)))
    y = jnp.einsum("bthp,hpd->btd", h, p["wo"].astype(dt))
    return y * gate


def mlstm_decode(p, x, cfg, cache: XLSTMCache):
    dt = cdt(cfg)
    heads, d_head = _dims(cfg)
    q, k, v, ig, fg = _mlstm_gates(p, x, cfg)
    state = (cache.c, cache.n, cache.m)
    state, h = _mlstm_step(
        state, (q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0]), d_head
    )
    gate = jax.nn.silu(jnp.einsum("btd,de->bte", x, p["wo_gate"].astype(dt)))
    y = jnp.einsum("bhp,hpd->bd", h.astype(dt), p["wo"].astype(dt))[:, None, :]
    return y * gate, XLSTMCache(*state)


def mlstm_cache_spec(cfg, batch, layers=None):
    heads, d_head = _dims(cfg)
    shp = lambda *s: (layers,) + s if layers else s
    return XLSTMCache(
        c=jax.ShapeDtypeStruct(shp(batch, heads, d_head, d_head), jnp.float32),
        n=jax.ShapeDtypeStruct(shp(batch, heads, d_head), jnp.float32),
        m=jax.ShapeDtypeStruct(shp(batch, heads), jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_spec(cfg):
    d = cfg.d_model
    h, p_ = _dims(cfg)
    return {
        "wx": P((d, 4, h, p_), ("embed", None, "heads", None)),  # i,f,z,o from x
        "wr": P((4, h, p_, p_), (None, "heads", None, None)),  # recurrent (block-diag per head)
        "b": P((4, h, p_), (None, "heads", None), "zeros"),
        "wo": P((h, p_, d), ("heads", None, "embed")),
    }


def _slstm_step(p, state, xt):
    c, n, h, m = state  # (B,H,P) ×3, (B,H)
    pre = xt + jnp.einsum("ghpq,bhq->bghp", p["wr"], h).reshape(xt.shape)  # (B,4,H,P) flat
    pre = pre + p["b"][None]
    ig, fg, zg, og = (pre[:, j] for j in range(4))  # (B,H,P)
    # per-head stabilizer uses the mean pre-activation across the head dim
    ig_s = jnp.mean(ig, -1)
    fg_s = jnp.mean(fg, -1)
    logf = jax.nn.log_sigmoid(fg_s)
    m_new = jnp.maximum(logf + m, ig_s)
    i_p = jnp.exp(ig - m_new[..., None])
    f_p = jnp.exp(logf[..., None] + (m - m_new)[..., None])
    c_new = f_p * c + i_p * jnp.tanh(zg)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_train(p, x, cfg):
    dt = cdt(cfg)
    b, t, d = x.shape
    heads, d_head = _dims(cfg)
    xg = jnp.einsum(
        "btd,dghp->btghp", x.astype(jnp.float32), p["wx"]
    )  # (B,T,4,H,P)

    def step(state, xt):
        s = _slstm_step(p, state, xt)
        return s, s[2]

    z = jnp.zeros((b, heads, d_head), jnp.float32)
    m0 = jnp.full((b, heads), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (z, z, z, m0), xg.transpose(1, 0, 2, 3, 4))
    h = hs.transpose(1, 0, 2, 3).astype(dt)  # (B,T,H,P)
    return jnp.einsum("bthp,hpd->btd", h, p["wo"].astype(dt))


def slstm_decode(p, x, cfg, cache: SLSTMCache):
    dt = cdt(cfg)
    xg = jnp.einsum("btd,dghp->btghp", x.astype(jnp.float32), p["wx"])[:, 0]
    state = _slstm_step(p, (cache.c, cache.n, cache.h, cache.m), xg)
    y = jnp.einsum("bhp,hpd->bd", state[2].astype(dt), p["wo"].astype(dt))
    return y[:, None, :], SLSTMCache(*state)


def slstm_cache_spec(cfg, batch, layers=None):
    heads, d_head = _dims(cfg)
    shp = lambda *s: (layers,) + s if layers else s
    z = lambda *s: jax.ShapeDtypeStruct(shp(*s), jnp.float32)
    return SLSTMCache(
        c=z(batch, heads, d_head),
        n=z(batch, heads, d_head),
        h=z(batch, heads, d_head),
        m=z(batch, heads),
    )


# ---------------------------------------------------------------------------
# Chunkwise mLSTM (beyond-paper §Perf optimization; exact vs the scan form)
# ---------------------------------------------------------------------------
#
# The sequential scan streams the (P×P) matrix memory through HBM every
# timestep: traffic ∝ T·B·H·P².  The chunkwise form (mlstm_kernels lineage,
# same algebra as GLA/SSD chunking but with the max-stabilizer carried
# across chunks) computes, per chunk of length L:
#
#   intra-chunk: D_ij = exp(b_i − b_j + ĩ_j − m_loc_i) for j ≤ i
#                (b = cumulative log-forget within the chunk)
#   inter-chunk: contribution of the carried state C_prev decayed by
#                exp(b_i + m_prev − m_i)
#   carry:       C_new = exp(b_L + m_prev − m_new)·C_prev
#                        + Σ_j exp(b_L − b_j + ĩ_j − m_new)·v_j k_jᵀ
#
# State traffic drops by the chunk length (T/L scan steps instead of T),
# and the intra-chunk math is MXU matmuls instead of outer products.


def _mlstm_chunk_scan(q, k, v, ig, fg, d_head: int, chunk: int):
    """q/k/v: (B,T,H,P) f32; gates (B,T,H) f32.  Returns h (B,T,H,P)."""
    b, t, h, p_ = q.shape
    nc = t // chunk
    k = k / (d_head**0.5)

    logf = jax.nn.log_sigmoid(fg)  # (B,T,H)
    cq = lambda x: x.reshape(b, nc, chunk, h, p_)
    qc, kc, vc = cq(q), cq(k), cq(v)
    igc = ig.reshape(b, nc, chunk, h)
    lfc = logf.reshape(b, nc, chunk, h)
    bcum = jnp.cumsum(lfc, axis=2)  # (B,nc,L,H) cumulative log-forget (incl. self)

    # local running max for the stabilizer within the chunk:
    #   m_loc_i = max_{j≤i} (b_i − b_j + ĩ_j)   (candidate from inputs)
    a_j = igc - bcum  # ĩ_j − b_j
    m_in = jax.lax.cummax(a_j, axis=2) + bcum  # (B,nc,L,H)

    def scan_fn(carry, xs):
        c_prev, n_prev, m_prev = carry  # (B,H,P,P),(B,H,P),(B,H)
        qx, kx, vx, bx, ax, igx, m_inx = xs
        # xs shapes: (B,L,H,P) ×3, (B,L,H) b-cum, a_j, ig, m_in
        # stabilizer: m_i = max(m_prev + b_i, m_in_i)
        m_i = jnp.maximum(m_prev[:, None] + bx, m_inx)  # (B,L,H)
        # inter-chunk: h_inter_i = (C_prev q_i)·exp(b_i + m_prev − m_i)
        dec_in = jnp.exp(bx + m_prev[:, None] - m_i)  # (B,L,H)
        h_inter = jnp.einsum("bhij,blhj->blhi", c_prev, qx) * dec_in[..., None]
        n_inter = jnp.einsum("bhj,blhj->blh", n_prev, qx) * dec_in
        # intra-chunk: D_ij = exp(b_i − b_j + ĩ_j − m_i), j ≤ i
        dmat = bx[:, :, None] - bx[:, None, :] + igx[:, None, :] - m_i[:, :, None]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], jnp.exp(dmat), 0.0)  # (B,L,L,H)
        scores = jnp.einsum("blhp,bjhp->bljh", qx, kx) * dmat
        h_intra = jnp.einsum("bljh,bjhp->blhp", scores, vx)
        n_intra = jnp.einsum("bljh->blh", scores * 1.0)  # Σ_j score_ij (k·q already in scores)
        num = h_inter + h_intra  # (B,L,H,P)
        den = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)
        hs = num / den[..., None]
        # carry to next chunk
        b_l = bx[:, -1]  # (B,H) total log-forget of the chunk
        m_cand = jnp.max(igx - bx, axis=1) + b_l  # max_j (ĩ_j − b_j) + b_L
        m_new = jnp.maximum(m_prev + b_l, m_cand)
        dec_c = jnp.exp(m_prev + b_l - m_new)  # (B,H)
        w_j = jnp.exp((b_l[:, None] - bx) + igx - m_new[:, None])  # (B,L,H)
        c_upd = jnp.einsum("blh,blhp,blhq->bhpq", w_j, vx, kx)
        n_upd = jnp.einsum("blh,blhp->bhp", w_j, kx)
        c_new = c_prev * dec_c[..., None, None] + c_upd
        n_new = n_prev * dec_c[..., None] + n_upd
        return (c_new, n_new, m_new), hs

    tr = lambda x: jnp.moveaxis(x, 1, 0)  # (nc, B, L, ...)
    xs = (tr(qc), tr(kc), tr(vc), tr(bcum), tr(a_j), tr(igc), tr(m_in))
    c0 = jnp.zeros((b, h, p_, p_), jnp.float32)
    n0 = jnp.zeros((b, h, p_), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    _, hs = jax.lax.scan(scan_fn, (c0, n0, m0), xs)  # (nc,B,L,H,P)
    return jnp.moveaxis(hs, 0, 1).reshape(b, t, h, p_)


def mlstm_train_chunked(p, x, cfg, chunk: int = 64):
    """Chunkwise-parallel mLSTM block (output-equivalent to mlstm_train)."""
    dt = cdt(cfg)
    b, t, d = x.shape
    heads, d_head = _dims(cfg)
    chunk = min(chunk, t)
    q, k, v, ig, fg = _mlstm_gates(p, x, cfg)
    # gates/q/k/v come out (B,T,H,*) from einsums already
    h = _mlstm_chunk_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        ig, fg, d_head, chunk,
    ).astype(dt)
    gate = jax.nn.silu(jnp.einsum("btd,de->bte", x, p["wo_gate"].astype(dt)))
    y = jnp.einsum("bthp,hpd->btd", h, p["wo"].astype(dt))
    return y * gate
