"""Attention variants: GQA (full / sliding-window), MLA (DeepSeek-V3
latent attention), and cross-attention (whisper decoder).

Train path uses the pure-jnp oracle (or the Pallas flash kernel when
cfg.use_pallas); decode path updates a static-shape KV cache and masks by
`kv_len` — the roofline-correct decode schedule (whole cache streamed once,
see kernels/decode_attention).

MLA decode uses the *absorbed* form: the cache stores only the compressed
latent c_kv (kv_lora + rope_head per token) — MLA's serving advantage — and
the per-head projections are folded into the score/output einsums.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.layers import apply_rope, cdt
from repro.models.params import P


class KVCache(NamedTuple):
    k: jax.Array  # (B, KH, S, D) — or MLA: latent (B, S, kv_lora+rope_head)
    v: Optional[jax.Array]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_spec(cfg):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    s = {
        "wq": P((d, h, dh), ("embed", "heads", None)),
        "wk": P((d, kh, dh), ("embed", "kv_heads", None)),
        "wv": P((d, kh, dh), ("embed", "kv_heads", None)),
        "wo": P((h, dh, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = P((h, dh), ("heads", None), "zeros")
        s["bk"] = P((kh, dh), ("kv_heads", None), "zeros")
        s["bv"] = P((kh, dh), ("kv_heads", None), "zeros")
    return s


def _qkv(p, x, cfg, positions):
    dt = cdt(cfg)
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)[None, :, None, :]
        k = k + p["bk"].astype(dt)[None, :, None, :]
        v = v + p["bv"].astype(dt)[None, :, None, :]
    if cfg.use_rope:
        q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def gqa_train(p, x, cfg, *, causal=True):
    """x: (B, S, d) → (B, S, d)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, x, cfg, positions)
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention

        o = flash_attention(q, k, v, causal, cfg.window, None, True)
    else:
        o = attention_ref(q, k, v, causal=causal, window=cfg.window)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(cdt(cfg)))


def gqa_decode(p, x, cfg, cache: KVCache, pos):
    """One-token decode.  x: (B, 1, d); pos: scalar current index.
    Returns (y (B,1,d), new cache)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)  # q (B,H,1,D); k/v (B,KH,1,D)
    z = jnp.zeros((), jnp.int32)
    idx = (z, z, jnp.asarray(pos, jnp.int32), z)
    knew = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), idx)
    vnew = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), idx)
    kv_len = pos + 1
    if cfg.window > 0:
        # SWA decode: only the trailing window is live.  We still keep the
        # full cache layout (static shapes); masking enforces the window —
        # on TPU the paging layer would bound reads to the window.
        o = _decode_windowed(q[:, :, 0], knew, vnew, kv_len, cfg.window)
    else:
        o = decode_attention_ref(q[:, :, 0], knew.astype(q.dtype), vnew.astype(q.dtype), kv_len)
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(cdt(cfg)))
    return y[:, None, :], KVCache(knew, vnew)


def _decode_windowed(q, k, v, kv_len, window):
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    group = h // kh
    kx = jnp.repeat(k.astype(q.dtype), group, axis=1)
    vx = jnp.repeat(v.astype(q.dtype), group, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kx.astype(jnp.float32))
    scores = scores / (d**0.5)
    idx = jnp.arange(s)[None, None, :]
    mask = (idx < kv_len) & (idx >= kv_len - window)
    scores = jnp.where(mask, scores, -1e30)
    p_ = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p_, vx.astype(jnp.float32)).astype(q.dtype)


def gqa_cache_spec(cfg, batch, s_max, layers=None):
    kh, dh = cfg.n_kv, cfg.d_head
    shape = (batch, kh, s_max, dh)
    if layers:
        shape = (layers,) + shape
    dt = jnp.dtype(cfg.dtype)
    return KVCache(
        k=jax.ShapeDtypeStruct(shape, dt), v=jax.ShapeDtypeStruct(shape, dt)
    )


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank latent KV + decoupled rope head
# ---------------------------------------------------------------------------


def mla_spec(cfg):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    ql, kvl, dr = cfg.q_lora, cfg.kv_lora, cfg.rope_head
    return {
        "wdq": P((d, ql), ("embed", None)),  # q down
        "wuq": P((ql, h, dh), (None, "heads", None)),  # q up (nope part)
        "wqr": P((ql, h, dr), (None, "heads", None)),  # q rope part
        "wdkv": P((d, kvl), ("embed", None)),  # kv joint down (the latent)
        "wkr": P((d, dr), ("embed", None)),  # shared k rope
        "wuk": P((kvl, h, dh), (None, "heads", None)),  # k up
        "wuv": P((kvl, h, dh), (None, "heads", None)),  # v up
        "wo": P((h, dh, d), ("heads", None, "embed")),
    }


def mla_train(p, x, cfg):
    dt = cdt(cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    cq = jnp.einsum("bsd,dq->bsq", x, p["wdq"].astype(dt))
    q_nope = jnp.einsum("bsq,qhk->bhsk", cq, p["wuq"].astype(dt))
    q_rope = jnp.einsum("bsq,qhr->bhsr", cq, p["wqr"].astype(dt))
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)

    ckv = jnp.einsum("bsd,dc->bsc", x, p["wdkv"].astype(dt))
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wkr"].astype(dt))
    k_rope = apply_rope(k_rope[:, None], positions[:, None, :], cfg.rope_theta)[:, 0]
    k_nope = jnp.einsum("bsc,chk->bhsk", ckv, p["wuk"].astype(dt))
    v = jnp.einsum("bsc,chk->bhsk", ckv, p["wuv"].astype(dt))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], q_rope.shape)], axis=-1
    )
    o = attention_ref(q, k, v, causal=True, sm_scale=1.0 / ((cfg.d_head + cfg.rope_head) ** 0.5))
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(dt))


def mla_decode(p, x, cfg, cache: KVCache, pos):
    """Absorbed MLA decode: cache = latent (B, S, kv_lora + rope_head)."""
    dt = cdt(cfg)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    cq = jnp.einsum("bsd,dq->bsq", x, p["wdq"].astype(dt))
    q_nope = jnp.einsum("bsq,qhk->bhsk", cq, p["wuq"].astype(dt))[:, :, 0]  # (B,H,dh)
    q_rope = jnp.einsum("bsq,qhr->bhsr", cq, p["wqr"].astype(dt))
    q_rope = apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)[:, :, 0]

    ckv = jnp.einsum("bsd,dc->bsc", x, p["wdkv"].astype(dt))[:, 0]  # (B, kvl)
    k_rope = jnp.einsum("bd,dr->br", x[:, 0], p["wkr"].astype(dt))
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)[:, 0]
    lat_new = jnp.concatenate([ckv, k_rope], axis=-1)[:, None, :]  # (B,1,C+R)
    z = jnp.zeros((), jnp.int32)
    lat = jax.lax.dynamic_update_slice(
        cache.k, lat_new.astype(cache.k.dtype), (z, jnp.asarray(pos, jnp.int32), z)
    )  # (B, S, C+R)
    kv_len = pos + 1

    c_lat = lat[..., : cfg.kv_lora].astype(dt)  # (B,S,C)
    r_lat = lat[..., cfg.kv_lora :].astype(dt)  # (B,S,R)
    # absorb W_UK into q: q_c (B,H,C) = q_nope @ W_UK^T
    q_c = jnp.einsum("bhk,chk->bhc", q_nope, p["wuk"].astype(dt))
    scores = jnp.einsum("bhc,bsc->bhs", q_c.astype(jnp.float32), c_lat.astype(jnp.float32))
    scores += jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), r_lat.astype(jnp.float32))
    scores = scores / ((cfg.d_head + cfg.rope_head) ** 0.5)
    smask = jnp.arange(lat.shape[1])[None, None, :] < kv_len
    scores = jnp.where(smask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    # attend over latents, then absorb W_UV on the way out
    o_lat = jnp.einsum("bhs,bsc->bhc", w, c_lat.astype(jnp.float32)).astype(dt)
    o = jnp.einsum("bhc,chk->bhk", o_lat, p["wuv"].astype(dt))
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(dt))
    return y[:, None, :], KVCache(lat, None)


def mla_cache_spec(cfg, batch, s_max, layers=None):
    shape = (batch, s_max, cfg.kv_lora + cfg.rope_head)
    if layers:
        shape = (layers,) + shape
    return KVCache(k=jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype)), v=None)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder → encoder output)
# ---------------------------------------------------------------------------


def cross_spec(cfg):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    return {
        "wq": P((d, h, dh), ("embed", "heads", None)),
        "wk": P((d, h, dh), ("embed", "heads", None)),
        "wv": P((d, h, dh), ("embed", "heads", None)),
        "wo": P((h, dh, d), ("heads", None, "embed")),
    }


def cross_apply(p, x, enc, cfg):
    dt = cdt(cfg)
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bhtk", enc, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bhtk", enc, p["wv"].astype(dt))
    o = attention_ref(q, k, v, causal=False)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(dt))
