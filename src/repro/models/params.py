"""Parameter-spec machinery: one declaration drives initialization, the
dry-run ShapeDtypeStruct tree, and sharding (logical-axis rules, MaxText
style).

Every parameter is declared as a ``P(shape, logical_axes, …)``.  Logical
axis names are mapped to physical mesh axes by a *rules* dict, so sharding
strategies (TP-only, FSDP×TP, EP, …) are data — hillclimbing swaps rule
tables, not model code.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class P(NamedTuple):
    """Parameter spec: shape + logical axes + init."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):  # pragma: no cover - NamedTuple has no post_init
        pass


# Default logical→physical rules.  `fsdp` variants additionally shard the
# non-contracting large dim over 'data' (ZeRO-3-equivalent under jit).
RULES_TP = {
    "layers": None,
    "embed": None,
    "vocab": "model",
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "experts": None,
    "expert_ffn": "model",
    "conv": None,
    "state": None,
}
RULES_FSDP_TP = dict(RULES_TP, embed="data")
# Expert parallelism: experts over 'model', expert-internal dims replicated.
RULES_EP = dict(RULES_TP, experts="model", expert_ffn=None)
RULES_EP_FSDP = dict(RULES_EP, embed="data")

RULE_SETS = {
    "tp": RULES_TP,
    "fsdp_tp": RULES_FSDP_TP,
    "ep": RULES_EP,
    "ep_fsdp": RULES_EP_FSDP,
}


def logical_to_pspec(axes, rules) -> PartitionSpec:
    phys = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        phys.append(m)
    return PartitionSpec(*phys)


def _leaf_key(path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.key(h)


def init_leaf(spec: P, path: str) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    k = _leaf_key(path)
    scale = spec.scale
    if spec.init == "embed":
        scale = 1.0 / np.sqrt(spec.shape[-1])
    return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, P)


def _walk(tree, path=""):
    if is_spec(tree):
        yield path, tree
        return
    for k in sorted(tree.keys()):
        yield from _walk(tree[k], f"{path}/{k}")


def map_specs(fn, tree):
    """Apply fn(path, P) to every spec leaf, preserving structure."""

    def rec(t, path):
        if is_spec(t):
            return fn(path, t)
        return {k: rec(v, f"{path}/{k}") for k, v in t.items()}

    return rec(tree, "")


def init_params(spec_tree) -> dict:
    return map_specs(lambda p, s: init_leaf(s, p), spec_tree)


def abstract_params(spec_tree) -> dict:
    return map_specs(lambda p, s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree)


def param_shardings(spec_tree, mesh: Mesh, rules) -> dict:
    def shard_one(path, s: P):
        pspec = logical_to_pspec(s.axes, rules)
        # drop shardings that do not divide evenly — replicate that dim
        fixed = []
        for dim, ax in zip(s.shape, pspec):
            if ax is None:
                fixed.append(None)
                continue
            axsize = int(np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
            fixed.append(ax if dim % axsize == 0 else None)
        return NamedSharding(mesh, PartitionSpec(*fixed))

    return map_specs(shard_one, spec_tree)


def count_params(spec_tree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _walk(spec_tree))


def activation_sharding(mesh: Mesh, *axes):
    """with_sharding_constraint helper for activations."""
    return NamedSharding(mesh, PartitionSpec(*axes))
