"""Mixture-of-experts FFN: top-k token-choice routing with static-capacity
sort-based dispatch (GShard/Switch style), shardable two ways:

  * EP  — experts over the `model` axis (`rules='ep'`): dispatch becomes an
    all-to-all in XLA; right when E % model == 0 (deepseek-v3: 256/16).
  * TP  — expert d_ff over `model` (`rules='tp'`): experts replicated,
    within-expert tensor parallel; right when E doesn't divide (granite 40).

The router is a hot skewed dictionary workload: expert-choice frequencies
are Zipfian, which is exactly the contention profile the paper's elimination
targets — serve/pages.py keeps router-stat counters in the Elim-ABtree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import cdt
from repro.models.params import P


def moe_spec(cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    s = {
        "router": P((d, e), ("embed", None)),
        "wi": P((e, d, f), ("experts", "embed", "expert_ffn")),
        "wg": P((e, d, f), ("experts", "embed", "expert_ffn")),
        "wo": P((e, f, d), ("experts", "expert_ffn", "embed")),
    }
    if cfg.n_shared:
        s["shared"] = {
            "wi": P((d, cfg.n_shared * f), ("embed", "ffn")),
            "wg": P((d, cfg.n_shared * f), ("embed", "ffn")),
            "wo": P((cfg.n_shared * f, d), ("ffn", "embed")),
        }
    return s


def _dispatch_ffn(p, xf, cfg, cap: int):
    """Sort-based capacity dispatch + expert SwiGLU over one token group
    xf: (T, d) → (T, d)."""
    dt = cdt(cfg)
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.sum(topw, -1, keepdims=True)  # renormalize over chosen

    # flatten (token, slot) pairs and rank within expert by sorted order
    eid = topi.reshape(-1)  # (T*k,)
    tok = jnp.repeat(jnp.arange(t), k)
    w = topw.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, w_s = eid[order], tok[order], w[order]
    # rank within expert: i - first index of this expert in the sorted list
    first = jnp.searchsorted(eid_s, jnp.arange(e), side="left")  # (E,)
    rank = jnp.arange(t * k) - first[eid_s]
    ok = rank < cap
    slot = jnp.where(ok, eid_s * cap + rank, e * cap)  # overflow → dropped row

    # gather tokens to (E, cap, d)
    xe = jnp.zeros((e * cap + 1, d), dt).at[slot].set(xf[tok_s].astype(dt))
    xe = xe[:-1].reshape(e, cap, d)

    # expert FFN (SwiGLU)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(dt))

    # scatter back with routing weights
    ye_flat = ye.reshape(e * cap, d)
    contrib = ye_flat[jnp.clip(slot, 0, e * cap - 1)] * jnp.where(ok, w_s, 0.0)[:, None].astype(dt)
    return jnp.zeros((t, d), dt).at[tok_s].add(contrib)


def _grouped_dispatch(p, xg, cfg, cap: int):
    """Grouped dispatch with the group dim pinned to the data axes.

    The dispatch scatter has data-dependent indices, which the SPMD
    partitioner cannot prove local — it replicates the (E, cap, d)
    dispatched tensor via giant all-reduces (observed: 64–128 GB/device on
    granite train_4k).  `shard_map` over the (pod, data) axes makes the
    scatter a *local* op on local shapes by construction; the `model` axis
    stays on auto so expert-weight sharding (TP d_ff or EP experts) is
    still handled by the partitioner inside the body."""
    import numpy as np

    from repro.parallel.ctx import _ambient_mesh

    mesh = _ambient_mesh()

    def run(p_, xx):
        return jax.vmap(lambda one: _dispatch_ffn(p_, one, cfg, cap))(xx)

    manual = tuple(a for a in ("pod", "data") if mesh is not None and a in mesh.axis_names)
    shards = int(np.prod([mesh.shape[a] for a in manual])) if manual else 1
    if mesh is None or not manual or xg.shape[0] % shards:
        return run(p, xg)
    from jax.sharding import PartitionSpec as PS

    from repro._shardmap_compat import shard_map_compat

    # shard_map with the manual axes; the model axis stays auto so the
    # partitioner still applies TP/EP weight sharding inside.
    fn = shard_map_compat(
        run,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: PS(), p), PS(manual, None, None)),
        out_specs=PS(manual, None, None),
        manual=manual,
    )
    return fn(p, xg)


def moe_apply(p, x, cfg):
    """x: (B, S, d) → (B, S, d).  Static capacity = T·k/E·cf per expert.

    ``cfg.moe_groups > 0`` enables GROUPED dispatch (§Perf beyond-paper
    optimization): tokens are routed within fixed groups that align with the
    (pod, data) batch sharding, so the sort/gather/scatter of the dispatch
    never crosses a data shard — experts are either replicated (TP rules)
    or model-sharded (EP rules), and in both cases the only cross-shard
    traffic left is the expert matmul's own reduction.  Routing semantics
    are identical except that capacity overflow is evaluated per group
    (same total capacity)."""
    dt = cdt(cfg)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = cfg.moe_groups if cfg.moe_groups and t % cfg.moe_groups == 0 else 1
    cap = int(max(1, round(t / g * k / e * cfg.capacity_factor)))
    xf = x.reshape(t, d)

    if g > 1:
        xg = xf.reshape(g, t // g, d)
        y = _grouped_dispatch(p, xg, cfg, cap)
        y = y.reshape(t, d)
    else:
        y = _dispatch_ffn(p, xf, cfg, cap)

    if cfg.n_shared:
        sp = p["shared"]
        hs = jnp.einsum("td,df->tf", xf, sp["wi"].astype(dt))
        gs = jnp.einsum("td,df->tf", xf, sp["wg"].astype(dt))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(gs) * hs, sp["wo"].astype(dt))

    return y.reshape(b, s, d)


def aux_load_balance_loss(logits: jax.Array, topi: jax.Array, n_experts: int):
    """Switch-style auxiliary load-balancing loss (returned by train_step
    for MoE archs)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jax.nn.one_hot(topi[..., 0], n_experts, dtype=jnp.float32), axis=0
    )  # fraction routed (top-1 proxy)
    return n_experts * jnp.sum(me * ce)
