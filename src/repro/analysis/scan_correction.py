import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Scan-trip-count correction for dry-run cost analysis.

XLA's `cost_analysis()` counts a while-loop body ONCE (verified
empirically: a 10-iteration scanned matmul reports 1 matmul of FLOPs), so
scanned-layer models underreport flops / bytes / collective bytes by ≈ the
layer count.  Unrolling the full depth is exact but prohibitively slow
(yi-34b train: 520 s per compile).

This module measures the per-layer cost with SMALL-depth *unrolled* probe
compiles and fits the linear model

    metric(counts) = out + Σ_stacks counts_i · body_i

probing each stack type at 1 and 2 layers (3 probes for two-stack archs).
The corrected metric for the full config is then `out + Σ L_i·body_i`.
Probes run at the FULL model width/batch on the same mesh — only depth is
reduced — so per-layer sharded costs are exact.

Writes results/scan_correction.json: cid → corrected metrics.
"""
import argparse  # noqa: E402
import json  # noqa: E402
from typing import Dict, List, Tuple  # noqa: E402

import numpy as np  # noqa: E402

from repro.configs import SHAPES, all_cells, get_config  # noqa: E402
from repro.configs.optimized import OPTIMIZED  # noqa: E402

# per family: (probe override dicts, their stack-count vectors, full-count fn)


def _probe_plan(cfg) -> Tuple[List[dict], List[List[int]], List[int]]:
    fam = cfg.family
    if fam in ("dense", "vlm") or (fam == "moe" and not cfg.first_k_dense):
        probes = [{"n_layers": 1}, {"n_layers": 2}]
        counts = [[1], [2]]
        full = [cfg.n_layers]
    elif fam == "moe":  # deepseek: dense prefix + moe stack
        probes = [
            {"first_k_dense": 1, "n_layers": 2},
            {"first_k_dense": 2, "n_layers": 3},
            {"first_k_dense": 1, "n_layers": 3},
        ]
        counts = [[1, 1], [2, 1], [1, 2]]
        full = [cfg.first_k_dense, cfg.n_layers - cfg.first_k_dense]
    elif fam == "ssm":  # xlstm: mlstm + slstm stacks
        probes = [
            {"n_layers": 2, "slstm_every": 2},
            {"n_layers": 3, "slstm_every": 3},
            {"n_layers": 4, "slstm_every": 2},
        ]
        counts = [[1, 1], [2, 1], [2, 2]]
        k = cfg.slstm_every
        n_s = cfg.n_layers // k if k else 0
        full = [cfg.n_layers - n_s, n_s]
    elif fam == "hybrid":  # zamba2: mamba layers + shared-attn invocations
        probes = [
            {"n_layers": 2, "shared_attn_every": 2},
            {"n_layers": 3, "shared_attn_every": 3},
            {"n_layers": 4, "shared_attn_every": 2},
        ]
        counts = [[2, 1], [3, 1], [4, 2]]
        k = cfg.shared_attn_every
        full = [cfg.n_layers, cfg.n_layers // k if k else 0]
    elif fam == "audio":
        return [], [], []  # whisper is already unrolled (scan_layers=False)
    else:
        raise ValueError(fam)
    return probes, counts, full


def _metrics(rec) -> np.ndarray:
    return np.array(
        [
            rec["cost"]["flops"],
            rec["cost"]["bytes_accessed"],
            float(rec["collectives"]["total_bytes"]),
        ]
    )


def correct_cell(arch: str, shape_name: str, mesh_kind: str, overrides=None) -> dict:
    from repro.launch.dryrun import run_cell

    cfg = get_config(arch)
    if overrides:
        ov = dict(overrides)
        ov.pop("param_dtype", None)
        cfg = cfg.replace(**ov)
    probes, counts, full = _probe_plan(cfg)
    if not probes:
        return {"corrected": False, "reason": "unrolled already"}
    ys = []
    for pov in probes:
        o = dict(overrides or {})
        o.update(pov)
        o["scan_layers"] = False
        rec = run_cell(arch, shape_name, mesh_kind, overrides=o)
        ys.append(_metrics(rec))
    a = np.array([[1.0] + [float(c) for c in row] for row in counts])
    y = np.stack(ys)  # (P, 3 metrics)
    coef, *_ = np.linalg.lstsq(a, y, rcond=None)  # (1+stacks, 3)
    want = np.array([1.0] + [float(c) for c in full])
    corrected = want @ coef
    body = coef[1:]
    return {
        "corrected": True,
        "flops": float(corrected[0]),
        "bytes_accessed": float(corrected[1]),
        "collective_bytes": float(max(corrected[2], 0.0)),
        "per_stack_flops": body[:, 0].tolist(),
        "full_counts": full,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/scan_correction.json")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--suite", default="baseline", choices=["baseline", "opt"])
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()

    out_path = args.out if args.suite == "baseline" else args.out.replace(
        ".json", "_opt.json"
    )
    results = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)

    for arch, cfg, shape, status in all_cells():
        if status != "run":
            continue
        if args.arch and arch != args.arch:
            continue
        if args.suite == "opt" and (arch, shape.name) not in OPTIMIZED:
            continue
        cid = f"{arch}|{shape.name}|{args.mesh}"
        if cid in results:
            print(f"skip (cached): {cid}")
            continue
        ov = OPTIMIZED.get((arch, shape.name)) if args.suite == "opt" else None
        print(f"=== correcting {cid} ===", flush=True)
        try:
            results[cid] = correct_cell(arch, shape.name, args.mesh, overrides=ov)
            if results[cid].get("corrected"):
                print(f"  flops → {results[cid]['flops']:.3e}")
        except Exception as e:  # noqa: BLE001
            results[cid] = {"corrected": False, "error": f"{type(e).__name__}: {e}"}
            print(f"  FAIL {results[cid]['error']}")
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
