"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh) cell:

    compute    = HLO_FLOPs            / (chips × peak_FLOP/s)
    memory     = HLO_bytes_accessed   / (chips × HBM_bw)
    collective = collective_op_bytes  / (chips × link_bw)

FLOPs/bytes come from compiled.cost_analysis(); collective bytes are NOT in
cost_analysis, so they are parsed from the optimized HLO text: the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.
"""
from __future__ import annotations

import re
from typing import Dict

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (~what one collective hop sustains)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(pred|[sufc]\d+|bf16)\[([\d,]*)\]")
# `%name = <result-shape(s)> <opcode>(<operands>), ... replica_groups=...`
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(.*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:  # [num_groups, group_size]<=[N]
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:  # {{0,1,2,3},{...}} — size of the first group
        return len(m.group(1).split(","))
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> Dict:
    """Per-device operand bytes per collective opcode, from optimized HLO.

    Optimized HLO lists operands by name only, so operand bytes are derived
    from the result shape + replica group size:
        all-reduce / all-to-all / collective-permute : operand = result
        all-gather                                   : operand = result / G
        reduce-scatter                               : operand = result × G
    Async (-start/-done) pairs are counted once (at -start).
    """
    out = {op: 0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shapes, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        result_bytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shapes)
        )
        if phase == "-start" and result_bytes:
            # start result is a (operand, result) buffer tuple → halve
            result_bytes //= 2
        g = _group_size(line)
        if op == "all-gather":
            nbytes = result_bytes // max(g, 1)
        elif op == "reduce-scatter":
            nbytes = result_bytes * g
        else:
            nbytes = result_bytes
        out[op] += nbytes
        counts[op] += 1
    return {
        "per_op_bytes": out,
        "per_op_count": counts,
        "total_bytes": int(sum(out.values())),
    }


def active_params(cfg) -> float:
    """Parameters touched per token: total minus unselected routed experts
    (expert tensors are stacked (L, E, …) — detect by the E dim)."""
    from repro.models import count_params, model_spec
    from repro.models.params import _walk

    spec = model_spec(cfg)
    total = count_params(spec)
    if not cfg.n_experts:
        return float(total)
    routed = 0
    for path, s in _walk(spec):
        if "/ffn/" in path and "shared" not in path and "router" not in path:
            if cfg.n_experts in s.shape:
                routed += int(_prod(s.shape))
    return float(total - routed + routed * cfg.top_k / cfg.n_experts)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens."""
    active = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * active * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * active * shape.batch * shape.seq
    # decode: one token per sequence
    return 2.0 * active * shape.batch


def analytic_memory_bytes(cfg, shape) -> float:
    """First-principles per-step HBM traffic floor (global): params read
    once (bf16) + decode-cache streamed once + activations written/read
    once per layer.  Reported next to HLO bytes_accessed because the
    CPU-backend HLO counts unfused operand traffic (pessimistic vs a real
    TPU executable — see EXPERIMENTS §Roofline notes)."""
    p_bytes = 2.0 * active_params(cfg)
    if shape.kind == "decode":
        cache = 0.0
        if cfg.attn == "mla":
            cache = cfg.n_layers * shape.batch * shape.seq * (cfg.kv_lora + cfg.rope_head) * 2.0
        elif cfg.family in ("dense", "moe", "vlm", "audio"):
            kv_layers = cfg.n_layers
            cache = kv_layers * shape.batch * cfg.n_kv * shape.seq * cfg.d_head * 2 * 2.0
            if cfg.window:
                cache = kv_layers * shape.batch * cfg.n_kv * min(cfg.window, shape.seq) * cfg.d_head * 2 * 2.0
        elif cfg.family == "hybrid":
            n_inv = cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0
            cache = n_inv * shape.batch * cfg.n_kv * shape.seq * cfg.d_head * 2 * 2.0
        return p_bytes + cache
    tokens = shape.batch * shape.seq
    act = 2.0 * tokens * cfg.d_model * cfg.n_layers * (4 if shape.kind == "train" else 2)
    return p_bytes + act


def _prod(t):
    n = 1
    for x in t:
        n *= x
    return n


def roofline_terms(rec: dict) -> Dict:
    """rec: one dry-run cell record → the three terms in seconds."""
    chips = rec["n_devices"]
    flops = rec["cost"]["flops"]
    mem_bytes = rec["cost"]["bytes_accessed"]
    coll_bytes = rec["collectives"]["total_bytes"]
    # cost_analysis on the host backend reports whole-program numbers for
    # the partitioned module (per-device program), see EXPERIMENTS.md notes.
    t_compute = flops / (PEAK_FLOPS)
    t_memory = mem_bytes / (HBM_BW)
    t_collective = coll_bytes / (ICI_BW)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "chips": chips,
    }
