"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results/dryrun.json
(+ results/scan_correction.json when present — see analysis/scan_correction)."""
from __future__ import annotations

import json
import os
import sys

from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops, roofline_terms
from repro.configs import SHAPES, get_config


def load_corrections(path="results/scan_correction.json"):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def corrected_record(rec: dict, corrections: dict) -> dict:
    """Overlay scan-trip-count-corrected metrics onto a dry-run record."""
    cid = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
    c = corrections.get(cid)
    if not c or not c.get("corrected"):
        return rec
    out = dict(rec)
    out["cost"] = {
        "flops": c["flops"],
        "bytes_accessed": c["bytes_accessed"],
    }
    out["collectives"] = dict(rec["collectives"], total_bytes=c["collective_bytes"])
    out["scan_corrected"] = True
    return out


def _fmt_b(x: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def _fmt_t(x: float) -> str:
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def improvement_hint(cfg, shape, dom: str) -> str:
    if dom == "collective":
        return "overlap/reshard: move the dominant all-gather into the scan body or change param layout"
    if dom == "memory":
        if shape.kind == "decode":
            return "KV/cache streaming is the floor; shrink cache reads (GQA layout, quantized KV)"
        return "fuse elementwise chains / reduce remat re-reads"
    return "compute-bound: MXU utilization is the lever (tile alignment, bf16 matmuls)"


def dryrun_table(results: dict, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | status | params | FLOPs/dev | bytes/dev | coll bytes/dev | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cid, r in sorted(results.items()):
        if r["mesh"] != mesh:
            continue
        if r["status"] != "run":
            lines.append(
                f"| {r['arch']} | {r['shape']} | SKIP ({r['status'].split(':')[1].strip()}) | | | | | |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | ok | {n:.2e} | {f:.2e} | {b} | {c} | {t} |".format(
                arch=r["arch"],
                shape=r["shape"],
                n=r.get("n_params", 0),
                f=r["cost"]["flops"],
                b=_fmt_b(r["cost"]["bytes_accessed"]),
                c=_fmt_b(r["collectives"]["total_bytes"]),
                t=r.get("lower_compile_s", 0),
            )
        )
    return "\n".join(lines)


def roofline_table(results: dict, mesh: str = "single", corrections=None) -> str:
    corrections = corrections if corrections is not None else load_corrections()
    lines = [
        "| arch | shape | t_compute | t_memory | t_coll | dominant | MODEL/HLO | corr | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for cid, r in sorted(results.items()):
        if r["mesh"] != mesh or r["status"] != "run":
            continue
        r = corrected_record(r, corrections)
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        t = roofline_terms(r)
        mf = model_flops(cfg, shape)
        hlo_global = r["cost"]["flops"] * r["n_devices"]
        ratio = mf / hlo_global if hlo_global else 0.0
        lines.append(
            "| {a} | {s} | {tc} | {tm} | {tl} | **{d}** | {ratio:.2f} | {corr} | {hint} |".format(
                a=r["arch"],
                s=r["shape"],
                tc=_fmt_t(t["t_compute_s"]),
                tm=_fmt_t(t["t_memory_s"]),
                tl=_fmt_t(t["t_collective_s"]),
                d=t["dominant"],
                ratio=ratio,
                corr="✓" if r.get("scan_corrected") else "–",
                hint=improvement_hint(cfg, shape, t["dominant"]),
            )
        )
    return "\n".join(lines)


def summary(results: dict) -> dict:
    corrections = load_corrections()
    out = {}
    for cid, r in results.items():
        if r["status"] != "run" or r["mesh"] != "single":
            continue
        r = corrected_record(r, corrections)
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        t = roofline_terms(r)
        tmax = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        frac = t["t_compute_s"] / tmax if tmax else 0.0
        out[cid] = {
            **t,
            "roofline_fraction": frac,
            "model_flops": model_flops(cfg, shape),
        }
    return out


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print("## §Dry-run (single-pod 16×16 = 256 chips)\n")
    print(dryrun_table(results, "single"))
    print("\n## §Dry-run (multi-pod 2×16×16 = 512 chips)\n")
    print(dryrun_table(results, "multi"))
    print("\n## §Roofline (single-pod, per-device terms)\n")
    print(roofline_table(results, "single"))


if __name__ == "__main__":
    main()
