"""AdamW with fully sharded optimizer state.

State mirrors the parameter pytree, so the same NamedShardings as params
apply to m/v — optimizer state is automatically ZeRO-sharded wherever the
parameter rules shard (models/params.py).  Decay masking follows the usual
convention (no decay on 1-D tensors: norms, biases)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params) -> OptState:
    z = lambda p: jnp.zeros_like(p, jnp.float32)
    return OptState(
        m=jax.tree.map(z, params),
        v=jax.tree.map(z, params),
        count=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(
    grads,
    state: OptState,
    params,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1**cf
    bc2 = 1.0 - b2**cf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay and p.ndim >= 2:  # no decay on norms/biases (1-D)
            step = step + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3 and not hasattr(t, "_fields")
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return params_new, OptState(m=m_new, v=v_new, count=count)
