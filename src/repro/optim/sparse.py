"""EmbedElim: publishing elimination applied to sparse embedding updates.

Zipfian token frequency makes embedding-row gradient updates a skewed
update-heavy dictionary workload — the paper's target profile.  In the OCC
analog every (token, grad) pair scatters its own row update (duplicate rows
rewritten k times); elimination combines duplicates first, so each hot row
is written once per batch.  On TPU the combine is a sort + segment-sum —
the same key-sorted segmented structure as core/elimination.py (and the
elim_combine kernel), with "insert(v)" generalized to "accumulate(v)".

`embed_elim_update` returns the updated table plus write statistics so
benchmarks can report the physical-write collapse (benchmarks/embed_elim).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SparseUpdateStats(NamedTuple):
    writes_occ: jax.Array  # rows written without elimination (= #tokens)
    writes_elim: jax.Array  # rows written with elimination (= #unique)
    eliminated: jax.Array


def embed_elim_update(
    table: jax.Array,  # (V, D)
    token_ids: jax.Array,  # (T,)
    row_grads: jax.Array,  # (T, D)
    lr: float | jax.Array,
):
    """Combine duplicate-row grads (sort + segment-sum) then scatter once
    per unique row."""
    t = token_ids.shape[0]
    order = jnp.argsort(token_ids, stable=True)
    ids_s = token_ids[order]
    grads_s = row_grads[order]
    seg_head = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
    seg_id = jnp.cumsum(seg_head.astype(jnp.int32)) - 1
    combined = jax.ops.segment_sum(grads_s, seg_id, num_segments=t)  # (T, D) padded
    # row id per segment (all elements of a segment share it); segments
    # beyond n_unique keep the sentinel row V (dropped below).
    sentinel = jnp.asarray(table.shape[0], ids_s.dtype)
    seg_rows = jnp.full((t,), sentinel).at[seg_id].min(ids_s)

    padded = jnp.concatenate([table, jnp.zeros((1, table.shape[1]), table.dtype)])
    new = padded.at[seg_rows].add((-lr * combined).astype(table.dtype))[:-1]

    n_unique = jnp.sum(seg_head.astype(jnp.int32))
    stats = SparseUpdateStats(
        writes_occ=jnp.asarray(t, jnp.int32),
        writes_elim=n_unique.astype(jnp.int32),
        eliminated=(t - n_unique).astype(jnp.int32),
    )
    return new, stats


def embed_occ_update(table, token_ids, row_grads, lr):
    """OCC analog: scatter every pair individually (duplicate rows written
    multiple times).  Numerically identical; physically k× the writes."""
    return table.at[token_ids].add((-lr * row_grads).astype(table.dtype))
