from repro.optim.adamw import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
)
from repro.optim.schedules import constant_lr, warmup_cosine
from repro.optim.sparse import embed_elim_update

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "warmup_cosine",
    "constant_lr",
    "embed_elim_update",
]
