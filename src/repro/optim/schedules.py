"""Learning-rate schedules (pure fns of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak=3e-4, warmup=100, total=10000, floor=0.1):
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, cos)


def constant_lr(step, *, peak=3e-4, **_):
    return jnp.full_like(step, peak, dtype=jnp.float32)
