from repro.parallel.sharding import (
    batch_pspecs,
    batch_shardings,
    cache_pspecs,
    cache_shardings,
)
from repro.parallel.compress import (
    dequantize_int8,
    quantize_int8,
    compressed_grad_reduce,
)

__all__ = [
    "batch_pspecs",
    "batch_shardings",
    "cache_pspecs",
    "cache_shardings",
    "quantize_int8",
    "dequantize_int8",
    "compressed_grad_reduce",
]
