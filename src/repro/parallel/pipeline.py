"""Pipeline parallelism over the `pod` axis (GPipe-style, collective-permute).

At the assigned scale (512 chips) TP×DP covers every config, so PP is not
enabled by default (DESIGN.md §6); this module provides the working stage
loop for ≥4-pod deployments where the pod axis becomes the PP axis:

  * the layer stack is split into `n_stages` equal groups, stage s resident
    on pod s (params sharded over 'pod' on the stacked-layer dim);
  * microbatches stream through stages; activations hop pods via
    `jax.lax.ppermute` (ICI/DCN point-to-point);
  * the steady state keeps all pods busy except the usual (S-1) bubble
    fill/drain — bubble fraction = (S-1)/(S-1+M) for M microbatches.

`pipeline_apply` is jit-compatible and differentiable (ppermute has a
transpose rule), and is exercised by tests/test_pipeline.py on a host mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def pipeline_apply(
    stage_params,  # pytree stacked on leading dim = n_stages
    x,  # (M, micro_batch, ...) microbatches
    body: Callable,  # body(params_slice, activation) -> activation
    mesh,
    axis: str = "pod",
):
    """Run x through n_stages pipeline stages laid out on `axis`.

    Schedule: for t in range(M + S - 1): every stage processes the
    microbatch it currently holds, then activations shift one pod to the
    right (ppermute ring).  Stage s processes microbatch m at t = m + s.
    """
    n_stages = mesh.shape[axis]
    m = x.shape[0]

    def shard_fn(params, xs):
        # params: this pod's stage slice (leading dim 1); xs: all microbatches
        sp = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        total = m + n_stages - 1

        def step(carry, t):
            acts, outs = carry  # acts: activation currently held (mb, ...)
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, m - 1)
            fresh = xs[mb_idx]
            cur = jnp.where(stage == 0, fresh, acts)
            live = (t - stage >= 0) & (t - stage < m)
            out = body(sp, cur)
            out = jnp.where(live, out, cur)
            # last stage emits; everyone else hands off to the right
            emit_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            is_emit = (stage == n_stages - 1) & live
            outs = outs.at[emit_idx].set(jnp.where(is_emit, out, outs[emit_idx]))
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (nxt, outs), None

        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(
            step, (jnp.zeros_like(xs[0]), outs0), jnp.arange(total)
        )
        # the final outputs live on the last stage; broadcast via psum of
        # one-hot contribution (everyone else holds zeros)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    manual = {axis}
    in_specs = (
        jax.tree.map(lambda _: PS(axis), stage_params),
        PS(),  # microbatches replicated in (activations stream through)
    )
    from repro._shardmap_compat import shard_map_compat

    fn = shard_map_compat(
        shard_fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=PS(),
        manual=manual,
    )
    return fn(stage_params, x)
