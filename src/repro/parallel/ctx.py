"""Ambient-mesh sharding constraints usable from deep inside model code.

`constrain(x, axes...)` resolves axis names against the mesh active in the
enclosing `with mesh:` context: missing axes and non-dividing dims degrade
to replication, and with no mesh at all it is the identity — model code
stays runnable in 1-device tests and host-mesh smoke runs."""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import PartitionSpec


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:  # noqa: BLE001
        pass
    return None


def constrain(x, *axes):
    """axes: one entry per dim — None | axis name | tuple of candidate axis
    names (filtered to those present; dropped unless they divide the dim)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    fixed = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            fixed.append(None)
            continue
        cand = ax if isinstance(ax, tuple) else (ax,)
        sel = tuple(a for a in cand if a in names)
        size = int(np.prod([mesh.shape[a] for a in sel])) if sel else 1
        fixed.append(sel if sel and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*fixed))


DATA_AXES = ("pod", "data")
