"""Cross-pod gradient compression (int8 + error feedback) and hierarchical
reduction — the distributed-optimization layer for the DCN hop.

At 1000+ nodes the cross-pod all-reduce rides DCN links an order of
magnitude slower than ICI, so the standard trick stack applies:

  1. hierarchical reduction: reduce-scatter inside the pod (ICI), cross-pod
     all-reduce only on the 1/|pod-size| scattered shard (DCN), all-gather
     inside the pod (ICI);
  2. int8 compression with per-block scales on the DCN hop only;
  3. error feedback: the quantization residual is carried into the next
     step so compression bias vanishes (1-bit-Adam/EF-SGD lineage).

`compressed_grad_reduce` composes 1–3 under `shard_map` over the pod axis.
It is optional (cfg.grad_compress) — the default jit path lets the SPMD
partitioner insert the reduction.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return out[:n].reshape(shape).astype(dtype)


def _ef_quantize(x, err):
    """Quantize x+err; return (q, scale, new_err)."""
    target = x + err
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale, x.shape, x.dtype)
    return q, scale, target - deq


def compressed_grad_reduce(grads, err, mesh: Mesh, pod_axis: str = "pod"):
    """All-reduce grads over `pod_axis` with int8 compression + error
    feedback.  grads/err: pytrees of equal structure, already reduced over
    the intra-pod data axis.  Returns (reduced grads, new err).

    Runs under shard_map with everything replicated except the pod axis —
    each pod quantizes its local contribution, the int8 payload is summed
    across pods (psum on the int32-accumulated dequantized blocks keeps the
    math exact for ≤ 2^15 pods), then scaled back.
    """
    from jax.experimental.shard_map import shard_map

    npods = mesh.shape[pod_axis]

    def reduce_leaf(g, e):
        q, scale, e_new = _ef_quantize(g, e)
        # transmit int8 payload + fp32 scales: psum the dequantized value
        # (XLA sends the small dequantized partial; the wire-size win is
        # modeled by the payload dtype — see benchmarks/compress_bench).
        deq = dequantize_int8(q, scale, g.shape, jnp.float32)
        total = jax.lax.psum(deq, pod_axis)
        return (total / npods).astype(g.dtype), e_new

    def body(gs, es):
        out = jax.tree.map(reduce_leaf, gs, es)
        g_out = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        e_out = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return g_out, e_out

    spec = jax.tree.map(lambda _: PartitionSpec(), grads)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
        check_rep=False,
    )
    return fn(grads, err)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
