"""Reusable HLO structure audit: count ops in lowered phase programs.

Promotes the ad-hoc sort/gather counting that lived in
``benchmarks/kernels_bench._hlo_op_counts`` into a shared surface used by
both the bench and the no-sort trace tests (``tests/test_tree_descend.py``)
— one place that knows how to lower the round engine's phases and inspect
the resulting StableHLO text.

The audit is also the enforcement arm of the tracer's overhead contract:
because tracing is host-side, ``lower(...).as_text()`` of any phase is
byte-identical with tracing enabled or disabled — ``test_obs.py`` pins
that with :func:`lower_text` snapshots.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, Tuple

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "count_ops",
    "lower_text",
    "audit_search_phases",
    "assert_no_sort",
]

# StableHLO ops worth counting when auditing the search/scan pipeline:
# sorts are the structural cost the device-resident descent removes,
# gathers approximate indexed-load traffic, while/scatter bound control
# and write structure.
DEFAULT_OPS: Tuple[str, ...] = (
    "stablehlo.sort",
    "stablehlo.gather",
    "stablehlo.scatter",
    "stablehlo.while",
)


def count_ops(hlo_text: str, ops: Iterable[str] = DEFAULT_OPS) -> Dict[str, int]:
    """Occurrences of each op mnemonic in lowered StableHLO text."""
    return {op: hlo_text.count(op) for op in ops}


def lower_text(fn, *args, **kwargs) -> str:
    """StableHLO text of ``fn`` lowered at these args (jitted fns expose
    ``.lower`` directly; plain callables are jitted first)."""
    lowered = fn.lower(*args, **kwargs) if hasattr(fn, "lower") else jax.jit(fn).lower(*args, **kwargs)
    return lowered.as_text()


def assert_no_sort(hlo_text: str, what: str = "program") -> None:
    n = hlo_text.count("stablehlo.sort")
    if n:
        raise AssertionError(f"{what}: expected sort-free HLO, found {n} stablehlo.sort op(s)")


def audit_search_phases(ops: Iterable[str] = DEFAULT_OPS) -> Dict[str, Dict[str, int]]:
    """Lower the round engine's search/scan phases on a small populated
    tree and count ``ops`` in each — the audit ``kernels_bench`` records
    and the no-sort tests assert against.

    Returns ``{program_name: {op: count}}`` for:
      * ``scan_descent``       — ``frontier_expand`` (tree_descend path)
      * ``scan_phase.narrow``  — ``rounds._phase_scan_flat`` narrow descent
      * ``search.ref``         — ``rounds._phase_search_combine`` jnp oracle
      * ``search.narrow``      — same phase on the fused narrow path
    """
    from repro.core import ABTree, OP_INSERT, TreeConfig
    from repro.core import rounds as R
    from repro.core.abtree import frontier_expand

    t = ABTree(TreeConfig(capacity=2048, b=8, a=2, max_height=12))
    rng = np.random.default_rng(0)
    keys = rng.choice(10**6, size=600, replace=False).astype(np.int64)
    t.apply_round(np.full(600, OP_INSERT, np.int32), keys, keys)
    lo = jnp.asarray([0, 10**5], jnp.int64)
    hi = jnp.asarray([10**4, 10**6], jnp.int64)
    fe = jax.jit(
        functools.partial(frontier_expand, frontier_cap=16), static_argnums=(1,)
    )
    batch = (
        jnp.zeros((256,), jnp.int32) + np.int32(OP_INSERT),
        jnp.asarray(rng.integers(0, 10**6, 256), jnp.int64),
        jnp.zeros((256,), jnp.int64),
    )
    # the flat ragged scan phase runs on the STACKED state with per-lane
    # shard ids (ABTree is the S=1 stack; both lanes expand in shard 0)
    sid = jnp.zeros(2, jnp.int32)
    programs = {
        "scan_descent": fe.lower(t.state, t.cfg, lo, hi).as_text(),
        "scan_phase.narrow": R._phase_scan_flat.lower(
            t.stacked, t.cfg, sid, lo, hi, 16, 32, True, True
        ).as_text(),
        "search.ref": R._phase_search_combine.lower(
            t.state, batch, t.cfg, False
        ).as_text(),
        "search.narrow": R._phase_search_combine.lower(
            t.state, batch, t.cfg, True
        ).as_text(),
    }
    return {name: count_ops(txt, ops) for name, txt in programs.items()}
