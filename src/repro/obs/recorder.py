"""Flight recorder: bounded ring buffer of semantic per-round audit records.

The tracer (``repro.obs.tracer``) answers *how long* each phase took; the
recorder answers *what the engine decided*: which lanes carried which ops,
which insert/delete pairs the publishing-elimination combiner annihilated,
why an occ sub-round or a scan validation retried, and which structural
transitions (shard split, cold-merge, boundary rebalance) the forest's
repartition state machine took.  One record per executed round, in arrival
order, is enough to replay the engine's chosen linearization through the
``DictOracle`` — that replay is the witness checker in
``repro.obs.witness``.

Overhead contract (pinned by ``tests/test_obs.py``, same shape as the
tracer's):

  * **Disabled** (``enabled=False`` — the shared ``NULL_RECORDER``):
    every recording method returns immediately after one attribute check;
    nothing is allocated and nothing is retained.  The recorder never
    appears inside ``jax.jit`` — records are captured host-side at round
    boundaries from values the engine already materialised — so the
    jitted round lowers to byte-identical HLO with recording on or off.
  * **Enabled**: one bounded ``deque`` append of plain-python lists per
    round (the ring drops the oldest record at capacity).  Measured
    in-bench: ≤ 5% ops/s on quick YCSB-A s4 (gated in
    ``benchmarks/ycsb.py``).

Record schema (one JSON object per line in the exported ``.jsonl``; see
``src/repro/obs/README.md`` for the field-by-field contract):

  ``{"kind": "round", "seq": int, "round": int, "mode": "elim"|"occ",
    "n_shards": int, "ops": [int], "keys": [int], "vals": [int],
    "results": [int], "found": [bool],
    "scans": {lane: [[k, v], ...]}|null, "scan_cap": int|null,
    "elim": [{"eliminated": [per-shard], "segments": [...]}]|null,
    "occ": {"subrounds": int, "active_per_subround": [int]}|null,
    "scan_phase": {"retries": int, "attempts": int}|null}``

  ``{"kind": "transition", "seq": int, "event": "split"|"merge"|
    "rebalance"|"repartition_pending", ...}``

  ``{"kind": "commit", "seq": int, "commit_idx": int, "rounds": int,
    "rounds_absorbed": int}``  (``rounds_absorbed`` > 1 marks a GROUP
    commit: that many journal rounds rode one manifest rename)

  ``{"kind": "fault", "seq": int, "site": str, "fault": "eio"|"enospc"|
    "torn"|"rename_fail"|"latency"|"crash"}``

``seq`` is the recorder's own monotone event counter; round records also
carry the holder's round number as ``round``.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Deque, List, Optional

import numpy as np

__all__ = ["Recorder", "NULL_RECORDER", "DEFAULT_CAPACITY"]

# Default ring size: big enough for any crash-matrix window and the quick
# benchmarks' full histories, small enough to stay off the allocator's radar.
DEFAULT_CAPACITY = 4096


def _int_list(x) -> List[int]:
    return np.asarray(x).astype(np.int64).tolist()


class Recorder:
    """Bounded ring buffer of semantic round-audit records.

    The enabled recorder is always-on and cheap (host-side list copies of
    arrays the round engine already pulled off-device); holders construct
    one by default.  The disabled ``NULL_RECORDER`` is the zero-cost
    opt-out (assign ``Recorder(enabled=False)``) and the engine's fallback
    for holders that carry no recorder at all.
    """

    def __init__(self, enabled: bool = True, *, capacity: int = DEFAULT_CAPACITY):
        self.enabled = enabled
        self.capacity = int(capacity)
        self._records: Deque[dict] = deque(maxlen=self.capacity)
        self._seq = 0
        # per-round scratch the engine's inner phases append to; drained
        # into the next ``round()`` record (combines can run several times
        # per round in occ mode).
        self._pending_elim: List[dict] = []
        self._pending_occ: Optional[dict] = None
        self._pending_scan: Optional[dict] = None

    # -- recording -------------------------------------------------------------

    def _push(self, rec: dict) -> None:
        rec["seq"] = self._seq
        self._seq += 1
        self._records.append(rec)

    def note_elim(self, note: dict) -> None:
        """One combine's elimination summary (per-shard eliminated counts +
        multi-op key segments with their net action) — attached to the
        enclosing round record when it is emitted."""
        if not self.enabled:
            return
        self._pending_elim.append(note)

    def note_occ(self, **fields) -> None:
        """The enclosing round's occ sub-round structure."""
        if not self.enabled:
            return
        self._pending_occ = fields

    def note_scan_phase(self, **fields) -> None:
        """The enclosing round's scan-phase validation outcome (retried
        lane count, attempts taken)."""
        if not self.enabled:
            return
        self._pending_scan = fields

    def round(
        self,
        *,
        round_no: int,
        mode: str,
        n_shards: int,
        ops,
        keys,
        vals,
        results,
        found,
        scans: Optional[dict] = None,
        scan_cap: Optional[int] = None,
        fused: Optional[str] = None,
    ) -> None:
        """One executed round, lanes in arrival order.  ``results``/
        ``found`` are the engine's answers for each lane; ``scans`` maps
        range-lane index -> ascending ``[k, v]`` pairs.  Arrival order IS
        the engine's chosen linearization — the witness replays exactly
        this record through the ``DictOracle``.  Pending elim/occ/scan
        notes from the round's inner phases are drained into the record."""
        if not self.enabled:
            return
        rec = {
            "kind": "round",
            "round": int(round_no),
            "mode": mode,
            "n_shards": int(n_shards),
            "ops": _int_list(ops),
            "keys": _int_list(keys),
            "vals": _int_list(vals),
            "results": _int_list(results),
            "found": np.asarray(found).astype(bool).tolist(),
            "scans": (
                None
                if scans is None
                else {
                    str(i): [[int(k), int(v)] for k, v in rows]
                    for i, rows in scans.items()
                }
            ),
            "scan_cap": scan_cap,
            "elim": self._pending_elim or None,
            "occ": self._pending_occ,
            "scan_phase": self._pending_scan,
        }
        if fused is not None:
            rec["fused"] = fused
        self._pending_elim = []
        self._pending_occ = None
        self._pending_scan = None
        self._push(rec)

    def transition(self, event: str, **fields) -> None:
        """Forest state-machine transition: shard split, cold-merge,
        boundary rebalance, repartition trigger."""
        if not self.enabled:
            return
        rec = {"kind": "transition", "event": event}
        for k, v in fields.items():
            rec[k] = v
        self._push(rec)

    def commit(self, commit_idx: int, rounds: int, **fields) -> None:
        """Durable manifest commit marker linking the audit stream to the
        journal's commit index (crash forensics anchor)."""
        if not self.enabled:
            return
        rec = {"kind": "commit", "commit_idx": int(commit_idx), "rounds": int(rounds)}
        for k, v in fields.items():
            rec[k] = v
        self._push(rec)

    def fault(self, site: str, kind: str, **fields) -> None:
        """One injected (or detected) durability fault at a commit I/O
        site — interleaves with round/commit records so forensics show
        exactly which commit attempt the fault hit.  May be called from a
        flush-pool thread: one deque append, safe under the GIL."""
        if not self.enabled:
            return
        rec = {"kind": "fault", "site": site, "fault": kind}
        for k, v in fields.items():
            rec[k] = v
        self._push(rec)

    # -- reading ---------------------------------------------------------------

    def records(self) -> List[dict]:
        """Materialised copy of the ring's current contents (oldest first)."""
        return list(self._records)

    def snapshot(self) -> dict:
        """Summary for ``stats()`` stitching — cheap, no record payloads."""
        rounds = sum(1 for r in self._records if r.get("kind") == "round")
        return {
            "enabled": self.enabled,
            "capacity": self.capacity,
            "events": len(self._records),
            "rounds": rounds,
            "seq": self._seq,
        }

    # -- lifecycle -------------------------------------------------------------

    def clear(self) -> None:
        self._records.clear()

    def export(self, path: str) -> str:
        """Write one JSON object per line (``.jsonl``), oldest first."""
        with open(path, "w") as f:
            for rec in self._records:
                f.write(json.dumps(rec) + "\n")
        return path

    def dump_records(self) -> List[str]:
        """JSONL lines without touching the filesystem (sidecar payload)."""
        return [json.dumps(rec) for rec in self._records]

    @staticmethod
    def load(path: str) -> List[dict]:
        """Parse an exported ``.jsonl`` (or forensics sidecar) back into
        records, tolerating trailing blank lines."""
        out: List[dict] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out


# The disabled singleton holders fall back to when no recorder is installed.
NULL_RECORDER = Recorder(enabled=False)
