"""Per-shard metrics registry: counters, gauges, histograms, one snapshot.

The engine's observable state used to be scattered across host ints
(``_rounds`` / ``_scans`` / ``_scan_retries``), the durable layer's
``DurableStats`` dataclass, and the device-resident ``TreeStats``.  The
registry absorbs all of them behind one queryable surface:

  * **counters** — monotone ints, optionally attributed to a shard
    (``inc("scan_retries", 3, shard=2)`` updates both the global counter
    and shard 2's cell).  The legacy holder attributes are properties
    backed by these counters, so the two surfaces can never drift.
  * **gauges** — last-write-wins values (pool capacity, live keys).
  * **histograms** — value reservoirs with percentile summaries (fsync
    latency, serve tick latency).
  * **collectors** — callables merged into ``snapshot()`` at query time;
    holders register one that drains the device ``TreeStats`` and the
    derived rates (retries/op, elimination rate, waves/round), so reading
    the snapshot is the only device sync metrics ever cause.

Shard attribution is positional (shard index).  A forest shard split
shifts indices ≥ the insert point up by one via :meth:`insert_shard`, so
per-shard history stays attributed to the shard that did the work.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = ["MetricsRegistry", "RegistryBackedCounters", "engine_collector"]


def _percentile(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(round(q * (len(sorted_vals) - 1)))))
    return float(sorted_vals[i])


class MetricsRegistry:
    def __init__(self):
        self._counters: Dict[str, int] = {}
        self._shard_counters: Dict[str, Dict[int, int]] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}
        self._collectors: List[Callable[[], dict]] = []

    # -- counters --------------------------------------------------------------

    def inc(self, name: str, n: int = 1, *, shard: Optional[int] = None) -> None:
        self._counters[name] = self._counters.get(name, 0) + int(n)
        if shard is not None:
            per = self._shard_counters.setdefault(name, {})
            per[int(shard)] = per.get(int(shard), 0) + int(n)

    def set_counter(self, name: str, value: int) -> None:
        """Absolute write — the legacy ``holder._rounds = v`` setter path."""
        self._counters[name] = int(value)

    def inc_shard(self, name: str, n: int, shard: int) -> None:
        """Per-shard attribution WITHOUT touching the global counter — for
        counters whose global total is written elsewhere (the legacy
        ``holder._scan_retries += n`` property path), so the per-shard
        cells always sum to the global value instead of doubling it."""
        per = self._shard_counters.setdefault(name, {})
        per[int(shard)] = per.get(int(shard), 0) + int(n)

    def value(self, name: str, *, shard: Optional[int] = None) -> int:
        if shard is not None:
            return self._shard_counters.get(name, {}).get(int(shard), 0)
        return self._counters.get(name, 0)

    def per_shard(self, name: str, n_shards: int) -> List[int]:
        per = self._shard_counters.get(name, {})
        return [per.get(s, 0) for s in range(n_shards)]

    # -- gauges ----------------------------------------------------------------

    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    # -- histograms ------------------------------------------------------------

    def observe(self, name: str, value: float) -> None:
        self._hists.setdefault(name, []).append(float(value))

    def histogram_summary(self, name: str) -> dict:
        vals = sorted(self._hists.get(name, []))
        return {
            "count": len(vals),
            "sum": float(np.sum(vals)) if vals else 0.0,
            "min": vals[0] if vals else 0.0,
            "max": vals[-1] if vals else 0.0,
            "p50": _percentile(vals, 0.50),
            "p90": _percentile(vals, 0.90),
            "p99": _percentile(vals, 0.99),
        }

    # -- shard lifecycle -------------------------------------------------------

    def insert_shard(self, at: int) -> None:
        """A forest shard split inserted a fresh shard at index ``at``:
        shift every per-shard cell with index ≥ ``at`` up by one so
        attribution follows the shards, not the positions."""
        for per in self._shard_counters.values():
            for s in sorted((s for s in per if s >= at), reverse=True):
                per[s + 1] = per.pop(s)

    def remove_shard(self, at: int) -> None:
        """A cold-shard merge retired the shard at index ``at``: drop its
        cells and shift every per-shard cell with index > ``at`` down by
        one so attribution keeps following the surviving shards."""
        for per in self._shard_counters.values():
            per.pop(at, None)
            for s in sorted(s for s in per if s > at):
                per[s - 1] = per.pop(s)

    # -- snapshot --------------------------------------------------------------

    def add_collector(self, fn: Callable[[], dict]) -> None:
        """``fn()`` is merged (top-level keys) into every ``snapshot()``."""
        self._collectors.append(fn)

    def snapshot(self) -> dict:
        """One queryable view of everything: raw counters, per-shard
        breakdowns, gauges, histogram summaries, plus every registered
        collector's output (device stats, derived rates)."""
        out = {
            "counters": dict(self._counters),
            "per_shard": {
                name: {str(s): v for s, v in sorted(per.items())}
                for name, per in self._shard_counters.items()
            },
            "gauges": dict(self._gauges),
            "histograms": {
                name: self.histogram_summary(name) for name in self._hists
            },
        }
        for fn in self._collectors:
            for k, v in fn().items():
                out[k] = v
        return out


class RegistryBackedCounters:
    """Mixin for round-engine holders: the legacy host counters become
    properties over the holder's ``metrics`` registry, so the legacy
    surface (``tree._rounds``, ``stats()['scan_retries']``) and the
    registry can never drift — they are one store.  ``__init__`` must set
    ``self.metrics = MetricsRegistry()`` before the first assignment."""

    @property
    def _rounds(self) -> int:
        return self.metrics.value("rounds")

    @_rounds.setter
    def _rounds(self, v: int) -> None:
        self.metrics.set_counter("rounds", v)

    @property
    def _scans(self) -> int:
        return self.metrics.value("scans")

    @_scans.setter
    def _scans(self, v: int) -> None:
        self.metrics.set_counter("scans", v)

    @property
    def _scan_retries(self) -> int:
        return self.metrics.value("scan_retries")

    @_scan_retries.setter
    def _scan_retries(self, v: int) -> None:
        self.metrics.set_counter("scan_retries", v)


def engine_collector(holder):
    """Snapshot collector for a round-engine holder: merges the holder's
    ``stats()`` dict (device TreeStats summed over shards + the legacy
    host counters) and the derived rates the engine's claims are stated
    in — retries/op, elimination rate, structural waves per round."""

    def collect() -> dict:
        st = holder.stats()
        reg = holder.metrics
        waves = reg.value("split_waves") + reg.value("underfull_waves")
        return {
            "engine": st,
            "derived": {
                "retries_per_op": st.get("scan_retries", 0)
                / max(1, st.get("scans", 0)),
                "elim_rate": st.get("eliminated", 0)
                / max(1, st.get("searches", 0)),
                "waves_per_round": waves / max(1, st.get("rounds", 0)),
            },
        }

    return collect
