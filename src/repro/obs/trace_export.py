"""Chrome trace-event JSON export + schema validation.

The ``Tracer`` already records events in Chrome trace-event form, so
export is a dump wrapped in the standard ``{"traceEvents": [...]}``
envelope plus process/thread name metadata (``ph="M"``) naming track 0
"engine" and track ``1+s`` "shard s".  The resulting file loads directly
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

``validate_trace`` is the schema check the trace tests and the report
CLI share: it verifies the envelope, the per-event required fields, and
the phase-specific fields (``dur`` on complete events, ``s`` on
instants) without any external schema library.
"""
from __future__ import annotations

import json
from typing import Dict, List

__all__ = ["to_chrome_trace", "write_chrome_trace", "validate_trace"]

_VALID_PH = {"X", "i", "C", "M"}


def to_chrome_trace(tracer) -> dict:
    """Build the ``{"traceEvents": [...]}`` document from a Tracer."""
    tids = sorted({int(ev.get("tid", 0)) for ev in tracer.events})
    meta: List[Dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": tracer.pid,
            "tid": 0,
            "args": {"name": "repro round engine"},
        }
    ]
    for tid in tids:
        meta.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": tracer.pid,
                "tid": tid,
                "args": {"name": "engine" if tid == 0 else f"shard {tid - 1}"},
            }
        )
    return {
        "traceEvents": meta + list(tracer.events),
        "displayTimeUnit": "ms",
    }


def write_chrome_trace(path: str, tracer) -> str:
    doc = to_chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def validate_trace(doc: dict) -> List[str]:
    """Return a list of schema violations (empty == valid).

    Accepts either the envelope dict or a parsed JSON file's contents.
    """
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"trace document must be a dict, got {type(doc).__name__}"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing or non-list 'traceEvents'"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: missing name")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                errs.append(f"{where}: missing int {field}")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                errs.append(f"{where}: missing numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: complete event needs dur >= 0")
        if ph == "i" and ev.get("s") not in (None, "t", "p", "g"):
            errs.append(f"{where}: instant scope must be t/p/g")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                errs.append(f"{where}: counter event needs args values")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"{where}: args must be an object")
    return errs


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
