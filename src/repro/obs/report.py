"""Phase/shard breakdown report from a Chrome trace-event file.

    PYTHONPATH=src python -m repro.obs.report results/trace_ycsb_a.json

Validates the trace schema first (non-zero exit on violations), then
renders two tables: total/mean duration per span name (track 0, the
engine's sequencing thread) and per-shard lane attribution (instants on
tracks 1+s).  This is the quick look; load the same file in Perfetto for
the timeline view.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from repro.obs.trace_export import load_trace, validate_trace

__all__ = ["render_report", "main"]


def render_report(doc: dict) -> str:
    spans = defaultdict(lambda: [0, 0.0])  # name -> [count, total_us]
    shard_lanes = defaultdict(lambda: defaultdict(int))  # name -> shard -> lanes
    shard_events = defaultdict(lambda: defaultdict(int))  # name -> shard -> count
    packs = []  # (width, real, pad_waste) per router_pack span
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        tid = int(ev.get("tid", 0))
        if ph == "X" and tid == 0:
            agg = spans[ev["name"]]
            agg[0] += 1
            agg[1] += float(ev.get("dur", 0.0))
            if ev["name"] == "router_pack":
                args = ev.get("args") or {}
                if "width" in args:
                    packs.append(
                        (
                            int(args["width"]),
                            int(args.get("real", 0)),
                            float(args.get("pad_waste", 0.0)),
                        )
                    )
        elif ph == "i" and tid >= 1:
            s = tid - 1
            shard_events[ev["name"]][s] += 1
            shard_lanes[ev["name"]][s] += int((ev.get("args") or {}).get("lanes", 0))

    lines = []
    lines.append("phase breakdown (engine track)")
    lines.append(f"  {'span':<24} {'count':>7} {'total_ms':>10} {'mean_us':>10}")
    for name, (cnt, tot) in sorted(spans.items(), key=lambda kv: -kv[1][1]):
        lines.append(
            f"  {name:<24} {cnt:>7} {tot / 1e3:>10.3f} {tot / max(cnt, 1):>10.1f}"
        )
    if not spans:
        lines.append("  (no spans)")

    lines.append("")
    lines.append("per-shard attribution (lane counts)")
    all_shards = sorted({s for per in shard_lanes.values() for s in per})
    if all_shards:
        hdr = "  " + f"{'event':<24}" + "".join(f"{'s' + str(s):>10}" for s in all_shards)
        lines.append(hdr)
        for name in sorted(shard_lanes):
            row = f"  {name:<24}"
            for s in all_shards:
                row += f"{shard_lanes[name][s]:>10}"
            lines.append(row)
    else:
        lines.append("  (no per-shard events)")

    # ragged router packing: how much padding did shipped lane blocks carry?
    # (the gauges router_pack_width / pad_waste_frac hold the latest pack;
    # this table aggregates every pack span the trace recorded.)
    lines.append("")
    lines.append("router pack stats (ragged batching)")
    if packs:
        n = len(packs)
        mean_w = sum(p[0] for p in packs) / n
        mean_r = sum(p[1] for p in packs) / n
        mean_waste = sum(p[2] for p in packs) / n
        lines.append(
            f"  {'packs':>7} {'mean_width':>11} {'mean_real':>10} {'mean_pad_waste':>15}"
        )
        lines.append(f"  {n:>7} {mean_w:>11.1f} {mean_r:>10.1f} {mean_waste:>15.3f}")
    else:
        lines.append("  (no router_pack spans)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Validate + summarize a Chrome trace-event file.",
    )
    ap.add_argument("trace", help="path to a trace JSON exported by Tracer.export")
    args = ap.parse_args(argv)

    doc = load_trace(args.trace)
    errs = validate_trace(doc)
    if errs:
        for e in errs[:20]:
            print(f"schema error: {e}", file=sys.stderr)
        print(f"{len(errs)} schema violation(s) in {args.trace}", file=sys.stderr)
        return 1
    print(f"{args.trace}: {len(doc.get('traceEvents', []))} events, schema OK")
    print(render_report(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
