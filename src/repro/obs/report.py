"""Phase/shard breakdown report from a Chrome trace-event file, plus the
crash-forensics explain-report for audit sidecars.

    PYTHONPATH=src python -m repro.obs.report results/trace_ycsb_a.json
    PYTHONPATH=src python -m repro.obs.report --json results/trace.json
    PYTHONPATH=src python -m repro.obs.report journal_dir/audit_00000042.jsonl

Trace files: validates the schema first (non-zero exit on violations),
then renders two tables: total/mean duration per span name (track 0, the
engine's sequencing thread) and per-shard lane attribution (instants on
tracks 1+s).  ``--json`` emits the same summary as one machine-readable
JSON object (CI consumes this instead of scraping the tables); the
exit-code contract is unchanged.

Audit sidecars (``*.jsonl``, from the flight recorder or a recovered
journal): renders the committed-prefix explain-report — round/lane/elim
counts, occ sub-round structure, scan retries, structural transitions —
the "what did the engine decide before the crash" view.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.obs.trace_export import load_trace, validate_trace

__all__ = ["render_report", "report_summary", "render_forensics", "main"]


def report_summary(doc: dict) -> dict:
    """The report's aggregates as one JSON-ready dict (the ``--json``
    surface; ``render_report`` renders exactly this)."""
    spans = defaultdict(lambda: [0, 0.0])  # name -> [count, total_us]
    shard_lanes = defaultdict(lambda: defaultdict(int))  # name -> shard -> lanes
    packs = []  # (width, real, pad_waste) per router_pack span
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        tid = int(ev.get("tid", 0))
        if ph == "X" and tid == 0:
            agg = spans[ev["name"]]
            agg[0] += 1
            agg[1] += float(ev.get("dur", 0.0))
            if ev["name"] == "router_pack":
                args = ev.get("args") or {}
                if "width" in args:
                    packs.append(
                        (
                            int(args["width"]),
                            int(args.get("real", 0)),
                            float(args.get("pad_waste", 0.0)),
                        )
                    )
        elif ph == "i" and tid >= 1:
            s = tid - 1
            shard_lanes[ev["name"]][s] += int((ev.get("args") or {}).get("lanes", 0))

    out = {
        "events": len(doc.get("traceEvents", [])),
        "phases": {
            name: {
                "count": cnt,
                "total_ms": tot / 1e3,
                "mean_us": tot / max(cnt, 1),
            }
            for name, (cnt, tot) in spans.items()
        },
        "per_shard_lanes": {
            name: {str(s): n for s, n in sorted(per.items())}
            for name, per in shard_lanes.items()
        },
        "router_pack": None,
    }
    if packs:
        n = len(packs)
        out["router_pack"] = {
            "packs": n,
            "mean_width": sum(p[0] for p in packs) / n,
            "mean_real": sum(p[1] for p in packs) / n,
            "mean_pad_waste": sum(p[2] for p in packs) / n,
        }
    return out


def render_report(doc: dict) -> str:
    s = report_summary(doc)
    lines = []
    lines.append("phase breakdown (engine track)")
    lines.append(f"  {'span':<24} {'count':>7} {'total_ms':>10} {'mean_us':>10}")
    phases = sorted(s["phases"].items(), key=lambda kv: -kv[1]["total_ms"])
    for name, agg in phases:
        lines.append(
            f"  {name:<24} {agg['count']:>7} {agg['total_ms']:>10.3f} "
            f"{agg['mean_us']:>10.1f}"
        )
    if not phases:
        lines.append("  (no spans)")

    lines.append("")
    lines.append("per-shard attribution (lane counts)")
    shard_lanes = s["per_shard_lanes"]
    all_shards = sorted({int(sh) for per in shard_lanes.values() for sh in per})
    if all_shards:
        hdr = "  " + f"{'event':<24}" + "".join(
            f"{'s' + str(sh):>10}" for sh in all_shards
        )
        lines.append(hdr)
        for name in sorted(shard_lanes):
            row = f"  {name:<24}"
            for sh in all_shards:
                row += f"{shard_lanes[name].get(str(sh), 0):>10}"
            lines.append(row)
    else:
        lines.append("  (no per-shard events)")

    # ragged router packing: how much padding did shipped lane blocks carry?
    # (the gauges router_pack_width / pad_waste_frac hold the latest pack;
    # this table aggregates every pack span the trace recorded.)
    lines.append("")
    lines.append("router pack stats (ragged batching)")
    rp = s["router_pack"]
    if rp:
        lines.append(
            f"  {'packs':>7} {'mean_width':>11} {'mean_real':>10} {'mean_pad_waste':>15}"
        )
        lines.append(
            f"  {rp['packs']:>7} {rp['mean_width']:>11.1f} {rp['mean_real']:>10.1f} "
            f"{rp['mean_pad_waste']:>15.3f}"
        )
    else:
        lines.append("  (no router_pack spans)")
    return "\n".join(lines)


# ----------------------------------------------------------------------------
# forensics explain-report (audit sidecars)
# ----------------------------------------------------------------------------


def forensics_summary(records) -> dict:
    """Aggregate an audit-record stream (recorder export or recovered
    forensics sidecar) into the committed-prefix summary."""
    out = {
        "sidecar": None,
        "rounds": 0,
        "lanes": 0,
        "eliminated": 0,
        "scan_lanes": 0,
        "scan_retries": 0,
        "occ_subrounds": 0,
        "transitions": defaultdict(int),
        "faults": defaultdict(int),
        "commits": 0,
        "rounds_absorbed": 0,  # rounds carried by those commits (group commit)
        "max_group": 0,  # deepest commit group observed
        "first_round": None,
        "last_round": None,
        "modes": defaultdict(int),
    }
    for rec in records:
        kind = rec.get("kind")
        if kind == "sidecar":
            out["sidecar"] = {
                "commit_idx": rec.get("commit_idx"),
                "rounds": rec.get("rounds"),
                "backend": rec.get("backend"),
            }
        elif kind == "round":
            out["rounds"] += 1
            out["modes"][rec.get("mode", "?")] += 1
            r = rec.get("round")
            if r is not None:
                if out["first_round"] is None:
                    out["first_round"] = r
                out["last_round"] = r
            out["lanes"] += sum(1 for op in rec.get("ops", []) if op)
            out["scan_lanes"] += len(rec.get("scans") or {})
            for note in rec.get("elim") or []:
                out["eliminated"] += sum(int(x) for x in note.get("eliminated", []))
            if rec.get("occ"):
                out["occ_subrounds"] += int(rec["occ"].get("subrounds", 0))
            if rec.get("scan_phase"):
                out["scan_retries"] += int(rec["scan_phase"].get("retries", 0))
        elif kind == "transition":
            name = rec.get("event", "?")
            if rec.get("action"):
                name = f"{name}:{rec['action']}"
            if rec.get("state"):  # durability degraded / reattached
                name = f"{name}:{rec['state']}"
            out["transitions"][name] += 1
        elif kind == "fault":
            out["faults"][f"{rec.get('site', '?')}:{rec.get('fault', '?')}"] += 1
        elif kind == "commit":
            out["commits"] += 1
            absorbed = int(rec.get("rounds_absorbed", 1))
            out["rounds_absorbed"] += absorbed
            out["max_group"] = max(out["max_group"], absorbed)
    out["transitions"] = dict(out["transitions"])
    out["faults"] = dict(out["faults"])
    out["modes"] = dict(out["modes"])
    return out


def render_forensics(records) -> str:
    s = forensics_summary(records)
    lines = ["committed-prefix forensics (flight recorder)"]
    if s["sidecar"]:
        sc = s["sidecar"]
        lines.append(
            f"  sidecar: commit {sc['commit_idx']} · {sc['backend']} · "
            f"{sc['rounds']} rounds committed"
        )
    lines.append(
        f"  rounds recorded: {s['rounds']}"
        + (
            f" (round {s['first_round']} … {s['last_round']})"
            if s["first_round"] is not None
            else ""
        )
    )
    lines.append(
        f"  lanes: {s['lanes']} ({s['scan_lanes']} range)  ·  "
        f"eliminated ops: {s['eliminated']}  ·  scan retries: {s['scan_retries']}"
    )
    if s["occ_subrounds"]:
        lines.append(f"  occ sub-rounds: {s['occ_subrounds']}")
    if s["commits"]:
        depth = s["rounds_absorbed"] / s["commits"]
        lines.append(
            f"  durable commit markers: {s['commits']}  ·  "
            f"group depth: {depth:.1f} rounds/commit (max {s['max_group']})"
        )
    if s["transitions"]:
        lines.append("  structural transitions:")
        for name, n in sorted(s["transitions"].items()):
            lines.append(f"    {name:<28} {n}")
    if s["faults"]:
        lines.append("  injected faults:")
        for name, n in sorted(s["faults"].items()):
            lines.append(f"    {name:<28} {n}")
    if s["modes"]:
        modes = ", ".join(f"{m}×{n}" for m, n in sorted(s["modes"].items()))
        lines.append(f"  modes: {modes}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Validate + summarize a Chrome trace-event file, or "
        "explain an audit sidecar (.jsonl).",
    )
    ap.add_argument(
        "trace",
        help="trace JSON exported by Tracer.export, or an audit .jsonl "
        "(recorder export / forensics sidecar)",
    )
    ap.add_argument(
        "--json",
        action="store_true",
        help="emit the summary as one machine-readable JSON object",
    )
    args = ap.parse_args(argv)

    if args.trace.endswith(".jsonl"):
        from repro.obs.recorder import Recorder

        try:
            records = Recorder.load(args.trace)
        except (OSError, ValueError) as e:
            print(f"unreadable audit log {args.trace}: {e}", file=sys.stderr)
            return 1
        if args.json:
            print(json.dumps({"audit": forensics_summary(records)}))
        else:
            print(f"{args.trace}: {len(records)} audit records")
            print(render_forensics(records))
        return 0

    doc = load_trace(args.trace)
    errs = validate_trace(doc)
    if errs:
        for e in errs[:20]:
            print(f"schema error: {e}", file=sys.stderr)
        print(f"{len(errs)} schema violation(s) in {args.trace}", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps({"trace": args.trace, **report_summary(doc)}))
        return 0
    print(f"{args.trace}: {len(doc.get('traceEvents', []))} events, schema OK")
    print(render_report(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
