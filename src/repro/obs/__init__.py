"""Round-engine telemetry: phase tracing, per-shard metrics, exporters,
and the semantic flight recorder + linearizability witness.

Cooperating layers, all host-side (nothing here ever enters a jitted
program — the overhead guarantee the spy tests pin):

  * :mod:`repro.obs.tracer` — ``Tracer``: span timers around every phase of
    the ``core/rounds.py`` pipeline (``scan → search/combine → apply →
    retry → rebalance``, plus occ sub-rounds, structural waves, router
    pack/stitch, journal flushes, manifest commits, serve ticks).  Fences
    with ``jax.block_until_ready`` ONLY when enabled; disabled it is a
    single attribute check returning a shared no-op span.
  * :mod:`repro.obs.metrics` — ``MetricsRegistry``: counters / gauges /
    histograms with optional per-shard attribution, one queryable
    ``snapshot()`` absorbing the engine's scattered counter surfaces
    (``_rounds`` / ``_scans`` / ``_scan_retries`` / ``DurableStats`` /
    device ``TreeStats``).
  * :mod:`repro.obs.recorder` — ``Recorder``: always-on bounded ring of
    semantic per-round audit records (lane ops/keys/results, elimination
    pairings, occ sub-round structure, scan validation outcomes, forest
    transitions).  Disabled it follows the tracer's exact no-op contract.
  * :mod:`repro.obs.witness` — replays a recorded history through the
    sequential ``DictOracle`` and verifies the engine's chosen
    linearization is a legal sequential history (CLI:
    ``python -m repro.obs.witness audit.jsonl``).
  * :mod:`repro.obs.trace_export` / :mod:`repro.obs.report` /
    :mod:`repro.obs.hlo_audit` — Chrome trace-event JSON (Perfetto-
    loadable), the phase/shard breakdown + forensics CLI (``--json`` for
    machines), and the reusable HLO sort/gather audit.

See ``src/repro/obs/README.md`` for the contract and overhead guarantees.
"""
from repro.obs.metrics import (
    MetricsRegistry,
    RegistryBackedCounters,
    engine_collector,
)
from repro.obs.recorder import NULL_RECORDER, Recorder
from repro.obs.tracer import NULL_TRACER, Tracer

__all__ = [
    "MetricsRegistry",
    "RegistryBackedCounters",
    "Tracer",
    "NULL_TRACER",
    "Recorder",
    "NULL_RECORDER",
    "engine_collector",
]
