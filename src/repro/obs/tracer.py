"""Low-overhead span tracer for the host-sequenced round engine.

The round engine is host code sequencing jitted phase kernels, so tracing
lives entirely on the host: a span wraps one phase's kernel launch and
fences (``jax.block_until_ready``) before taking the end timestamp, so the
recorded duration covers the device work, not just the dispatch.

Overhead contract (pinned by ``tests/test_obs.py``):

  * **Disabled** (``enabled=False``, or no tracer installed on the
    holder): ``span()`` returns a shared no-op context manager; ``fence``
    is the identity; NO ``block_until_ready`` is ever issued and nothing
    is recorded.  Because the tracer never appears inside ``jax.jit``,
    the jitted round lowers to byte-identical HLO with tracing on or off
    — zero added device ops, zero recompiles.
  * **Enabled**: one ``perf_counter`` pair + one dict append per span,
    plus the explicit fences.  Fencing serializes host/device overlap, so
    an enabled tracer is a measurement tool, not a production default.

Events use the Chrome trace-event model (complete events ``ph="X"``,
instants ``ph="i"``, counters ``ph="C"``) so export is a dump, not a
transform — see ``repro.obs.trace_export``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax

__all__ = ["Tracer", "NULL_TRACER"]


class _NullSpan:
    """Shared do-nothing span: the whole disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, x):
        return x

    def note(self, **kw):
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "shard", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, shard, args: dict):
        self._tracer = tracer
        self.name = name
        self.shard = shard
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def fence(self, x):
        """Block until ``x``'s device work completes (enabled path only):
        the span's duration then covers the kernels it launched."""
        return jax.block_until_ready(x)

    def note(self, **kw):
        """Attach key/values to the span's args (visible in the trace)."""
        self.args.update(kw)

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        tr.events.append(
            {
                "name": self.name,
                "ph": "X",
                "ts": (self._t0 - tr._epoch) * 1e6,
                "dur": (t1 - self._t0) * 1e6,
                "pid": tr.pid,
                "tid": 0 if self.shard is None else 1 + int(self.shard),
                "args": self.args,
            }
        )
        return False


class Tracer:
    """Span/instant/counter recorder in Chrome trace-event form.

    ``tid`` convention: track 0 is the engine's sequencing thread (phase
    spans); track ``1 + s`` is shard ``s``'s attribution track (per-shard
    instants/counters).  ``export(path)`` writes Perfetto-loadable JSON.
    """

    def __init__(self, enabled: bool = True, *, pid: int = 0):
        self.enabled = enabled
        self.pid = pid
        self.events: List[Dict] = []
        self._epoch = time.perf_counter()

    # -- recording -------------------------------------------------------------

    def span(self, name: str, *, shard: Optional[int] = None, **args):
        """Context manager timing one phase.  ``with tracer.span("apply")
        as sp: out = kernel(...); sp.fence(out)``."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, shard, args)

    def instant(self, name: str, *, shard: Optional[int] = None, **args):
        """Zero-duration marker (per-shard attribution events)."""
        if not self.enabled:
            return
        self.events.append(
            {
                "name": name,
                "ph": "i",
                "s": "t",
                "ts": (time.perf_counter() - self._epoch) * 1e6,
                "pid": self.pid,
                "tid": 0 if shard is None else 1 + int(shard),
                "args": args,
            }
        )

    def counter(self, name: str, value, *, shard: Optional[int] = None):
        """Chrome counter-track sample (rendered as a graph in Perfetto)."""
        if not self.enabled:
            return
        self.events.append(
            {
                "name": name,
                "ph": "C",
                "ts": (time.perf_counter() - self._epoch) * 1e6,
                "pid": self.pid,
                "tid": 0 if shard is None else 1 + int(shard),
                "args": {"value": float(value)},
            }
        )

    def shard_marks(self, name: str, per_shard, **extra):
        """One instant per shard with non-zero work: the per-shard
        attribution of a vmapped phase (the vmap spans all shards in one
        launch, so per-shard *time* is unobservable from the host — lane
        counts are the honest per-shard cost signal)."""
        if not self.enabled:
            return
        for s, n in enumerate(per_shard):
            if int(n):
                self.instant(name, shard=s, lanes=int(n), **extra)

    # -- lifecycle -------------------------------------------------------------

    def clear(self):
        self.events.clear()
        self._epoch = time.perf_counter()

    def export(self, path: str) -> str:
        """Write the Chrome trace-event JSON file (Perfetto-loadable)."""
        from repro.obs.trace_export import write_chrome_trace

        return write_chrome_trace(path, self)


# The disabled singleton holders fall back to when no tracer is installed.
NULL_TRACER = Tracer(enabled=False)
