"""Linearizability witness: replay a recorded audit history and verify it.

The flight recorder (``repro.obs.recorder``) captures one record per round
in the engine's chosen linearization (arrival order per key; scans
linearized at round start).  This module replays those records through the
sequential ``DictOracle`` and verifies that every recorded per-lane result
— including the return values of elim-annihilated insert/delete pairs —
is exactly what a legal sequential history would have produced.  That
turns the paper's linearizability claim into a checked property of the
recorded history itself, not just of the test suite's synthetic rounds.

What is checked, per round record:

  * every point lane's ``results[i]`` / ``found[i]`` equals the oracle's
    §3 dictionary semantics applied in arrival order (insert returns the
    existing value on presence, delete returns the removed value, find
    the current value — NOTFOUND/absent otherwise);
  * every range lane's recorded rows equal the oracle's snapshot scan of
    ``[lo, lo+span)`` at round start, clipped to the recorded
    ``scan_cap`` (scans linearize before the round's writes);
  * elim notes are structurally consistent: a round's per-shard
    eliminated counts never exceed its update-lane count.

A history that fails any check is NOT a legal linearization of the
recorded operations — the checker raises :class:`WitnessError` (CLI exit
code 1).  The negative tests in ``tests/test_witness.py`` corrupt a valid
history (swap an eliminated insert/delete pair's results, drop a delete)
and prove the checker rejects it.

The replay needs the history from its true start: a ring that dropped old
rounds cannot be replayed from an empty oracle.  ``check_history`` detects
this (first round record's ``seq`` preceded by evicted round records is
undetectable in general, so callers size the recorder's ring to the run —
the benchmarks' ``--audit`` legs do).

CLI::

    python -m repro.obs.witness audit.jsonl        # exit 0 iff valid
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Sequence

from repro.core.abtree import NOTFOUND, OP_DELETE, OP_INSERT, OP_NOP, OP_RANGE
from repro.core.oracle import DictOracle
from repro.obs.recorder import Recorder

__all__ = ["WitnessError", "WitnessReport", "check_history", "main"]

_NOTFOUND = int(NOTFOUND)


class WitnessError(AssertionError):
    """The recorded history is not a legal sequential history."""


class WitnessReport:
    """Outcome of a successful replay."""

    def __init__(self, rounds: int, lanes: int, eliminated: int, state: dict,
                 prefix_states=None):
        self.rounds = rounds  # round records replayed
        self.lanes = lanes  # non-NOP lanes verified
        self.eliminated = eliminated  # elim-annihilated update ops audited
        self.state = state  # oracle contents after the full history
        # with collect_prefixes: oracle contents after each round prefix
        # (prefix_states[r] = state after the first r rounds; [0] = empty).
        # The fault-soak's recovery check: a recovered tree's contents must
        # equal SOME committed prefix state of the witnessed history.
        self.prefix_states = prefix_states

    def summary(self) -> str:
        return (
            f"witness OK: {self.rounds} rounds, {self.lanes} lanes verified, "
            f"{self.eliminated} eliminated ops, {len(self.state)} live keys"
        )


def _check_round(oracle: DictOracle, rec: dict, idx: int) -> int:
    """Replay one round record; returns the verified lane count."""
    ops = rec["ops"]
    keys = rec["keys"]
    vals = rec["vals"]
    results = rec["results"]
    found = rec["found"]
    if not (len(ops) == len(keys) == len(vals) == len(results) == len(found)):
        raise WitnessError(f"record {idx}: ragged lane arrays")
    cap = rec.get("scan_cap")
    exp_res, exp_found, exp_scans = oracle.apply_mixed_round(ops, keys, vals, cap=cap)
    scans = rec.get("scans") or {}
    lanes = 0
    for i, op in enumerate(ops):
        if op == int(OP_NOP):
            continue
        lanes += 1
        if op == int(OP_RANGE):
            got_rows = scans.get(str(i))
            want_rows = [[int(k), int(v)] for k, v in exp_scans[i]]
            if got_rows is not None and got_rows != want_rows:
                raise WitnessError(
                    f"record {idx} (round {rec.get('round')}): range lane {i} "
                    f"[{keys[i]}, {keys[i]}+{vals[i]}) returned rows "
                    f"{got_rows[:4]}… but a sequential history scans "
                    f"{want_rows[:4]}…"
                )
            # the count/found surface must agree even when rows were elided
            if int(results[i]) != len(want_rows) or bool(found[i]) != bool(want_rows):
                raise WitnessError(
                    f"record {idx} (round {rec.get('round')}): range lane {i} "
                    f"count {results[i]} != sequential count {len(want_rows)}"
                )
            continue
        if int(results[i]) != int(exp_res[i]) or bool(found[i]) != bool(exp_found[i]):
            raise WitnessError(
                f"record {idx} (round {rec.get('round')}): lane {i} "
                f"op {op} key {keys[i]} returned "
                f"(result={results[i]}, found={found[i]}) but the arrival-order "
                f"linearization gives (result={exp_res[i]}, found={exp_found[i]})"
            )
    return lanes


def _check_elim_notes(rec: dict, idx: int) -> int:
    """Structural audit of the round's elimination notes; returns the
    eliminated-op count attributed to this round."""
    notes = rec.get("elim") or []
    n_upd = sum(1 for op in rec["ops"] if op in (int(OP_INSERT), int(OP_DELETE)))
    total = 0
    for note in notes:
        total += sum(int(x) for x in note.get("eliminated", []))
        for seg in note.get("segments", []):
            if len(seg.get("lanes", [])) < 2:
                raise WitnessError(
                    f"record {idx}: elim segment for key {seg.get('key')} "
                    f"claims a pairing with < 2 update ops"
                )
    if total > n_upd:
        raise WitnessError(
            f"record {idx}: {total} ops eliminated but only {n_upd} "
            f"update lanes in the round"
        )
    return total


def check_history(records: Sequence[dict], *,
                  collect_prefixes: bool = False) -> WitnessReport:
    """Replay every round record through the oracle; raise
    :class:`WitnessError` on the first illegal transition.  With
    ``collect_prefixes`` the report also carries the oracle state after
    every round prefix — the committed-prefix candidates a crash-recovered
    tree must land on (``benchmarks/fault_soak.py``)."""
    oracle = DictOracle()
    rounds = lanes = eliminated = 0
    prefixes = [dict(oracle.items())] if collect_prefixes else None
    for idx, rec in enumerate(records):
        if rec.get("kind") != "round":
            continue
        lanes += _check_round(oracle, rec, idx)
        eliminated += _check_elim_notes(rec, idx)
        rounds += 1
        if collect_prefixes:
            prefixes.append(dict(oracle.items()))
    return WitnessReport(rounds, lanes, eliminated, oracle.items(), prefixes)


def check_file(path: str) -> WitnessReport:
    return check_history(Recorder.load(path))


def main(argv: List[str] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.witness",
        description="Verify a recorded audit history is a legal sequential "
        "history (linearizability witness).",
    )
    p.add_argument("audit", help="audit .jsonl (recorder export or forensics sidecar)")
    p.add_argument(
        "-q", "--quiet", action="store_true", help="suppress the success summary"
    )
    args = p.parse_args(argv)
    try:
        report = check_file(args.audit)
    except WitnessError as e:
        print(f"{args.audit}: WITNESS FAILED: {e}", file=sys.stderr)
        return 1
    except (OSError, ValueError, KeyError) as e:
        print(f"{args.audit}: unreadable audit log: {e}", file=sys.stderr)
        return 1
    if not args.quiet:
        print(f"{args.audit}: {report.summary()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
