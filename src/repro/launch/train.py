"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 100 --batch 8 --seq 256 --reduced --ckpt /tmp/ckpt

On the CPU container use --reduced (tiny same-family config); on a real
slice drop it and pass --mesh to pick the production topology.  Training
auto-resumes from the newest durable checkpoint in --ckpt.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.data import make_data_iter
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import reduced
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None, help="inject a crash (testing)")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = {
        "host": make_host_mesh,
        "single": lambda: make_production_mesh(multi_pod=False),
        "multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()

    tcfg = TrainerConfig(
        ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every,
        max_steps=args.steps,
        microbatch=args.microbatch,
        fail_at_step=args.fail_at,
    )
    mk_iter = lambda step: make_data_iter(
        cfg, batch=args.batch, seq=args.seq, start_step=step
    )
    trainer = Trainer(cfg, tcfg, mesh, mk_iter)
    if trainer.resumed_from is not None:
        print(f"resumed from durable checkpoint at step {trainer.resumed_from}")
    out = trainer.run()
    print(json.dumps(out, indent=1, default=str))


if __name__ == "__main__":
    main()
