"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Topology: TPU v5e, 256 chips per pod arranged (16, 16); the multi-pod mesh
prepends a `pod` axis (DCN/superpod links).  At larger scale the same
function extends: pods×16×16 with `pod` as the pure-DP (or PP) axis —
DESIGN.md §6.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for smoke tests / local runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
