"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation (the dry-run pattern)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import backbone
from repro.models.config import ModelConfig


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Returns (kind, specs) where specs are the abstract arguments for the
    corresponding step function."""
    b, s = shape.batch, shape.seq
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "vlm":
            batch["vis_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vis_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return batch
    # decode: one new token against a cache of length s
    return {
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": backbone.cache_spec(cfg, b, s),
    }
