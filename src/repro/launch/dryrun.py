import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()
# The two lines above MUST run before any other import (jax locks the device
# count at first backend init).

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

For each cell this script
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. constructs abstract params / batch / cache (ShapeDtypeStructs — no
     allocation) with their NamedShardings,
  3. lowers + compiles the corresponding step function,
  4. records memory_analysis(), cost_analysis(), and the collective-bytes
     breakdown parsed from the optimized HLO,
  5. appends the record to the results JSON (resumable cache: cells already
     present are skipped unless --force).

Usage:
  python -m repro.launch.dryrun                    # everything (slow)
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --list             # show cells + status
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, all_cells, cell_status, get_config  # noqa: E402
from repro.launch.inputs import input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results")


def _cell_id(arch, shape, mesh_kind):
    return f"{arch}|{shape}|{mesh_kind}"


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides=None) -> dict:
    from repro.analysis.roofline import collective_bytes_from_hlo

    cfg = get_config(arch)
    overrides = dict(overrides or {})
    param_dtype = overrides.pop("param_dtype", None)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": status,
        "kind": shape.kind,
    }
    if status != "run":
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    from repro.models import abstract_params, backbone, count_params
    from repro.models.params import RULE_SETS, param_shardings
    from repro.train.step import make_prefill_step, make_serve_step, make_train_step

    spec_tree = backbone.model_spec(cfg)
    aparams = abstract_params(spec_tree)
    if param_dtype:  # serving-weight dtype override (§Perf)
        import jax.numpy as jnp

        aparams = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.dtype(param_dtype)), aparams
        )
    rec["n_params"] = count_params(spec_tree)

    with mesh:
        if shape.kind == "train":
            from repro.optim.adamw import OptState
            import jax.numpy as jnp

            jit_maker, sh = make_train_step(cfg, mesh)
            batch = input_specs(cfg, shape)
            aopt = OptState(
                m=aparams, v=aparams, count=jax.ShapeDtypeStruct((), jnp.int32)
            )
            astep = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jit_maker(batch).lower(aparams, aopt, batch, astep)
        elif shape.kind == "prefill":
            jit_maker, sh = make_prefill_step(cfg, mesh)
            batch = input_specs(cfg, shape)
            lowered = jit_maker(batch).lower(aparams, batch)
        else:  # decode
            import jax.numpy as jnp

            jitted, sh = make_serve_step(cfg, mesh, shape.batch, shape.seq)
            specs = input_specs(cfg, shape)
            lowered = jitted.lower(
                aparams, specs["cache"], specs["tokens"], specs["pos"]
            )
        compiled = lowered.compile()

    rec["lower_compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k, 0) or 0)
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    rec["cost"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0))),
    }
    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes_from_hlo(hlo)
    rec["n_devices"] = 512 if mesh_kind == "multi" else 256
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--suite", default="baseline", choices=["baseline", "opt"],
                    help="opt = §Perf hillclimb configs (configs/optimized.py)")
    args = ap.parse_args()

    fname = "dryrun.json" if args.suite == "baseline" else "dryrun_opt.json"
    out_path = args.out or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", fname)
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)

    if args.list:
        for arch, cfg, shape, status in all_cells():
            for mk in ("single", "multi"):
                cid = _cell_id(arch, shape.name, mk)
                done = "✓" if cid in results and results[cid].get("ok") else " "
                print(f"[{done}] {cid}: {status}")
        return

    from repro.configs.optimized import OPTIMIZED, overrides_for

    cells = []
    for arch, cfg, shape, status in all_cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        if args.suite == "opt" and (arch, shape.name) not in OPTIMIZED:
            continue
        for mk in ("single", "multi"):
            if args.mesh and mk != args.mesh:
                continue
            cells.append((arch, shape.name, mk))

    for arch, shape_name, mk in cells:
        cid = _cell_id(arch, shape_name, mk)
        if not args.force and cid in results and results[cid].get("ok"):
            print(f"skip (cached): {cid}")
            continue
        print(f"=== {cid} ===", flush=True)
        try:
            ov = overrides_for(arch, shape_name) if args.suite == "opt" else None
            rec = run_cell(arch, shape_name, mk, overrides=ov)
            if ov:
                rec["overrides"] = ov
            rec["ok"] = True
            if rec["status"] == "run":
                print(
                    f"  ok in {rec['lower_compile_s']}s; flops={rec['cost']['flops']:.3e} "
                    f"coll_bytes={rec['collectives']['total_bytes']:.3e}"
                    if "cost" in rec
                    else "  ok"
                )
            else:
                print(f"  {rec['status']}")
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            rec = {
                "arch": arch,
                "shape": shape_name,
                "mesh": mk,
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"  FAIL: {rec['error']}")
        results[cid] = rec
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok → {out_path}")


if __name__ == "__main__":
    main()
