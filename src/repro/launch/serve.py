"""Serving launcher: continuous-batching engine with the Elim-ABtree
prefix index.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --requests 16 --index elim
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.models import reduced
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--index", default="elim", choices=["elim", "occ"])
    ap.add_argument("--hot-frac", type=float, default=0.7,
                    help="fraction of requests sharing a hot system prompt")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), n_layers=2)
    eng = ServeEngine(cfg, max_batch=4, s_max=128, n_pages=256, index_mode=args.index)
    rng = np.random.default_rng(0)
    hot_prompt = rng.integers(0, cfg.vocab, 16).tolist()
    for rid in range(args.requests):
        if rng.random() < args.hot_frac:
            prompt = list(hot_prompt)
        else:
            prompt = rng.integers(0, cfg.vocab, 16).tolist()
        eng.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    done = eng.run_until_done()
    print(json.dumps(eng.stats(), indent=1))
    print(f"completed {len(done)} requests")


if __name__ == "__main__":
    main()
