from repro.data.pipeline import DataConfig, make_data_iter, synthetic_batches
from repro.data.workloads import WorkloadConfig, op_stream, zipf_keys

__all__ = [
    "DataConfig",
    "make_data_iter",
    "synthetic_batches",
    "WorkloadConfig",
    "zipf_keys",
    "op_stream",
]
