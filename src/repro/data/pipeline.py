"""Deterministic, resumable synthetic data pipeline.

Production properties kept even for synthetic data:
  * per-host deterministic sharding: host h of H draws disjoint index
    ranges (seed, step, host) → identical global batch under any host
    count that divides the batch;
  * resumable: iterators are constructed at (step) and reproduce the exact
    batch sequence after restart (checkpoint/restart correctness tested in
    test_fault_tolerance);
  * dedup hook: the Elim-ABtree seen-key index filters repeated documents
    (data/dedup.py path in benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    host: int = 0
    n_hosts: int = 1
    family: str = "dense"
    d_model: int = 0
    enc_frames: int = 0
    vis_tokens: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host])
    )


def synthetic_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    """Zipfian token stream (matches real-text frequency skew — also what
    makes EmbedElim effective)."""
    local_b = cfg.batch // cfg.n_hosts
    step = start_step
    while True:
        rng = _batch_rng(cfg, step)
        toks = rng.zipf(1.3, size=(local_b, cfg.seq)).astype(np.int64)
        toks = np.minimum(toks - 1, cfg.vocab - 1).astype(np.int32)
        batch = {"tokens": toks}
        if cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (local_b, cfg.enc_frames, cfg.d_model)
            ).astype(np.float32)
        if cfg.family == "vlm":
            batch["vis_embeds"] = rng.standard_normal(
                (local_b, cfg.vis_tokens, cfg.d_model)
            ).astype(np.float32)
        yield batch
        step += 1


def make_data_iter(model_cfg, batch: int, seq: int, *, seed=0, start_step=0):
    cfg = DataConfig(
        vocab=model_cfg.vocab,
        batch=batch,
        seq=seq,
        seed=seed,
        family=model_cfg.family,
        d_model=model_cfg.d_model,
        enc_frames=model_cfg.enc_frames,
        vis_tokens=model_cfg.vis_tokens,
    )
    return synthetic_batches(cfg, start_step)
