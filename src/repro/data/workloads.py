"""Dictionary-operation workload generators for the paper's benchmarks
(SetBench-style): uniform / Zipfian key streams × update fraction."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.abtree import OP_DELETE, OP_FIND, OP_INSERT


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    key_range: int = 10_000
    update_frac: float = 1.0  # inserts+deletes fraction (rest = finds)
    dist: str = "uniform"  # uniform | zipf
    zipf_s: float = 1.0
    batch: int = 256
    seed: int = 0


def zipf_keys(rng: np.random.Generator, n: int, key_range: int, s: float):
    """Bounded Zipf(s) over [0, key_range) via inverse-CDF sampling (exact,
    unlike np.random.zipf which is unbounded)."""
    ranks = np.arange(1, key_range + 1, dtype=np.float64)
    w = 1.0 / np.power(ranks, s)
    cdf = np.cumsum(w) / np.sum(w)
    u = rng.random(n)
    return np.searchsorted(cdf, u).astype(np.int64)


def op_stream(cfg: WorkloadConfig, n_rounds: int):
    """Yields (ops, keys, vals) rounds."""
    rng = np.random.default_rng(cfg.seed)
    # precompute zipf cdf once
    if cfg.dist == "zipf":
        ranks = np.arange(1, cfg.key_range + 1, dtype=np.float64)
        w = 1.0 / np.power(ranks, cfg.zipf_s)
        cdf = np.cumsum(w) / np.sum(w)
    for _ in range(n_rounds):
        if cfg.dist == "zipf":
            keys = np.searchsorted(cdf, rng.random(cfg.batch)).astype(np.int64)
        else:
            keys = rng.integers(0, cfg.key_range, cfg.batch).astype(np.int64)
        u = rng.random(cfg.batch)
        ops = np.where(
            u < cfg.update_frac / 2,
            OP_INSERT,
            np.where(u < cfg.update_frac, OP_DELETE, OP_FIND),
        ).astype(np.int32)
        vals = rng.integers(0, 1 << 30, cfg.batch).astype(np.int64)
        yield ops, keys, vals


def prefill_tree(tree, cfg: WorkloadConfig, target_frac: float = 0.5):
    """Prefill to the expected steady-state size (paper methodology)."""
    rng = np.random.default_rng(cfg.seed + 999)
    n = int(cfg.key_range * target_frac)
    keys = rng.choice(cfg.key_range, size=n, replace=False).astype(np.int64)
    bs = 1024
    for i in range(0, n, bs):
        chunk = keys[i : i + bs]
        tree.apply_round(
            np.full(chunk.size, OP_INSERT, np.int32), chunk, chunk
        )
    return tree
