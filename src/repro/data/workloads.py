"""Dictionary-operation workload generators for the paper's benchmarks
(SetBench-style): uniform / Zipfian key streams × update fraction, plus the
YCSB-E scan-heavy mix served by the range-scan subsystem."""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.abtree import OP_DELETE, OP_FIND, OP_INSERT, OP_NOP, OP_RANGE


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    key_range: int = 10_000
    update_frac: float = 1.0  # inserts+deletes fraction (rest = finds)
    dist: str = "uniform"  # uniform | zipf
    zipf_s: float = 1.0
    batch: int = 256
    seed: int = 0


@functools.lru_cache(maxsize=32)
def _zipf_cdf(key_range: int, s: float) -> np.ndarray:
    """Inverse-CDF table for bounded Zipf(s) over [0, key_range) — built
    once per (key_range, s); every sampler below shares it."""
    ranks = np.arange(1, key_range + 1, dtype=np.float64)
    w = 1.0 / np.power(ranks, s)
    return np.cumsum(w) / np.sum(w)


def zipf_keys(rng: np.random.Generator, n: int, key_range: int, s: float):
    """Bounded Zipf(s) over [0, key_range) via inverse-CDF sampling (exact,
    unlike np.random.zipf which is unbounded)."""
    return np.searchsorted(_zipf_cdf(key_range, s), rng.random(n)).astype(np.int64)


def _sample_keys(rng: np.random.Generator, cfg: WorkloadConfig) -> np.ndarray:
    if cfg.dist == "zipf":
        return zipf_keys(rng, cfg.batch, cfg.key_range, cfg.zipf_s)
    return rng.integers(0, cfg.key_range, cfg.batch).astype(np.int64)


def op_stream(cfg: WorkloadConfig, n_rounds: int):
    """Yields (ops, keys, vals) rounds."""
    rng = np.random.default_rng(cfg.seed)
    for _ in range(n_rounds):
        keys = _sample_keys(rng, cfg)
        u = rng.random(cfg.batch)
        ops = np.where(
            u < cfg.update_frac / 2,
            OP_INSERT,
            np.where(u < cfg.update_frac, OP_DELETE, OP_FIND),
        ).astype(np.int32)
        vals = rng.integers(0, 1 << 30, cfg.batch).astype(np.int64)
        yield ops, keys, vals


def ycsb_e_stream(
    cfg: WorkloadConfig,
    n_rounds: int,
    scan_frac: float = 0.95,
    max_span: int = 64,
):
    """YCSB Workload-E analog: ``scan_frac`` short range scans (start key
    from the configured distribution, span uniform in [1, max_span]) and
    the remainder inserts.  Rounds are genuinely mixed: OP_RANGE rows
    encode lo = key, span = val — exactly the round engine's fused lane
    encoding, so each round feeds straight into ``ABTree.apply_round``
    (one fused round per batch).  ``split_scan_round`` remains only as the
    split-path baseline for A/B comparisons."""
    rng = np.random.default_rng(cfg.seed)
    for _ in range(n_rounds):
        keys = _sample_keys(rng, cfg)
        u = rng.random(cfg.batch)
        ops = np.where(u < scan_frac, OP_RANGE, OP_INSERT).astype(np.int32)
        spans = rng.integers(1, max_span + 1, cfg.batch).astype(np.int64)
        vals = np.where(
            ops == OP_RANGE, spans, rng.integers(0, 1 << 30, cfg.batch)
        ).astype(np.int64)
        yield ops, keys, vals


def split_scan_round(ops: np.ndarray, keys: np.ndarray, vals: np.ndarray):
    """Split one mixed round into its scan half and its point-op half.

    BASELINE ONLY: the round engine executes mixed batches fused (one
    ``ABTree.apply_round`` call, scans linearized before the round's
    writes), so the hot path never splits.  This helper survives as the
    split-path baseline for A/B benchmarks (``benchmarks/ycsb.py
    --scan-path split``), which runs every batch as TWO rounds.

    Returns ``((lo, hi), (ops', keys', vals'))``: OP_RANGE rows become
    ``[lo, lo + span)`` scan intervals (for ``ABTree.scan_round``); in the
    point-op arrays they are masked to OP_NOP so per-op result positions
    are preserved for ``apply_round``."""
    ops = np.asarray(ops)
    keys = np.asarray(keys)
    vals = np.asarray(vals)
    is_scan = ops == OP_RANGE
    lo = keys[is_scan]
    hi = lo + np.maximum(vals[is_scan], 1)
    point_ops = np.where(is_scan, OP_NOP, ops).astype(np.int32)
    return (lo, hi), (point_ops, keys, np.where(is_scan, 0, vals))


def prefill_tree(tree, cfg: WorkloadConfig, target_frac: float = 0.5):
    """Prefill to the expected steady-state size (paper methodology)."""
    rng = np.random.default_rng(cfg.seed + 999)
    n = int(cfg.key_range * target_frac)
    keys = rng.choice(cfg.key_range, size=n, replace=False).astype(np.int64)
    bs = 1024
    for i in range(0, n, bs):
        chunk = keys[i : i + bs]
        tree.apply_round(
            np.full(chunk.size, OP_INSERT, np.int32), chunk, chunk
        )
    return tree
