"""Sample dedup via the Elim-ABtree seen-key index.

Training pipelines dedup documents by content hash; the hash stream is
heavily skewed (boilerplate, templates) — again the paper's workload.  The
index answers "seen before?" for a whole batch in one round: inserts of
already-present hashes return the prior value (found=True) without a write
— the elimination path does the per-key collapse when a batch itself
contains duplicates."""
from __future__ import annotations

import hashlib
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.abtree import ABTree, OP_INSERT, TreeConfig


def content_hash(tokens: Sequence[int]) -> int:
    h = hashlib.blake2b(np.asarray(tokens, np.int32).tobytes(), digest_size=8)
    return int.from_bytes(h.digest(), "little") >> 1


class DedupIndex:
    def __init__(self, capacity: int = 1 << 15, mode: str = "elim"):
        self.tree = ABTree(TreeConfig(capacity=capacity), mode=mode)
        self.seen = 0
        self.dups = 0

    def filter_batch(self, docs: List[Sequence[int]]) -> Tuple[List[int], dict]:
        """Returns indices of NEW documents; duplicates (within the batch or
        vs history) are dropped."""
        if not docs:
            return [], {}
        hashes = [content_hash(d) for d in docs]
        out = self.tree.apply_round(
            [OP_INSERT] * len(docs), hashes, list(range(self.seen, self.seen + len(docs)))
        )
        found = np.asarray(out.found)
        keep = [i for i in range(len(docs)) if not found[i]]
        self.seen += len(docs)
        self.dups += int(found.sum())
        return keep, {"seen": self.seen, "duplicates": self.dups, **self.tree.stats()}
