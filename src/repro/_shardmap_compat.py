"""Version-compat wrapper for shard_map.

Newer jax exposes ``jax.shard_map(..., axis_names=..., check_vma=...)``;
the jax pinned in some environments only has
``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)``.
This module has no repro-internal imports so both ``repro.models`` and
``repro.parallel`` can use it without import cycles.
"""
from __future__ import annotations

import jax


def shard_map_compat(fn, *, mesh, in_specs, out_specs, manual):
    """shard_map ``fn`` with the given ``manual`` axis names; every other
    mesh axis stays auto (the partitioner shards inside the body).
    Replication checking is disabled on both API spellings."""
    manual = set(manual)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=manual,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
        auto=frozenset(mesh.axis_names) - manual,
    )
