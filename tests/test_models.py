"""Per-architecture smoke tests: REDUCED same-family configs run one
forward + one train-grad step + one decode step on CPU, asserting shapes
and finiteness (the full configs are exercised only by the dry-run)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    forward_decode,
    forward_train,
    init_cache,
    init_params,
    loss_fn,
    model_spec,
    reduced,
)

B, S = 2, 32


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["vis_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vis_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(42)
    params = init_params(model_spec(cfg))
    batch = _batch(cfg, rng)
    extra = {k: v for k, v in batch.items() if k != "tokens"} or None

    logits = forward_train(params, batch["tokens"], cfg, extra)
    assert logits.shape == (B, S, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    loss, metrics = loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss)), "non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step(arch):
    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(0)
    params = init_params(model_spec(cfg))
    batch = _batch(cfg, rng)

    grads = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "non-finite grads"
    # at least one grad should be nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    rng = np.random.default_rng(1)
    params = init_params(model_spec(cfg))
    cache = init_cache(cfg, B, 64)
    if cfg.family == "audio":
        # encoder output lives in the cache
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)), jnp.float32
        )
        from repro.models.backbone import _audio_encode

        cache["enc_out"] = _audio_encode(params, frames, cfg)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)
    logits, cache2 = forward_decode(params, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # a second step at pos 1 must also work with the returned cache
    logits2, _ = forward_decode(params, cache2, tok, jnp.int32(1), cfg)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "xlstm-350m", "zamba2-1.2b"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced forward logits
    (cache correctness): run forward on a short prompt, then decode the
    same tokens step by step and compare the final-position logits."""
    cfg = reduced(get_config(arch), n_layers=2)
    rng = np.random.default_rng(5)
    params = init_params(model_spec(cfg))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)

    full = forward_train(params, tokens, cfg, None)  # (1, 8, V)

    cache = init_cache(cfg, 1, 16)
    for t in range(8):
        logits, cache = forward_decode(params, cache, tokens[:, t], jnp.int32(t), cfg)
    np.testing.assert_allclose(
        np.asarray(logits[0]), np.asarray(full[0, -1]), atol=2e-2, rtol=2e-2
    )
