"""Durable-tree tests: commit/recover roundtrips, crash injection at every
protocol step (paper §5 strict-linearizability discipline), and the
persistence-cost accounting that elimination reduces (Table 1 analog)."""
import numpy as np
import pytest

from repro.core import (
    CrashPoint,
    DictOracle,
    DurableABTree,
    OP_DELETE,
    OP_INSERT,
    TreeConfig,
    check_invariants,
    recover,
)
from repro.core.durable import SimulatedCrash
from repro.core.oracle import tree_contents

CFG = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _mk_rounds(n_rounds=6, bsz=32, seed=0):
    rng = np.random.default_rng(seed)
    rounds = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).tolist()
        keys = rng.integers(0, 64, bsz).tolist()
        vals = rng.integers(0, 1000, bsz).tolist()
        rounds.append((ops, keys, vals))
    return rounds


def test_commit_recover_roundtrip(tmp_path):
    d = str(tmp_path / "tree")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=3)
    o = DictOracle()
    for ops, keys, vals in _mk_rounds():
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()
    # recovered tree remains fully operational
    r.apply_round([OP_INSERT], [999], [1])
    assert r.tree.find(999) == 1


@pytest.mark.parametrize("step", ["after_segment", "mid_manifest", "before_dirsync"])
@pytest.mark.parametrize("at_commit", [2, 4])
def test_crash_injection_recovers_prefix(tmp_path, step, at_commit):
    """A crash at any protocol step recovers exactly the last committed
    round (strict linearizability at round granularity):
      - crash before the manifest rename → previous round's state;
      - crash after the rename (before dir sync) → either is acceptable in
        general, but with os.replace durability on a journaled fs the new
        round is visible; we assert it equals one of the two prefixes."""
    d = str(tmp_path / "tree")
    crash = CrashPoint(step=step, at_commit=at_commit)
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100, crash=crash)
    o = DictOracle()
    prefix_states = [o.items()]  # oracle contents after each committed round
    crashed = False
    rounds = _mk_rounds(8, seed=at_commit)
    for i, (ops, keys, vals) in enumerate(rounds):
        try:
            t.apply_round(ops, keys, vals)
            o.apply_round(ops, keys, vals)
            prefix_states.append(o.items())
        except SimulatedCrash:
            crashed = True
            # the crashed round's effects must NOT be externally visible:
            # oracle for the crashed round intentionally not applied for the
            # "previous prefix"; but if the rename landed, the round IS
            # durable — compute that prefix too.
            o2 = DictOracle()
            o2.d = dict(prefix_states[-1])
            o2.apply_round(ops, keys, vals)
            prefix_states.append(o2.items())
            break
    assert crashed, "crash point did not fire"
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    got = tree_contents(r.tree.state, r.tree.cfg)
    acceptable = prefix_states[-2:] if step != "after_segment" else prefix_states[-2:-1]
    assert got in acceptable, (
        f"recovered state is not a committed prefix (step={step})"
    )


def test_elimination_reduces_flushes(tmp_path):
    """Paper Table 1 analog: p-Elim flushes fewer node images than p-OCC on
    a skewed update-heavy workload."""
    rng = np.random.default_rng(7)
    bsz, n_rounds = 64, 5
    rounds = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).tolist()
        keys = np.minimum(rng.zipf(1.8, bsz), 16).tolist()  # very hot keys
        vals = rng.integers(0, 100, bsz).tolist()
        rounds.append((ops, keys, vals))

    te = DurableABTree(str(tmp_path / "elim"), CFG, mode="elim", snapshot_every=10**9)
    to = DurableABTree(str(tmp_path / "occ"), CFG, mode="occ", snapshot_every=10**9)
    for ops, keys, vals in rounds:
        te.apply_round(ops, keys, vals)
        to.apply_round(ops, keys, vals)
    se, so = te.stats(), to.stats()
    assert se["slot_writes"] < so["slot_writes"]
    # Elim commits once per round; OCC commits once per round too, but its
    # sub-rounds dirty strictly more node-versions → more flushed bytes in
    # the occ log would require per-subround commits; at round granularity
    # the observable difference is writes + eliminated count.
    assert se["eliminated"] > 0 and so["eliminated"] == 0
    assert tree_contents(te.tree.state, te.tree.cfg) == tree_contents(
        to.tree.state, to.tree.cfg
    )


def test_recover_after_growth(tmp_path):
    d = str(tmp_path / "grow")
    t = DurableABTree(d, TreeConfig(capacity=64, b=8, a=2, max_height=12),
                      mode="elim", snapshot_every=10**9)
    o = DictOracle()
    keys = list(range(300))
    t.apply_round([OP_INSERT] * 300, keys, keys)
    o.apply_round([OP_INSERT] * 300, keys, keys)
    t.apply_round([OP_DELETE] * 50, keys[:50], [0] * 50)
    o.apply_round([OP_DELETE] * 50, keys[:50], [0] * 50)
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()
