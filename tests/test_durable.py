"""Durable-tree tests: commit/recover roundtrips, crash injection at every
protocol step (paper §5 strict-linearizability discipline) for the single
tree AND the per-shard-journaled ``DurableForest`` (crash matrix × shard
counts, including a crash injected mid-shard-split), journal garbage
collection, and the persistence-cost accounting that elimination reduces
(Table 1 analog)."""
import os

import numpy as np
import pytest

from repro.core import (
    CrashPoint,
    DictOracle,
    DurableABTree,
    DurableForest,
    OP_DELETE,
    OP_INSERT,
    TreeConfig,
    check_forest_invariants,
    check_invariants,
    recover,
    recover_forest,
)
from repro.core.durable import SimulatedCrash
from repro.core.oracle import tree_contents

CFG = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _assert_forensics_sidecar(r, expected_items, backend):
    """Crash-forensics contract: the recovered journal carries the audit
    sidecar of the committed manifest, the recorded history is
    witness-legal, and replaying it through the oracle reproduces EXACTLY
    the recovered contents — the sidecar reference rides the manifest's
    atomic rename, so it can never describe an uncommitted prefix.  (Elim
    mode only: occ's per-sub-round commits land mid-round, when the
    in-flight round's record is not yet on the ring.)"""
    from repro.obs.witness import check_history

    recs = r.forensics_records()
    assert recs, "recovered journal must carry a forensics sidecar"
    head = recs[0]
    assert head["kind"] == "sidecar" and head["backend"] == backend
    assert head["rounds"] >= 1 and head["commit_idx"] >= 1
    rep = check_history(recs)
    assert rep.rounds >= 1
    assert rep.state == expected_items, (
        "sidecar replay does not reproduce the committed round prefix"
    )
    return head


def _mk_rounds(n_rounds=6, bsz=32, seed=0):
    rng = np.random.default_rng(seed)
    rounds = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).tolist()
        keys = rng.integers(0, 64, bsz).tolist()
        vals = rng.integers(0, 1000, bsz).tolist()
        rounds.append((ops, keys, vals))
    return rounds


def test_commit_recover_roundtrip(tmp_path):
    d = str(tmp_path / "tree")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=3)
    o = DictOracle()
    for ops, keys, vals in _mk_rounds():
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()
    _assert_forensics_sidecar(r, o.items(), "tree")
    # recovered tree remains fully operational
    r.apply_round([OP_INSERT], [999], [1])
    assert r.tree.find(999) == 1


@pytest.mark.parametrize("step", ["after_segment", "mid_manifest", "before_dirsync"])
@pytest.mark.parametrize("at_commit", [2, 4])
def test_crash_injection_recovers_prefix(tmp_path, step, at_commit):
    """A crash at any protocol step recovers exactly the last committed
    round (strict linearizability at round granularity):
      - crash before the manifest rename → previous round's state;
      - crash after the rename (before dir sync) → either is acceptable in
        general, but with os.replace durability on a journaled fs the new
        round is visible; we assert it equals one of the two prefixes."""
    d = str(tmp_path / "tree")
    crash = CrashPoint(step=step, at_commit=at_commit)
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100, crash=crash)
    o = DictOracle()
    prefix_states = [o.items()]  # oracle contents after each committed round
    crashed = False
    rounds = _mk_rounds(8, seed=at_commit)
    for i, (ops, keys, vals) in enumerate(rounds):
        try:
            t.apply_round(ops, keys, vals)
            o.apply_round(ops, keys, vals)
            prefix_states.append(o.items())
        except SimulatedCrash:
            crashed = True
            # the crashed round's effects must NOT be externally visible:
            # oracle for the crashed round intentionally not applied for the
            # "previous prefix"; but if the rename landed, the round IS
            # durable — compute that prefix too.
            o2 = DictOracle()
            o2.d = dict(prefix_states[-1])
            o2.apply_round(ops, keys, vals)
            prefix_states.append(o2.items())
            break
    assert crashed, "crash point did not fire"
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    got = tree_contents(r.tree.state, r.tree.cfg)
    acceptable = prefix_states[-2:] if step != "after_segment" else prefix_states[-2:-1]
    assert got in acceptable, (
        f"recovered state is not a committed prefix (step={step})"
    )
    # whichever manifest survived the crash, its audit sidecar replays to
    # exactly the recovered contents
    _assert_forensics_sidecar(r, got, "tree")


def test_elimination_reduces_flushes(tmp_path):
    """Paper Table 1 analog: p-Elim flushes fewer node images than p-OCC on
    a skewed update-heavy workload."""
    rng = np.random.default_rng(7)
    bsz, n_rounds = 64, 5
    rounds = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).tolist()
        keys = np.minimum(rng.zipf(1.8, bsz), 16).tolist()  # very hot keys
        vals = rng.integers(0, 100, bsz).tolist()
        rounds.append((ops, keys, vals))

    te = DurableABTree(str(tmp_path / "elim"), CFG, mode="elim", snapshot_every=10**9)
    to = DurableABTree(str(tmp_path / "occ"), CFG, mode="occ", snapshot_every=10**9)
    for ops, keys, vals in rounds:
        te.apply_round(ops, keys, vals)
        to.apply_round(ops, keys, vals)
    se, so = te.stats(), to.stats()
    assert se["slot_writes"] < so["slot_writes"]
    # Elim commits once per round; OCC commits once per round too, but its
    # sub-rounds dirty strictly more node-versions → more flushed bytes in
    # the occ log would require per-subround commits; at round granularity
    # the observable difference is writes + eliminated count.
    assert se["eliminated"] > 0 and so["eliminated"] == 0
    assert tree_contents(te.tree.state, te.tree.cfg) == tree_contents(
        to.tree.state, to.tree.cfg
    )


def test_recover_after_growth(tmp_path):
    d = str(tmp_path / "grow")
    t = DurableABTree(d, TreeConfig(capacity=64, b=8, a=2, max_height=12),
                      mode="elim", snapshot_every=10**9)
    o = DictOracle()
    keys = list(range(300))
    t.apply_round([OP_INSERT] * 300, keys, keys)
    o.apply_round([OP_INSERT] * 300, keys, keys)
    t.apply_round([OP_DELETE] * 50, keys[:50], [0] * 50)
    o.apply_round([OP_DELETE] * 50, keys[:50], [0] * 50)
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()


# ---------------------------------------------------------------------------
# Satellite regressions: recovery completeness + journal GC
# ---------------------------------------------------------------------------


def test_occ_recover_reinstalls_subround_hook_and_snapshot_every(tmp_path):
    """A recovered p-OCC tree must keep per-sub-round durability (the
    ``subround_hook`` is re-installed by ``recover``) and resume with the
    journaled ``snapshot_every``, not a hardcoded default."""
    d = str(tmp_path / "occ")
    t = DurableABTree(d, CFG, mode="occ", snapshot_every=7)
    t.apply_round([OP_INSERT] * 4, [5, 5, 6, 7], [1, 2, 3, 4])
    r = recover(d)
    assert r.snapshot_every == 7
    assert r.tree.subround_hook is not None
    # functional check: a round with duplicate keys commits once per
    # sub-round on the RECOVERED tree (2 duplicate ranks → 2 commits).
    c0 = r.dstats.commits
    r.apply_round([OP_INSERT] * 4, [9, 9, 10, 11], [1, 2, 3, 4])
    assert r.dstats.commits - c0 == 2
    # and the recovered journal is readable again
    r2 = recover(d)
    assert tree_contents(r2.tree.state, r2.tree.cfg) == tree_contents(
        r.tree.state, r.tree.cfg
    )


def _journal_files(d):
    return {
        f for f in os.listdir(d)
        if f.endswith(".npz")
        and ("_segment_" in f or "_snapshot_" in f or "_delta_" in f)
    }


def _referenced(d):
    """Union of journal files referenced by EVERY retained manifest
    generation (MANIFEST, MANIFEST.prev, MANIFEST.prevN...) — GC must keep
    anything a fallback generation could still recover from."""
    import json

    refs = set()
    for name in os.listdir(d):
        if name != "MANIFEST" and not name.startswith("MANIFEST.prev"):
            continue
        with open(os.path.join(d, name)) as fh:
            manifest = json.load(fh)
        for sh in manifest["shards"]:
            if sh["snapshot"]:
                refs.add(sh["snapshot"])
            refs.update(sh["segments"])
    return refs


def test_journal_gc_unlinks_unreferenced_files(tmp_path):
    """After a snapshot commit, segment/snapshot files no longer referenced
    by ANY retained manifest generation are unlinked (they must not
    accumulate) and counted in ``DurableStats.gc_removed``."""
    d = str(tmp_path / "gc")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=3)
    for i in range(10):
        t.apply_round([OP_INSERT] * 4, [i, i + 40, i + 80, i + 120], [i] * 4)
    assert t.dstats.gc_removed > 0
    assert _journal_files(d) == _referenced(d), "unreferenced journal files survive"


def test_forest_journal_gc_across_shards(tmp_path):
    d = str(tmp_path / "fgc")
    f = DurableForest(d, n_shards=2, cfg=CFG, key_space=(0, 128), snapshot_every=3)
    rng = np.random.default_rng(3)
    for _ in range(8):
        keys = rng.integers(0, 128, 16).tolist()
        f.apply_round([OP_INSERT] * 16, keys, keys)
    assert f.dstats.gc_removed > 0
    assert _journal_files(d) == _referenced(d)


# ---------------------------------------------------------------------------
# DurableForest: per-shard journals, crash matrix × shard counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_durable_forest_commit_recover_roundtrip(tmp_path, shards, mode):
    d = str(tmp_path / f"forest{shards}")
    f = DurableForest(
        d, n_shards=shards, cfg=CFG, mode=mode, key_space=(0, 64),
        snapshot_every=3,
    )
    o = DictOracle()
    for ops, keys, vals in _mk_rounds(5, bsz=24, seed=shards):
        f.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    r = recover_forest(d)
    check_forest_invariants(r.forest)
    assert r.items() == o.items()
    assert r.forest.n_shards == shards
    if mode == "occ":
        assert r.forest.subround_hook is not None
    # recovered forest remains fully operational (routing restored; key 999
    # is outside the workload's range, so the insert is fresh)
    r.apply_round([OP_INSERT], [999], [123])
    assert r.items()[999] == 123


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("step", ["after_segment", "mid_manifest", "before_dirsync"])
def test_forest_crash_injection_recovers_prefix(tmp_path, step, shards):
    """The crash matrix × shard counts: a crash at any protocol step
    recovers exactly a committed round boundary (strict linearizability at
    round granularity) — the recovered forest equals the oracle prefix of
    the committed rounds, for every shard count.  The manifest rename
    commits ALL shards' journal advances atomically, so no mixed-shard
    state can ever recover."""
    at_commit = 3
    d = str(tmp_path / "crash")
    crash = CrashPoint(step=step, at_commit=at_commit)
    f = DurableForest(
        d, n_shards=shards, cfg=CFG, mode="elim", key_space=(0, 64),
        snapshot_every=100, crash=crash,
    )
    o = DictOracle()
    prefix_states = [o.items()]  # oracle contents after each committed round
    crashed = False
    for ops, keys, vals in _mk_rounds(6, bsz=24, seed=at_commit + shards):
        try:
            f.apply_round(ops, keys, vals)
            o.apply_round(ops, keys, vals)
            prefix_states.append(o.items())
        except SimulatedCrash:
            crashed = True
            # if the rename landed before the crash, the round IS durable —
            # compute that prefix too.
            o2 = DictOracle()
            o2.d = dict(prefix_states[-1])
            o2.apply_round(ops, keys, vals)
            prefix_states.append(o2.items())
            break
    assert crashed, "crash point did not fire"
    r = recover_forest(d)
    check_forest_invariants(r.forest)
    got = r.items()
    acceptable = prefix_states[-2:] if step == "before_dirsync" else prefix_states[-2:-1]
    assert got in acceptable, (
        f"recovered state is not a committed prefix (step={step}, shards={shards})"
    )
    # the committed manifest's audit sidecar replays to exactly the
    # recovered contents, at every crash step and shard count
    _assert_forensics_sidecar(r, got, "forest")


def test_forest_crash_mid_shard_split_recovers_committed_prefix(tmp_path):
    """A crash injected while a shard split is restacking the forest must
    recover the last committed ROUND boundary: nothing of the splitting
    round (nor the half-swept shard) is visible, and the recovered forest
    still splits on its next overflow."""
    rng = np.random.default_rng(23)
    ks = rng.choice(4096, size=120, replace=False).astype(np.int64)
    chunks = [ks[i : i + 24] for i in range(0, ks.size, 24)]

    # dry run: find the round whose shard split fires first.  During round
    # r (0-based) the commit counter stands at r + 1 (the init snapshot is
    # commit 0), which is the index ``mid_split`` fires against.
    ref = DurableForest(
        str(tmp_path / "split_ref"), n_shards=2, cfg=CFG, key_space=(0, 4096),
        max_keys_per_shard=40, snapshot_every=10**9,
    )
    o_ref = DictOracle()
    ref_prefixes = [o_ref.items()]
    first_split_round = None
    for r_i, c in enumerate(chunks):
        ref.apply_round(np.full(c.size, OP_INSERT, np.int32), c, c * 3)
        o_ref.apply_round([OP_INSERT] * c.size, c.tolist(), (c * 3).tolist())
        ref_prefixes.append(o_ref.items())
        if first_split_round is None and ref.forest.n_shards > 2:
            first_split_round = r_i
    assert first_split_round is not None, "workload did not trigger a shard split"

    crash = CrashPoint(step="mid_split", at_commit=first_split_round + 1)
    d = str(tmp_path / "split_crash")
    f = DurableForest(
        d, n_shards=2, cfg=CFG, key_space=(0, 4096),
        max_keys_per_shard=40, snapshot_every=10**9, crash=crash,
    )
    o = DictOracle()
    prefixes = [o.items()]
    crashed = False
    for c in chunks:
        try:
            f.apply_round(np.full(c.size, OP_INSERT, np.int32), c, c * 3)
            o.apply_round([OP_INSERT] * c.size, c.tolist(), (c * 3).tolist())
            prefixes.append(o.items())
        except SimulatedCrash:
            crashed = True
            break
    assert crashed, "mid-split crash did not fire"
    r = recover_forest(d)
    check_forest_invariants(r.forest)
    # nothing of the splitting round committed: recovery = previous round's
    # oracle prefix, with the PRE-split shard layout.
    assert r.items() == prefixes[-1]
    assert r.forest.n_shards == 2
    # the sidecar stops at the committed prefix too: no trace of the
    # crashed round or the half-swept shard in the forensics replay
    _assert_forensics_sidecar(r, prefixes[-1], "forest")
    # the recovered forest is operational and still re-partitions on
    # overflow (split machinery + journal re-keying survive recovery)
    for c in chunks:
        r.apply_round(np.full(c.size, OP_INSERT, np.int32), c, c * 3)
    assert r.forest.n_shards > 2
    assert r.items() == ref_prefixes[-1]
    check_forest_invariants(r.forest)
    r2 = recover_forest(str(tmp_path / "split_crash"))
    assert r2.items() == ref_prefixes[-1]
    assert r2.forest.n_shards == r.forest.n_shards


def _skewed_write_rounds(n_rounds=8, seed=31):
    """Insert rounds with an 80/20 hot-prefix skew on a (0, 400) 2-shard
    key space: enough sustained shard-0 load to trip a 64-lane hot window
    into a boundary rebalance, while shard 1's 20% share stays above the
    cold-merge threshold."""
    rng = np.random.default_rng(seed)
    rounds = []
    for r in range(n_rounds):
        keys = np.concatenate(
            [rng.integers(0, 100, 38), rng.integers(200, 400, 10)]
        ).astype(np.int64)
        vals = rng.integers(0, 1000, 48).astype(np.int64)
        rounds.append(([OP_INSERT] * 48, keys.tolist(), vals.tolist()))
    return rounds


def test_forest_crash_mid_repartition_recovers_committed_prefix(tmp_path):
    """A crash injected while a load-aware boundary rebalance is moving
    keys must recover the last committed ROUND boundary: nothing of the
    repartitioning round (nor the half-swept range) is visible, and the
    recovered forest keeps the PRE-move partition.  The crash discipline
    is identical to mid-split — a repartition is journal re-keying plus
    forced snapshots, never a commit of its own."""
    chunks = _skewed_write_rounds()

    # dry run: find the round whose rebalance fires first (during round r
    # the commit counter stands at r + 1; the init snapshot is commit 0).
    ref = DurableForest(
        str(tmp_path / "rep_ref"), n_shards=2, cfg=CFG, key_space=(0, 400),
        snapshot_every=10**9, auto_repartition=True,
    )
    ref.forest.hot_shard_window = 64
    o_ref = DictOracle()
    ref_prefixes = [o_ref.items()]
    first_rep_round = None
    for r_i, (ops, keys, vals) in enumerate(chunks):
        ref.apply_round(ops, keys, vals)
        o_ref.apply_round(ops, keys, vals)
        ref_prefixes.append(o_ref.items())
        reps = int(ref.forest.metrics.snapshot()["counters"].get("repartitions", 0))
        if first_rep_round is None and reps >= 1:
            first_rep_round = r_i
    assert first_rep_round is not None, "workload never tripped a rebalance"
    moved_splits = ref.forest.splits.tolist()
    assert moved_splits != [200], "rebalance did not move the boundary"

    crash = CrashPoint(step="mid_repartition", at_commit=first_rep_round + 1)
    d = str(tmp_path / "rep_crash")
    f = DurableForest(
        d, n_shards=2, cfg=CFG, key_space=(0, 400),
        snapshot_every=10**9, auto_repartition=True, crash=crash,
    )
    f.forest.hot_shard_window = 64
    o = DictOracle()
    prefixes = [o.items()]
    crashed = False
    for ops, keys, vals in chunks:
        try:
            f.apply_round(ops, keys, vals)
            o.apply_round(ops, keys, vals)
            prefixes.append(o.items())
        except SimulatedCrash:
            crashed = True
            break
    assert crashed, "mid-repartition crash did not fire"
    r = recover_forest(d)
    check_forest_invariants(r.forest)
    # nothing of the repartitioning round committed: recovery = previous
    # round's oracle prefix with the PRE-move partition.
    assert r.items() == prefixes[-1]
    assert r.forest.n_shards == 2
    assert r.forest.splits.tolist() == [200]
    # forensics discipline holds mid-repartition as well: the sidecar
    # replays to the committed prefix, with no half-moved range visible
    _assert_forensics_sidecar(r, prefixes[-1], "forest")
    # the recovered forest is operational: replaying the remaining rounds
    # converges to the reference contents (the rebalance never changes
    # contents, only the partition), and a re-recovery agrees.
    for ops, keys, vals in chunks[first_rep_round:]:
        r.apply_round(ops, keys, vals)
    assert r.items() == ref_prefixes[-1]
    check_forest_invariants(r.forest)
    r2 = recover_forest(d)
    assert r2.items() == ref_prefixes[-1]


def test_forest_split_snapshots_only_affected_shards(tmp_path):
    """A shard split forces snapshots of exactly the two affected shards;
    untouched shards keep their segment chains (journals are keyed by
    stable uids, so the restack does not re-journal them)."""
    import json

    d = str(tmp_path / "splitsnap")
    f = DurableForest(
        d, n_shards=3, cfg=CFG, key_space=(0, 3000),
        max_keys_per_shard=40, snapshot_every=10**9,
    )
    # seed every shard, then overflow only the middle one (keys 1000-2000)
    seed = list(range(0, 3000, 100))
    f.apply_round([OP_INSERT] * len(seed), seed, seed)
    hot = list(range(1000, 1900, 18))  # 50 keys > threshold in shard 1
    f.apply_round([OP_INSERT] * len(hot), hot, hot)
    assert f.forest.n_shards == 4
    with open(os.path.join(d, "MANIFEST")) as fh:
        manifest = json.load(fh)
    by_uid = {sh["uid"]: sh for sh in manifest["shards"]}
    uids = [sh["uid"] for sh in manifest["shards"]]
    assert uids[0] == "s0000" and uids[-1] == "s0002"  # outer shards keep uids
    assert uids[2] == "s0003"  # the fresh shard's uid, restacked at s+1
    # affected shards (split + fresh) were force-snapshotted at the commit;
    # the untouched outer shards still ride their original snapshot+segments
    assert by_uid["s0001"]["snapshot"].endswith(f"{manifest['commit']:08d}.npz")
    assert by_uid["s0003"]["snapshot"].endswith(f"{manifest['commit']:08d}.npz")
    assert by_uid["s0000"]["snapshot"].endswith("_00000000.npz")
    assert by_uid["s0002"]["snapshot"].endswith("_00000000.npz")


def test_durable_forest_elimination_reduces_flush_traffic(tmp_path):
    """Paper Table-1, sharded: p-Elim flushes fewer bytes than p-OCC on a
    skewed update-heavy workload at every shard count (occ pays a segment
    per sub-round; eliminated ops dirty no nodes)."""
    rng = np.random.default_rng(7)
    rounds = []
    for _ in range(4):
        ops = rng.choice([OP_INSERT, OP_DELETE], 48).tolist()
        keys = np.minimum(rng.zipf(1.8, 48), 60).tolist()  # very hot keys
        vals = rng.integers(0, 100, 48).tolist()
        rounds.append((ops, keys, vals))
    for shards in (1, 2):
        stats = {}
        for mode in ("elim", "occ"):
            f = DurableForest(
                str(tmp_path / f"{mode}{shards}"), n_shards=shards, cfg=CFG,
                mode=mode, key_space=(0, 64), snapshot_every=10**9,
            )
            for ops, keys, vals in rounds:
                f.apply_round(ops, keys, vals)
            stats[mode] = f.stats()
        assert stats["elim"]["flush_bytes"] < stats["occ"]["flush_bytes"], shards
        assert stats["elim"]["fsyncs"] < stats["occ"]["fsyncs"], shards


def test_durable_session_index_warm_restart(tmp_path):
    """The serving layer's durable sharded index option: a SessionIndex
    pointed at an existing journal directory recovers its contents (warm
    restart), keeping the evict_range contract."""
    from repro.serve.pages import SessionIndex

    d = str(tmp_path / "sessions")
    si = SessionIndex(mode="elim", shards=2, key_space=(0, 256), durable_dir=d)
    si.publish_batch(list(range(100, 140)), list(range(40)))
    freed = si.evict_range(100, 120, cap=8)
    assert sorted(freed) == list(range(20))
    si2 = SessionIndex(mode="elim", shards=2, key_space=(0, 256), durable_dir=d)
    assert si2.lookup_batch([119, 120, 139]) == [None, 20, 39]
    assert si2.tree.n_shards == 2


def test_latency_histograms_cover_every_fsync_site(tmp_path):
    """``fsync_latency_s`` must observe ALL THREE fsync sites — each
    journal file, the manifest, and the directory entry — not just the
    parallel journal lanes (the old under-report), and ``commit_latency_s``
    must observe one whole-commit duration per successful commit."""
    t = DurableABTree(str(tmp_path / "t"), CFG, mode="elim", snapshot_every=100)
    for ops, keys, vals in _mk_rounds(5, seed=21):
        t.apply_round(ops, keys, vals)
    commits = t.dstats.commits
    fs = t.metrics.histogram_summary("fsync_latency_s")
    cl = t.metrics.histogram_summary("commit_latency_s")
    # single tree ⇒ exactly 3 fsyncs per commit (1 journal file + manifest
    # + directory), and the stats counter agrees with the histogram.
    assert fs["count"] == 3 * commits == t.dstats.fsyncs
    assert cl["count"] == commits
    assert cl["p50"] >= fs["p50"] > 0.0


# ---------------------------------------------------------------------------
# Group commit: several rounds per manifest rename (bounded data loss)
# ---------------------------------------------------------------------------


def test_group_commit_batches_rounds_and_drains(tmp_path):
    """With ``group_commit_every=G`` (and an effectively infinite max-wait)
    G rounds share ONE manifest rename; ``drain()`` flushes a partial tail
    group; the batch depth is observable (``rounds_per_commit``); and the
    exact fsync accounting — 3 per commit that actually happened — survives
    grouping."""
    d = str(tmp_path / "grp")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9,
                      group_commit_every=4, group_commit_max_wait_s=1e9)
    c0 = t.dstats.commits  # the constructor's initial (forced) commit
    o = DictOracle()
    for ops, keys, vals in _mk_rounds(6, seed=11):
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    # 6 rounds at G=4 → one boundary commit at round 4, rounds 5-6 pending
    assert t.dstats.commits - c0 == 1
    st = t.durability_status()
    assert st["group_commit_every"] == 4
    assert st["pending_rounds"] == 2 and st["pending_age_s"] > 0.0
    t.drain()
    assert t.dstats.commits - c0 == 2
    assert t.durability_status()["pending_rounds"] == 0
    assert t.metrics.histogram_summary("rounds_per_commit")["max"] == 4.0
    assert t.dstats.fsyncs == 3 * t.dstats.commits
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()


def test_group_commit_recovery_lands_on_last_group_boundary(tmp_path):
    """Absorbed-but-unflushed rounds vanish ATOMICALLY as a group: a
    recovery that never saw ``drain()`` (a kill between rounds) gets
    exactly the prefix at the last group boundary — never a partial
    group."""
    d = str(tmp_path / "grpcut")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9,
                      group_commit_every=3, group_commit_max_wait_s=1e9)
    o = DictOracle()
    prefixes = [o.items()]
    for ops, keys, vals in _mk_rounds(8, seed=13):
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
        prefixes.append(o.items())
    # boundaries after rounds 3 and 6; rounds 7-8 are pending
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == prefixes[6]
    t.drain()  # the persist fence makes the tail durable
    r2 = recover(d)
    assert tree_contents(r2.tree.state, r2.tree.cfg) == prefixes[8]


def test_group_commit_max_wait_bounds_staleness(tmp_path):
    """``group_commit_max_wait_s=0`` forces a boundary on every round even
    with a huge group size — the age bound wins over batching."""
    d = str(tmp_path / "wait")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9,
                      group_commit_every=64, group_commit_max_wait_s=0.0)
    c0 = t.dstats.commits
    for ops, keys, vals in _mk_rounds(4, seed=17):
        t.apply_round(ops, keys, vals)
    assert t.dstats.commits - c0 == 4


def test_async_commit_keeps_exact_fsync_accounting(tmp_path):
    """``commit_async=True`` moves boundary I/O off the caller's thread;
    after ``drain()`` the stats are still EXACT on the non-grouped path —
    one commit per round, 3 fsyncs per commit, histogram == counter — and
    recovery is exact."""
    d = str(tmp_path / "async")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9,
                      commit_async=True)
    o = DictOracle()
    for ops, keys, vals in _mk_rounds(6, seed=19):
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    t.drain()
    assert t.dstats.commits == 7  # init + one per round
    assert t.dstats.fsyncs == 3 * t.dstats.commits
    assert t.metrics.histogram_summary("fsync_latency_s")["count"] == t.dstats.fsyncs
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()


def test_recovered_journal_keeps_group_commit_knobs(tmp_path):
    """``recover(...)`` accepts the commit knobs so a restarted engine
    resumes grouping: a recovered journal batches rounds exactly like the
    original."""
    d = str(tmp_path / "rk")
    t = DurableABTree(d, CFG, mode="elim", group_commit_every=2,
                      group_commit_max_wait_s=1e9)
    for ops, keys, vals in _mk_rounds(4, seed=37):
        t.apply_round(ops, keys, vals)
    t.drain()
    r = recover(d, group_commit_every=2, group_commit_max_wait_s=1e9)
    assert r.group_commit_every == 2
    c0 = r.dstats.commits
    o = DictOracle()
    o.d = dict(tree_contents(r.tree.state, r.tree.cfg))
    for ops, keys, vals in _mk_rounds(2, seed=38):
        r.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    assert r.dstats.commits - c0 == 1  # two rounds, one boundary
    r.drain()
    assert tree_contents(recover(d).tree.state, CFG) == o.items()


# ---------------------------------------------------------------------------
# Incremental (delta) snapshots
# ---------------------------------------------------------------------------


def test_incremental_snapshots_roundtrip_and_forced_full(tmp_path):
    """Periodic snapshots write ``_delta_`` files (rows dirtied since the
    last full image) that REPLACE the segment chain; every
    ``full_snapshot_every`` deltas a full snapshot is forced so chains
    cannot grow without bound.  Recovery through a delta chain is exact."""
    d = str(tmp_path / "delta")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=2,
                      full_snapshot_every=3)
    o = DictOracle()
    for ops, keys, vals in _mk_rounds(12, seed=23):
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    assert t.metrics.value("delta_snapshots") >= 3
    assert t.metrics.value("full_snapshots") >= 2  # init + forced full
    assert any("_delta_" in f for f in _journal_files(d))
    r = recover(d)
    check_invariants(r.tree.state, r.tree.cfg)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()
    # the recovered journal keeps working (and forces a clean FULL at its
    # next periodic snapshot — delta bookkeeping did not survive recovery)
    r.apply_round([OP_INSERT], [777], [9])
    assert recover(d).tree.find(777) == 9


def test_forest_incremental_snapshots_roundtrip(tmp_path):
    d = str(tmp_path / "fdelta")
    f = DurableForest(d, n_shards=2, cfg=CFG, key_space=(0, 64),
                      snapshot_every=2, full_snapshot_every=4)
    o = DictOracle()
    for ops, keys, vals in _mk_rounds(9, seed=31):
        f.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    assert f.metrics.value("delta_snapshots") > 0
    assert any("_delta_" in fn for fn in _journal_files(d))
    r = recover_forest(d)
    assert r.items() == o.items()
    check_forest_invariants(r.forest)
