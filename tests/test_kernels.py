"""Per-kernel allclose tests vs the pure-jnp oracles, swept over shapes and
dtypes (interpret=True executes the Pallas kernel body on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.leaf_probe import leaf_probe_pallas, leaf_probe_ref
from repro.kernels.elim_combine import elim_combine_pallas, elim_combine_ref
from repro.kernels.flash_attention import attention_ref, flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas, decode_attention_ref


# ---------------------------------------------------------------------------
# leaf_probe
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bsz,b", [(1, 8), (7, 8), (64, 8), (200, 16), (33, 11)])
def test_leaf_probe_sweep(bsz, b):
    rng = np.random.default_rng(bsz * 31 + b)
    keys = rng.integers(0, 50, (bsz, b)).astype(np.int32)
    vals = rng.integers(0, 1000, (bsz, b)).astype(np.int32)
    # force some guaranteed hits
    queries = rng.integers(0, 50, (bsz,)).astype(np.int32)
    queries[: bsz // 2] = keys[: bsz // 2, rng.integers(0, b)]
    # make rows unique per slot to avoid ambiguity on slot index: dedupe by
    # marking duplicate slots with a sentinel the query never matches
    for i in range(bsz):
        seen = set()
        for j in range(b):
            if int(keys[i, j]) in seen:
                keys[i, j] = -7 - j
            seen.add(int(keys[i, j]))
    slot_p, val_p = leaf_probe_pallas(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(queries), interpret=True
    )
    slot_r, val_r = leaf_probe_ref(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(queries)
    )
    np.testing.assert_array_equal(np.asarray(slot_p), np.asarray(slot_r))
    np.testing.assert_array_equal(np.asarray(val_p), np.asarray(val_r))


# ---------------------------------------------------------------------------
# elim_combine
# ---------------------------------------------------------------------------


def _mk_combine_batch(bsz, n_keys, seed):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, n_keys, bsz))
    ops = rng.integers(1, 4, bsz).astype(np.int32)
    vals = rng.integers(1, 100, bsz).astype(np.int32)
    seg_head = np.ones(bsz, bool)
    seg_head[1:] = keys[1:] != keys[:-1]
    present0 = np.zeros(bsz, bool)
    val0 = np.zeros(bsz, np.int32)
    # random initial state per segment, broadcast
    cur_p, cur_v = False, 0
    for i in range(bsz):
        if seg_head[i]:
            cur_p = bool(rng.integers(0, 2))
            cur_v = int(rng.integers(1, 100)) if cur_p else 0
        present0[i], val0[i] = cur_p, cur_v
    return ops, vals, seg_head, present0, val0


@pytest.mark.parametrize("bsz,n_keys,tile", [(16, 3, 8), (256, 10, 64), (1000, 7, 256), (513, 200, 128)])
def test_elim_combine_sweep(bsz, n_keys, tile):
    ops, vals, seg_head, present0, val0 = _mk_combine_batch(bsz, n_keys, bsz + tile)
    args = tuple(jnp.asarray(x) for x in (ops, vals, seg_head, present0, val0))
    got = elim_combine_pallas(*args, tile=tile, interpret=True)
    want = elim_combine_ref(*args)
    for g, w, name in zip(got, want, ("bp", "bv", "ap", "av")):
        # values are only meaningful where the corresponding present flag is
        # set; compare presence exactly and values under the mask.
        if name in ("bp", "ap"):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    bp, bv, ap, av = got
    wbp, wbv, wap, wav = want
    np.testing.assert_array_equal(
        np.asarray(bv)[np.asarray(wbp)], np.asarray(wbv)[np.asarray(wbp)]
    )
    np.testing.assert_array_equal(
        np.asarray(av)[np.asarray(wap)], np.asarray(wav)[np.asarray(wap)]
    )


def test_elim_combine_cross_tile_segment():
    """A single hot key spanning many tiles must fold correctly through the
    scratch carry (the publishing-elimination contention case)."""
    bsz, tile = 64, 8
    ops = np.tile([2, 3], bsz // 2).astype(np.int32)  # ins, del, ins, del...
    vals = np.arange(bsz).astype(np.int32)
    seg_head = np.zeros(bsz, bool)
    seg_head[0] = True
    present0 = np.zeros(bsz, bool)
    val0 = np.zeros(bsz, np.int32)
    args = tuple(jnp.asarray(x) for x in (ops, vals, seg_head, present0, val0))
    got = elim_combine_pallas(*args, tile=tile, interpret=True)
    want = elim_combine_ref(*args)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    # final state: last op is delete → absent
    assert not bool(got[2][-1])


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,kh,s,d,causal,window,dtype",
    [
        (1, 2, 2, 128, 64, True, 0, jnp.float32),
        (2, 4, 2, 256, 64, True, 0, jnp.float32),  # GQA
        (1, 8, 1, 128, 64, True, 0, jnp.bfloat16),  # MQA bf16
        (1, 2, 2, 200, 32, True, 0, jnp.float32),  # ragged pad
        (1, 2, 1, 256, 64, True, 64, jnp.float32),  # sliding window
        (1, 2, 2, 128, 128, False, 0, jnp.float32),  # bidirectional
    ],
)
def test_flash_attention_sweep(b, h, kh, s, d, causal, window, dtype):
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kh, s, d)), dtype)
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64, interpret=True
    )
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_nonsquare_pad_noncausal():
    """Non-causal with padded seq: pad keys must not leak attention mass."""
    rng = np.random.default_rng(0)
    b, h, s, d = 1, 2, 100, 32  # pads to 128
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    got = flash_attention_pallas(
        q, k, v, causal=False, block_q=64, block_k=64, interpret=True
    )
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,kh,s,d,kv_len,dtype",
    [
        (1, 4, 4, 512, 64, None, jnp.float32),
        (2, 8, 2, 512, 64, 300, jnp.float32),  # GQA + ragged len
        (1, 14, 2, 1024, 64, 1000, jnp.float32),  # qwen2-like kv=2
        (2, 4, 1, 256, 128, None, jnp.bfloat16),  # MQA bf16
    ],
)
def test_decode_attention_sweep(b, h, kh, s, d, kv_len, dtype):
    rng = np.random.default_rng(s + h)
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kh, s, d)), dtype)
    got = decode_attention_pallas(q, k, v, kv_len, block_k=128, interpret=True)
    want = decode_attention_ref(q, k, v, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_grad_matches_ref():
    """custom_vjp: kernel forward, oracle backward — grads must match the
    pure ref end-to-end."""
    from repro.kernels.flash_attention.ops import flash_attention

    rng = np.random.default_rng(3)
    b, h, s, d = 1, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)

    def loss_kernel(q, k, v):
        return flash_attention(q, k, v, True, 0, None, True).sum()

    def loss_ref(q, k, v):
        return attention_ref(q, k, v, causal=True).sum()

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-4, rtol=1e-4)
