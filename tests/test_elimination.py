"""Property tests for the elimination combine itself (the paper's §4
algebra): the segmented associative scan must equal a naive sequential fold
for every op sequence, and the linearization it encodes must be valid."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import elimination as elim


def naive_fold(ops, vals, seg_head, present0, val0):
    """Sequential per-segment fold (ground truth)."""
    n = len(ops)
    before_p, before_v, after_p, after_v = [], [], [], []
    p = v = None
    for i in range(n):
        if seg_head[i]:
            p, v = bool(present0[i]), int(val0[i])
        before_p.append(p)
        before_v.append(v)
        op = int(ops[i])
        if op == 2 and not p:  # insert
            p, v = True, int(vals[i])
        elif op == 3 and p:  # delete
            p = False
        after_p.append(p)
        after_v.append(v)
    return before_p, before_v, after_p, after_v


@settings(max_examples=50, deadline=None)
@given(
    data=st.lists(
        st.tuples(
            st.integers(0, 3),  # op (0=nop,1=find,2=ins,3=del)
            st.integers(1, 50),  # val
            st.booleans(),  # segment head
            st.booleans(),  # present0 (if head)
        ),
        min_size=1,
        max_size=100,
    )
)
def test_combine_matches_naive_fold(data):
    n = len(data)
    ops = np.array([d[0] for d in data], np.int32)
    vals = np.array([d[1] for d in data], np.int64)
    seg_head = np.array([d[2] for d in data], bool)
    seg_head[0] = True
    present0 = np.array([d[3] for d in data], bool)
    val0 = np.where(present0, 99, 0).astype(np.int64)

    res = elim.eliminate_batch(
        jnp.asarray(ops), jnp.asarray(vals), jnp.asarray(seg_head),
        jnp.asarray(present0), jnp.asarray(val0),
    )
    bp, bv, ap, av = naive_fold(ops, vals, seg_head, present0, val0)
    np.testing.assert_array_equal(np.asarray(res.before_present), bp)
    np.testing.assert_array_equal(np.asarray(res.after_present), ap)
    # values only compared where present
    got_bv = np.asarray(res.before_val)
    got_av = np.asarray(res.after_val)
    for i in range(n):
        if bp[i]:
            assert got_bv[i] == bv[i], i
        if ap[i]:
            assert got_av[i] == av[i], i


@settings(max_examples=40, deadline=None)
@given(
    n_ops=st.integers(1, 60),
    present0=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_single_key_write_collapse(n_ops, present0, seed):
    """All ops on ONE key: at most one net write regardless of op count —
    the paper's headline write-collapse."""
    rng = np.random.default_rng(seed)
    ops = rng.integers(1, 4, n_ops).astype(np.int32)
    vals = rng.integers(1, 100, n_ops).astype(np.int64)
    seg_head = np.zeros(n_ops, bool)
    seg_head[0] = True
    p0 = np.full(n_ops, present0)
    v0 = np.where(p0, 7, 0).astype(np.int64)
    res = elim.eliminate_batch(
        jnp.asarray(ops), jnp.asarray(vals), jnp.asarray(seg_head),
        jnp.asarray(p0), jnp.asarray(v0),
    )
    n_net = int(
        jnp.sum(res.net_insert) + jnp.sum(res.net_delete) + jnp.sum(res.net_overwrite)
    )
    assert n_net <= 1
    # eliminated counter consistency: would-write ops minus net writes
    would = int(np.sum((ops == 2) & ~np.asarray(res.before_present))
                + np.sum((ops == 3) & np.asarray(res.before_present)))
    assert int(res.n_eliminated) == would - n_net
