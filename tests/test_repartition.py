"""Load-aware repartitioning tests: the forest's partition may move under
skew (boundary rebalance toward the hot prefix, cold-shard merge, and
load-quantile overflow split points) but its CONTENTS must stay
oracle-exact through every restack, with live traffic before, during and
after.  Uniform traffic is pinned to never trip the detector — the
partition only moves when the load says so."""
import numpy as np
import pytest

try:  # property tests need hypothesis; deterministic tests run without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    ABForest,
    DictOracle,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_RANGE,
    TreeConfig,
    check_forest_invariants,
)

SMALL = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _repartitions(f) -> int:
    return int(f.metrics.snapshot()["counters"].get("repartitions", 0))


def _seed(f, o, keys, vals=None):
    keys = list(keys)
    vals = [k * 3 for k in keys] if vals is None else list(vals)
    f.apply_round([OP_INSERT] * len(keys), keys, vals)
    o.apply_round([OP_INSERT] * len(keys), keys, vals)


def _mixed_round(f, o, rng, lo, hi, bsz=32):
    """One random mixed round (point + range lanes) checked op-for-op
    against the oracle — the live-traffic probe used around restacks."""
    ops = rng.choice(
        [OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE], bsz, p=[0.3, 0.3, 0.2, 0.2]
    ).astype(np.int32)
    keys = rng.integers(lo, hi, bsz).astype(np.int64)
    vals = rng.integers(0, 1000, bsz).astype(np.int64)
    vals = np.where(ops == OP_RANGE, rng.integers(0, 24, bsz), vals)
    out = f.apply_round(ops.tolist(), keys.tolist(), vals.tolist(), scan_cap=32)
    exp_res, exp_found, _ = o.apply_mixed_round(
        ops.tolist(), keys.tolist(), vals.tolist(), cap=32
    )
    got_found = np.asarray(out.found).tolist()
    got_res = np.asarray(out.results).tolist()
    for i, op in enumerate(ops):
        assert got_found[i] == exp_found[i], (i, int(op))
        if op == OP_RANGE or exp_found[i]:
            assert got_res[i] == exp_res[i], (i, int(op))
    assert f.items() == o.items()


def test_boundary_rebalance_under_skew_matches_oracle():
    """Sustained point-read skew at 2 shards moves the boundary toward the
    hot prefix (the load-weighted quantile), and the forest stays
    oracle-exact through the restack and under traffic after it."""
    f = ABForest(
        n_shards=2, cfg=SMALL, key_space=(0, 400),
        auto_repartition=True, hot_shard_window=64,
    )
    o = DictOracle()
    rng = np.random.default_rng(41)
    _seed(f, o, range(0, 400, 2))
    assert f.splits.tolist() == [200]

    # 80/20 reads: shard 0's frac 0.79 clears the max(0.5, 1.5/2) = 0.75
    # trip point, while shard 1's 0.21 share stays safely above
    # cold_shard_frac — this must take the REBALANCE arm, not the merge.
    for r in range(6):
        keys = np.concatenate(
            [rng.integers(0, 100, 38), rng.integers(200, 400, 10)]
        ).astype(np.int64)
        f.apply_round([OP_FIND] * 48, keys.tolist(), [0] * 48)
        o.apply_round([OP_FIND] * 48, keys.tolist(), [0] * 48)
        if _repartitions(f) >= 1:
            break
    assert _repartitions(f) >= 1, "hot window never tripped a rebalance"
    assert f.n_shards == 2  # rebalance moves a boundary, never restacks S
    new_split = int(f.splits[0])
    assert new_split < 200, f"boundary did not move toward the hot prefix: {new_split}"
    assert 0 < new_split <= 150, new_split  # lands toward the observed hot range
    check_forest_invariants(f)
    assert f.items() == o.items()

    # live traffic across the moved boundary stays oracle-exact
    for _ in range(3):
        _mixed_round(f, o, rng, 0, 400)
    check_forest_invariants(f)


def test_cold_shard_merge_retires_shard_matches_oracle():
    """Traffic that never touches one shard (window share ≤ cold_shard_frac)
    retires it into its neighbor at the next hot-window fire: S shrinks by
    one, the survivor owns the merged range, contents stay oracle-exact."""
    f = ABForest(
        n_shards=4, cfg=SMALL, key_space=(0, 400),
        auto_repartition=True, hot_shard_window=64, cold_shard_frac=0.05,
    )
    o = DictOracle()
    rng = np.random.default_rng(43)
    _seed(f, o, range(0, 400, 2))
    assert f.splits.tolist() == [100, 200, 300]

    # 60/40 reads on shards 0/1, shard 3 starved: shard 0's frac ≥ 0.5
    # trips the window and shard 3's zero share selects the merge arm.
    for r in range(8):
        k0 = rng.integers(0, 100, 30)
        k1 = rng.integers(100, 200, 18)
        keys = np.concatenate([k0, k1]).astype(np.int64)
        f.apply_round([OP_FIND] * 48, keys.tolist(), [0] * 48)
        o.apply_round([OP_FIND] * 48, keys.tolist(), [0] * 48)
        if f.n_shards < 4:
            break
    assert f.n_shards == 3, "cold shard was never merged"
    assert _repartitions(f) >= 1
    assert len(f.splits) == 2
    check_forest_invariants(f)
    assert f.items() == o.items()

    # the retired shard's range still serves traffic (from the survivor)
    for _ in range(3):
        _mixed_round(f, o, rng, 250, 400)
    check_forest_invariants(f)


def test_overflow_split_prefers_load_quantile():
    """A shard-overflow split with a populated key sample picks the
    load-weighted quantile as its split point — balancing observed traffic,
    not key population — and stays oracle-exact through the restack."""
    f = ABForest(
        n_shards=2, cfg=SMALL, key_space=(0, 400),
        max_keys_per_shard=130,
        hot_shard_window=1 << 30,  # window never fires: isolate the split path
    )
    o = DictOracle()
    rng = np.random.default_rng(47)
    _seed(f, o, range(0, 400, 4))  # 50 keys per shard: no overflow yet

    # reads concentrated in [0, 64): the sample's in-shard-0 median sits
    # well below shard 0's population median (~100)
    for _ in range(6):
        keys = rng.integers(0, 64, 64).astype(np.int64)
        f.apply_round([OP_FIND] * 64, keys.tolist(), [0] * 64)
        o.apply_round([OP_FIND] * 64, keys.tolist(), [0] * 64)

    # overflow shard 0 (range [0, 200)): 50 seeded + 100 fresh keys > 130,
    # and either side of a load-median split stays under the cap (one split)
    _seed(f, o, range(1, 200, 2))
    assert f.n_shards == 3, "overflow did not split"
    split_pt = int(f.splits[0])
    assert split_pt < 100, (
        f"split point {split_pt} tracks population, not load "
        f"(load median ≈ 32, population median ≈ 100)"
    )
    check_forest_invariants(f)
    assert f.items() == o.items()
    for _ in range(3):
        _mixed_round(f, o, rng, 0, 400)
    check_forest_invariants(f)


def test_uniform_traffic_never_repartitions():
    """The skew detector's false-positive pin: uniform traffic across many
    full windows trips nothing — no shard reaches 1.5x fair share, the
    partition stays put and the repartition counter stays zero."""
    f = ABForest(
        n_shards=4, cfg=SMALL, key_space=(0, 400),
        auto_repartition=True, hot_shard_window=64,
    )
    o = DictOracle()
    rng = np.random.default_rng(53)
    _seed(f, o, range(0, 400, 2))
    splits0 = f.splits.tolist()
    for _ in range(12):  # ~9 full windows of uniform reads
        keys = rng.integers(0, 400, 48).astype(np.int64)
        f.apply_round([OP_FIND] * 48, keys.tolist(), [0] * 48)
    assert _repartitions(f) == 0
    assert f.n_shards == 4
    assert f.splits.tolist() == splits0
    assert f.items() == o.items()


def test_single_shard_never_repartitions():
    """S=1 has no partition to move: total skew must be a no-op."""
    f = ABForest(
        n_shards=1, cfg=SMALL, key_space=(0, 400),
        auto_repartition=True, hot_shard_window=64,
    )
    f.apply_round([OP_INSERT] * 32, list(range(32)), list(range(32)))
    for _ in range(6):
        f.apply_round([OP_FIND] * 48, [1] * 48, [0] * 48)
    assert _repartitions(f) == 0
    assert f.n_shards == 1


@pytest.mark.parametrize("n_shards", [2, 4])
def test_repartition_live_mixed_traffic_matches_oracle(n_shards):
    """Deterministic soak: skewed mixed rounds (inserts/deletes/ranges over
    a hot prefix) with auto-repartition on stay oracle-exact round for
    round, whether or not a window fires mid-stream."""
    f = ABForest(
        n_shards=n_shards, cfg=SMALL, key_space=(0, 400),
        auto_repartition=True, hot_shard_window=64,
    )
    o = DictOracle()
    rng = np.random.default_rng(59 + n_shards)
    _seed(f, o, range(0, 400, 2))
    for r in range(10):
        # hot prefix 3/4 of the time: windows fire mid-stream at some point
        lo, hi = (0, 80) if r % 4 else (0, 400)
        _mixed_round(f, o, rng, lo, hi, bsz=48)
    check_forest_invariants(f)
    assert f.items() == o.items()


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    @given(
        n_shards=st.sampled_from([1, 2, 3, 4]),
        hot_lo=st.integers(min_value=0, max_value=300),
        seed=st.integers(min_value=0, max_value=2**16),
        rounds=st.integers(min_value=2, max_value=6),
    )
    def test_property_repartition_oracle_equivalence(n_shards, hot_lo, seed, rounds):
        """For every shard count and any hot-range placement, skewed mixed
        traffic with auto-repartition on is oracle-equivalent: whatever
        boundary moves or merges the detector triggers, contents and
        per-round results never diverge."""
        f = ABForest(
            n_shards=n_shards, cfg=SMALL, key_space=(0, 400),
            auto_repartition=True, hot_shard_window=48,
        )
        o = DictOracle()
        rng = np.random.default_rng(seed)
        _seed(f, o, range(0, 400, 4))
        hot_hi = min(hot_lo + 60, 400)
        for _ in range(rounds):
            _mixed_round(f, o, rng, hot_lo, hot_hi, bsz=48)
        check_forest_invariants(f)
        assert f.items() == o.items()

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_repartition_oracle_equivalence():
        pass
