"""Round-engine tests: fused mixed-op rounds (OP_RANGE lanes alongside
finds/inserts/deletes in one ``apply_round`` call) against the oracle's
mixed-round reference semantics, lane classification (``RoundPlan``), the
fused scan+delete round, and the scan cursor API (``scan_stream``)."""
import numpy as np
import pytest

try:  # property tests need hypothesis; deterministic tests run without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    ABTree,
    DictOracle,
    EMPTY,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_NOP,
    OP_RANGE,
    TreeConfig,
    build_plan,
    check_invariants,
)
from repro.core.oracle import tree_contents

SMALL = TreeConfig(capacity=512, b=8, a=2, max_height=12)

_NOTFOUND_SCANLESS = None  # marker only; point lanes have scans[i] is None


def _check_mixed_round(tree, oracle, ops, keys, vals, cap=64):
    """One fused apply_round vs the oracle's mixed-round semantics."""
    out = tree.apply_round(ops, keys, vals, scan_cap=cap)
    exp_res, exp_found, exp_scans = oracle.apply_mixed_round(ops, keys, vals, cap=cap)
    got_res = np.asarray(out.results).tolist()
    got_found = np.asarray(out.found).tolist()
    for i, op in enumerate(ops):
        assert got_found[i] == exp_found[i], (i, op, got_found[i], exp_found[i])
        if op == OP_RANGE or exp_found[i]:
            assert got_res[i] == exp_res[i], (i, op, got_res[i], exp_res[i])
        if exp_scans[i] is not None:
            n = int(np.asarray(out.scan.count)[i])
            row = [
                (int(k), int(v))
                for k, v in zip(
                    np.asarray(out.scan.keys)[i, :n], np.asarray(out.scan.vals)[i, :n]
                )
            ]
            assert row == exp_scans[i], (i, row[:4], exp_scans[i][:4])
            # rows beyond count stay EMPTY-padded
            assert all(
                int(k) == int(EMPTY) for k in np.asarray(out.scan.keys)[i, n:]
            )
    assert tree_contents(tree.state, tree.cfg) == oracle.items()
    return out


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_fused_mixed_round_acceptance(mode):
    """The headline capability: finds + inserts + deletes + ≥2 range lanes
    in ONE apply_round call, oracle-exact, scans linearized before the
    round's net writes."""
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    pre = list(range(0, 100, 2))  # evens present pre-round
    t.apply_round([OP_INSERT] * len(pre), pre, [k * 10 for k in pre])
    o.apply_round([OP_INSERT] * len(pre), pre, [k * 10 for k in pre])
    ops = [OP_FIND, OP_INSERT, OP_RANGE, OP_DELETE, OP_RANGE, OP_INSERT, OP_FIND]
    keys = [4, 5, 0, 6, 3, 7, 99]
    vals = [0, 55, 10, 0, 5, 77, 0]  # lane 2 scans [0,10); lane 4 scans [3,8)
    rounds_before = t.stats()["rounds"]
    out = _check_mixed_round(t, o, ops, keys, vals, cap=16)
    assert t.stats()["rounds"] == rounds_before + 1  # ONE round
    # scans observe the pre-round state: 5 and 7 (inserted this round) are
    # invisible; 6 (deleted this round) is still visible.
    scan0 = np.asarray(out.scan.keys)[2, :5].tolist()
    assert scan0 == [0, 2, 4, 6, 8]
    assert np.asarray(out.scan.keys)[4, :2].tolist() == [4, 6]
    check_invariants(t.state, t.cfg)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_fused_randomized_rounds_match_oracle(mode):
    """Randomized mixed rounds on overlapping keys stay oracle-exact."""
    rng = np.random.default_rng(7)
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    for r in range(10):
        bsz = 48
        ops = rng.choice(
            [OP_NOP, OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE],
            bsz,
            p=[0.05, 0.2, 0.3, 0.25, 0.2],
        ).astype(np.int32)
        keys = rng.integers(0, 300, bsz).astype(np.int64)
        vals = rng.integers(0, 1000, bsz).astype(np.int64)
        vals = np.where(ops == OP_RANGE, rng.integers(0, 80, bsz), vals)
        _check_mixed_round(t, o, ops.tolist(), keys.tolist(), vals.tolist(), cap=32)
        if r % 3 == 0:
            check_invariants(t.state, t.cfg)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_pure_range_batch_via_apply_round(mode):
    """An all-OP_RANGE batch through apply_round matches scan_round."""
    t = ABTree(SMALL, mode=mode)
    keys = list(range(64))
    t.apply_round([OP_INSERT] * 64, keys, [k * 2 for k in keys])
    lo = np.array([0, 10, 60], np.int64)
    span = np.array([5, 30, 100], np.int64)
    want = t.scan_round(lo, lo + span, cap=32)
    out = t.apply_round([OP_RANGE] * 3, lo, span, scan_cap=32)
    np.testing.assert_array_equal(np.asarray(out.scan.keys), np.asarray(want.keys))
    np.testing.assert_array_equal(np.asarray(out.scan.count), np.asarray(want.count))
    assert np.asarray(out.results).tolist() == np.asarray(want.count).tolist()


def test_zero_span_range_lane_is_legal_empty_scan():
    t = ABTree(SMALL)
    t.apply_round([OP_INSERT], [5], [50])
    out = t.apply_round([OP_RANGE], [5], [0])  # [5, 5): empty, not malformed
    assert int(np.asarray(out.scan.count)[0]) == 0
    assert not bool(np.asarray(out.found)[0])


def test_range_lane_hi_saturates_at_top_of_key_space():
    """lo + span past the int64 top must scan 'everything ≥ lo' (like the
    unbounded oracle), not wrap to a negative hi that scans nothing."""
    t = ABTree(SMALL)
    o = DictOracle()
    big = int(EMPTY) - 5  # valid key just below the EMPTY sentinel
    t.apply_round([OP_INSERT] * 2, [big, 7], [1, 2])
    o.apply_round([OP_INSERT] * 2, [big, 7], [1, 2])
    _check_mixed_round(t, o, [OP_RANGE], [big - 10], [100], cap=8)


def test_malformed_lanes_raise():
    t = ABTree(SMALL)
    with pytest.raises(ValueError, match="malformed"):
        t.apply_round([OP_RANGE, OP_INSERT], [10, 1], [-2, 5])
    with pytest.raises(ValueError, match="unknown op"):
        t.apply_round([7], [0], [0])
    with pytest.raises(ValueError, match="equal-length"):
        t.apply_round([OP_INSERT], [1, 2], [0, 0])


def test_round_plan_classification():
    plan = build_plan(
        [OP_NOP, OP_FIND, OP_RANGE, OP_DELETE], [0, 1, 10, 3], [0, 0, 7, 0]
    )
    assert plan.has_point and plan.has_range and plan.n_range == 1
    assert np.asarray(plan.is_range).tolist() == [False, False, True, False]
    # OP_RANGE masked out of the combine's batch
    assert np.asarray(plan.point_ops).tolist() == [OP_NOP, OP_FIND, OP_NOP, OP_DELETE]
    assert int(np.asarray(plan.lo)[2]) == 10 and int(np.asarray(plan.hi)[2]) == 17
    # non-range lanes scan the empty interval [EMPTY, EMPTY)
    assert int(np.asarray(plan.lo)[0]) == int(EMPTY)
    point_only = build_plan([OP_INSERT], [1], [1])
    assert point_only.has_point and not point_only.has_range


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_scan_delete_round_is_one_round(mode):
    t = ABTree(SMALL, mode=mode)
    keys = list(range(100))
    t.apply_round([OP_INSERT] * 100, keys, [k * 3 for k in keys])
    r0 = t.stats()["rounds"]
    out = t.scan_delete_round([20], [40], cap=64)
    assert t.stats()["rounds"] == r0 + 1
    assert int(np.asarray(out.count)[0]) == 20
    assert [int(k) for k in np.asarray(out.keys)[0, :20]] == list(range(20, 40))
    assert [int(v) for v in np.asarray(out.vals)[0, :20]] == [
        k * 3 for k in range(20, 40)
    ]
    check_invariants(t.state, t.cfg)
    assert sorted(t.items()) == [k for k in keys if not 20 <= k < 40]


def test_session_eviction_round_count_halved():
    """evict_range now costs ONE round per chunk (was scan + delete = 2)."""
    from repro.serve.pages import SessionIndex

    si = SessionIndex(mode="elim")
    si.publish_batch(list(range(100, 140)), list(range(40)))
    r0 = si.tree.stats()["rounds"]
    freed = si.evict_range(100, 120, cap=8)  # 20 matches, cap 8 → 3 chunks
    assert sorted(freed) == list(range(20))
    # 3 truncated-chunk sweeps: each is exactly one fused round
    assert si.tree.stats()["rounds"] - r0 == 3
    assert si.lookup_batch([105, 125]) == [None, 25]


def test_scan_stream_straddles_leaf_boundaries():
    """Cursor API: a cap-bounded stream resumes from the last emitted key
    and crosses leaf boundaries without loss or duplication."""
    t = ABTree(SMALL)  # b=8 → 150 keys span many leaves
    o = DictOracle()
    rng = np.random.default_rng(11)
    keys = rng.choice(2000, size=150, replace=False).tolist()
    vals = [k * 5 for k in keys]
    t.apply_round([OP_INSERT] * 150, keys, vals)
    o.apply_round([OP_INSERT] * 150, keys, vals)
    # cap=7 < leaf fanout 8 guarantees pages end mid-leaf AND at boundaries
    got = list(t.scan_stream(0, 2000, cap=7))
    assert got == o.range(0, 2000)
    # sub-range with both endpoints interior
    lo, hi = sorted(keys)[10] + 1, sorted(keys)[120]
    assert list(t.scan_stream(lo, hi, cap=7)) == o.range(lo, hi)
    # empty and reversed ranges stream nothing
    assert list(t.scan_stream(3000, 4000, cap=7)) == []
    assert list(t.scan_stream(50, 50, cap=7)) == []
    # non-positive cap is rejected eagerly (before the first next())
    with pytest.raises(ValueError, match="cap"):
        t.scan_stream(0, 100, cap=0)


def test_scan_stream_is_capacity_bounded():
    """The stream issues ceil(n/cap) scan rounds of ≤ cap entries each."""
    t = ABTree(SMALL)
    n = 60
    t.apply_round([OP_INSERT] * n, list(range(n)), list(range(n)))
    scans0 = t.stats()["scans"]
    got = list(t.scan_stream(0, n, cap=16))
    assert len(got) == n
    pages = t.stats()["scans"] - scans0
    assert pages == -(-n // 16)  # 4 pages of ≤ 16


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    lane_strategy = st.one_of(
        st.tuples(  # point lane
            st.sampled_from([OP_FIND, OP_INSERT, OP_DELETE]),
            st.integers(min_value=0, max_value=30),
            st.integers(min_value=0, max_value=10**6),
        ),
        st.tuples(  # range lane: lo in the same hot key range, short span
            st.just(OP_RANGE),
            st.integers(min_value=0, max_value=30),
            st.integers(min_value=0, max_value=12),
        ),
    )

    @settings(
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    @given(
        rounds=st.lists(
            st.lists(lane_strategy, min_size=1, max_size=40), min_size=1, max_size=5
        ),
        mode=st.sampled_from(["elim", "occ"]),
    )
    def test_property_mixed_rounds_oracle_equivalence(rounds, mode):
        """For any interleaving of OP_RANGE lanes with elim/occ point ops on
        overlapping keys, fused rounds are oracle-exact — in particular a
        scan never observes writes from its own round (the oracle evaluates
        scans on the pre-round snapshot)."""
        t = ABTree(SMALL, mode=mode)
        o = DictOracle()
        for r in rounds:
            ops = [x[0] for x in r]
            keys = [x[1] for x in r]
            vals = [x[2] for x in r]
            _check_mixed_round(t, o, ops, keys, vals, cap=16)
        check_invariants(t.state, t.cfg)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_mixed_rounds_oracle_equivalence():
        pass


# ---------------------------------------------------------------------------
# Unified engine: exactly one host-sequencing implementation
# ---------------------------------------------------------------------------


def test_forest_has_no_host_sequencing_copies():
    """Grep-pin for the unified engine: ``core/forest.py`` must contain NO
    copy of the round engine's host loops — they live once, in
    ``core/rounds.py``'s (S, wave_w) form, shared with ABTree (S = 1)."""
    import inspect

    import repro.core.forest as F
    import repro.core.rounds as R

    src = inspect.getsource(F)
    for token in (
        "_drain_deferred",
        "_split_cascade",
        "_occ_round",
        "_fix_underfull",
        "underfull",
        "_combine_apply",
        "_v_scan",
        "_v_split",
        "_v_underfull",
        "run_scan_phase",
        "run_point_phases",
        "subrounds",
    ):
        assert token not in src, f"forest.py re-implements/host-sequences {token!r}"
    rsrc = inspect.getsource(R)
    for token in (
        "_drain_deferred",
        "_split_cascade",
        "_occ_round",
        "_fix_underfull_all",
        "run_scan_phase",
        "execute_plan",
        "execute_scan_delete",
    ):
        assert token in rsrc, f"rounds.py lost the unified {token!r}"


def test_abtree_rounds_execute_through_s1_stacked_path(monkeypatch):
    """ABTree rounds must run through the unified engine's vmapped S = 1
    path: every phase sees a leading shard axis of size 1, and the
    RoundOutput semantics are unchanged (oracle-exact)."""
    from repro.core import rounds as R

    combine_shapes = []
    scan_shapes = []
    scan_sids = []
    orig_combine = R._v_search_combine
    orig_scan = R._phase_scan_flat

    def spy_combine(state, batch, cfg, narrow=False):
        combine_shapes.append(tuple(np.asarray(batch[0]).shape))
        return orig_combine(state, batch, cfg, narrow)

    def spy_scan(state, cfg, sid, lo, hi, fc, cap, narrow, narrow_descent=False):
        scan_shapes.append(tuple(np.asarray(lo).shape))
        scan_sids.append(np.asarray(sid))
        return orig_scan(state, cfg, sid, lo, hi, fc, cap, narrow, narrow_descent)

    monkeypatch.setattr(R, "_v_search_combine", spy_combine)
    monkeypatch.setattr(R, "_phase_scan_flat", spy_scan)

    t = ABTree(SMALL)
    o = DictOracle()
    ops = [OP_INSERT, OP_INSERT, OP_RANGE, OP_DELETE, OP_FIND]
    keys = [3, 9, 0, 3, 9]
    vals = [30, 90, 20, 0, 0]
    _check_mixed_round(t, o, ops, keys, vals, cap=16)
    assert combine_shapes and all(s[0] == 1 and len(s) == 2 for s in combine_shapes)
    # the scan phase is flat/ragged: 1-D packed sub-lane blocks whose every
    # live lane routes to the single shard (sid == 0 at S = 1)
    assert scan_shapes and all(len(s) == 1 for s in scan_shapes)
    assert all((sid == 0).all() for sid in scan_sids)
