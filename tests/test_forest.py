"""ABForest tests: oracle equivalence at every shard count, cross-shard
range scans (straddling / empty / full-keyspace / boundary-exact),
scan_stream cursor chaining, the one-fused-round scan+delete contract,
shard-overflow splitting, and the forest-backed serving indexes."""
import numpy as np
import pytest

try:  # property tests need hypothesis; deterministic tests run without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    ABForest,
    ABTree,
    DictOracle,
    EMPTY,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_NOP,
    OP_RANGE,
    TreeConfig,
    check_forest_invariants,
)

SMALL = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _check_mixed_round(forest, oracle, ops, keys, vals, cap=32):
    """One fused forest apply_round vs the oracle's mixed-round semantics."""
    out = forest.apply_round(ops, keys, vals, scan_cap=cap)
    exp_res, exp_found, exp_scans = oracle.apply_mixed_round(ops, keys, vals, cap=cap)
    got_res = np.asarray(out.results).tolist()
    got_found = np.asarray(out.found).tolist()
    for i, op in enumerate(ops):
        assert got_found[i] == exp_found[i], (i, op, got_found[i], exp_found[i])
        if op == OP_RANGE or exp_found[i]:
            assert got_res[i] == exp_res[i], (i, op, got_res[i], exp_res[i])
        if exp_scans[i] is not None:
            n = int(np.asarray(out.scan.count)[i])
            row = [
                (int(k), int(v))
                for k, v in zip(
                    np.asarray(out.scan.keys)[i, :n], np.asarray(out.scan.vals)[i, :n]
                )
            ]
            assert row == exp_scans[i], (i, row[:4], exp_scans[i][:4])
            assert all(
                int(k) == int(EMPTY) for k in np.asarray(out.scan.keys)[i, n:]
            )
    assert forest.items() == oracle.items()
    return out


@pytest.mark.parametrize("n_shards", [1, 2, 4])
@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_forest_randomized_mixed_rounds_match_oracle(n_shards, mode):
    """Random mixed rounds (point + range lanes on overlapping keys) are
    oracle-exact for every shard count — the forest's headline contract."""
    rng = np.random.default_rng(7 + n_shards)
    f = ABForest(n_shards=n_shards, cfg=SMALL, mode=mode, key_space=(0, 300))
    o = DictOracle()
    for r in range(8):
        bsz = 48
        ops = rng.choice(
            [OP_NOP, OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE],
            bsz,
            p=[0.05, 0.2, 0.3, 0.25, 0.2],
        ).astype(np.int32)
        keys = rng.integers(0, 300, bsz).astype(np.int64)
        vals = rng.integers(0, 1000, bsz).astype(np.int64)
        vals = np.where(ops == OP_RANGE, rng.integers(0, 120, bsz), vals)
        _check_mixed_round(f, o, ops.tolist(), keys.tolist(), vals.tolist())
        if r % 3 == 0:
            check_forest_invariants(f)
    check_forest_invariants(f)


def test_forest_single_shard_matches_tree():
    """ABForest(1) runs the identical phase pipeline: results, scan rows and
    contents must match ABTree exactly, round for round."""
    rng = np.random.default_rng(11)
    f = ABForest(n_shards=1, cfg=SMALL, key_space=(0, 200))
    t = ABTree(SMALL)
    for _ in range(5):
        bsz = 32
        ops = rng.choice(
            [OP_FIND, OP_INSERT, OP_DELETE, OP_RANGE], bsz, p=[0.3, 0.3, 0.2, 0.2]
        ).astype(np.int32)
        keys = rng.integers(0, 200, bsz).astype(np.int64)
        vals = rng.integers(0, 500, bsz).astype(np.int64)
        vals = np.where(ops == OP_RANGE, rng.integers(0, 60, bsz), vals)
        fo = f.apply_round(ops, keys, vals, scan_cap=16)
        to = t.apply_round(ops, keys, vals, scan_cap=16)
        np.testing.assert_array_equal(np.asarray(fo.results), np.asarray(to.results))
        np.testing.assert_array_equal(np.asarray(fo.found), np.asarray(to.found))
        np.testing.assert_array_equal(
            np.asarray(fo.scan.keys), np.asarray(to.scan.keys)
        )
        np.testing.assert_array_equal(
            np.asarray(fo.scan.vals), np.asarray(to.scan.vals)
        )
    assert f.items() == t.items()


def test_cross_shard_ranges_straddle_and_boundaries():
    """Scans straddling 1..3 shard boundaries, empty scans, full-keyspace
    scans, and lo/hi exactly ON split points are oracle-exact."""
    f = ABForest(n_shards=4, cfg=SMALL, key_space=(0, 400))  # splits 100/200/300
    o = DictOracle()
    keys = list(range(0, 400, 3))
    vals = [k * 7 for k in keys]
    f.apply_round([OP_INSERT] * len(keys), keys, vals)
    o.apply_round([OP_INSERT] * len(keys), keys, vals)
    cases = [
        (95, 110),  # straddles one boundary
        (95, 305),  # straddles all three
        (0, 400),  # full keyspace
        (0, 10**9),  # past the top
        (100, 200),  # boundary-exact lo AND hi (one whole shard)
        (100, 101),  # boundary-exact lo, 1-wide
        (199, 200),  # hi exactly at a split point
        (200, 200),  # empty at a boundary
        (150, 120),  # reversed → empty
        (399, 400),  # last key
    ]
    lo = np.array([c[0] for c in cases], np.int64)
    hi = np.array([c[1] for c in cases], np.int64)
    out = f.scan_round(lo, hi, cap=256)
    for i, (l, h) in enumerate(cases):
        exp = o.range(l, h)
        n = int(np.asarray(out.count)[i])
        got = list(
            zip(
                np.asarray(out.keys)[i, :n].tolist(),
                np.asarray(out.vals)[i, :n].tolist(),
            )
        )
        assert got == exp, (i, (l, h), got[:5], exp[:5])
        assert not bool(np.asarray(out.truncated)[i])
    # the same intervals as fused OP_RANGE lanes (span encoding)
    spans = [max(h - l, 0) for l, h in cases]
    _check_mixed_round(
        f, o, [OP_RANGE] * len(cases), [c[0] for c in cases], spans, cap=256
    )


def test_cross_shard_truncation_takes_global_smallest():
    """A truncated cross-shard scan must emit the cap smallest keys overall
    (lower shards win), and mark truncation."""
    f = ABForest(n_shards=2, cfg=SMALL, key_space=(0, 100))  # split at 50
    o = DictOracle()
    keys = list(range(100))
    f.apply_round([OP_INSERT] * 100, keys, keys)
    o.apply_round([OP_INSERT] * 100, keys, keys)
    out = f.scan_round([30], [90], cap=10)
    n = int(np.asarray(out.count)[0])
    assert n == 10
    assert np.asarray(out.keys)[0, :n].tolist() == list(range(30, 40))
    assert bool(np.asarray(out.truncated)[0])


def test_forest_scan_stream_chains_shard_cursors():
    """scan_stream pages stay ≤ cap, cross shard boundaries in order, and
    every page's gather touches only the shard holding the cursor."""
    f = ABForest(n_shards=4, cfg=SMALL, key_space=(0, 2000))
    o = DictOracle()
    rng = np.random.default_rng(13)
    keys = rng.choice(2000, size=150, replace=False).tolist()
    vals = [k * 5 for k in keys]
    f.apply_round([OP_INSERT] * 150, keys, vals)
    o.apply_round([OP_INSERT] * 150, keys, vals)
    assert list(f.scan_stream(0, 2000, cap=7)) == o.range(0, 2000)
    lo, hi = sorted(keys)[10] + 1, sorted(keys)[120]
    assert list(f.scan_stream(lo, hi, cap=7)) == o.range(lo, hi)
    assert list(f.scan_stream(3000, 4000, cap=7)) == []
    assert list(f.scan_stream(50, 50, cap=7)) == []
    with pytest.raises(ValueError, match="cap"):
        f.scan_stream(0, 100, cap=0)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_forest_scan_delete_round_is_one_round(mode):
    """A cross-shard scan+delete is ONE forest round; only emitted keys are
    deleted, so truncated chunks leave the remainder for the next sweep."""
    f = ABForest(n_shards=4, cfg=SMALL, mode=mode, key_space=(0, 400))
    o = DictOracle()
    keys = list(range(0, 400, 2))
    f.apply_round([OP_INSERT] * len(keys), keys, [k * 3 for k in keys])
    o.apply_round([OP_INSERT] * len(keys), keys, [k * 3 for k in keys])
    r0 = f.stats()["rounds"]
    out = f.scan_delete_round([90], [310], cap=64)  # spans all 3 boundaries
    assert f.stats()["rounds"] == r0 + 1
    n = int(np.asarray(out.count)[0])
    exp = o.range(90, 310)
    assert n == 64 and bool(np.asarray(out.truncated)[0])
    got = list(
        zip(np.asarray(out.keys)[0, :n].tolist(), np.asarray(out.vals)[0, :n].tolist())
    )
    assert got == exp[:64]
    for k, _ in exp[:64]:
        o.d.pop(k)
    assert f.items() == o.items()
    # second chunk finishes the sweep
    out = f.scan_delete_round([90], [310], cap=64)
    assert not bool(np.asarray(out.truncated)[0])
    for k in np.asarray(out.keys)[0, : int(np.asarray(out.count)[0])].tolist():
        o.d.pop(k)
    assert f.items() == o.items()
    check_forest_invariants(f)


def test_forest_shard_overflow_splits():
    """Crossing max_keys_per_shard re-partitions the hottest shard: a new
    split point appears, contents stay oracle-exact, scans stay sorted."""
    f = ABForest(
        n_shards=2, cfg=SMALL, key_space=(0, 10000), max_keys_per_shard=40
    )
    o = DictOracle()
    rng = np.random.default_rng(17)
    ks = rng.choice(10000, size=240, replace=False).astype(np.int64)
    for i in range(0, ks.size, 48):
        c = ks[i : i + 48]
        f.apply_round(np.full(c.size, OP_INSERT, np.int32), c, c * 3)
        o.apply_round([OP_INSERT] * c.size, c.tolist(), (c * 3).tolist())
    assert f.n_shards > 2
    assert np.all(np.diff(f.splits) > 0)
    assert (f._live_key_counts() <= 40).all()
    assert f.items() == o.items()
    assert list(f.scan_stream(0, 10000, cap=17)) == o.range(0, 10000)
    check_forest_invariants(f)


def test_cross_shard_lane_validates_against_one_snapshot():
    """A cross-shard lane's sub-lanes must accept against ONE snapshot.
    Regression: with independent per-shard acceptance, a writer hitting
    shard 1 (attempt 0) then shard 0 AND shard 1 (attempt 1) produced a
    stitched row mixing states that never coexisted."""
    f = ABForest(n_shards=2, cfg=SMALL, key_space=(0, 400))  # split at 200
    f.apply_round([OP_INSERT] * 2, [10, 210], [1, 1])
    calls = {"n": 0}

    def hook():
        calls["n"] += 1
        if calls["n"] == 1:  # invalidates shard 1 only
            f.apply_round([OP_INSERT], [220], [1])
        elif calls["n"] == 2:  # invalidates BOTH shards
            f.apply_round([OP_INSERT, OP_DELETE], [20, 210], [1, 0])

    f.scan_hook = hook
    out = f.scan_round([0], [400], cap=16)  # one lane spanning both shards
    f.scan_hook = None
    n = int(np.asarray(out.count)[0])
    got = np.asarray(out.keys)[0, :n].tolist()
    # must equal ONE of the states the dictionary actually passed through
    states = [[10, 210], [10, 210, 220], [10, 20, 220]]
    assert got in states, got
    # and lanes on both shards were retried together at least once
    assert f.stats()["scan_retries"] >= 2


def test_scan_hook_overflow_defers_shard_split():
    """A scan_hook writer pushing a shard past max_keys_per_shard must NOT
    restack the forest under the in-flight scan's lane routing — the split
    defers to the next update round (regression: vmap axis mismatch)."""
    f = ABForest(n_shards=2, cfg=SMALL, key_space=(0, 400), max_keys_per_shard=40)
    seed = list(range(200, 400, 8))  # shard 1 only, under threshold
    f.apply_round([OP_INSERT] * len(seed), seed, seed)
    fired = {}

    def hook():
        if not fired:
            fired["x"] = True
            w = np.arange(0, 100, 2, dtype=np.int64)  # 50 keys > threshold
            f.apply_round(np.full(w.size, OP_INSERT, np.int32), w, w * 3)

    f.scan_hook = hook
    out = f.scan_round([0], [400], cap=256)  # spans both shards
    f.scan_hook = None
    assert f.n_shards == 2  # split deferred, scan survived
    # shard 0's lanes retried post-write: the scan sees the hook's keys
    n = int(np.asarray(out.count)[0])
    assert np.asarray(out.keys)[0, :n].tolist() == sorted(
        seed + np.arange(0, 100, 2).tolist()
    )
    # the next update round performs the deferred split
    f.apply_round([OP_INSERT], [399], [1])
    assert f.n_shards > 2
    assert (f._live_key_counts() <= 40).all()
    check_forest_invariants(f)


def test_tiny_capacity_pool_grows_before_split_waves():
    """Regression: pools smaller than a structural wave's allocation slice
    (2·wave_w) must grow before the first split cascade, tree and forest."""
    tiny = TreeConfig(capacity=24, b=8, a=2, max_height=12)
    keys = np.arange(200, dtype=np.int64)
    f = ABForest(n_shards=2, cfg=tiny, key_space=(0, 1000))
    f.apply_round(np.full(keys.size, OP_INSERT, np.int32), keys, keys * 2)
    assert list(f.scan_stream(0, 1000, cap=64)) == [(int(k), int(k) * 2) for k in keys]
    check_forest_invariants(f)
    t = ABTree(tiny)
    t.apply_round(np.full(keys.size, OP_INSERT, np.int32), keys, keys * 2)
    assert t.items() == {int(k): int(k) * 2 for k in keys}


def test_forest_per_shard_conflict_validation():
    """A concurrent writer (scan_hook) touching one shard retries ONLY that
    shard's lanes — the conflict-window shrink sharding buys."""

    def run(k):
        f = ABForest(n_shards=k, cfg=SMALL, key_space=(0, 400))
        keys = np.arange(0, 400, 2, dtype=np.int64)
        f.apply_round(np.full(keys.size, OP_INSERT, np.int32), keys, keys)
        reads = np.arange(0, 400, 8, dtype=np.int64)  # spans all shards
        fired = {}

        def hook():
            if not fired:
                fired["x"] = True
                w = np.arange(0, 16, 2, dtype=np.int64)  # shard-0 keys only
                ops = np.concatenate(
                    [np.full(8, OP_DELETE, np.int32), np.full(8, OP_INSERT, np.int32)]
                )
                f.apply_round(ops, np.concatenate([w, w]), np.concatenate([w, w * 9]))

        f.scan_hook = hook
        out = f.scan_round(reads, reads + 1, cap=1)
        f.scan_hook = None
        assert int(np.asarray(out.count).sum()) == reads.size  # all still found
        return f.stats()["scan_retries"]

    r1, r4 = run(1), run(4)
    assert r1 == 50  # whole batch retried once
    assert 0 < r4 < r1  # only the written shard's lanes retried


def test_forest_backed_session_index_evict_range():
    """Regression (satellite): SessionIndex(shards=...) keeps the
    one-fused-round-per-chunk evict_range contract across shard
    boundaries, and frees exactly the evicted page-table ids."""
    from repro.serve.pages import SessionIndex

    si = SessionIndex(mode="elim", shards=2, key_space=(0, 256))
    si.publish_batch(list(range(100, 140)), list(range(40)))
    r0 = si.tree.stats()["rounds"]
    # [100, 136) straddles the shard boundary at 128; 36 matches, cap 8 → 5 chunks
    freed = si.evict_range(100, 136, cap=8)
    assert sorted(freed) == list(range(36))
    assert si.tree.stats()["rounds"] - r0 == 5  # one fused round per chunk
    assert si.lookup_batch([135, 136, 139]) == [None, 36, 39]
    # single-tree behavior is unchanged
    si1 = SessionIndex(mode="elim")
    si1.publish_batch(list(range(100, 140)), list(range(40)))
    r0 = si1.tree.stats()["rounds"]
    assert sorted(si1.evict_range(100, 136, cap=8)) == list(range(36))
    assert si1.tree.stats()["rounds"] - r0 == 5


def test_forest_backed_prefix_index_roundtrip():
    from repro.serve.pages import PrefixIndex

    idx = PrefixIndex(shards=4)
    hs = [123456789012345, 7, 2**62 + 5, 999]
    idx.publish_batch(hs, [1, 2, 3, 4])
    assert idx.lookup_batch(hs) == [1, 2, 3, 4]
    assert idx.lookup_batch([42]) == [None]
    idx.evict_batch([7])
    assert idx.lookup_batch(hs) == [1, None, 3, 4]


def test_forest_narrow_scan_matches_ref_path():
    """narrow_scan=True (Pallas int32 kernel inside the vmapped fused scan)
    must be bit-identical to the int64 jnp ref path."""
    rng = np.random.default_rng(19)
    keys = rng.choice(2000, size=150, replace=False).tolist()
    vals = [k * 5 for k in keys]
    outs = []
    for narrow in (False, True):
        f = ABForest(n_shards=4, cfg=SMALL, key_space=(0, 2000), narrow_scan=narrow)
        f.apply_round([OP_INSERT] * 150, keys, vals)
        outs.append(
            f.apply_round(
                [OP_RANGE] * 3, [0, 777, 1500], [800, 600, 10**6], scan_cap=64
            )
        )
    for field in ("keys", "vals", "count", "truncated"):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs[0].scan, field)),
            np.asarray(getattr(outs[1].scan, field)),
        )


def test_occ_subround_early_exit_per_shard():
    """occ-mode vmapped sub-rounds run max-over-shards duplicate ranks; a
    shard whose own rank budget is exhausted must NOT account the all-NOP
    tail sub-rounds (per-shard early-exit): its ``subrounds`` counter stops
    at its own duplicate depth while results stay oracle-exact."""
    f = ABForest(n_shards=2, cfg=SMALL, mode="occ", key_space=(0, 100))
    o = DictOracle()
    # shard 0 (keys < 50): one key hit 4× → 4 sub-rounds there;
    # shard 1 (keys ≥ 50): all-distinct keys → exactly 1 sub-round.
    ops = [OP_INSERT] * 8
    keys = [7, 7, 7, 7, 60, 61, 62, 63]
    vals = [1, 2, 3, 4, 5, 6, 7, 8]
    got = f.apply_round(ops, keys, vals)
    wres, wfound = o.apply_round(ops, keys, vals)
    np.testing.assert_array_equal(np.asarray(got.results), wres)
    np.testing.assert_array_equal(np.asarray(got.found), wfound)
    assert f.items() == o.items()
    per = f.stats_per_shard()
    assert per[0]["subrounds"] == 4  # the skewed shard pays its depth
    assert per[1]["subrounds"] == 1  # the unskewed shard skips the tail
    # a shard with no lanes at all accounts zero sub-rounds.
    f2 = ABForest(n_shards=2, cfg=SMALL, mode="occ", key_space=(0, 100))
    f2.apply_round([OP_INSERT, OP_INSERT], [3, 3], [1, 2])
    per2 = f2.stats_per_shard()
    assert per2[0]["subrounds"] == 2 and per2[1]["subrounds"] == 0


def test_forest_malformed_lanes_raise():
    f = ABForest(n_shards=2, cfg=SMALL, key_space=(0, 100))
    with pytest.raises(ValueError, match="malformed"):
        f.apply_round([OP_RANGE, OP_INSERT], [10, 1], [-2, 5])
    with pytest.raises(ValueError, match="unknown op"):
        f.apply_round([7], [0], [0])
    with pytest.raises(ValueError, match="equal-length"):
        f.apply_round([OP_INSERT], [1, 2], [0, 0])


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    lane_strategy = st.one_of(
        st.tuples(  # point lane
            st.sampled_from([OP_FIND, OP_INSERT, OP_DELETE]),
            st.integers(min_value=0, max_value=30),
            st.integers(min_value=0, max_value=10**6),
        ),
        st.tuples(  # range lane: lo in the same hot key range, short span
            st.just(OP_RANGE),
            st.integers(min_value=0, max_value=30),
            st.integers(min_value=0, max_value=12),
        ),
    )

    @settings(
        max_examples=20,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    @given(
        rounds=st.lists(
            st.lists(lane_strategy, min_size=1, max_size=30), min_size=1, max_size=4
        ),
        n_shards=st.sampled_from([1, 2, 4]),
        mode=st.sampled_from(["elim", "occ"]),
    )
    def test_property_forest_oracle_equivalence(rounds, n_shards, mode):
        """ABForest(n_shards=k) is oracle-equivalent for random mixed rounds
        and every k — shard routing, packing, sub-lane splitting and
        stitching preserve the single-round linearization exactly.  Keys are
        drawn around the shard boundaries (key_space (0, 32) with up to 4
        shards ⇒ boundaries at 8/16/24 sit inside the hot range)."""
        f = ABForest(n_shards=n_shards, cfg=SMALL, mode=mode, key_space=(0, 32))
        o = DictOracle()
        for r in rounds:
            ops = [x[0] for x in r]
            keys = [x[1] for x in r]
            vals = [x[2] for x in r]
            _check_mixed_round(f, o, ops, keys, vals, cap=16)
        check_forest_invariants(f)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_forest_oracle_equivalence():
        pass
