"""Tests for the PP stage loop and the dedup index."""
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential():
    """4-stage pipeline over a 4-pod mesh == sequential layer stack."""
    code = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=4'
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ('pod',))
rng = np.random.default_rng(0)
n_stages, m, b, d = 4, 3, 2, 8
w = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.standard_normal((m, b, d)), jnp.float32)

body = lambda wi, h: jnp.tanh(h @ wi)
with mesh:
    out = pipeline_apply({'w': w}, x, lambda p, h: body(p['w'], h), mesh)

ref = x
for s in range(n_stages):
    ref = body(w[s], ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print('PIPELINE_OK')
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}, cwd=REPO,
    )
    assert "PIPELINE_OK" in out.stdout, (out.stdout + out.stderr)[-3000:]


def test_dedup_index():
    from repro.data.dedup import DedupIndex

    idx = DedupIndex(capacity=1024)
    docs = [[1, 2, 3], [4, 5, 6], [1, 2, 3], [7, 8]]  # in-batch dup
    keep, stats = idx.filter_batch(docs)
    assert keep == [0, 1, 3]
    # history dup across batches
    keep2, stats2 = idx.filter_batch([[4, 5, 6], [9, 9]])
    assert keep2 == [1]
    assert stats2["duplicates"] == 2
