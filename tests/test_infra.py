"""Infrastructure tests: optimizer, checkpoint/restart + elasticity,
trainer fault tolerance, gradient compression, EmbedElim, data pipeline
determinism, sharding helpers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import backbone, init_params, reduced
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.sparse import embed_elim_update, embed_occ_update


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_decreases_loss():
    cfg = reduced(get_config("qwen2-0.5b"), n_layers=2)
    params = init_params(backbone.model_spec(cfg))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}

    @jax.jit
    def step(params, opt):
        (l, _), g = jax.value_and_grad(
            lambda p: backbone.loss_fn(p, batch, cfg), has_aux=True
        )(params)
        g, _ = clip_by_global_norm(g, 1.0)
        params, opt = adamw_update(g, opt, params, jnp.float32(1e-3))
        return params, opt, l

    losses = []
    for _ in range(8):
        params, opt, l = step(params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((10,)) * 4.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(gn), np.sqrt(90 + 160), rtol=1e-5)
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# EmbedElim (paper technique on the sparse-update path)
# ---------------------------------------------------------------------------


def test_embed_elim_matches_occ():
    rng = np.random.default_rng(3)
    v, d, t = 50, 8, 200
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    ids = jnp.asarray(np.minimum(rng.zipf(1.5, t), v) - 1, jnp.int32)
    grads = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)

    elim_out, stats = embed_elim_update(table, ids, grads, 0.1)
    occ_out = embed_occ_update(table, ids, grads, 0.1)
    np.testing.assert_allclose(np.asarray(elim_out), np.asarray(occ_out), atol=1e-5)
    assert int(stats.eliminated) > 0  # zipf ⇒ duplicates collapsed
    assert int(stats.writes_elim) == len(set(np.asarray(ids).tolist()))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_quantization_roundtrip():
    from repro.parallel.compress import dequantize_int8, quantize_int8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((777,)) * 0.01, jnp.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape, x.dtype)
    err = float(jnp.max(jnp.abs(x - y)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-8


def test_error_feedback_unbiased():
    """With error feedback, the cumulative applied update converges to the
    cumulative true gradient (compression bias vanishes)."""
    from repro.parallel.compress import _ef_quantize, dequantize_int8

    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((256,)) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for _ in range(50):
        q, s, err = _ef_quantize(g, err)
        applied = applied + dequantize_int8(q, s, g.shape, g.dtype)
    # applied ≈ 50·g up to one quantization step of residual
    np.testing.assert_allclose(
        np.asarray(applied), np.asarray(50 * g), atol=float(jnp.max(jnp.abs(g)))
    )


# ---------------------------------------------------------------------------
# checkpoint + trainer fault tolerance
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager, latest_step, restore

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=2)
    params = init_params(backbone.model_spec(cfg))
    opt = adamw_init(params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"params": params, "opt": opt}, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    got = restore(str(tmp_path), 7, {"params": params, "opt": opt})
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves({"params": params, "opt": opt})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_crash_restart_resume(tmp_path):
    """Inject a hard failure mid-training; a fresh Trainer must resume from
    the last durable checkpoint and finish, with the data pipeline
    continuing deterministically from the restored step."""
    from repro.data import make_data_iter
    from repro.launch.mesh import make_host_mesh
    from repro.train import Trainer, TrainerConfig
    from repro.train.trainer import SimulatedFailure

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=1)
    mesh = make_host_mesh()
    mk_iter = lambda step: make_data_iter(cfg, batch=4, seq=16, start_step=step)

    tcfg = TrainerConfig(
        ckpt_dir=str(tmp_path), max_steps=12, ckpt_every=4, fail_at_step=6,
        log_every=1,
    )
    t1 = Trainer(cfg, tcfg, mesh, mk_iter)
    with pytest.raises(SimulatedFailure):
        t1.run()

    # restart: resumes from step 4 (last durable commit before the crash)
    tcfg2 = TrainerConfig(ckpt_dir=str(tmp_path), max_steps=12, ckpt_every=4, log_every=1)
    t2 = Trainer(cfg, tcfg2, mesh, mk_iter)
    assert t2.resumed_from == 4
    out = t2.run()
    assert out["final_step"] == 12
    assert np.isfinite(out["final_loss"])


def test_elastic_restore_changes_sharding(tmp_path):
    """A checkpoint written under one mesh restores under another (the
    elastic-scaling path): values identical, shardings = new mesh."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.checkpoint import CheckpointManager, restore

    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    x = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, x)
    sh = {"w": NamedSharding(mesh1, PartitionSpec(None, None))}
    got = restore(str(tmp_path), 1, x, sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(x["w"]))
    assert got["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism_and_resume():
    from repro.data import make_data_iter

    cfg = reduced(get_config("qwen2-0.5b"))
    it1 = make_data_iter(cfg, batch=4, seq=8, seed=1)
    seq1 = [next(it1)["tokens"] for _ in range(5)]
    it2 = make_data_iter(cfg, batch=4, seq=8, seed=1, start_step=3)
    seq2 = [next(it2)["tokens"] for _ in range(2)]
    np.testing.assert_array_equal(seq1[3], seq2[0])
    np.testing.assert_array_equal(seq1[4], seq2[1])


def test_zipf_workload_is_skewed():
    from repro.data.workloads import WorkloadConfig, op_stream

    cfg = WorkloadConfig(key_range=1000, dist="zipf", zipf_s=1.2, batch=4096)
    ops, keys, vals = next(iter(op_stream(cfg, 1)))
    _, counts = np.unique(keys, return_counts=True)
    assert counts.max() > 50  # hot keys dominate
    assert keys.max() < 1000 and keys.min() >= 0


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_serve_engine_end_to_end():
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_config("qwen2-0.5b"), n_layers=1)
    eng = ServeEngine(cfg, max_batch=2, s_max=64, n_pages=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        prompt = rng.integers(0, cfg.vocab, 8).tolist()
        eng.submit(Request(rid=rid, prompt=prompt, max_new=4))
    done = eng.run_until_done(max_ticks=500)
    assert len(done) == 4
    assert all(len(r.out) == 4 for r in done)
    s = eng.stats()
    assert s["n_done"] == 4
    assert s["pages_used"] == 0  # all released


def test_prefix_index_hit_on_shared_prompt():
    from repro.serve.pages import PAGE, PrefixIndex, prefix_hashes

    idx = PrefixIndex()
    prompt = list(range(PAGE * 2))
    chain = prefix_hashes(prompt)
    idx.publish_batch([h for h, _ in chain], [11, 22])
    hits = idx.lookup_batch([h for h, _ in chain])
    assert hits == [11, 22]
    # a different prompt misses
    other = prefix_hashes(list(range(7, 7 + PAGE)))
    assert idx.lookup_batch([other[0][0]]) == [None]
