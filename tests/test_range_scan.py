"""Range-scan subsystem tests: ``ABTree.scan_round`` vs ``DictOracle.range``
vs the host ``range_query`` on trees mutated by interleaved update rounds,
the ``kernels/range_scan`` Pallas kernel vs its jnp ref, the optimistic
retry/conflict paths, and the serving session-range eviction sweep."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    ABTree,
    DictOracle,
    EMPTY,
    OP_DELETE,
    OP_INSERT,
    OP_RANGE,
    ScanConflictError,
    TreeConfig,
    range_query,
)
from repro.kernels.range_scan import range_scan, range_scan_pallas, range_scan_ref

SMALL = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _scan_items(out, i):
    c = int(np.asarray(out.count)[i])
    ks = np.asarray(out.keys)[i]
    vs = np.asarray(out.vals)[i]
    return [(int(ks[j]), int(vs[j])) for j in range(c)]


def _check_scans(tree, oracle, los, his, cap=512):
    out = tree.scan_round(los, his, cap=cap)
    for i, (lo, hi) in enumerate(zip(los, his)):
        want = oracle.range(int(lo), int(hi))
        got = _scan_items(out, i)
        if len(want) > cap:
            assert bool(np.asarray(out.truncated)[i])
            want = want[:cap]
        else:
            assert not bool(np.asarray(out.truncated)[i])
        assert got == want, (i, int(lo), int(hi), got[:4], want[:4])
        # padding beyond count is EMPTY
        assert all(
            int(k) == int(EMPTY) for k in np.asarray(out.keys)[i, len(got) :]
        )
    return out


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_scan_edge_ranges(mode):
    """Empty / full / reversed / single-key / leaf-straddling ranges."""
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    keys = list(range(0, 400, 3))  # many leaves; boundaries at leaf splits
    vals = [k * 7 for k in keys]
    t.apply_round([OP_INSERT] * len(keys), keys, vals)
    o.apply_round([OP_INSERT] * len(keys), keys, vals)
    los = np.array([0, 50, 399, 100, 0, 250, 120, 10**9], np.int64)
    his = np.array([400, 50, 400, 90, 10**9, 251, 131, 2 * 10**9], np.int64)
    _check_scans(t, o, los, his)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_scan_interleaved_with_update_rounds(mode):
    """Randomized schedules of update rounds and scan rounds must stay
    oracle-exact (and agree with the host range_query)."""
    rng = np.random.default_rng(42)
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    for r in range(12):
        bsz = 48
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).astype(np.int32)
        keys = rng.integers(0, 600, bsz).astype(np.int64)
        vals = rng.integers(0, 1000, bsz).astype(np.int64)
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
        los = rng.integers(0, 600, 8).astype(np.int64)
        his = los + rng.integers(0, 300, 8).astype(np.int64)
        out = _check_scans(t, o, los, his)
        # spot-check one query against the host-side DFS reader
        assert _scan_items(out, 0) == range_query(t, int(los[0]), int(his[0]))


def test_scan_output_shape_is_cap_even_for_tiny_trees():
    """ScanOutput is (B, cap) regardless of how few candidate slots the
    leaf frontier holds (regression: the ref used to clip to n < cap)."""
    t = ABTree(SMALL)
    t.apply_round([OP_INSERT] * 3, [1, 2, 3], [10, 20, 30])
    out = t.scan_round([0, 2], [10, 3], cap=128)
    assert out.keys.shape == (2, 128) and out.vals.shape == (2, 128)
    assert _scan_items(out, 0) == [(1, 10), (2, 20), (3, 30)]
    assert int(np.asarray(out.keys)[0, 127]) == int(EMPTY)


def test_scan_truncation_at_capacity():
    t = ABTree(SMALL)
    o = DictOracle()
    keys = list(range(200))
    t.apply_round([OP_INSERT] * 200, keys, keys)
    o.apply_round([OP_INSERT] * 200, keys, keys)
    out = t.scan_round([0, 50], [200, 60], cap=16)
    assert int(np.asarray(out.count)[0]) == 16
    assert bool(np.asarray(out.truncated)[0])
    assert _scan_items(out, 0) == o.range(0, 200)[:16]  # smallest keys win
    assert int(np.asarray(out.count)[1]) == 10
    assert not bool(np.asarray(out.truncated)[1])


def test_scan_full_key_space_grows_frontier():
    t = ABTree(TreeConfig(capacity=2048, b=8, a=2, max_height=12))
    rng = np.random.default_rng(3)
    keys = rng.choice(10**8, size=900, replace=False).astype(np.int64)
    t.apply_round(np.full(900, OP_INSERT, np.int32), keys, keys)
    f0 = t._scan_frontier
    out = t.scan_round([0], [int(EMPTY) - 1], cap=1024)
    assert int(np.asarray(out.count)[0]) == 900
    assert t._scan_frontier > f0  # full-tree frontier forced doubling
    got = [k for k, _ in _scan_items(out, 0)]
    assert got == sorted(int(k) for k in keys)


def test_scan_retry_then_conflict():
    """An interleaved update round invalidates the scan (retry, counted in
    stats); a persistent mutator exhausts retries → ScanConflictError."""
    t = ABTree(SMALL)
    o = DictOracle()
    keys = list(range(100))
    t.apply_round([OP_INSERT] * 100, keys, keys)
    o.apply_round([OP_INSERT] * 100, keys, keys)

    fired = []

    def once():
        if not fired:
            fired.append(1)
            t.apply_round([OP_DELETE] * 5, list(range(5)), [0] * 5)
            o.apply_round([OP_DELETE] * 5, list(range(5)), [0] * 5)

    t.scan_hook = once
    out = t.scan_round([0], [50], cap=128)
    t.scan_hook = None
    assert _scan_items(out, 0) == o.range(0, 50)  # post-update linearization
    assert t.stats()["scan_retries"] >= 1

    flip = []

    def always():
        # toggle a key so every validation sees a bumped version (a same-
        # round insert+delete would be eliminated without any write)
        op = OP_INSERT if len(flip) % 2 == 0 else OP_DELETE
        flip.append(1)
        t.apply_round([op], [500], [1])

    t.scan_hook = always
    with pytest.raises(ScanConflictError):
        t.scan_round([0], [1000], max_retries=3)
    t.scan_hook = None


def test_range_query_raises_scan_conflict_type():
    assert issubclass(ScanConflictError, RuntimeError)


def test_op_range_accepted_by_apply_round():
    """OP_RANGE lanes route through the fused pipeline (no more host-side
    pre-splitting); only *malformed* lanes are rejected."""
    t = ABTree(SMALL)
    t.apply_round([OP_INSERT] * 3, [1, 2, 3], [10, 20, 30])
    out = t.apply_round([OP_RANGE], [0], [10])  # scan [0, 10)
    assert int(np.asarray(out.results)[0]) == 3  # range lane result = count
    assert _scan_items(out.scan, 0) == [(1, 10), (2, 20), (3, 30)]
    with pytest.raises(ValueError, match="malformed"):
        t.apply_round([OP_RANGE], [5], [-1])  # negative span: hi < lo


# ---------------------------------------------------------------------------
# kernels/range_scan: Pallas kernel vs jnp ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "bsz,n,cap", [(1, 16, 4), (7, 48, 8), (64, 64, 16), (33, 96, 96), (4, 8, 16)]
)
def test_range_scan_kernel_matches_ref(bsz, n, cap):
    rng = np.random.default_rng(bsz * 7 + n)
    empty32 = np.iinfo(np.int32).max
    keys = np.stack([rng.choice(10**6, size=n, replace=False) for _ in range(bsz)])
    keys = np.where(rng.random((bsz, n)) < 0.25, empty32, keys).astype(np.int32)
    vals = rng.integers(0, 10**6, (bsz, n)).astype(np.int32)
    lo = rng.integers(0, 10**6, bsz).astype(np.int32)
    hi = lo + rng.integers(0, 10**6, bsz).astype(np.int32)
    got = range_scan_pallas(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi),
        cap=cap, interpret=True,
    )
    want = range_scan_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi), cap)
    for g, w, name in zip(got, want, ("keys", "vals", "count", "truncated")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)


def test_range_scan_ops_narrow_int64_roundtrip():
    """ops.range_scan narrows 64-bit keys that fit in 32 bits onto the
    kernel and widens the result, restoring the EMPTY sentinel."""
    rng = np.random.default_rng(0)
    bsz, n, cap = 5, 32, 8
    empty = int(EMPTY)
    keys = np.stack([rng.choice(10**6, size=n, replace=False) for _ in range(bsz)])
    keys = np.where(rng.random((bsz, n)) < 0.3, empty, keys).astype(np.int64)
    vals = rng.integers(0, 10**6, (bsz, n)).astype(np.int64)
    lo = rng.integers(0, 10**6, bsz).astype(np.int64)
    hi = lo + rng.integers(0, 10**6, bsz).astype(np.int64)
    args = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo), jnp.asarray(hi))
    got = range_scan(*args, cap=cap, narrow=True)
    want = range_scan_ref(*args, cap)
    for g, w, name in zip(got, want, ("keys", "vals", "count", "truncated")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
    assert got[0].dtype == jnp.int64


def test_fused_narrow_scan_routes_through_pallas(monkeypatch):
    """ABTree(narrow_scan=True) sends the FUSED round's scan gather through
    the Pallas kernel (ROADMAP "fused-round scan kernel" follow-up) and the
    results stay bit-identical to the int64 ref path."""
    import repro.kernels.range_scan.ops as scan_ops

    calls = []
    orig = scan_ops.range_scan_pallas

    def spy(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(scan_ops, "range_scan_pallas", spy)
    keys = list(range(0, 200, 3))
    vals = [k * 7 for k in keys]
    outs = []
    # scan_cap=37 is unique to this test, forcing a fresh trace of the scan
    # phase so the spy observes the (trace-time) kernel dispatch.
    for narrow in (True, False):
        t = ABTree(SMALL, narrow_scan=narrow)
        t.apply_round([OP_INSERT] * len(keys), keys, vals)
        traced = len(calls)
        outs.append(
            t.apply_round(
                [OP_RANGE, OP_INSERT, OP_RANGE], [10, 7, 150], [80, 70, 10**6],
                scan_cap=37,
            )
        )
        if narrow:
            assert len(calls) > traced, "narrow fused scan did not hit the kernel"
    for field in ("keys", "vals", "count", "truncated"):
        np.testing.assert_array_equal(
            np.asarray(getattr(outs[0].scan, field)),
            np.asarray(getattr(outs[1].scan, field)),
            err_msg=field,
        )
    np.testing.assert_array_equal(
        np.asarray(outs[0].results), np.asarray(outs[1].results)
    )


# ---------------------------------------------------------------------------
# workload + serving integration
# ---------------------------------------------------------------------------


def test_ycsb_e_stream_split_baseline():
    """``split_scan_round`` survives as the A/B baseline: its two-round
    execution must agree with the default fused one-round execution."""
    from repro.data.workloads import WorkloadConfig, split_scan_round, ycsb_e_stream

    wl = WorkloadConfig(key_range=1000, dist="zipf", batch=128, seed=2)
    (ops, keys, vals) = next(iter(ycsb_e_stream(wl, 1, scan_frac=0.9, max_span=16)))
    n_scan = int(np.sum(ops == OP_RANGE))
    assert 0 < n_scan < len(ops)
    (lo, hi), (pops, pkeys, pvals) = split_scan_round(ops, keys, vals)
    assert lo.shape == hi.shape == (n_scan,)
    assert np.all(hi > lo) and np.all(hi - lo <= 16)
    assert not np.any(pops == OP_RANGE)
    assert pops.shape == ops.shape  # result positions preserved
    prefill = list(range(0, 1000, 3))
    t = ABTree(SMALL)
    tf = ABTree(SMALL)
    for tree in (t, tf):
        tree.apply_round([OP_INSERT] * len(prefill), prefill, prefill)
    split_scan = t.scan_round(lo, hi, cap=32)
    t.apply_round(pops, pkeys, pvals)
    assert t.stats()["rounds"] == 2  # scan_round is not a combining round
    # fused path: the same mixed batch in ONE apply_round call
    out = tf.apply_round(ops, keys, vals, scan_cap=32)
    assert tf.stats()["rounds"] == 2
    assert tf.items() == t.items()
    scan_rows = np.asarray(out.scan.keys)[np.asarray(ops) == OP_RANGE]
    np.testing.assert_array_equal(scan_rows, np.asarray(split_scan.keys))


def test_session_index_range_eviction():
    from repro.serve.pages import SessionIndex

    si = SessionIndex(mode="elim")
    si.publish_batch(list(range(100, 140)), list(range(40)))
    freed = si.evict_range(100, 120, cap=8)  # cap < matches → chunked sweep
    assert sorted(freed) == list(range(20))
    assert si.lookup_batch([105, 125]) == [None, 25]
    assert sorted(si.evict_range(0, 1000)) == list(range(20, 40))
    assert si.tree.items() == {}
