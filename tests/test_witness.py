"""Linearizability witness over flight-recorder histories: positive
replay across modes x shard counts (mixed point/range rounds, fused
scan+delete, elim-annihilated insert/delete pairs), and provable
rejection of corrupted histories — a swapped elimination pair and a
dropped delete both raise ``WitnessError`` / exit the CLI non-zero."""
import copy
import json

import numpy as np
import pytest

from repro.core import (
    ABForest,
    ABTree,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_RANGE,
    TreeConfig,
)
from repro.obs.recorder import Recorder
from repro.obs.witness import WitnessError, check_history, main

CFG = TreeConfig(capacity=2048, b=8, a=2, max_height=12)
KEY_RANGE = 4096


def _holder(mode, shards):
    if shards == 1:
        h = ABTree(CFG, mode=mode)
    else:
        h = ABForest(
            n_shards=shards, cfg=CFG, mode=mode, key_space=(0, KEY_RANGE)
        )
    h.recorder = Recorder(capacity=100_000)
    return h


def _mixed_history(mode="elim", shards=1, rounds=6, seed=0):
    """Drive a holder through mixed rounds (duplicate keys so elimination
    segments form, a few range lanes per round, a fused scan+delete and a
    trailing scan round) and return the recorded history."""
    h = _holder(mode, shards)
    rng = np.random.default_rng(seed)
    n = 64
    for _ in range(rounds):
        ops = rng.choice(
            [OP_INSERT, OP_DELETE, OP_FIND], size=n, p=[0.5, 0.25, 0.25]
        ).astype(np.int32)
        # small key domain → duplicate keys → multi-op segments to combine
        keys = rng.integers(0, KEY_RANGE, n).astype(np.int64)
        vals = rng.integers(1, 1000, n).astype(np.int64)
        ops[:3] = OP_RANGE
        keys[:3] = rng.integers(0, KEY_RANGE - 64, 3)
        vals[:3] = rng.integers(1, 64, 3)
        h.apply_round(ops, keys, vals, scan_cap=16)
    h.scan_delete_round([0], [32], cap=8)
    h.scan_round([0], [KEY_RANGE], cap=32)
    return h.recorder.records()


@pytest.mark.parametrize("mode", ["elim", "occ"])
@pytest.mark.parametrize("shards", [1, 4])
def test_witness_validates_mixed_history(mode, shards):
    recs = _mixed_history(mode=mode, shards=shards)
    rep = check_history(recs)
    assert rep.rounds >= 8  # mixed rounds + fused scan_delete + scan
    assert rep.lanes > 0
    assert rep.state, "history must leave live keys to have checked reads"


def test_witness_audits_elim_reordered_pairs():
    """Insert+delete of the same key in one round: the elimination
    combiner annihilates the pair, and the witness must both accept the
    engine's chosen intra-round order and count the audited pairs."""
    t = _holder("elim", 1)
    ops = np.array(
        [OP_INSERT, OP_DELETE, OP_INSERT, OP_DELETE, OP_INSERT], np.int32
    )
    keys = np.array([5, 5, 9, 9, 123], np.int64)
    vals = np.array([50, 0, 90, 0, 7], np.int64)
    t.apply_round(ops, keys, vals)
    t.apply_round(
        np.full(3, OP_FIND, np.int32),
        np.array([5, 9, 123], np.int64),
        np.zeros(3, np.int64),
    )
    recs = t.recorder.records()
    rounds = [r for r in recs if r["kind"] == "round"]
    assert any(r.get("elim") for r in rounds), "elim note missing"
    rep = check_history(recs)
    assert rep.eliminated >= 2  # both same-key pairs annihilated
    assert sorted(rep.state) == [123]  # 5 and 9 net to absent


def _pair_history():
    """One deterministic annihilated pair plus a later read of the key."""
    t = _holder("elim", 1)
    t.apply_round(
        np.array([OP_INSERT, OP_DELETE, OP_INSERT], np.int32),
        np.array([5, 5, 77], np.int64),
        np.array([50, 0, 700], np.int64),
    )
    t.apply_round(
        np.full(2, OP_FIND, np.int32),
        np.array([5, 77], np.int64),
        np.zeros(2, np.int64),
    )
    return t.recorder.records()


def test_witness_rejects_swapped_elimination_pair():
    """Corruption: hand the eliminated delete's answer to the insert lane
    and vice versa.  The pair's recorded order (insert misses, delete hits
    the value the insert published) is the only legal linearization — the
    swap must be rejected."""
    recs = _pair_history()
    check_history(recs)  # sanity: the uncorrupted history is legal
    bad = copy.deepcopy(recs)
    rr = next(r for r in bad if r["kind"] == "round")
    i, j = rr["ops"].index(OP_INSERT), rr["ops"].index(OP_DELETE)
    assert rr["keys"][i] == rr["keys"][j] == 5
    assert rr["found"][i] != rr["found"][j]  # pair really was ordered
    rr["results"][i], rr["results"][j] = rr["results"][j], rr["results"][i]
    rr["found"][i], rr["found"][j] = rr["found"][j], rr["found"][i]
    with pytest.raises(WitnessError):
        check_history(bad)


def test_witness_rejects_dropped_delete():
    """Corruption: drop a delete round from the history.  The later read
    of the deleted key (recorded as a miss) is then impossible in the
    replayed state, so the witness must reject."""
    t = _holder("elim", 1)
    t.apply_round(
        np.full(2, OP_INSERT, np.int32),
        np.array([11, 22], np.int64),
        np.array([110, 220], np.int64),
    )
    t.apply_round(  # the record the corruption drops
        np.array([OP_DELETE], np.int32),
        np.array([11], np.int64),
        np.zeros(1, np.int64),
    )
    t.apply_round(  # reads 11 as a miss — proves the delete happened
        np.full(2, OP_FIND, np.int32),
        np.array([11, 22], np.int64),
        np.zeros(2, np.int64),
    )
    recs = t.recorder.records()
    check_history(recs)  # sanity: the full history is legal
    bad = [
        r
        for r in recs
        if not (r["kind"] == "round" and OP_DELETE in r["ops"])
    ]
    assert len(bad) == len(recs) - 1
    with pytest.raises(WitnessError):
        check_history(bad)


def test_witness_cli_exit_codes(tmp_path, capsys):
    good, bad = _pair_history(), None
    bad = copy.deepcopy(good)
    rr = next(r for r in bad if r["kind"] == "round")
    rr["found"] = [not f for f in rr["found"]]
    p_good, p_bad = tmp_path / "good.jsonl", tmp_path / "bad.jsonl"
    for p, recs in ((p_good, good), (p_bad, bad)):
        p.write_text("".join(json.dumps(r) + "\n" for r in recs))
    assert main([str(p_good)]) == 0
    assert "witness OK" in capsys.readouterr().out
    assert main([str(p_bad)]) == 1
    assert "WITNESS FAILED" in capsys.readouterr().err
