"""Multi-device parallel-layer tests (subprocess with 8 host devices):
compressed cross-pod gradient reduction, sharding helpers, and a sharded
end-to-end train step."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=560):
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert out.returncode == 0, (out.stdout + out.stderr)[-3000:]
    return out.stdout


def test_compressed_grad_reduce_multidevice():
    _run(
        """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.compress import compressed_grad_reduce, init_error_feedback
mesh = jax.make_mesh((2,2,2), ('pod','data','model'))
g = {'w': jnp.asarray(np.random.default_rng(0).standard_normal((64,)), jnp.float32)}
e = init_error_feedback(g)
with mesh:
    out, e2 = jax.jit(lambda g_, e_: compressed_grad_reduce(g_, e_, mesh))(g, e)
np.testing.assert_allclose(np.asarray(out['w']), np.asarray(g['w']), atol=2e-2)
print('OK')
"""
    )


def test_sharded_train_step_runs_multidevice():
    """One real train step on an 8-device (data=4, model=2) mesh with the
    production sharding rules — numerics must match the 1-device run."""
    _run(
        """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import backbone, init_params, reduced
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step

cfg = reduced(get_config('qwen2-0.5b'), n_layers=2, d_model=64, n_heads=4, n_kv=2)
mesh = jax.make_mesh((4, 2), ('data', 'model'))
params = init_params(backbone.model_spec(cfg))
opt = adamw_init(params)
rng = np.random.default_rng(0)
batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
with mesh:
    jit_maker, sh = make_train_step(cfg, mesh, donate=False)
    sd = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    step = jit_maker(sd)
    out = step(params, opt, batch, jnp.int32(0))
loss_sharded = float(out.metrics['loss'])

# single-device reference
l_ref, _ = backbone.loss_fn(params, batch, cfg)
np.testing.assert_allclose(loss_sharded, float(l_ref), rtol=2e-4)
print('OK sharded loss', loss_sharded)
"""
    )


def test_cache_pspecs_cover_all_archs():
    """Sharding assignment must produce valid PartitionSpecs for every
    (arch, decode shape) without error."""
    _run(
        """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import jax
from repro.configs import ARCH_IDS, SHAPES, get_config, cell_status
from repro.parallel.sharding import cache_pspecs
mesh = jax.make_mesh((2,2,2), ('pod','data','model'))
n = 0
for arch in ARCH_IDS:
    cfg = get_config(arch)
    for sn in ('decode_32k', 'long_500k'):
        shape = SHAPES[sn]
        if cell_status(cfg, shape) != 'run':
            continue
        specs = cache_pspecs(cfg, shape.batch, shape.seq, mesh)
        n += len(jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, '__iter__') and not isinstance(x, dict)))
print('OK', n)
"""
    )
