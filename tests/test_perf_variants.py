"""Equivalence tests for the §Perf optimized variants: optimizations must
not change results (the hillclimb rule: keep the speedup, prove it exact)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params, reduced
from repro.models.moe import moe_apply, moe_spec
from repro.models.xlstm import mlstm_spec, mlstm_train, mlstm_train_chunked
from repro.models.params import init_params as init_params_spec


def test_chunked_mlstm_exact_vs_scan():
    cfg = reduced(get_config("xlstm-350m"))
    p = init_params_spec(mlstm_spec(cfg))
    rng = np.random.default_rng(0)
    for t, chunk in [(64, 16), (128, 32), (96, 96)]:
        x = jnp.asarray(rng.standard_normal((2, t, cfg.d_model)), jnp.float32)
        a = mlstm_train(p, x, cfg)
        b = mlstm_train_chunked(p, x, cfg, chunk=chunk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)


def test_chunked_mlstm_grad_close():
    cfg = reduced(get_config("xlstm-350m"))
    p = init_params_spec(mlstm_spec(cfg))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), jnp.float32)
    g1 = jax.grad(lambda xx: mlstm_train(p, xx, cfg).sum())(x)
    g2 = jax.grad(lambda xx: mlstm_train_chunked(p, xx, cfg, chunk=8).sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4, rtol=1e-3)


def test_grouped_moe_matches_global_ample_capacity():
    cfg = reduced(get_config("granite-moe-3b-a800m"), n_layers=1)
    p = init_params_spec(moe_spec(cfg))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 16, cfg.d_model)), jnp.float32)
    y0 = moe_apply(p, x, cfg.replace(moe_groups=0, capacity_factor=8.0))
    y4 = moe_apply(p, x, cfg.replace(moe_groups=4, capacity_factor=8.0))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y4), atol=1e-5)


def test_grouped_moe_shardmap_matches_vmap():
    """Under a real (multi-device) mesh the shard_map path must equal the
    plain vmap path."""
    import subprocess
    import sys

    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import reduced
from repro.models.moe import moe_apply, moe_spec
from repro.models.params import init_params

cfg = reduced(get_config('granite-moe-3b-a800m'), n_layers=1).replace(
    moe_groups=4, capacity_factor=8.0)
p = init_params(moe_spec(cfg))
rng = np.random.default_rng(3)
x = jnp.asarray(rng.standard_normal((8, 16, cfg.d_model)), jnp.float32)

ref = moe_apply(p, x, cfg)  # no mesh → vmap fallback

mesh = jax.make_mesh((4, 2), ('data', 'model'))
with mesh:
    f = jax.jit(lambda p_, x_: moe_apply(p_, x_, cfg),
                in_shardings=(None, NamedSharding(mesh, P('data', None, None))))
    got = f(p, x)
np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=2e-5, rtol=1e-4)
print('SHARDMAP_OK')
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("os").path.dirname(__import__("os").path.dirname(__file__)),
    )
    assert "SHARDMAP_OK" in out.stdout, out.stdout + out.stderr
