"""Correctness tests for the batched OCC/Elim-ABtree against the sequential
oracle, including hypothesis property tests of the paper's invariants."""
import numpy as np
import pytest

try:  # property tests need hypothesis; deterministic tests run without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import (
    ABTree,
    DictOracle,
    OP_DELETE,
    OP_FIND,
    OP_INSERT,
    OP_NOP,
    TreeConfig,
    check_invariants,
)
from repro.core.oracle import tree_contents

SMALL = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _run_rounds(tree, oracle, rounds, check_every=1):
    for i, (ops, keys, vals) in enumerate(rounds):
        out = tree.apply_round(ops, keys, vals)
        exp_res, exp_found = oracle.apply_round(ops, keys, vals)
        got_res = np.asarray(out.results).tolist()
        got_found = np.asarray(out.found).tolist()
        for j, (op, k) in enumerate(zip(ops, keys)):
            assert got_found[j] == exp_found[j], (
                f"round {i} op {j} ({op},{k}): found {got_found[j]} != {exp_found[j]}"
            )
            if exp_found[j]:
                assert got_res[j] == exp_res[j], (
                    f"round {i} op {j} ({op},{k}): val {got_res[j]} != {exp_res[j]}"
                )
        if (i + 1) % check_every == 0:
            check_invariants(tree.state, tree.cfg)
            assert tree_contents(tree.state, tree.cfg) == oracle.items()


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_single_ops(mode):
    t = ABTree(SMALL, mode=mode)
    assert t.insert(5, 50) is None
    assert t.insert(5, 51) == 50  # insert on present returns existing value
    assert t.find(5) == 50
    assert t.delete(5) == 50
    assert t.find(5) is None
    assert t.delete(5) is None
    check_invariants(t.state, t.cfg)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_sequential_fill_and_drain(mode):
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    n = 200
    rounds = []
    for k in range(n):
        rounds.append(([OP_INSERT], [k * 7 % n], [k]))
    for k in range(n):
        rounds.append(([OP_DELETE], [k * 3 % n], [0]))
    _run_rounds(t, o, rounds, check_every=20)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_batch_round_mixed(mode):
    rng = np.random.default_rng(0)
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    for r in range(30):
        bsz = 64
        ops = rng.integers(1, 4, bsz).tolist()
        keys = rng.integers(0, 40, bsz).tolist()  # heavy duplication
        vals = rng.integers(0, 1000, bsz).tolist()
        _run_rounds(t, o, [(ops, keys, vals)], check_every=1)


@pytest.mark.parametrize("mode", ["elim", "occ"])
def test_batch_zipf_churn(mode):
    """The paper's target workload: skewed update-heavy (inserts+deletes of
    the same hot keys)."""
    rng = np.random.default_rng(1)
    t = ABTree(SMALL, mode=mode)
    o = DictOracle()
    zipf = np.minimum(rng.zipf(1.5, 2000), 500)
    i = 0
    for r in range(25):
        bsz = 80
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).tolist()
        keys = zipf[i : i + bsz].tolist()
        i += bsz
        vals = rng.integers(0, 100, bsz).tolist()
        _run_rounds(t, o, [(ops, keys, vals)], check_every=5)


def test_elimination_reduces_writes():
    """Core paper claim: under same-key contention, Elim does ~1 write per
    unique key; OCC does ~1 per op."""
    cfg = SMALL
    ops = [OP_INSERT, OP_DELETE] * 32  # 64 ops, all on key 7
    keys = [7] * 64
    vals = list(range(64))

    te = ABTree(cfg, mode="elim")
    te.apply_round(ops, keys, vals)
    to = ABTree(cfg, mode="occ")
    to.apply_round(ops, keys, vals)

    se, so = te.stats(), to.stats()
    assert se["slot_writes"] <= 2  # at most one net insert (2 slot writes)
    assert so["slot_writes"] >= 60  # every op wrote
    assert se["eliminated"] >= 60
    assert so["subrounds"] == 64
    # both must agree with the oracle
    o = DictOracle()
    o.apply_round(ops, keys, vals)
    assert tree_contents(te.state, te.cfg) == o.items()
    assert tree_contents(to.state, to.cfg) == o.items()


def test_empty_and_nop_round():
    t = ABTree(SMALL)
    out = t.apply_round([OP_NOP] * 8, [0] * 8, [0] * 8)
    assert not np.asarray(out.found).any()
    check_invariants(t.state, t.cfg)


def test_large_batch_single_leaf_overflow():
    """All inserts land in one leaf → cascading splits in one round."""
    t = ABTree(SMALL)
    o = DictOracle()
    ops = [OP_INSERT] * 128
    keys = list(range(128))
    vals = [k * 10 for k in keys]
    _run_rounds(t, o, [(ops, keys, vals)])
    # drain to force merges
    ops = [OP_DELETE] * 128
    _run_rounds(t, o, [(ops, keys, vals)])
    assert t.items() == {}


def test_pool_growth():
    t = ABTree(TreeConfig(capacity=64, b=8, a=2, max_height=12))
    o = DictOracle()
    ops = [OP_INSERT] * 256
    keys = list(range(256))
    vals = keys
    _run_rounds(t, o, [(ops, keys, vals)])
    assert t.cfg.capacity > 64


def test_elim_record_published():
    """After a modifying round the leaf's ElimRecord reflects the last
    modification with an odd version (paper §4.1)."""
    t = ABTree(SMALL)
    t.apply_round([OP_INSERT], [42], [4200])
    s = t.state
    leaf = int(np.asarray(s.root))  # single-leaf tree
    assert int(np.asarray(s.rec_key)[leaf]) == 42
    assert int(np.asarray(s.rec_val)[leaf]) == 4200
    rec_ver = int(np.asarray(s.rec_ver)[leaf])
    ver = int(np.asarray(s.ver)[leaf])
    assert rec_ver % 2 == 1 and rec_ver == ver - 1


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped when hypothesis is not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    op_strategy = st.tuples(
        st.sampled_from([OP_FIND, OP_INSERT, OP_DELETE]),
        st.integers(min_value=0, max_value=30),
        st.integers(min_value=0, max_value=10**6),
    )

    @settings(
        max_examples=40,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    @given(
        rounds=st.lists(
            st.lists(op_strategy, min_size=1, max_size=48), min_size=1, max_size=6
        ),
        mode=st.sampled_from(["elim", "occ"]),
    )
    def test_property_oracle_equivalence(rounds, mode):
        """For any op sequence, batched results == sequential oracle and all
        of the paper's structural invariants hold after every round."""
        t = ABTree(TreeConfig(capacity=512, b=8, a=2, max_height=12), mode=mode)
        o = DictOracle()
        prepared = []
        for r in rounds:
            ops = [x[0] for x in r]
            keys = [x[1] for x in r]
            vals = [x[2] for x in r]
            prepared.append((ops, keys, vals))
        _run_rounds(t, o, prepared, check_every=1)

    @settings(
        max_examples=15, deadline=None, suppress_health_check=[HealthCheck.too_slow]
    )
    @given(
        keys=st.lists(
            st.integers(min_value=0, max_value=10**9),
            min_size=1,
            max_size=200,
            unique=True,
        ),
        b=st.sampled_from([6, 8, 12]),
    )
    def test_property_bulk_insert_all_found(keys, b):
        t = ABTree(TreeConfig(capacity=2048, b=b, a=2, max_height=12))
        ops = [OP_INSERT] * len(keys)
        vals = [k % 997 for k in keys]
        t.apply_round(ops, keys, vals)
        check_invariants(t.state, t.cfg)
        out = t.apply_round([OP_FIND] * len(keys), keys, [0] * len(keys))
        assert np.asarray(out.found).all()
        assert np.asarray(out.results).tolist() == vals

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_oracle_equivalence():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_bulk_insert_all_found():
        pass


def test_range_query_matches_oracle():
    from repro.core.abtree import range_query

    rng = np.random.default_rng(9)
    t = ABTree(SMALL)
    o = DictOracle()
    keys = rng.choice(5000, size=400, replace=False).tolist()
    vals = [k * 3 for k in keys]
    t.apply_round([OP_INSERT] * 400, keys, vals)
    o.apply_round([OP_INSERT] * 400, keys, vals)
    for lo, hi in [(0, 5000), (100, 200), (4999, 5000), (200, 100), (2500, 2600)]:
        got = range_query(t, lo, hi)
        want = sorted((k, v) for k, v in o.d.items() if lo <= k < hi)
        assert got == want, (lo, hi)
