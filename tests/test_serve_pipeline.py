"""Pipelined serving tests: the double-buffered tick (decode dispatched,
next round's admit overlapped under the in-flight device work), the
``tick_overlap_frac`` telemetry that PINS the overlap, span ordering in the
tracer, and the pipelined engine's durability surface — group-commit depth,
drain-at-exit, warm restart with the grouping knobs."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import reduced
from repro.obs import Tracer
from repro.serve.engine import Request, ServeEngine

CFG = reduced(get_config("qwen2-0.5b"), n_layers=1)


def _mk_engine(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("s_max", 64)
    kw.setdefault("n_pages", 128)
    return ServeEngine(CFG, **kw)


def _submit_all(eng, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(Request(rid=i, prompt=list(rng.integers(0, 100, 8)),
                           max_new=max_new))


def test_pipelined_completes_same_requests_as_serial():
    """Pipelining reorders HOST work only: every submitted request still
    completes with exactly ``max_new`` generated tokens, admission and
    retirement counters agree with the serial engine."""
    results = {}
    for pipelined in (False, True):
        eng = _mk_engine(pipelined=pipelined)
        _submit_all(eng, 6, seed=1)
        done = eng.run_until_done(max_ticks=200)
        results[pipelined] = {
            "rids": sorted(r.rid for r in done),
            "lens": sorted(len(r.out) for r in done),
            "admitted": eng.metrics.value("admitted"),
            "retired": eng.metrics.value("retired"),
        }
    assert results[False] == results[True]
    assert results[True]["rids"] == list(range(6))
    assert results[True]["lens"] == [4] * 6


def test_tick_overlap_frac_is_positive():
    """The whole point of the double-buffered tick: admit work runs WHILE a
    decode is in flight, so the overlap fraction must be strictly positive
    on decode ticks (and the gauge reflects the last tick)."""
    eng = _mk_engine(pipelined=True)
    _submit_all(eng, 8, seed=2)
    eng.run_until_done(max_ticks=200)
    h = eng.metrics.histogram_summary("tick_overlap_frac")
    assert h["count"] == eng.metrics.value("ticks")
    assert h["max"] > 0.0, "no tick overlapped host work with a decode"
    assert eng.metrics.snapshot()["gauges"]["tick_overlap_frac"] > 0.0


def test_serial_engine_does_not_emit_overlap_metric():
    eng = _mk_engine(pipelined=False)
    _submit_all(eng, 2, seed=3)
    eng.run_until_done(max_ticks=100)
    assert eng.metrics.histogram_summary("tick_overlap_frac")["count"] == 0


def test_pipelined_span_ordering_proves_overlap():
    """Tracer evidence of the pipeline shape: within a tick the spans
    close in dispatch → admit → decode(fence) order, the overlapped admit
    is flagged, and the dispatch span is CHEAP relative to the fenced
    decode span (dispatch returns before the device finishes)."""
    eng = _mk_engine(pipelined=True)
    eng.tracer = Tracer()
    _submit_all(eng, 6, seed=4)
    eng.run_until_done(max_ticks=200)
    names = [e["name"] for e in eng.tracer.events]
    assert "serve.decode.dispatch" in names
    # per-tick ordering: every dispatch is followed by an admit and then a
    # fenced decode before the next dispatch
    seq = [n for n in names
           if n in ("serve.decode.dispatch", "serve.admit", "serve.decode")]
    for i, n in enumerate(seq):
        if n == "serve.decode.dispatch":
            assert seq[i + 1] == "serve.admit" and seq[i + 2] == "serve.decode"
    overlapped = [e for e in eng.tracer.events
                  if e["name"] == "serve.admit" and e["args"].get("overlapped")]
    assert overlapped, "no admit ran under an in-flight decode"
    # start-time ordering inside one tick: admit starts after the dispatch
    # span opened, decode fences after the admit finished
    ev = {e["name"]: e for e in eng.tracer.events
          if e["name"].startswith("serve.")}  # last tick's spans win
    d, a, f = (ev["serve.decode.dispatch"], ev["serve.admit"], ev["serve.decode"])
    assert d["ts"] <= a["ts"] <= f["ts"]


def test_pipelined_durable_engine_groups_drains_and_restarts(tmp_path):
    """The full PR-10 stack: pipelined ticks + grouped async commits on
    both index journals.  ``stats()['durability']`` surfaces the group
    depth (``rounds_per_commit``) and the pending-group age;
    ``run_until_done`` drains so NOTHING stays volatile at exit; a second
    engine on the same directory warm-restarts with the same knobs."""
    d = str(tmp_path / "idx")
    eng = _mk_engine(pipelined=True, index_shards=2, index_durable_dir=d,
                     group_commit_every=4, group_commit_max_wait_s=1e9)
    _submit_all(eng, 12, seed=5, max_new=3)
    done = eng.run_until_done(max_ticks=300)
    assert sorted(r.rid for r in done) == list(range(12))
    dur = eng.stats()["durability"]
    assert not dur["degraded"]
    for name in ("prefix", "sessions"):
        assert dur[name]["group_commit_every"] == 4
        assert dur[name]["pending_rounds"] == 0, "exit drain left a group pending"
    # the session journal carries the churn: groups actually batched
    assert dur["sessions"]["rounds_per_commit"]["max"] > 1
    # warm restart with the same grouping knobs — the recovered journals
    # resume grouped commits and the engine serves on top of them
    eng2 = _mk_engine(pipelined=True, index_shards=2, index_durable_dir=d,
                      group_commit_every=4, group_commit_max_wait_s=1e9)
    assert eng2.sessions.tree.group_commit_every == 4
    _submit_all(eng2, 4, seed=6, max_new=2)
    done2 = eng2.run_until_done(max_ticks=100)
    assert len(done2) == 4
    assert eng2.stats()["durability"]["sessions"]["pending_rounds"] == 0
