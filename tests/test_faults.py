"""Failpoint-registry tests: deterministic fault schedules, transient-fault
retry, the durability circuit breaker (degraded volatile mode + reattach),
corruption-hardened recovery (CRC truncation + quarantine, torn snapshots
sinking a generation), GC fault tolerance, and the disabled-plan purity
contract (no HLO or commit-path delta).  The hypothesis property test
truncates a journal segment at arbitrary byte offsets and asserts recovery
always lands on an oracle-verified committed round prefix."""
import json
import os
import shutil

import numpy as np
import pytest

try:  # property tests need hypothesis; deterministic tests run without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (
    DictOracle,
    DurableABTree,
    DurableForest,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    OP_DELETE,
    OP_INSERT,
    RecoveryError,
    TreeConfig,
    recover,
    recover_forest,
)
from repro.core.oracle import tree_contents

CFG = TreeConfig(capacity=512, b=8, a=2, max_height=12)


def _mk_rounds(n_rounds=6, bsz=32, seed=0):
    rng = np.random.default_rng(seed)
    rounds = []
    for _ in range(n_rounds):
        ops = rng.choice([OP_INSERT, OP_DELETE], bsz).tolist()
        keys = rng.integers(0, 64, bsz).tolist()
        vals = rng.integers(0, 1000, bsz).tolist()
        rounds.append((ops, keys, vals))
    return rounds


def _run_with_oracle(t, rounds):
    """Apply ``rounds``; return the oracle prefix states ([0] = empty)."""
    o = DictOracle()
    prefixes = [o.items()]
    for ops, keys, vals in rounds:
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
        prefixes.append(o.items())
    return prefixes


# ---------------------------------------------------------------------------
# FaultPlan unit behaviour
# ---------------------------------------------------------------------------


def test_fault_schedule_is_deterministic():
    """Fire decisions are a pure function of (seed, site, commit, shard,
    attempt) — two identical plans produce the identical schedule, hit in
    any order."""
    def schedule(plan):
        out = []
        for commit in range(20):
            for shard in (0, 1):
                for attempt in (0, 1):
                    try:
                        r = plan.fail(
                            "segment_fsync", commit=commit, shard=shard,
                            attempt=attempt,
                        )
                        out.append(("ok", r))
                    except InjectedFault as e:
                        out.append(("fault", e.kind))
        return out

    mk = lambda: FaultPlan(seed=42).add(
        FaultSpec(site="segment_fsync", kind="eio", p=0.3)
    )
    assert schedule(mk()) == schedule(mk())
    assert schedule(mk()) != schedule(
        FaultPlan(seed=43).add(FaultSpec(site="segment_fsync", kind="eio", p=0.3))
    )


def test_fault_spec_windows_and_budget():
    plan = FaultPlan(seed=0).add(
        FaultSpec(site="manifest_rename", kind="rename_fail", commits=(3, 5))
    )
    for commit in (0, 2, 5, 9):
        assert plan.fail("manifest_rename", commit=commit) is None
        assert plan.fail("segment_write", commit=4) is None  # wrong site
    for commit in (3, 4):
        with pytest.raises(InjectedFault):
            plan.fail("manifest_rename", commit=commit)
    budget = FaultPlan(seed=0).add(
        FaultSpec(site="dir_fsync", kind="eio", times=2)
    )
    fired = 0
    for commit in range(10):
        try:
            budget.fail("dir_fsync", commit=commit)
        except InjectedFault:
            fired += 1
    assert fired == 2  # transient: clears once the budget is spent


# ---------------------------------------------------------------------------
# Retry + circuit breaker
# ---------------------------------------------------------------------------


def test_transient_eio_retries_then_succeeds(tmp_path):
    d = str(tmp_path / "t")
    plan = FaultPlan(seed=1).add(
        FaultSpec(site="segment_fsync", kind="eio", times=2)
    )
    t = DurableABTree(d, CFG, mode="elim", faults=plan, commit_backoff_s=0.0)
    prefixes = _run_with_oracle(t, _mk_rounds())
    s = t.durability_status()
    assert s["commit_retries"] >= 2 and not s["degraded"]
    assert t.metrics.value("fault_injected") == 2
    assert t.metrics.value("commit_retries") == s["commit_retries"]
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == prefixes[-1]


def test_persistent_failure_degrades_then_reattaches(tmp_path):
    """A sick disk must never surface through apply_round: commits are
    retried, then abandoned, then suspended (degraded VOLATILE mode); a
    healed disk reattaches on the next probe and re-journals everything."""
    d = str(tmp_path / "t")
    plan = FaultPlan(seed=2).add(
        FaultSpec(site="manifest_rename", kind="rename_fail")  # p=1: always
    )
    t = DurableABTree(
        d, CFG, mode="elim", faults=plan, commit_retries=1,
        commit_backoff_s=0.0, degrade_after=2, reattach_every=2,
    )
    rounds = _mk_rounds(8, seed=3)
    prefixes = _run_with_oracle(t, rounds)  # raises nothing, by contract
    s = t.durability_status()
    assert s["degraded"] and s["commits_suspended"] >= 1
    assert t.metrics.value("durability_degraded") == 1
    # while degraded nothing committed: recovery sees no manifest at all
    # (every rename failed → the empty prefix) or an old prefix — never a
    # partial round.
    try:
        assert tree_contents(recover(d).tree.state, CFG) in prefixes
    except FileNotFoundError:
        pass  # nothing ever committed — the empty prefix

    plan.clear()  # the disk healed
    more = _mk_rounds(4, seed=4)
    o = DictOracle()
    o.d = dict(prefixes[-1])
    for ops, keys, vals in more:
        t.apply_round(ops, keys, vals)
        o.apply_round(ops, keys, vals)
    s2 = t.durability_status()
    assert not s2["degraded"], "reattach probe must close the breaker"
    assert t.metrics.value("durability_reattached") == 1
    # the reattach snapshot re-journals the degraded-era rounds too
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == o.items()
    # the breaker transition trail is on the flight recorder
    kinds = [rec.get("state") for rec in r.forensics_records()
             if rec.get("kind") == "transition" and rec.get("event") == "durability"]
    assert "degraded" in kinds and "reattached" in kinds


def test_degraded_forest_serves_and_recovers_prefix(tmp_path):
    d = str(tmp_path / "f")
    # commits 0-1 land, then the disk goes permanently sick — so recovery
    # has a real (non-empty) committed prefix to fall back on.
    plan = FaultPlan(seed=5).add(
        FaultSpec(site="manifest_fsync", kind="eio", commits=(2, 10**9))
    )
    f = DurableForest(
        d, n_shards=2, cfg=CFG, mode="elim", key_space=(0, 64), faults=plan,
        commit_retries=1, commit_backoff_s=0.0, degrade_after=2,
    )
    prefixes = _run_with_oracle(f, _mk_rounds(6, seed=6))
    assert f.durability_status()["degraded"]
    assert f.items() == prefixes[-1], "degraded mode must keep serving"
    assert recover_forest(d).items() in prefixes


# ---------------------------------------------------------------------------
# Corruption-hardened recovery
# ---------------------------------------------------------------------------


def test_torn_segment_truncates_and_quarantines(tmp_path):
    """A torn segment write (fsync lied) is caught by the per-file CRC at
    recovery: replay truncates at the torn record, later segments are
    unreachable, and both move to quarantine/ instead of being trusted."""
    d = str(tmp_path / "t")
    plan = FaultPlan(seed=7).add(
        FaultSpec(site="segment_write", kind="torn", commits=(3, 4),
                  torn_frac=0.5)
    )
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100, faults=plan)
    prefixes = _run_with_oracle(t, _mk_rounds(6, seed=8))
    r = recover(d)
    got = tree_contents(r.tree.state, r.tree.cfg)
    assert got == prefixes[2], "cut must land just before the torn commit"
    assert r._quarantined and all(q.startswith("quarantine/") for q in r._quarantined)
    assert r.metrics.value("segments_quarantined") == len(r._quarantined)
    assert os.path.isdir(os.path.join(d, "quarantine"))
    # the recovered journal keeps working past the cut
    r.apply_round([OP_INSERT], [999], [123])
    assert recover(d).tree.find(999) == 123


def test_corrupt_snapshot_sinks_both_generations(tmp_path):
    """A bad SNAPSHOT has no earlier file to truncate to — the generation
    is unrecoverable; when both retained manifests reference it, recovery
    refuses loudly (RecoveryError) rather than fabricating state."""
    d = str(tmp_path / "t")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100)
    _run_with_oracle(t, _mk_rounds(4, seed=9))
    snaps = [f for f in os.listdir(d) if "_snapshot_" in f]
    assert snaps
    for f in snaps:  # corrupt every snapshot both generations could use
        p = os.path.join(d, f)
        blob = bytearray(open(p, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(p, "wb").write(bytes(blob))
    with pytest.raises(RecoveryError):
        recover(d)


def test_manifest_checksum_rejects_bitflip_falls_back_to_prev(tmp_path):
    d = str(tmp_path / "t")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100)
    prefixes = _run_with_oracle(t, _mk_rounds(5, seed=10))
    mpath = os.path.join(d, "MANIFEST")
    man = json.load(open(mpath))
    man["shards"][0]["commit"] += 1  # tamper without refreshing checksum
    json.dump(man, open(mpath, "w"))
    r = recover(d)  # MANIFEST rejected by checksum → MANIFEST.prev
    got = tree_contents(r.tree.state, r.tree.cfg)
    assert got == prefixes[-2]


def test_gc_skips_missing_files_without_raising(tmp_path, monkeypatch):
    d = str(tmp_path / "t")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=2)
    real_unlink, dropped = os.unlink, []

    def flaky_unlink(path):
        if "_segment_" in os.path.basename(path) and not dropped:
            dropped.append(path)
            raise FileNotFoundError(path)  # vanished under concurrent GC
        return real_unlink(path)

    monkeypatch.setattr("repro.core.durable.os.unlink", flaky_unlink)
    prefixes = _run_with_oracle(t, _mk_rounds(10, seed=11))
    assert dropped, "snapshot churn must have attempted a GC unlink"
    assert t.dstats.gc_skipped >= 1
    assert t.metrics.value("gc_skipped") == t.dstats.gc_skipped
    assert tree_contents(recover(d).tree.state, CFG) == prefixes[-1]


# ---------------------------------------------------------------------------
# Disabled-plan purity
# ---------------------------------------------------------------------------


def test_disabled_faultplan_changes_nothing(tmp_path):
    """An installed-but-empty FaultPlan is free: identical commit protocol
    (commit/fsync/byte counts), identical recovered contents, and — since
    the plan is host-side only — byte-identical lowered HLO."""
    import jax.numpy as jnp

    from repro.core import rounds as R

    rounds = _mk_rounds(5, seed=12)
    stats = {}
    for name, faults in (("off", None), ("on", FaultPlan(seed=0))):
        d = str(tmp_path / name)
        t = DurableABTree(d, CFG, mode="elim", snapshot_every=3, faults=faults)
        st0 = t.tree.state
        batch = (
            jnp.full((32,), OP_INSERT, jnp.int32),
            jnp.asarray(np.arange(32), jnp.int64),
            jnp.zeros((32,), jnp.int64),
        )
        hlo = R._phase_search_combine.lower(st0, batch, t.tree.cfg, False).as_text()
        _run_with_oracle(t, rounds)
        s = t.stats()
        stats[name] = (
            {k: s[k] for k in ("commits", "fsyncs", "flush_bytes", "nodes_flushed")},
            tree_contents(recover(d).tree.state, CFG),
            hlo,
        )
    assert stats["off"] == stats["on"]


# ---------------------------------------------------------------------------
# Property: truncation at ANY byte offset recovers a committed prefix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def seeded_journal(tmp_path_factory):
    """One committed journal + its oracle prefix states, built once; the
    property tests mutilate throwaway copies of it."""
    d = str(tmp_path_factory.mktemp("faults") / "journal")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100)
    prefixes = _run_with_oracle(t, _mk_rounds(6, bsz=24, seed=13))
    segs = sorted(f for f in os.listdir(d) if "_segment_" in f)
    assert len(segs) >= 5
    return d, prefixes, segs


def _recovered_is_witnessed_prefix(d, prefixes):
    from repro.obs.witness import check_history

    r = recover(d)
    got = tree_contents(r.tree.state, r.tree.cfg)
    assert got in prefixes, "recovery must land on a committed round prefix"
    rep = check_history(r.forensics_records(), collect_prefixes=True)
    if rep.prefix_states is not None and rep.rounds:
        assert got in rep.prefix_states, (
            "recovered contents must match a witnessed sidecar prefix"
        )
    return prefixes.index(got)


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(seg_idx=st.integers(0, 4), cut=st.integers(0, 2**16))
    def test_property_truncated_segment_recovers_committed_prefix(
        seeded_journal, tmp_path_factory, seg_idx, cut
    ):
        src, prefixes, segs = seeded_journal
        d = str(tmp_path_factory.mktemp("trunc") / "j")
        shutil.copytree(src, d)
        victim = os.path.join(d, segs[seg_idx])
        size = os.path.getsize(victim)
        with open(victim, "r+b") as f:
            f.truncate(cut % size)  # every offset, including 0
        n = _recovered_is_witnessed_prefix(d, prefixes)
        # the cut can never EXCEED the victim's commit: segments after the
        # first invalid record are unreachable by definition.
        assert n <= seg_idx + 1
        shutil.rmtree(d, ignore_errors=True)

    @settings(
        max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(seg_idx=st.integers(0, 4), pos=st.integers(0, 2**16),
           flip=st.integers(1, 255))
    def test_property_bitflip_detected_by_crc(
        seeded_journal, tmp_path_factory, seg_idx, pos, flip
    ):
        """ANY single corrupted byte in a referenced segment must be
        detected (per-file CRC32) and truncated away — never replayed."""
        src, prefixes, segs = seeded_journal
        d = str(tmp_path_factory.mktemp("flip") / "j")
        shutil.copytree(src, d)
        victim = os.path.join(d, segs[seg_idx])
        size = os.path.getsize(victim)
        with open(victim, "r+b") as f:
            f.seek(pos % size)
            b = f.read(1)
            f.seek(pos % size)
            f.write(bytes([b[0] ^ flip]))
        n = _recovered_is_witnessed_prefix(d, prefixes)
        assert n <= seg_idx + 1
        shutil.rmtree(d, ignore_errors=True)

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_truncated_segment_recovers_committed_prefix():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_bitflip_detected_by_crc():
        pass


# ---------------------------------------------------------------------------
# Manifest retention ring (K generations) + delta-chain corruption rules
# ---------------------------------------------------------------------------


def test_retention_ring_walks_to_third_generation(tmp_path):
    """The two-generation fallback is now a K-deep ring (default 3):
    corrupting the snapshot shared by the two NEWEST generations sinks
    both, and recovery lands on MANIFEST.prev2's prefix instead of
    raising."""
    from repro.core import CrashPoint  # noqa: F401  (matrix symmetry)

    d = str(tmp_path / "ring")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=3,
                      incremental_snapshots=False)
    prefixes = _run_with_oracle(t, _mk_rounds(7, seed=40))
    for name in ("MANIFEST", "MANIFEST.prev", "MANIFEST.prev2"):
        assert os.path.exists(os.path.join(d, name)), name
    # round i commits at index i (init snapshot = commit 0); the periodic
    # snapshot at commit 6 is referenced by generations @7 (S6 + seg7) and
    # @6 (S6) but NOT by @5 (S3 + segments 4-5 — kept alive by the
    # ring-aware GC).
    snaps = [f for f in os.listdir(d) if f.endswith("_snapshot_00000006.npz")]
    assert len(snaps) == 1
    p = os.path.join(d, snaps[0])
    blob = bytearray(open(p, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(p, "wb").write(bytes(blob))
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == prefixes[5]


def test_torn_delta_sinks_generation_falls_back(tmp_path):
    """A delta REPLACES the segment chain, so a torn delta cannot be
    truncated away like a segment — every generation referencing it must
    sink, and recovery falls to an older manifest rather than silently
    dropping the delta's rows."""
    d = str(tmp_path / "td")
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=2,
                      full_snapshot_every=100)
    prefixes = _run_with_oracle(t, _mk_rounds(6, seed=41))
    # deltas at commits 2/4/6; ladder on disk: MANIFEST@6 (D6), .prev@5
    # (D4 + seg5), .prev2@4 (D4).  Tear D6: were it truncated away like a
    # segment, MANIFEST@6 would "recover" the EMPTY prefix — sinking the
    # generation instead falls back to @5's intact chain.
    deltas = sorted(f for f in os.listdir(d) if "_delta_" in f)
    assert deltas
    p = os.path.join(d, deltas[-1])
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == prefixes[5]


# ---------------------------------------------------------------------------
# Group-commit crash matrix: a group is lost or kept ATOMICALLY
# ---------------------------------------------------------------------------


def test_crash_mid_group_recovers_last_group_boundary(tmp_path):
    """A fail-stop while rounds sit ABSORBED in a pending group (no
    boundary I/O yet) loses at most ``group_commit_every - 1`` rounds:
    recovery lands exactly on the last complete group boundary, witnessed
    by the forensics sidecar."""
    from repro.core import CrashPoint
    from repro.core.durable import SimulatedCrash

    d = str(tmp_path / "mg")
    crash = CrashPoint(step="mid_group", at_commit=3)
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9, crash=crash,
                      group_commit_every=3, group_commit_max_wait_s=1e9)
    o = DictOracle()
    prefixes = [o.items()]
    crashed = False
    for ops, keys, vals in _mk_rounds(9, seed=42):
        try:
            t.apply_round(ops, keys, vals)
            o.apply_round(ops, keys, vals)
            prefixes.append(o.items())
        except SimulatedCrash:
            crashed = True
            break
    assert crashed, "mid_group crash point did not fire"
    # groups committed at rounds 3 (commit 1) and 6 (commit 2); the crash
    # fired on the first round absorbed toward commit 3 → prefix 6.
    n = _recovered_is_witnessed_prefix(d, prefixes)
    assert n == 6


@pytest.mark.parametrize("step", ["mid_group", "after_segment",
                                  "mid_manifest", "before_dirsync"])
@pytest.mark.parametrize("at_commit", [2, 3])
def test_group_crash_matrix_cut_lands_on_group_boundary(tmp_path, step, at_commit):
    """Fail-stop at EVERY protocol step around a grouped commit: the
    recovered prefix always ends ON a group boundary (never inside one)
    and never exceeds the crashed commit's group."""
    from repro.core import CrashPoint
    from repro.core.durable import SimulatedCrash

    G = 3
    d = str(tmp_path / "matrix")
    crash = CrashPoint(step=step, at_commit=at_commit)
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9, crash=crash,
                      group_commit_every=G, group_commit_max_wait_s=1e9)
    o = DictOracle()
    prefixes = [o.items()]
    crashed = False
    for ops, keys, vals in _mk_rounds(9, bsz=16, seed=at_commit):
        try:
            t.apply_round(ops, keys, vals)
            o.apply_round(ops, keys, vals)
            prefixes.append(o.items())
        except SimulatedCrash:
            crashed = True
            # if the rename landed (before_dirsync) the crashed commit's
            # whole group IS durable — its prefix is a legal outcome too.
            if step == "before_dirsync":
                o2 = DictOracle()
                o2.d = dict(prefixes[-1])
                o2.apply_round(ops, keys, vals)
                prefixes.append(o2.items())
            break
    assert crashed, f"crash point {step}@{at_commit} did not fire"
    n = _recovered_is_witnessed_prefix(d, prefixes)
    assert n % G == 0, "cut must land ON a group boundary"
    # before the rename lands the crashed group must be invisible; after it
    # (before_dirsync) the whole group — never part of it — may be durable.
    bound = at_commit if step == "before_dirsync" else at_commit - 1
    assert n <= G * bound, "cut can never exceed the crashed group"


def test_enospc_at_group_boundary_retried_to_success(tmp_path):
    """A transient ENOSPC at a group-boundary segment write is retried;
    the WHOLE group lands once the disk clears — grouping never converts a
    transient fault into data loss."""
    d = str(tmp_path / "gnospc")
    plan = FaultPlan(seed=44).add(
        FaultSpec(site="segment_write", kind="enospc", commits=(2, 3), times=1)
    )
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9, faults=plan,
                      commit_backoff_s=0.0,
                      group_commit_every=3, group_commit_max_wait_s=1e9)
    prefixes = _run_with_oracle(t, _mk_rounds(6, seed=45))
    t.drain()
    s = t.durability_status()
    assert s["commit_retries"] >= 1 and not s["degraded"]
    assert t.metrics.value("fault_injected") == 1
    assert tree_contents(recover(d).tree.state, CFG) == prefixes[-1]


def test_torn_group_boundary_segment_loses_whole_group(tmp_path):
    """One journal segment carries a WHOLE group's dirty rows; tearing it
    costs exactly that group at recovery — the cut lands on the previous
    group boundary, never inside a group."""
    d = str(tmp_path / "tg")
    plan = FaultPlan(seed=46).add(
        FaultSpec(site="segment_write", kind="torn", commits=(3, 4),
                  torn_frac=0.5)
    )
    t = DurableABTree(d, CFG, mode="elim", snapshot_every=100, faults=plan,
                      group_commit_every=2, group_commit_max_wait_s=1e9)
    prefixes = _run_with_oracle(t, _mk_rounds(6, seed=47))
    t.drain()
    # boundaries at rounds 2/4/6 (commits 1/2/3); commit 3's segment —
    # carrying rounds 5 AND 6 — is torn, so the cut truncates to commit 2:
    # the whole last group is gone, the prefix before it is intact.
    r = recover(d)
    assert tree_contents(r.tree.state, r.tree.cfg) == prefixes[4]


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(n_rounds=st.integers(1, 10), G=st.integers(2, 4))
    def test_property_kill_at_any_group_offset_recovers_group_prefix(
        tmp_path_factory, n_rounds, G
    ):
        """ANY fail-stop between rounds — every offset within a commit
        group — recovers the oracle-verified prefix at the LAST group
        boundary: exactly ``n_rounds // G * G`` rounds, sidecar-witnessed."""
        d = str(tmp_path_factory.mktemp("gkill") / "j")
        t = DurableABTree(d, CFG, mode="elim", snapshot_every=10**9,
                          group_commit_every=G, group_commit_max_wait_s=1e9)
        prefixes = _run_with_oracle(t, _mk_rounds(n_rounds, bsz=16, seed=G))
        # abandon t without drain(): a kill at this round offset
        n = _recovered_is_witnessed_prefix(d, prefixes)
        assert n == (n_rounds // G) * G
        shutil.rmtree(d, ignore_errors=True)

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_kill_at_any_group_offset_recovers_group_prefix():
        pass
