"""Observability subsystem: tracer overhead contract (no fences, no HLO
delta when disabled), flight-recorder overhead contract (host-side only,
no HLO delta on/off), metrics-registry/legacy-counter equivalence (incl.
the durable layer's ``DurableStats`` and merge re-keying), Chrome
trace-event schema + report CLI, and the forest's hot-shard hook."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ABForest, ABTree, OP_FIND, OP_INSERT, TreeConfig
from repro.obs import MetricsRegistry, NULL_TRACER, Tracer

CFG = TreeConfig(capacity=2048, b=8, a=2, max_height=12)


def _insert_batch(rng, n=128, hi=10**6):
    keys = rng.choice(hi, size=n, replace=False).astype(np.int64)
    return np.full(n, OP_INSERT, np.int32), keys, keys * 2


# ---------------------------------------------------------------------------
# tracer overhead contract
# ---------------------------------------------------------------------------


def test_tracer_disabled_adds_no_fences_and_no_hlo(monkeypatch):
    """The whole disabled path is one attribute check: an untraced round
    must issue ZERO ``block_until_ready`` calls, and the jitted phases
    lower to byte-identical HLO before/after installing a live tracer
    (the tracer never enters jit)."""
    from repro.core import rounds as R

    t = ABTree(CFG)
    rng = np.random.default_rng(0)
    st0 = t.state
    batch = (
        jnp.full((64,), OP_INSERT, jnp.int32),
        jnp.asarray(rng.integers(0, 10**6, 64), jnp.int64),
        jnp.zeros((64,), jnp.int64),
    )
    hlo_before = R._phase_search_combine.lower(st0, batch, t.cfg, False).as_text()

    calls = []
    real = jax.block_until_ready

    def spy(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr("repro.obs.tracer.jax.block_until_ready", spy)

    assert t.tracer is NULL_TRACER  # no tracer installed → shared no-op
    t.apply_round(*_insert_batch(rng))
    t.scan_round([0], [10**6], cap=8)
    assert calls == [], "disabled tracer must never fence"

    t.tracer = Tracer()
    t.apply_round(*_insert_batch(rng))
    assert calls, "enabled tracer must fence the phases it times"
    assert t.tracer.events, "enabled tracer must record spans"

    hlo_after = R._phase_search_combine.lower(st0, batch, t.cfg, False).as_text()
    assert hlo_before == hlo_after, "tracing must not change lowered HLO"


def test_null_tracer_span_is_shared_noop():
    s1 = NULL_TRACER.span("a", shard=3, foo=1)
    s2 = NULL_TRACER.span("b")
    assert s1 is s2  # one shared object, no allocation per phase
    with s1 as sp:
        assert sp.fence(123) == 123
        sp.note(k=1)
    assert NULL_TRACER.events == []


# ---------------------------------------------------------------------------
# flight-recorder overhead contract
# ---------------------------------------------------------------------------


def test_recorder_host_side_only_and_no_hlo_delta(monkeypatch):
    """The recorder mirrors the tracer's overhead contract: it never
    fences (host-side capture of values the engine already materialised),
    disabling it turns every recording method into one attribute check,
    and the jitted phases lower to byte-identical HLO with recording on
    or off (the recorder never enters jit)."""
    from repro.core import rounds as R
    from repro.obs import NULL_RECORDER, Recorder

    t = ABTree(CFG)
    rng = np.random.default_rng(21)
    st0 = t.state
    batch = (
        jnp.full((64,), OP_INSERT, jnp.int32),
        jnp.asarray(rng.integers(0, 10**6, 64), jnp.int64),
        jnp.zeros((64,), jnp.int64),
    )
    hlo_on = R._phase_search_combine.lower(st0, batch, t.cfg, False).as_text()

    calls = []
    real = jax.block_until_ready
    monkeypatch.setattr(
        "repro.obs.tracer.jax.block_until_ready",
        lambda x: (calls.append(1), real(x))[1],
    )
    assert t.recorder.enabled, "holders construct an always-on recorder"
    t.apply_round(*_insert_batch(rng))
    t.scan_round([0], [10**6], cap=8)
    assert t.recorder.records(), "always-on recorder must capture rounds"
    assert calls == [], "the recorder must never fence"

    t.recorder = Recorder(enabled=False)
    t.apply_round(*_insert_batch(rng))
    t.scan_round([0], [10**6], cap=8)
    assert t.recorder.records() == []
    assert t.recorder.snapshot()["events"] == 0
    hlo_off = R._phase_search_combine.lower(st0, batch, t.cfg, False).as_text()
    assert hlo_on == hlo_off, "recording must not change lowered HLO"


def test_null_recorder_is_shared_noop():
    from repro.obs import NULL_RECORDER

    NULL_RECORDER.note_elim({"eliminated": [1]})
    NULL_RECORDER.note_occ(subrounds=3)
    NULL_RECORDER.note_scan_phase(retries=1, attempts=2)
    NULL_RECORDER.round(
        round_no=0, mode="elim", n_shards=1,
        ops=[1], keys=[2], vals=[3], results=[0], found=[False],
    )
    NULL_RECORDER.transition("split", shard=0)
    NULL_RECORDER.commit(0, 0)
    assert NULL_RECORDER.records() == []
    assert NULL_RECORDER.snapshot() == {
        "enabled": False,
        "capacity": NULL_RECORDER.capacity,
        "events": 0,
        "rounds": 0,
        "seq": 0,
    }


def test_recorder_ring_is_bounded():
    from repro.obs import Recorder

    r = Recorder(capacity=4)
    for i in range(10):
        r.transition("split", shard=i)
    recs = r.records()
    assert len(recs) == 4  # ring drops the oldest
    assert [x["shard"] for x in recs] == [6, 7, 8, 9]
    assert r.snapshot()["seq"] == 10


def test_recorder_in_serve_stats():
    """``ServeEngine.stats()`` exposes the recorder snapshot, and the
    setter installs one recorder across both index holders."""
    from repro.configs import get_config
    from repro.models import reduced
    from repro.obs import Recorder
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(
        reduced(get_config("qwen2-0.5b"), n_layers=1),
        max_batch=2,
        s_max=64,
        n_pages=64,
    )
    rec = Recorder()
    eng.recorder = rec
    assert eng.index.tree.recorder is rec
    assert eng.sessions.tree.recorder is rec
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
    eng.run_until_done(max_ticks=20)
    s = eng.stats()
    assert s["recorder"]["enabled"] is True
    assert s["recorder"]["rounds"] > 0  # lookup/publish rounds recorded


# ---------------------------------------------------------------------------
# metrics registry + legacy-counter equivalence
# ---------------------------------------------------------------------------


def test_metrics_registry_shard_attribution():
    m = MetricsRegistry()
    m.inc("x", 3, shard=0)
    m.inc("x", 2, shard=2)
    m.inc_shard("x", 5, 1)  # per-shard only: global stays 5
    assert m.value("x") == 5
    assert m.per_shard("x", 3) == [3, 5, 2]
    m.insert_shard(1)  # split at 1: cells ≥ 1 shift up
    assert m.per_shard("x", 4) == [3, 0, 5, 2]
    m.observe("h", 1.0)
    m.observe("h", 3.0)
    s = m.histogram_summary("h")
    assert s["count"] == 2 and s["min"] == 1.0 and s["max"] == 3.0
    snap = m.snapshot()
    assert snap["counters"]["x"] == 5
    assert snap["per_shard"]["x"] == {"0": 3, "2": 5, "3": 2}
    assert snap["histograms"]["h"]["count"] == 2


def test_legacy_counters_are_registry_backed():
    """``tree._rounds`` / ``_scans`` / ``_scan_retries`` and the registry
    are ONE store — reads agree after writes through either surface."""
    t = ABTree(CFG)
    rng = np.random.default_rng(1)
    t.apply_round(*_insert_batch(rng))
    t.apply_round(*_insert_batch(rng))
    t.scan_round([0], [10**6], cap=8)
    assert t._rounds == t.stats()["rounds"] == t.metrics.value("rounds") == 2
    assert t._scans == t.stats()["scans"] == t.metrics.value("scans") == 1
    assert t._scan_retries == t.metrics.value("scan_retries")
    t._rounds = 77  # legacy write lands in the registry
    assert t.metrics.value("rounds") == 77
    snap = t.metrics.snapshot()
    assert snap["engine"]["rounds"] == 77
    assert "retries_per_op" in snap["derived"]


def test_metrics_registry_remove_shard_rekeys_cells():
    """``remove_shard`` drops the retired shard's cells and shifts the
    cells above it down — attribution keeps following surviving shards."""
    m = MetricsRegistry()
    m.inc("x", 1, shard=0)
    m.inc("x", 2, shard=1)
    m.inc("x", 3, shard=2)
    m.remove_shard(1)
    assert m.per_shard("x", 2) == [1, 3]
    assert m.value("x") == 6  # the global total keeps the retired cell


def test_merge_cold_attributes_to_survivor_after_rekeying():
    """Regression: ``_merge_cold`` must re-key the registry BEFORE
    attributing the merge.  When the survivor is the retired shard's
    upper neighbor its post-restack index EQUALS the retired index, so
    incrementing first left the count on the cell ``remove_shard`` was
    about to pop — the survivor read 0 merges."""
    f = ABForest(n_shards=2, cfg=CFG, key_space=(0, 4096))
    keys = np.arange(0, 4096, 16, dtype=np.int64)
    f.apply_round(np.full(keys.size, OP_INSERT, np.int32), keys, keys)
    n_before = len(f.items())
    assert f._merge_cold(0)  # survivor t=1 restacks to index 0
    assert f.n_shards == 1
    assert len(f.items()) == n_before  # merge moved, never dropped, keys
    assert f.metrics.value("shard_merges", shard=0) == 1
    assert f.metrics.per_shard("shard_merges", 1) == [1]


def test_forest_per_shard_lanes_sum_to_global():
    f = ABForest(n_shards=4, cfg=CFG, key_space=(0, 4096))
    rng = np.random.default_rng(2)
    keys = rng.choice(4096, size=256, replace=False).astype(np.int64)
    f.apply_round(np.full(256, OP_INSERT, np.int32), keys, keys)
    total = f.metrics.value("point_lanes")
    assert total == 256
    assert sum(f.metrics.per_shard("point_lanes", 4)) == total


def test_durable_stats_match_registry(tmp_path):
    """The durable layer mirrors every ``DurableStats`` field into the
    backing holder's registry (ONE ``holder.metrics`` surface), and
    snapshot churn actually garbage-collects superseded journal files."""
    from repro.core.durable import DurableForest

    dur = DurableForest(str(tmp_path), 2, CFG, snapshot_every=2)
    rng = np.random.default_rng(3)
    for _ in range(5):
        dur.apply_round(*_insert_batch(rng, n=64))
    m = dur.metrics  # delegated to the backing forest's registry
    assert m is dur.forest.metrics
    for field in ("commits", "flush_bytes", "fsyncs", "nodes_flushed", "gc_removed"):
        assert m.value(field) == getattr(dur.dstats, field), field
    assert dur.dstats.gc_removed > 0
    h = m.histogram_summary("fsync_latency_s")
    assert h["count"] > 0 and h["p99"] >= h["p50"] > 0.0


# ---------------------------------------------------------------------------
# trace export schema + report CLI
# ---------------------------------------------------------------------------


def _traced_forest_trace(tmp_path):
    f = ABForest(n_shards=2, cfg=CFG, key_space=(0, 4096))
    f.tracer = Tracer()
    rng = np.random.default_rng(4)
    keys = rng.choice(4096, size=200, replace=False).astype(np.int64)
    f.apply_round(np.full(200, OP_INSERT, np.int32), keys, keys)
    f.scan_round([0, 2048], [2048, 4096], cap=16)
    path = str(tmp_path / "trace.json")
    f.tracer.export(path)
    return path


def test_trace_export_schema(tmp_path):
    from repro.obs.trace_export import load_trace, validate_trace

    path = _traced_forest_trace(tmp_path)
    doc = load_trace(path)
    assert validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    # every engine phase of the round pipeline shows up in one traced run
    for phase in ("round", "search_combine", "apply", "retry", "rebalance", "scan"):
        assert phase in names, phase
    # per-shard attribution rides instant events on tid >= 1
    assert any(
        e["ph"] == "i" and e["tid"] >= 1 for e in doc["traceEvents"]
    ), "expected per-shard instants"


def test_report_cli_roundtrip(tmp_path, capsys):
    from repro.obs import report

    path = _traced_forest_trace(tmp_path)
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "phase breakdown" in out and "search_combine" in out

    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [{"ph": "Q"}]}')
    assert report.main([str(bad)]) == 1


def test_validate_trace_rejects_malformed():
    from repro.obs.trace_export import validate_trace

    assert validate_trace({"nope": 1})
    assert validate_trace({"traceEvents": [{"name": "x", "ph": "X", "pid": 0}]})
    ok = {
        "traceEvents": [
            {"name": "x", "ph": "X", "ts": 0.0, "dur": 1.0, "pid": 0, "tid": 0}
        ]
    }
    assert validate_trace(ok) == []


# ---------------------------------------------------------------------------
# hot-shard hook
# ---------------------------------------------------------------------------


def test_hot_shard_hook_fires_under_skew():
    """A Zipf-skewed stream concentrating on one shard's key range must
    trip the hook with that shard's id once the observation window fills;
    a uniform stream across shards must not."""
    events = []
    f = ABForest(
        n_shards=2, cfg=CFG, key_space=(0, 4096),
        hot_shard_frac=0.9, hot_shard_window=128,
    )
    f.hot_shard_hook = lambda s, info: events.append((s, info))
    rng = np.random.default_rng(5)
    for _ in range(3):
        keys = rng.choice(2048, size=128, replace=False).astype(np.int64)
        f.apply_round(np.full(128, OP_INSERT, np.int32), keys, keys)  # all shard 0
    assert events, "skewed load must fire the hot-shard hook"
    s, info = events[0]
    assert s == 0
    assert info["frac"] >= 0.9
    assert info["bounds"][0] <= 0 < info["bounds"][1]
    assert f.metrics.value("hot_shard_events", shard=0) == len(events)

    events.clear()
    f2 = ABForest(
        n_shards=2, cfg=CFG, key_space=(0, 4096),
        hot_shard_frac=0.9, hot_shard_window=128,
    )
    f2.hot_shard_hook = lambda s, info: events.append((s, info))
    keys = rng.choice(4096, size=256, replace=False).astype(np.int64)  # uniform
    f2.apply_round(np.full(256, OP_INSERT, np.int32), keys, keys)
    assert not events, "balanced load must not fire the hook"


# ---------------------------------------------------------------------------
# ragged-router pack telemetry + repartition span
# ---------------------------------------------------------------------------


def test_pad_waste_drops_under_ragged_packing():
    """The router's pow2-bucketed per-shard widths must ship materially
    less padding than the full-batch-width packing they replaced: on a
    uniform round the observed ``pack_pad_waste`` sits well below the
    waste of padding every shard to the whole batch's pow2 width.  The
    ``router_pack_width`` / ``pad_waste_frac`` gauges expose the last
    pack's numbers."""
    from repro.core.rounds import _pow2

    f = ABForest(n_shards=4, cfg=CFG, key_space=(0, 4096))
    rng = np.random.default_rng(9)
    bsz = 64
    for _ in range(3):
        keys = rng.integers(0, 4096, bsz).astype(np.int64)
        f.apply_round(np.full(bsz, OP_INSERT, np.int32), keys, keys)
    h = f.metrics.histogram_summary("pack_pad_waste")
    assert h["count"] >= 3
    # full-width packing pads every shard to pow2(batch): 4·pow2(64) slots
    # for 64 real lanes.
    full_waste = (4 * _pow2(bsz) - bsz) / (4 * _pow2(bsz))
    assert h["p50"] < full_waste - 0.15, (h, full_waste)
    snap = f.metrics.snapshot()["gauges"]
    assert snap["router_pack_width"] >= bsz  # S·w slots actually shipped
    assert 0.0 <= snap["pad_waste_frac"] < full_waste


def test_report_surfaces_pack_stats_and_repartition_span(tmp_path, capsys):
    """``python -m repro.obs.report`` renders the router pack table (count,
    mean width, mean pad waste) and lists the ``repartition`` span in the
    phase breakdown once a load-aware rebalance has fired in the trace."""
    from repro.obs import report

    f = ABForest(
        n_shards=2, cfg=CFG, key_space=(0, 400),
        auto_repartition=True, hot_shard_window=64,
    )
    f.tracer = Tracer()
    rng = np.random.default_rng(13)
    seed = np.arange(0, 400, 2, dtype=np.int64)
    f.apply_round(np.full(seed.size, OP_INSERT, np.int32), seed, seed)
    for _ in range(4):  # 80/20 skew: trips the window into a rebalance
        keys = np.concatenate(
            [rng.integers(0, 100, 38), rng.integers(200, 400, 10)]
        ).astype(np.int64)
        f.apply_round(np.full(48, OP_FIND, np.int32), keys, np.zeros(48, np.int64))
        if int(f.metrics.snapshot()["counters"].get("repartitions", 0)):
            break
    assert int(f.metrics.snapshot()["counters"].get("repartitions", 0)) >= 1
    path = str(tmp_path / "trace_rep.json")
    f.tracer.export(path)
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "repartition" in out  # the span rides the phase breakdown
    assert "router pack stats" in out
    assert "mean_pad_waste" in out
    assert "(no router_pack spans)" not in out
