"""Integration test: the multi-pod dry-run machinery end-to-end for one
cell per step kind (subprocess: the 512-device XLA flag must be set before
jax init, and must NOT leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, mesh):
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
import json
rec = run_cell({arch!r}, {shape!r}, {mesh!r})
print("REC=" + json.dumps(rec))
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("REC=")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize(
    "arch,shape,mesh",
    [
        ("whisper-tiny", "train_4k", "single"),  # train step, enc-dec
        ("qwen2-0.5b", "decode_32k", "multi"),  # serve step, multi-pod
        ("xlstm-350m", "long_500k", "single"),  # ssm long-context decode
    ],
)
def test_dryrun_cell_compiles(arch, shape, mesh):
    rec = _run_cell(arch, shape, mesh)
    assert rec["status"] == "run"
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["argument_size_in_bytes"] > 0
    assert rec["n_devices"] == (512 if mesh == "multi" else 256)


def test_dryrun_skip_rule():
    rec = _run_cell("yi-9b", "long_500k", "single")
    assert rec["status"].startswith("skip")


def test_results_json_complete():
    """The committed sweep artifact must cover all 80 cells, all ok."""
    path = os.path.join(REPO, "results", "dryrun.json")
    if not os.path.exists(path):
        pytest.skip("sweep artifact not present")
    with open(path) as f:
        res = json.load(f)
    assert len(res) == 80
    assert all(v.get("ok") for v in res.values())
    n_skip = sum(1 for v in res.values() if v["status"] != "run")
    assert n_skip == 14  # 7 full-attention archs × long_500k × 2 meshes
